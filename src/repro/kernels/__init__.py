"""Pallas TPU kernels for the paper's compute hot-spots.

  fp4_matmul        quantize-once K-panel pipeline: per-operand quantize
                    pass + decoupled-tiling MXU matmul (the §3.2 FFN)
  rounding          shared bit-exact integer RTN / stochastic-rounding
                    codec + counter-hash noise (single source of truth)
  quantize          standalone per-tile quantizer
  flash_attention   causal online-softmax attention fwd (§3.1 protection)

Each kernel ships with ops.py (jit'd wrapper + interpret fallback on CPU)
and ref.py (pure-jnp oracle used by the allclose test sweeps).

``fp4_matmul.fused_qmm`` / ``ops.pallas_qmm`` form the role-parameterized
quantized-matmul family backing the training path's fwd, dgrad and wgrad
(``core.qlinear.pallas_qmatmul``), including in-kernel stochastic rounding
and the quantize-pass telemetry epilogue.
"""
from repro.kernels.ops import (flash_attention, fp4_matmul, pallas_qmm,
                               quantize_blockwise)

__all__ = ["flash_attention", "fp4_matmul", "pallas_qmm",
           "quantize_blockwise"]
