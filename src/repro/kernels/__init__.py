"""Pallas TPU kernels for the paper's compute hot-spots.

  fp4_matmul        fused per-block QDQ + tiled MXU matmul (the §3.2 FFN)
  quantize          standalone per-tile quantizer
  flash_attention   causal online-softmax attention fwd (§3.1 protection)

Each kernel ships with ops.py (jit'd wrapper + interpret fallback on CPU)
and ref.py (pure-jnp oracle used by the allclose test sweeps).

``fp4_matmul`` generalizes to ``fused_qmm`` / ``pallas_qmm``: the
role-parameterized fused quantize+matmul family backing the training path's
fwd, dgrad and wgrad (``core.qlinear.pallas_qmatmul``).
"""
from repro.kernels.ops import (flash_attention, fp4_matmul, pallas_qmm,
                               quantize_blockwise)

__all__ = ["flash_attention", "fp4_matmul", "pallas_qmm",
           "quantize_blockwise"]
