"""Shared rounding / scaling helpers for every quantization code path.

This is the single source of truth for kernel-side rounding: the fused
matmul pipeline (``kernels.fp4_matmul``), the standalone quantizer
(``kernels.quantize``) and the pure-jnp oracles (``kernels.ref``) all import
from here instead of carrying private ``_round_tile`` copies.

``round_to_grid`` is the *bit-exact integer* round-to-nearest: instead of the
``log2``/``ldexp`` transcendentals of ``formats.round_to_format`` (VPU-hostile
inside a Pallas kernel), it extracts the binade exponent straight from the
f32 bit pattern and assembles the per-binade grid step by writing the
exponent field back — every intermediate is an exact integer/power-of-two
operation, so the result lands on exactly the same grid as
``formats.round_to_format`` (tested on a dense sweep of exponent-boundary
values in ``tests/test_rounding.py``).  With ``noise`` it becomes the
unbiased stochastic-rounding codec (``floor(t + u)``, ``u ~ U[0,1)``),
matching the QDQ SR reference in distribution.

``hash_uniform`` is a counter-based (Philox-style, but cheaper) uniform
generator built purely from uint32 vector arithmetic: every element's noise
is a hash of its *global* (row, col) coordinate plus the seed, so stochastic
rounding results are independent of the kernel's tile sizes and grid order,
and the same code path runs under Pallas interpret mode (where
``pltpu.prng_seed``/``prng_random_bits`` have no CPU lowering) and on TPU.
The fused kernel uses the hardware PRNG on real TPUs and this hash in
interpret mode (see ``kernels.fp4_matmul``).

Dtype discipline: math runs in f32 internally (bit tricks need the IEEE
f32 layout) but both grids and steps are exact powers of two, so results
cast back to bf16 without error — callers keep the input-dtype QDQ
discipline of ``core.quantize.quantize_dequantize``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# The Eq.-3 scale formula (including its eps floor) is owned by
# core.quantize — shared bitwise by the QDQ reference, these kernels and
# the telemetry stats; re-exported here under the kernel-side names.
from repro.core.quantize import pow2_floor, scale_from_amax

__all__ = ["round_to_grid", "pow2_floor", "group_scale",
           "quantize_tile", "hash_bits", "hash_uniform",
           "uniform_from_bits", "fold_seed", "snap_to_dtype"]

_F32_MANT = 23
_F32_BIAS = 127


def snap_to_dtype(t: jnp.ndarray) -> jnp.ndarray:
    """Force a (possibly wider-carried) bf16 intermediate onto the bf16 grid.

    Inside a fused Pallas kernel XLA:CPU carries bf16 intermediates at f32
    precision; a value that the two-pass pipeline would round through a bf16
    HBM write can therefore reach a downstream consumer (the MXU dot, a
    rounding tie) with extra mantissa bits.  A bitcast round-trip forces
    materialization on the bf16 grid; outside kernels, and for every other
    dtype, it is an exact no-op.
    """
    if t.dtype == jnp.bfloat16:
        return jax.lax.bitcast_convert_type(
            jax.lax.bitcast_convert_type(t, jnp.uint16), jnp.bfloat16)
    return t


def round_to_grid(t: jnp.ndarray, fmt,
                  noise: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Round pre-scaled values onto ``fmt``'s grid — bit-exact integer RTN.

    Matches ``formats.round_to_format`` exactly (RTN half-to-even, clip to
    ``fmt.max_value``, fixed subnormal grid ``2^(emin - mbits)``) without
    transcendentals: the binade exponent comes from the f32 exponent field
    and the grid step is assembled by writing ``e - mbits`` back into an
    exponent field.  ``noise`` (uniform [0,1), same shape) switches to
    stochastic rounding ``floor(t/step + u) * step`` — the unbiased codec
    the QDQ reference implements via ``jax.random.uniform``.
    """
    orig_dtype = t.dtype
    # The pre-scaled quotient reaching us may be carried wider than bf16
    # inside a fused kernel — a plain upcast would leak that extra precision
    # and flip RTN ties vs the (properly rounded) QDQ reference; snap it
    # onto the bf16 grid first (see snap_to_dtype).
    t = snap_to_dtype(t)
    xf = t.astype(jnp.float32)
    sign = jnp.sign(xf)
    mag = jnp.minimum(jnp.abs(xf), np.float32(fmt.max_value))
    bits = jax.lax.bitcast_convert_type(mag, jnp.int32)
    # floor(log2(mag)) for normal f32 is the unbiased exponent field; f32
    # subnormals (and 0) give field 0 -> e = -127 -> clamped to emin, which
    # reproduces round_to_format's fixed subnormal grid including the
    # round-to-zero of anything far below it.
    e = jnp.maximum((bits >> _F32_MANT) - _F32_BIAS, fmt.emin)
    step = jax.lax.bitcast_convert_type(
        (e - fmt.mbits + _F32_BIAS) << _F32_MANT, jnp.float32)
    scaled = mag / step  # step is a power of two: division is exact
    if noise is None:
        q = jnp.round(scaled)  # round-half-to-even, IEEE default
    else:
        q = jnp.floor(scaled + noise.astype(jnp.float32))
    out = sign * q * step
    # Rounding the top binade up can exceed max_value -> saturate again.
    out = jnp.clip(out, -fmt.max_value, fmt.max_value)
    return out.astype(orig_dtype)


def group_scale(amax: jnp.ndarray, fmt, pow2: bool = False,
                qmax=None) -> jnp.ndarray:
    """Per-group scale ``alpha = amax / Q_max`` — alias of
    ``core.quantize.scale_from_amax`` (one formula, shared bitwise across
    the QDQ path, the fused pipeline and the telemetry stats).  In-kernel
    callers must pass ``qmax`` as a traced scalar (see scale_from_amax)."""
    return scale_from_amax(amax, fmt, pow2, qmax)


def quantize_tile(tile: jnp.ndarray, fmt, *, per_row: bool,
                  pow2: bool = False,
                  noise: Optional[jnp.ndarray] = None,
                  qmax: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """QDQ a VMEM tile: per-row (1 x cols) scales or one whole-tile scale.

    Input-dtype discipline (amax in the input dtype, scale math f32,
    divide/round/rescale in the input dtype) matches
    ``core.quantize.quantize_dequantize`` elementwise — in bf16 too.
    In-kernel callers pass ``qmax`` as a traced scalar so the scale division
    stays true IEEE division (see ``core.quantize.scale_from_amax``).
    """
    mag = jnp.abs(tile)
    amax = (jnp.max(mag, axis=-1, keepdims=True) if per_row
            else jnp.max(mag))
    s = group_scale(amax, fmt, pow2, qmax).astype(tile.dtype)
    return round_to_grid(tile / s, fmt, noise) * s


# ---------------------------------------------------------------------------
# Counter-based uniform noise (stochastic rounding, interpret-mode safe)
# ---------------------------------------------------------------------------

# numpy scalars (not jnp): Pallas kernels may not close over jax arrays.
_PHI = np.uint32(0x9E3779B9)   # golden-ratio increment (Weyl / xxhash)
_M1 = np.uint32(0x85EBCA6B)    # murmur3 finalizer constants
_M2 = np.uint32(0xC2B2AE35)


def _mix(h: jnp.ndarray) -> jnp.ndarray:
    h = h ^ (h >> 16)
    h = h * _M1
    h = h ^ (h >> 13)
    h = h * _M2
    h = h ^ (h >> 16)
    return h


def hash_bits(shape, seed: jnp.ndarray, row0, col0) -> jnp.ndarray:
    """uint32 hash bits keyed by (seed, global row, global col).

    ``row0``/``col0`` are the tile's global offsets (traced scalars are
    fine); pure uint32 vector ops, so this lowers inside Pallas on TPU and
    in interpret mode alike, and the stream is tiling-invariant.
    """
    r = jnp.asarray(row0).astype(jnp.uint32) + jax.lax.broadcasted_iota(
        jnp.uint32, shape, 0)
    c = jnp.asarray(col0).astype(jnp.uint32) + jax.lax.broadcasted_iota(
        jnp.uint32, shape, 1)
    h = jnp.asarray(seed).astype(jnp.uint32) * _PHI
    h = _mix(h ^ (r * _M1))
    h = _mix(h ^ (c * _M2))
    return h


def uniform_from_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """Map uint32 bits to f32 uniform [0, 1) using the top 24 bits."""
    return (bits >> np.uint32(8)).astype(jnp.float32) * np.float32(2 ** -24)


def hash_uniform(shape, seed: jnp.ndarray, row0, col0) -> jnp.ndarray:
    """f32 uniform [0,1) noise keyed by (seed, global element coordinate)."""
    return uniform_from_bits(hash_bits(shape, seed, row0, col0))


def fold_seed(key_data: jnp.ndarray, salt: int, which: int) -> jnp.ndarray:
    """Derive an int32 kernel PRNG seed from raw uint32[2] key material.

    Cheap integer folding with the same mixing constants as ``hash_bits``
    (one source of truth); distinct per (key, salt, operand index).
    """
    kd = key_data.astype(jnp.uint32)
    base = kd[0] ^ (kd[1] * _PHI)
    base = base ^ np.uint32(((salt * 2 + which) * int(_M1)) & 0xFFFFFFFF)
    return base.astype(jnp.int32).reshape(1)
