"""jit'd public wrappers for the Pallas kernels: padding, GQA handling,
custom_vjp glue, and interpret-mode fallback for CPU.

On CPU (this container) every entry point runs with ``interpret=True`` —
the kernel body executes in Python, validating the exact TPU code path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import routing
from repro.core.quantize import QuantSpec
from repro.kernels import fp4_matmul as _mm
from repro.kernels import quantize as _q
from repro.kernels import flash_attention as _fa
from repro.models.attention import chunked_attention
from repro.telemetry.profiler import graph_span

__all__ = ["fp4_matmul", "pallas_qmm", "quantize_blockwise",
           "flash_attention"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad2d(x, block):
    m, n = x.shape
    pm, pn = (-m) % block, (-n) % block
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x, m, n


def fp4_matmul(x: jnp.ndarray, w: jnp.ndarray, *,
               x_fmt: str = "fp4_e2m1", w_fmt: str = "fp4_e2m1",
               block: int = 128,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused block-quantized matmul; pads to tile multiples.

    NOTE on padding semantics: zero-padding K changes nothing (zeros add
    nothing and per-row amax over the padded segment is unchanged for the
    rows that exist); padding M/N rows/cols are sliced away.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    xp, m, k = _pad2d(x, block)
    wp, _, n = _pad2d(w, block)
    y = _mm.fp4_matmul(xp, wp, x_fmt=x_fmt, w_fmt=w_fmt, block=block,
                       interpret=interpret)
    return y[:m, :n]


def pallas_qmm(a: jnp.ndarray, b: jnp.ndarray,
               spec_a: QuantSpec, spec_b: QuantSpec, *,
               mode_a: str, mode_b: str,
               trans_a: bool = False, trans_b: bool = False,
               block: int = 128,
               key_data: Optional[jnp.ndarray] = None, salt: int = 0,
               pipeline: Optional[str] = None,
               bm: Optional[int] = None, bn: Optional[int] = None,
               bk: Optional[int] = None,
               collect_stats: bool = False,
               interpret: Optional[bool] = None,
               role: Optional[str] = None, cell=None):
    """Per-role quantized matmul ``Q(A') @ Q(B')`` through the fused
    pipeline (streaming single-pass by default, two-pass as reference —
    see ``kernels.fp4_matmul``), with padding.

    ``a``/``b`` are stored arrays; ``A' = a^T`` under ``trans_a`` (same for
    B') — the kernels read the stored layout via their index maps and
    quantize in effective orientation.  Quantization (``mode_*`` from
    ``core.qlinear.kernel_quant_mode``) is relative to the *effective*
    orientation, i.e. each backward matmul's own reduction axis; ``token``/
    ``tensor`` amax needs its whole-axis sweep and automatically routes
    through the two-pass pipeline.  Stochastic specs draw in-kernel noise
    seeded from ``key_data``+``salt``.  ``pipeline``/``bm``/``bn``/``bk``
    pass straight through to ``fused_qmm`` (None = default pipeline +
    autotuned-or-heuristic tiles).
    Padding semantics: zero K-padding adds nothing to the dot and leaves
    real rows' amax groups unchanged; padded M/N rows/cols quantize on the
    eps-floor scale path and are sliced away.  With ``collect_stats``
    returns ``(y, (stats_a, stats_b))`` raw telemetry-epilogue vectors
    (``kernels.fp4_matmul.finalize_quant_stats`` reduces them).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    ap, _, _ = _pad2d(a, block)
    bp, _, _ = _pad2d(b, block)
    m = a.shape[1] if trans_a else a.shape[0]
    k = a.shape[0] if trans_a else a.shape[1]
    n = b.shape[0] if trans_b else b.shape[1]
    a_sr = bool(spec_a.stochastic) and mode_a != "pass"
    b_sr = bool(spec_b.stochastic) and mode_b != "pass"
    if routing.active() is not None:
        routing.record(
            role or "?", "pallas", spec_a.to_str(), spec_b.to_str(),
            mode_a=mode_a, mode_b=mode_b,
            pipeline=_mm.resolve_pipeline(pipeline, mode_a, mode_b),
            sr_a=a_sr and key_data is not None,
            sr_b=b_sr and key_data is not None, cell=cell)
    seed_a = seed_b = None
    if a_sr or b_sr:
        assert key_data is not None, "stochastic spec needs key_data"
        from repro.kernels.rounding import fold_seed
        seed_a = fold_seed(key_data, salt, 0) if a_sr else None
        seed_b = fold_seed(key_data, salt, 1) if b_sr else None
    with graph_span("quantize"):   # fused quantize+matmul: one phase scope
        out = _mm.fused_qmm(
            ap, bp, a_mode=mode_a, b_mode=mode_b,
            a_fmt=spec_a.fmt, b_fmt=spec_b.fmt,
            a_pow2=spec_a.pow2_scale, b_pow2=spec_b.pow2_scale,
            a_sr=a_sr, b_sr=b_sr, seed_a=seed_a, seed_b=seed_b,
            trans_a=trans_a, trans_b=trans_b, block=block,
            bm=bm, bn=bn, bk=bk, pipeline=pipeline,
            real_dims=(m, k, n), collect_stats=collect_stats,
            interpret=interpret)
    if collect_stats:
        y, stats = out
        return y[:m, :n], stats
    return out[:m, :n]


def quantize_blockwise(x: jnp.ndarray, fmt_name: str = "fp4_e2m1",
                       block: int = 128, *, per_row: bool = False,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    interpret = (not _on_tpu()) if interpret is None else interpret
    xp, m, n = _pad2d(x, block)
    y = _q.quantize_blockwise(xp, fmt_name, block, per_row=per_row,
                              interpret=interpret)
    return y[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, causal, chunk, interpret):
    """(B, S, H, D) attention; Pallas fwd, chunked-jnp bwd."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    bq = min(128, sq)
    bk = min(128, kf.shape[1])
    o = _fa.flash_attention_fwd(qf, kf, vf, causal=causal, bq=bq, bk=bk,
                                interpret=interpret)
    return o.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


def _flash_fwd(q, k, v, causal, chunk, interpret):
    return _flash(q, k, v, causal, chunk, interpret), (q, k, v)


def _flash_bwd(causal, chunk, interpret, res, g):
    q, k, v = res

    def ref_fn(q, k, v):
        sq = q.shape[1]
        pos = jnp.arange(sq, dtype=jnp.int32)
        kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
        return chunked_attention(q, k, v, pos, kpos, causal=causal,
                                 chunk=chunk)

    _, vjp = jax.vjp(ref_fn, q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, chunk: int = 1024,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Differentiable flash attention: Pallas forward (TPU target),
    chunked-jnp backward.  q/k/v: (B, S, H|KVH, D)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _flash(q, k, v, causal, chunk, interpret)
