"""Pallas TPU kernel: standalone per-tile FP4/FP8 quantize-dequantize.

Used where quantization is NOT fused into a matmul (e.g. producing FP8
gradients for the compressed all-reduce, or materializing FP4 weights for
serving).  One grid step = one (block x block) VMEM tile; amax reduction,
scale, RTN rounding and rescale all happen on the tile in registers/VMEM —
HBM traffic is exactly read-once/write-once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import FORMATS
# Rounding/QDQ math comes from the shared kernel-side helper module (the
# same bit-exact integer RTN the fused pipeline uses) — no private copy.
from repro.kernels.rounding import quantize_tile

__all__ = ["quantize_blockwise"]


def _q_kernel(qmax_ref, x_ref, o_ref, *, fmt, per_row):
    o_ref[...] = quantize_tile(
        x_ref[...].astype(jnp.float32), fmt,
        per_row=per_row, qmax=qmax_ref[0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fmt_name", "block", "per_row",
                                             "interpret"))
def quantize_blockwise(x: jnp.ndarray, fmt_name: str = "fp4_e2m1",
                       block: int = 128, *, per_row: bool = False,
                       interpret: bool = False) -> jnp.ndarray:
    """Tilewise QDQ of a 2-D array.  Shapes must be block multiples
    (ops.py pads).  per_row=True gives (1 x block) granularity."""
    m, n = x.shape
    assert m % block == 0 and n % block == 0, (m, n, block)
    fmt = FORMATS[fmt_name]
    kernel = functools.partial(_q_kernel, fmt=fmt, per_row=per_row)
    from jax.experimental.pallas import tpu as pltpu
    # Q_max as a traced SMEM scalar so the in-kernel scale division is true
    # IEEE division (constant divisors get reciprocal-multiplied by XLA).
    qmax = jax.lax.optimization_barrier(
        jnp.full((1,), fmt.max_value, jnp.float32))
    return pl.pallas_call(
        kernel,
        grid=(m // block, n // block),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec((block, block), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(qmax, x)
