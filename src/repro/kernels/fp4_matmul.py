"""Pallas TPU kernels: fused per-group FP4/FP8 quantize + tiled MXU matmul.

The paper's §3.2 hotspot: an FFN linear whose activations are quantized
per-(1 x 128) along the reduction dim and whose weights are quantized
per-(128 x 128) tiles, with the dot running on the low-precision unit.  On
TPU the natural mapping is:

  * grid (M/bm, N/bn, K/bk) with K innermost (revisiting the same output
    block accumulates in a VMEM f32 scratch — no HBM roundtrips);
  * every tile 128-aligned so dequantized operands feed the 128x128 MXU
    directly; the per-tile scales are rank-1 rescales computed IN-KERNEL
    from the VMEM-resident tile (fused: quantize + dequantize + matmul in
    one pass, the HBM traffic is exactly one read of x and w per K-step);
  * FP4 arithmetic itself is simulated (QDQ then bf16/f32 dot) as in the
    paper; on FP4-capable hardware only the dot changes.

``block`` here equals the quantization block size AND the tile size (128).

``fused_qmm`` is the role-parameterized generalization that backs all three
training matmuls (fwd / dgrad / wgrad — see ``core.qlinear.pallas_qmatmul``):
each operand gets an independent quantization *mode*

  * ``pass``   — no quantization (bf16 passthrough roles, e.g. the paper's
                 unquantized FFN dgrad);
  * ``block``  — per-(1 x 128) groups along the reduction axis, scale
                 computed in-kernel from the VMEM tile (LHS rows / RHS cols);
  * ``tile``   — one scale per (128 x 128) tile, in-kernel;
  * ``scaled`` — scale precomputed outside the kernel and streamed in as a
                 rank-1 operand (per-token / per-tensor granularities whose
                 amax group spans the whole reduction axis, so a single
                 K-step tile cannot compute it);

plus ``trans_a`` / ``trans_b`` operand transposition handled via the
BlockSpec index maps, so dgrad ``g @ w^T`` and wgrad ``x^T @ g`` read the
stored arrays directly (no HBM transpose) while quantizing relative to their
own reduction axes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import FORMATS

__all__ = ["fp4_matmul", "fused_qmm", "quantize_tile", "compiler_params"]

_EPS = 1e-12

# jax renamed TPUCompilerParams -> CompilerParams across versions; the repo
# must run on both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def compiler_params(**kw):
    """Version-portable ``pltpu.CompilerParams`` constructor."""
    return _CompilerParams(**kw)


def _round_tile(t: jnp.ndarray, fmt) -> jnp.ndarray:
    """RTN onto the fmt grid (kernel-side copy of formats.round_to_format,
    written with primitive jnp ops only so it lowers inside Pallas)."""
    sign = jnp.sign(t)
    mag = jnp.minimum(jnp.abs(t), fmt.max_value)
    safe = jnp.maximum(mag, fmt.min_subnormal * 0.25)
    e = jnp.maximum(jnp.floor(jnp.log2(safe)), float(fmt.emin))
    step = jnp.ldexp(jnp.ones_like(t), (e - fmt.mbits).astype(jnp.int32))
    q = jnp.round(mag / step)
    return jnp.clip(sign * q * step, -fmt.max_value, fmt.max_value)


def quantize_tile(tile: jnp.ndarray, fmt, *, per_row: bool) -> jnp.ndarray:
    """QDQ a VMEM tile: per-row (1 x bk) scales or whole-tile scale."""
    mag = jnp.abs(tile)
    amax = (jnp.max(mag, axis=-1, keepdims=True) if per_row
            else jnp.max(mag))
    scale = jnp.maximum(amax, _EPS) / fmt.max_value
    return _round_tile(tile / scale, fmt) * scale


def _quant_operand(t: jnp.ndarray, fmt, mode: str, red_axis: int,
                   scale: Optional[jnp.ndarray], pow2: bool) -> jnp.ndarray:
    """QDQ one effective-orientation operand tile inside the kernel.

    ``red_axis`` is the reduction axis of the tile (1 for LHS, 0 for RHS);
    ``block`` groups reduce over it, ``tile`` over the whole tile, ``scaled``
    uses the streamed-in rank-1 scale.

    Dtype discipline mirrors ``core.quantize.quantize_dequantize`` exactly
    (amax in the input dtype, scale math in f32, divide/round/rescale in
    the input dtype) so 'qdq' and 'pallas' impls agree elementwise on the
    quantized operands — in bf16 training too, not just f32 tests.
    """
    if mode == "pass":
        return t
    if mode == "scaled":
        s = scale.astype(t.dtype)
    else:
        mag = jnp.abs(t)
        amax = (jnp.max(mag, axis=red_axis, keepdims=True)
                if mode == "block" else jnp.max(mag))
        s = jnp.maximum(amax.astype(jnp.float32), _EPS) / fmt.max_value
        if pow2:
            s = jnp.exp2(jnp.floor(jnp.log2(s)))
        s = s.astype(t.dtype)
    return _round_tile(t / s, fmt) * s


def _qmm_kernel(*refs, n_k, a_mode, b_mode, a_fmt, b_fmt, a_pow2, b_pow2,
                trans_a, trans_b):
    """One (bm, bn) output tile step at K-step pl.program_id(2)."""
    it = iter(refs)
    a_ref, b_ref = next(it), next(it)
    as_ref = next(it) if a_mode == "scaled" else None
    bs_ref = next(it) if b_mode == "scaled" else None
    o_ref, acc_ref = next(it), next(it)

    @pl.when(pl.program_id(2) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Quantize in the INPUT dtype (bf16 stays bf16, matching the unfused
    # qdq path elementwise); only the MXU dot upcasts, via its f32
    # accumulator.
    at = a_ref[...]
    if trans_a:
        at = at.T
    bt = b_ref[...]
    if trans_b:
        bt = bt.T
    aq = _quant_operand(at, a_fmt, a_mode, 1,
                        as_ref[...] if as_ref is not None else None, a_pow2)
    bq = _quant_operand(bt, b_fmt, b_mode, 0,
                        bs_ref[...] if bs_ref is not None else None, b_pow2)
    acc_ref[...] += jnp.dot(aq, bq, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "a_mode", "b_mode", "a_fmt", "b_fmt", "a_pow2", "b_pow2",
    "trans_a", "trans_b", "block", "interpret"))
def fused_qmm(a: jnp.ndarray, b: jnp.ndarray, *,
              a_mode: str = "block", b_mode: str = "tile",
              a_fmt: str = "fp4_e2m1", b_fmt: str = "fp4_e2m1",
              a_scale: Optional[jnp.ndarray] = None,
              b_scale: Optional[jnp.ndarray] = None,
              a_pow2: bool = False, b_pow2: bool = False,
              trans_a: bool = False, trans_b: bool = False,
              block: int = 128, interpret: bool = False) -> jnp.ndarray:
    """y = Q(A') @ Q(B') fused in VMEM, A' = a^T if trans_a else a (same for
    B').  Effective shapes A': (M, K), B': (K, N); all dims must be multiples
    of ``block`` (the ops.py wrapper pads).  Returns A'.dtype (M, N).

    ``a_scale`` (M, 1) / ``b_scale`` (1, N) are required exactly when the
    matching mode is ``scaled`` (f32, already divided by the format's Q_max).
    """
    m, k = (a.shape[1], a.shape[0]) if trans_a else a.shape
    kb, n = (b.shape[1], b.shape[0]) if trans_b else b.shape
    assert k == kb, (a.shape, b.shape, trans_a, trans_b)
    assert m % block == 0 and k % block == 0 and n % block == 0, \
        (m, k, n, block)
    assert (a_scale is not None) == (a_mode == "scaled")
    assert (b_scale is not None) == (b_mode == "scaled")
    n_k = k // block
    fa, fb = FORMATS[a_fmt], FORMATS[b_fmt]

    in_specs = [
        pl.BlockSpec((block, block),
                     (lambda i, j, kk: (kk, i)) if trans_a
                     else (lambda i, j, kk: (i, kk))),
        pl.BlockSpec((block, block),
                     (lambda i, j, kk: (j, kk)) if trans_b
                     else (lambda i, j, kk: (kk, j))),
    ]
    operands = [a, b]
    if a_scale is not None:
        assert a_scale.shape == (m, 1), a_scale.shape
        in_specs.append(pl.BlockSpec((block, 1), lambda i, j, kk: (i, 0)))
        operands.append(a_scale.astype(jnp.float32))
    if b_scale is not None:
        assert b_scale.shape == (1, n), b_scale.shape
        in_specs.append(pl.BlockSpec((1, block), lambda i, j, kk: (0, j)))
        operands.append(b_scale.astype(jnp.float32))

    kernel = functools.partial(
        _qmm_kernel, n_k=n_k, a_mode=a_mode, b_mode=b_mode, a_fmt=fa,
        b_fmt=fb, a_pow2=a_pow2, b_pow2=b_pow2, trans_a=trans_a,
        trans_b=trans_b)
    return pl.pallas_call(
        kernel,
        grid=(m // block, n // block, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block, block), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((block, block), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit, static_argnames=("x_fmt", "w_fmt", "block",
                                             "interpret"))
def fp4_matmul(x: jnp.ndarray, w: jnp.ndarray, *,
               x_fmt: str = "fp4_e2m1", w_fmt: str = "fp4_e2m1",
               block: int = 128, interpret: bool = False) -> jnp.ndarray:
    """y = Q_blk(x) @ Q_tile(w), fused in VMEM (the paper's fwd FFN matmul).

    x: (M, K), w: (K, N); M, K, N must be multiples of ``block``
    (the ops.py wrapper pads).  Returns x.dtype.  Kept as the historical
    fwd-only entry point; a thin specialization of ``fused_qmm``.
    """
    return fused_qmm(x, w, a_mode="block", b_mode="tile", a_fmt=x_fmt,
                     b_fmt=w_fmt, block=block, interpret=interpret)
