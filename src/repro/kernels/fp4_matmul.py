"""Pallas TPU kernel: fused per-block FP4/FP8 quantize + tiled MXU matmul.

The paper's §3.2 hotspot: an FFN linear whose activations are quantized
per-(1 x 128) along the reduction dim and whose weights are quantized
per-(128 x 128) tiles, with the dot running on the low-precision unit.  On
TPU the natural mapping is:

  * grid (M/bm, N/bn, K/bk) with K innermost (revisiting the same output
    block accumulates in a VMEM f32 scratch — no HBM roundtrips);
  * every tile 128-aligned so dequantized operands feed the 128x128 MXU
    directly; the per-tile scales are rank-1 rescales computed IN-KERNEL
    from the VMEM-resident tile (fused: quantize + dequantize + matmul in
    one pass, the HBM traffic is exactly one read of x and w per K-step);
  * FP4 arithmetic itself is simulated (QDQ then bf16/f32 dot) as in the
    paper; on FP4-capable hardware only the dot changes.

``block`` here equals the quantization block size AND the tile size (128).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import FORMATS

__all__ = ["fp4_matmul", "quantize_tile"]

_EPS = 1e-12


def _round_tile(t: jnp.ndarray, fmt) -> jnp.ndarray:
    """RTN onto the fmt grid (kernel-side copy of formats.round_to_format,
    written with primitive jnp ops only so it lowers inside Pallas)."""
    sign = jnp.sign(t)
    mag = jnp.minimum(jnp.abs(t), fmt.max_value)
    safe = jnp.maximum(mag, fmt.min_subnormal * 0.25)
    e = jnp.maximum(jnp.floor(jnp.log2(safe)), float(fmt.emin))
    step = jnp.ldexp(jnp.ones_like(t), (e - fmt.mbits).astype(jnp.int32))
    q = jnp.round(mag / step)
    return jnp.clip(sign * q * step, -fmt.max_value, fmt.max_value)


def quantize_tile(tile: jnp.ndarray, fmt, *, per_row: bool) -> jnp.ndarray:
    """QDQ a VMEM tile: per-row (1 x bk) scales or whole-tile scale."""
    mag = jnp.abs(tile)
    amax = (jnp.max(mag, axis=-1, keepdims=True) if per_row
            else jnp.max(mag))
    scale = jnp.maximum(amax, _EPS) / fmt.max_value
    return _round_tile(tile / scale, fmt) * scale


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, x_fmt, w_fmt, n_k):
    """One (bm, bn) output tile step at K-step pl.program_id(2)."""
    @pl.when(pl.program_id(2) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xq = quantize_tile(x_ref[...].astype(jnp.float32), x_fmt, per_row=True)
    wq = quantize_tile(w_ref[...].astype(jnp.float32), w_fmt, per_row=False)
    acc_ref[...] += jnp.dot(xq, wq, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("x_fmt", "w_fmt", "block",
                                             "interpret"))
def fp4_matmul(x: jnp.ndarray, w: jnp.ndarray, *,
               x_fmt: str = "fp4_e2m1", w_fmt: str = "fp4_e2m1",
               block: int = 128, interpret: bool = False) -> jnp.ndarray:
    """y = Q_blk(x) @ Q_tile(w), fused in VMEM.

    x: (M, K), w: (K, N); M, K, N must be multiples of ``block``
    (the ops.py wrapper pads).  Returns x.dtype.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and m % block == 0 and k % block == 0 and n % block == 0
    n_k = k // block
    fx, fw = FORMATS[x_fmt], FORMATS[w_fmt]
    kernel = functools.partial(_mm_kernel, x_fmt=fx, w_fmt=fw, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(m // block, n // block, n_k),
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block, block), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block, block), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
