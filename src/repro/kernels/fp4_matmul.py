"""Pallas TPU kernels: quantize-once K-panel pipeline for FP4/FP8 matmuls.

The paper's §3.2 hotspot — a linear whose operands are quantized per-group
and whose dot runs on the low-precision unit — used to be ONE fused kernel
that re-quantized every LHS K-tile ``N/bn`` times and every RHS K-tile
``M/bm`` times (once per output-tile visit), with RTN emulated through
``log2``/``ldexp`` transcendentals on the VPU.  Following the
quantize-once operand caching of "FP4 All the Way" (Chmiel et al., 2025)
and "Quartet" (Castro et al., 2025), the pipeline is now two phases:

**Phase 1 — quantize pass** (``quantize_panels`` / ``_quantize_operand``).
One grid sweep over each operand's K-panels QDQs every element exactly
once, in the *effective* (post-transpose) orientation, and writes the
on-grid values back in MXU-ready layout.  In this QDQ simulation the
emitted values are the dequantized grid points (bf16/f32 — what the MXU
consumes); on FP4-native hardware the same layout holds the 4-bit codes
plus per-group scales.  All four paper granularities run in-kernel:

  * ``block`` — per-(1 x 128) groups along the reduction axis;
  * ``tile``  — per-(128 x 128) tiles;
  * ``token`` / ``tensor`` — amax groups spanning the whole reduction axis,
    computed by a two-sweep grid (sweep 0 accumulates amax in scratch,
    sweep 1 quantizes) — this subsumes the old external ``_rank1_scale``
    XLA reduction, so "scaled" modes no longer exist.

Rounding is the **bit-exact integer RTN** of ``kernels.rounding`` (exponent
extracted from the f32 bit pattern, grid step assembled by writing the
exponent field — no transcendentals), verified bit-exact against
``formats.round_to_format``.  ``sr=True`` switches to in-kernel unbiased
stochastic rounding: on TPU via ``pltpu.prng_seed`` +
``pltpu.prng_random_bits``, in interpret mode (no CPU lowering for the TPU
PRNG) via the tiling-invariant counter hash ``rounding.hash_uniform`` —
noise is keyed by each element's *global* coordinate, so results do not
depend on panel sizes.  ``collect_stats=True`` adds a telemetry epilogue:
clip/underflow/rel-err/scale-spread accumulators ride in VMEM scratch and
are emitted as one (1, 8) vector, replacing the full re-QDQ that
``telemetry.tap_matmul`` used to pay (see ``finalize_quant_stats``).

**Phase 2 — matmul pass** (``_tiled_matmul``).  A plain tiled MXU matmul
over the quantize-pass outputs with grid tiling ``(bm, bn, bk)`` fully
**decoupled** from the 128-element quant group — multiple quant groups per
MXU tile, fewer grid steps, zero re-quantization.  K stays innermost and
accumulates into an f32 VMEM scratch; ``pass``-mode (unquantized bf16)
operands skip phase 1 entirely and are read transposed via BlockSpec index
maps, exactly as before.

**Single-pass streaming pipeline** (``_stream_kernel``, the default since
the overlap round).  The two-pass split still paid a full HBM round-trip
of the dequantized K-panels between the phases.  The streaming pipeline is
ONE ``pallas_call`` whose grid walks ``(M/bm, N/bn)`` output tiles with K
innermost: each K-step's operand tiles are fetched by the grid pipeline
(double-buffered HBM->VMEM DMA, Pallas' standard prefetch), quantized
in-registers/VMEM, and consumed directly by the MXU accumulation — the
quantize work rides inside the GEMM's dataflow (the quantize-fused-GEMM
unit of cost of Quartet and "Optimizing LLM Training Using FP4
Quantization") and the dequantized panels never touch HBM.  The LHS row
panel is additionally cached in a VMEM scratch across the ``N/bn``
output-column revisits (quantized exactly once, weight-stationary style)
and the quantized RHS across the ``M/bm`` output-row revisits, each under
its own VMEM budget; past the budgets, tiles re-quantize per revisit —
recompute that overlaps the MXU on hardware.
Because the codec is the bit-exact integer RTN of ``kernels.rounding`` and
SR noise is keyed by each element's *global* coordinate, re-quantizing a
tile reproduces the quantize pass bit-for-bit: for the same ``(bm, bn,
bk)`` the streaming output ``y`` (and the telemetry epilogue's counter /
extrema lanes) is **bit-identical** to the two-pass pipeline, which stays
selectable as the reference implementation (``pipeline='two_pass'`` /
``use_pipeline``).  ``token``/``tensor`` granularities need their
whole-reduction-axis amax sweep before any element can quantize, so those
roles route through the two-pass pipeline automatically.

``fused_qmm`` orchestrates the pipelines and keeps its role-parameterized
contract: per-operand modes ``pass | block | tile | token | tensor``,
``trans_a``/``trans_b`` stored-layout transposition, per-operand formats
and pow2-scale flags, plus per-operand ``sr`` flags and seeds.  Tile
knobs: ``block`` (quant group, 128), ``bm``/``bn``/``bk`` (MXU tiling —
when all three are omitted the persistent autotuning table
(``kernels.autotune``, committed ``tuning_table.json``, populated by
``kernel_bench --autotune``) is consulted first, falling back to the
``_pick_tile`` heuristic on a miss).
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import FORMATS
from repro.kernels.rounding import (group_scale, hash_uniform, round_to_grid,
                                    snap_to_dtype, uniform_from_bits)

__all__ = ["fp4_matmul", "fused_qmm", "quantize_panels", "compiler_params",
           "finalize_quant_stats", "QUANT_MODES", "STATS_WIDTH",
           "PIPELINES", "default_pipeline", "use_pipeline",
           "stream_supported", "resolve_pipeline"]

QUANT_MODES = ("pass", "block", "tile", "token", "tensor")

# Matmul pipelines: "stream" = single-pass quantize->MXU fusion (default),
# "two_pass" = the PR-3 quantize-pass + matmul-pass reference.  token/tensor
# granularities always take two_pass (see stream_supported).
PIPELINES = ("stream", "two_pass")

# LHS row-panel VMEM cache budget for the streaming kernel: the quantized
# (bm, K) panel is kept in scratch across N/bn output-column revisits when it
# fits, so the LHS quantizes exactly once.  Tests monkeypatch this to force
# the requantize-per-revisit branch.
_AQ_CACHE_BYTES = 4 * 1024 * 1024

# RHS VMEM cache budget: the full quantized (K, N) operand is kept in scratch
# across M/bm output-row revisits when it fits, so the RHS also quantizes
# exactly once.  Safe to cache bitwise: the SR noise is keyed by the tile's
# (j, kk) coordinates only, so an i-revisit would reproduce identical bits.
_BQ_CACHE_BYTES = 4 * 1024 * 1024

# Telemetry-epilogue accumulator lanes (f32, shape (1, STATS_WIDTH)):
#   0 clip count   1 underflow count   2 nonzero count   3 sum err^2
#   4 sum x^2      5 min group scale   6 max group scale 7 valid-element count
STATS_WIDTH = 8
_STATS_BIG = 3.0e38

# jax renamed TPUCompilerParams -> CompilerParams across versions; the repo
# must run on both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def compiler_params(**kw):
    """Version-portable ``pltpu.CompilerParams`` constructor."""
    return _CompilerParams(**kw)


def _pick_tile(dim: int, block: int = 128) -> int:
    """Largest friendly tile (multiple of ``block``) dividing ``dim``."""
    for c in (4 * block, 3 * block, 2 * block, block):
        if dim % c == 0:
            return c
    raise ValueError(f"dim {dim} not a multiple of block {block}")


# Stack-shaped so nested `use_pipeline` contexts unwind correctly; the
# resolution happens OUTSIDE the jit boundary (`fused_qmm` is a plain python
# wrapper), so flipping the pipeline can never serve a stale jit cache.
_pipeline_stack = ["stream"]


def default_pipeline() -> str:
    """The pipeline `fused_qmm` uses when none is passed explicitly."""
    return _pipeline_stack[-1]


@contextlib.contextmanager
def use_pipeline(name: str):
    """Temporarily override the default matmul pipeline (re-entrant)."""
    assert name in PIPELINES, name
    _pipeline_stack.append(name)
    try:
        yield
    finally:
        _pipeline_stack.pop()


def stream_supported(a_mode: str, b_mode: str) -> bool:
    """Whether the streaming pipeline can run this granularity pair.

    ``token``/``tensor`` scale groups span the whole reduction axis — their
    amax sweep must complete before the first element can quantize, which is
    exactly the dependency the streaming pipeline removes — so those roles
    fall back to the two-pass pipeline.
    """
    streamable = ("pass", "block", "tile")
    return a_mode in streamable and b_mode in streamable


def resolve_pipeline(pipeline: Optional[str], a_mode: str,
                     b_mode: str) -> str:
    """The pipeline ``fused_qmm`` will actually run for this call: the
    explicit choice (or the process default), demoted to ``two_pass`` when
    the granularity pair is not streamable.  Exposed so observability
    layers (routing census / qlint) report the EFFECTIVE pipeline, not the
    requested one."""
    if pipeline is None:
        pipeline = default_pipeline()
    assert pipeline in PIPELINES, pipeline
    if pipeline == "stream" and not stream_supported(a_mode, b_mode):
        pipeline = "two_pass"
    return pipeline


def finalize_quant_stats(vec: jnp.ndarray):
    """Reduce a quantize-pass stats vector to the telemetry stat dict.

    Same four signals as ``telemetry.collect.operand_stats`` (clip /
    underflow / rel_err / scale_spread), but computed over the FULL operand
    in the quantization kernel itself (no group subsampling, no second QDQ
    pass).  Padded rows/cols are masked out of counts and scale extrema.
    """
    v = vec.reshape(STATS_WIDTH).astype(jnp.float32)
    clip_c, under, nz, err2, val2, smin, smax, cnt = (v[i] for i in range(8))
    smin = jnp.minimum(smin, smax)  # guard the +inf init if no valid group
    return {
        "clip": clip_c / jnp.maximum(cnt, 1.0),
        "underflow": under / jnp.maximum(nz, 1.0),
        "rel_err": jnp.sqrt(err2 / jnp.maximum(val2, 1e-30)),
        "scale_spread": jnp.log2(jnp.maximum(smax, 1e-30)
                                 / jnp.maximum(smin, 1e-30)),
    }


# ---------------------------------------------------------------------------
# In-kernel telemetry accumulation (shared by both pipelines)
# ---------------------------------------------------------------------------

def _stats_init():
    """Fresh per-grid-step stats partials (numpy scalars: kernel-closable)."""
    return dict(clip=np.float32(0), under=np.float32(0), nzc=np.float32(0),
                err2=np.float32(0), val2=np.float32(0),
                smin=np.float32(_STATS_BIG), smax=np.float32(0),
                cnt=np.float32(0))


def _stats_accum(st, sub, qsub, scale_f32, gvalid, fmt):
    """Fold one quant group's QDQ result into the stats partials."""
    af, qf = sub.astype(jnp.float32), qsub.astype(jnp.float32)
    magf = jnp.abs(af)
    nonzero = magf > 0  # zero-padding never counts as nonzero
    thr = scale_f32 * np.float32(fmt.max_value * (1.0 + 1e-6))
    st["clip"] += jnp.sum((magf > thr).astype(jnp.float32))
    st["under"] += jnp.sum((nonzero & (qf == 0)).astype(jnp.float32))
    st["nzc"] += jnp.sum(nonzero.astype(jnp.float32))
    st["err2"] += jnp.sum((af - qf) ** 2)
    st["val2"] += jnp.sum(af * af)
    st["smin"] = jnp.minimum(
        st["smin"], jnp.min(jnp.where(gvalid, scale_f32, _STATS_BIG)))
    st["smax"] = jnp.maximum(
        st["smax"], jnp.max(jnp.where(gvalid, scale_f32, 0.0)))


def _stats_slab_flush(sacc_ref, row, lane, st):
    """Fold one (block-row, k-slab) stats partial into its block-row's
    accumulator row of the (R, STATS_WIDTH) scratch (``row`` may be traced).

    Accumulation granularity is one ``(block, block)`` slab per flush —
    never a whole multi-slab tile — so the f32 fold each block-row sees is
    the SAME sequence of adds (its k-slabs in increasing-k order) no matter
    how the surrounding kernel tiles the operand.  This is what makes the
    stats bit-identical between the streaming and two-pass pipelines and
    across every ``(bm, bn, bk)``: order-sensitive float sums are pinned to
    a canonical order instead of the kernel's walk order.
    """
    addvec = jnp.stack(
        [st["clip"], st["under"], st["nzc"], st["err2"], st["val2"],
         jnp.zeros(()), jnp.zeros(()), st["cnt"]]).reshape(1, STATS_WIDTH)
    acc = sacc_ref[pl.ds(row, 1), :]
    new = acc + addvec
    new = jnp.where(lane == 5, jnp.minimum(acc, st["smin"]), new)
    new = jnp.where(lane == 6, jnp.maximum(acc, st["smax"]), new)
    sacc_ref[pl.ds(row, 1), :] = new


def _stats_fold(sacc_ref, lane):
    """Canonical final fold of the (R, STATS_WIDTH) per-block-row partials
    into the (1, STATS_WIDTH) output vector.  R depends only on the operand
    shape (never on the kernel tiling), so this reduction's shape — and
    therefore its bit pattern — is identical across pipelines and tilings."""
    acc = sacc_ref[...]
    tot = jnp.sum(acc, axis=0, keepdims=True)
    mn = jnp.min(acc, axis=0, keepdims=True)
    mx = jnp.max(acc, axis=0, keepdims=True)
    return jnp.where(lane == 5, mn, jnp.where(lane == 6, mx, tot))


# ---------------------------------------------------------------------------
# Phase 1: quantize pass
# ---------------------------------------------------------------------------

def _quant_kernel(*refs, mode, fmt, pow2, sr, trans, emit_trans, use_hw_rng,
                  grid_kind, bq, bkq, nk, block, m_real, k_real,
                  collect_stats):
    """QDQ one (bq, bkq) quant-orientation panel tile.

    Quant orientation = (non-reduction rows, reduction cols): (M, K) for
    the LHS, (N, K) for the RHS — groups always reduce along axis 1 here.
    ``trans`` transposes the stored read into that orientation in VMEM;
    ``emit_trans`` transposes the write back out (the RHS emits (K, N) so
    the matmul pass reads it plain).

    ``grid_kind``: 'one' = single sweep, grid (panels, ktiles) — block/tile
    groups live inside a tile.  'token' = grid (panels, 2, ktiles), sweep 0
    accumulates per-row amax in scratch; 'tensor' = grid (2, panels,
    ktiles), sweep 0 accumulates one global amax (the scale group spans the
    whole operand, so amax must complete before any element quantizes).
    """
    it = iter(refs)
    seed_ref = next(it) if sr else None
    # Q_max arrives as a traced SMEM scalar: a compile-time-constant divisor
    # would be strength-reduced to reciprocal-multiply inside the kernel
    # (1 ulp off the QDQ reference's true division, and non-idempotent).
    qmax_ref = next(it)
    x_ref, o_ref = next(it), next(it)
    stats_ref = next(it) if collect_stats else None
    amax_ref = next(it) if grid_kind in ("token", "tensor") else None
    sacc_ref = next(it) if collect_stats else None
    qm = qmax_ref[0]

    if grid_kind == "one":
        p, kt, s = pl.program_id(0), pl.program_id(1), None
        first = (p == 0) & (kt == 0)
        last = ((p == pl.num_programs(0) - 1)
                & (kt == pl.num_programs(1) - 1))
    elif grid_kind == "token":
        p, s, kt = pl.program_id(0), pl.program_id(1), pl.program_id(2)
        first = (p == 0) & (s == 0) & (kt == 0)
        last = ((p == pl.num_programs(0) - 1) & (s == 1)
                & (kt == pl.num_programs(2) - 1))
    else:  # tensor
        s, p, kt = pl.program_id(0), pl.program_id(1), pl.program_id(2)
        first = (s == 0) & (p == 0) & (kt == 0)
        last = ((s == 1) & (p == pl.num_programs(1) - 1)
                & (kt == pl.num_programs(2) - 1))

    xt = x_ref[...]
    if trans:
        xt = xt.T  # stored (bkq, bq) -> effective (bq, bkq)
    in_dt = xt.dtype
    mag = jnp.abs(xt)

    if collect_stats:
        lane = jax.lax.broadcasted_iota(jnp.int32, (1, STATS_WIDTH), 1)

        @pl.when(first)
        def _():
            sacc_ref[...] = jnp.broadcast_to(
                jnp.where(lane == 5, _STATS_BIG, 0.0), sacc_ref.shape)

    # --- sweep 0: amax accumulation for whole-reduction-axis groups ------
    if grid_kind == "token":
        @pl.when((s == 0) & (kt == 0))
        def _():
            amax_ref[...] = jnp.zeros_like(amax_ref)

        @pl.when(s == 0)
        def _():
            amax_ref[...] = jnp.maximum(
                amax_ref[...],
                jnp.max(mag, axis=1, keepdims=True).astype(jnp.float32))
    elif grid_kind == "tensor":
        @pl.when(first)
        def _():
            amax_ref[...] = jnp.zeros_like(amax_ref)

        @pl.when(s == 0)
        def _():
            amax_ref[...] = jnp.maximum(amax_ref[...],
                                        jnp.max(mag).astype(jnp.float32))

    # --- quantize sweep ---------------------------------------------------
    def _quantize():
        if sr:
            if use_hw_rng:
                # Distinct hardware stream per grid step (TPU path).
                pltpu.prng_seed(seed_ref[0] + p * nk + kt)
                bits = pltpu.bitcast(pltpu.prng_random_bits((bq, bkq)),
                                     jnp.uint32)
                noise = uniform_from_bits(bits)
            else:
                # Interpret mode: tiling-invariant counter hash keyed by the
                # element's global (row, col) in the effective operand.
                noise = hash_uniform((bq, bkq), seed_ref[0],
                                     p * bq, kt * bkq)
        else:
            noise = None

        if collect_stats:
            rows_valid = (p * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, 1), 0)) < m_real
            cols_valid = (kt * bkq + jax.lax.broadcasted_iota(
                jnp.int32, (1, bkq), 1)) < k_real

        if mode in ("block", "tile"):
            per_row = mode == "block"
            for i in range(bq // block):
                for j in range(bkq // block):
                    rs = slice(i * block, (i + 1) * block)
                    cs = slice(j * block, (j + 1) * block)
                    sub, smag = xt[rs, cs], mag[rs, cs]
                    amax = (jnp.max(smag, axis=1, keepdims=True) if per_row
                            else jnp.max(smag))
                    scale = group_scale(amax, fmt, pow2, qm)
                    sc = scale.astype(in_dt)
                    nsub = noise[rs, cs] if noise is not None else None
                    qsub = round_to_grid(sub / sc, fmt, nsub) * sc
                    if emit_trans:
                        o_ref[cs, rs] = qsub.T
                    else:
                        o_ref[rs, cs] = qsub
                    if collect_stats:
                        if per_row:  # (1 x block) groups: row x k-group
                            gvalid = (rows_valid[rs]
                                      & (kt * bkq + j * block < k_real))
                        else:        # one (block x block) tile group
                            gvalid = ((p * bq + i * block < m_real)
                                      & (kt * bkq + j * block < k_real))
                        st = _stats_init()
                        _stats_accum(st, sub, qsub, scale, gvalid, fmt)
                        st["cnt"] = (
                            jnp.sum(rows_valid[rs].astype(jnp.float32))
                            * jnp.sum(cols_valid[:, cs].astype(jnp.float32)))
                        _stats_slab_flush(sacc_ref, p * (bq // block) + i,
                                          lane, st)
        else:  # token / tensor: scale broadcast from the amax scratch
            scale = group_scale(amax_ref[...], fmt, pow2, qm)
            sc = scale.astype(in_dt)
            qt = round_to_grid(xt / sc, fmt, noise) * sc
            o_ref[...] = qt.T if emit_trans else qt
            if collect_stats:
                gvalid = rows_valid if grid_kind == "token" else True
                st = _stats_init()
                _stats_accum(st, xt, qt, scale, gvalid, fmt)
                st["cnt"] = (jnp.sum(rows_valid.astype(jnp.float32))
                             * jnp.sum(cols_valid.astype(jnp.float32)))
                # Whole-tile partial into the panel's first block-row: the
                # final fold sums all rows, so placement is arbitrary (only
                # two-pass runs token/tensor — no cross-pipeline order
                # contract to honor here).
                _stats_slab_flush(sacc_ref, p * (bq // block), lane, st)

    if grid_kind == "one":
        _quantize()
    else:
        pl.when(s == 1)(_quantize)

    if collect_stats:
        @pl.when(last)
        def _():
            stats_ref[...] = _stats_fold(sacc_ref, lane)


def _quantize_operand(t: jnp.ndarray, *, mode: str, fmt, pow2: bool,
                      sr: bool, seed: Optional[jnp.ndarray], trans: bool,
                      emit_trans: bool, block: int, m_real: int, k_real: int,
                      collect_stats: bool, interpret: bool
                      ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Run the quantize pass over one padded stored operand.

    ``t`` read in quant orientation (rows, reduction) — transposed from the
    stored layout when ``trans`` — QDQ'd once, and written out as
    (rows, reduction), or (reduction, rows) when ``emit_trans`` (the RHS
    case, so phase 2 reads (K, N) plain).  Also returns the raw stats
    vector when ``collect_stats``.
    """
    if trans:
        k_eff, m_eff = t.shape
    else:
        m_eff, k_eff = t.shape
    bq, bkq = _pick_tile(m_eff, block), _pick_tile(k_eff, block)
    np_, nk = m_eff // bq, k_eff // bkq
    grid_kind = {"block": "one", "tile": "one",
                 "token": "token", "tensor": "tensor"}[mode]

    if grid_kind == "one":
        grid = (np_, nk)
        gids = lambda p, kt: (p, kt)            # noqa: E731
    elif grid_kind == "token":
        grid = (np_, 2, nk)
        gids = lambda p, s, kt: (p, kt)         # noqa: E731
    else:
        grid = (2, np_, nk)
        gids = lambda s, p, kt: (p, kt)         # noqa: E731

    def xmap(*ids):
        p, kt = gids(*ids)
        return (kt, p) if trans else (p, kt)

    in_specs = []
    operands = []
    if sr:
        assert seed is not None, "stochastic quantize pass needs a seed"
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
        operands.append(seed.reshape(1).astype(jnp.int32))
    # Q_max as a traced SMEM scalar (see _quant_kernel); the optimization
    # barrier keeps XLA from constant-folding it back into the kernel
    # (which would re-enable the reciprocal-multiply strength reduction).
    in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
    operands.append(jax.lax.optimization_barrier(
        jnp.full((1,), fmt.max_value, jnp.float32)))
    in_specs.append(pl.BlockSpec((bkq, bq) if trans else (bq, bkq), xmap))
    operands.append(t)

    if emit_trans:
        out_specs = [pl.BlockSpec((bkq, bq),
                                  lambda *ids: tuple(reversed(gids(*ids))))]
        out_shapes = [jax.ShapeDtypeStruct((k_eff, m_eff), t.dtype)]
    else:
        out_specs = [pl.BlockSpec((bq, bkq), lambda *ids: gids(*ids))]
        out_shapes = [jax.ShapeDtypeStruct((m_eff, k_eff), t.dtype)]
    if collect_stats:
        out_specs.append(pl.BlockSpec((1, STATS_WIDTH), lambda *ids: (0, 0)))
        out_shapes.append(jax.ShapeDtypeStruct((1, STATS_WIDTH), jnp.float32))

    scratch = []
    if grid_kind == "token":
        scratch.append(pltpu.VMEM((bq, 1), jnp.float32))
    elif grid_kind == "tensor":
        scratch.append(pltpu.VMEM((1, 1), jnp.float32))
    if collect_stats:
        # Per-block-row partials (see _stats_slab_flush): R rows depend only
        # on the operand shape, keeping the stats fold order canonical.
        scratch.append(pltpu.VMEM((m_eff // block, STATS_WIDTH),
                                  jnp.float32))

    kernel = functools.partial(
        _quant_kernel, mode=mode, fmt=fmt, pow2=pow2, sr=sr, trans=trans,
        emit_trans=emit_trans, use_hw_rng=not interpret, grid_kind=grid_kind,
        bq=bq, bkq=bkq, nk=nk, block=block, m_real=m_real, k_real=k_real,
        collect_stats=collect_stats)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        scratch_shapes=scratch,
        # Scratch accumulators (amax sweeps, stats epilogue) need sequential
        # revisiting; the quantize pass is VPU/bandwidth-bound anyway.
        compiler_params=compiler_params(
            dimension_semantics=("arbitrary",) * len(grid)),
        interpret=interpret,
    )(*operands)
    if collect_stats:
        return out[0], out[1]
    return out[0], None


@functools.partial(jax.jit, static_argnames=(
    "mode", "fmt_name", "pow2", "sr", "trans", "block", "real_dims",
    "collect_stats", "interpret"))
def quantize_panels(t: jnp.ndarray, *, mode: str = "block",
                    fmt_name: str = "fp4_e2m1", pow2: bool = False,
                    sr: bool = False, seed: Optional[jnp.ndarray] = None,
                    trans: bool = False, block: int = 128,
                    real_dims: Optional[Tuple[int, int]] = None,
                    collect_stats: bool = False,
                    interpret: Optional[bool] = None):
    """Public quantize-pass entry point (phase 1 standalone).

    ``t``: stored 2-D operand, dims multiples of ``block``; effective
    orientation is ``t.T`` under ``trans``; groups reduce along axis 1 of
    the effective operand (the LHS convention).  Returns the QDQ'd
    effective operand, or ``(values, stats_vec)`` with ``collect_stats``
    (see ``finalize_quant_stats``).  ``real_dims`` = unpadded (rows, cols)
    of the effective operand, used only to mask padding out of the stats.
    """
    assert mode in QUANT_MODES and mode != "pass", mode
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m_eff, k_eff = (t.shape[1], t.shape[0]) if trans else t.shape
    m_real, k_real = real_dims if real_dims is not None else (m_eff, k_eff)
    q, stats = _quantize_operand(
        t, mode=mode, fmt=FORMATS[fmt_name], pow2=pow2, sr=sr, seed=seed,
        trans=trans, emit_trans=False, block=block, m_real=m_real,
        k_real=k_real, collect_stats=collect_stats, interpret=interpret)
    return (q, stats) if collect_stats else q


# ---------------------------------------------------------------------------
# Phase 2: tiled matmul pass (no quantization left in here)
# ---------------------------------------------------------------------------

def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k, trans_a, trans_b):
    """One (bm, bn) output tile at K-step pl.program_id(2)."""
    @pl.when(pl.program_id(2) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    at = a_ref[...]
    if trans_a:
        at = at.T
    bt = b_ref[...]
    if trans_b:
        bt = bt.T
    acc_ref[...] += jnp.dot(at, bt, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _tiled_matmul(a: jnp.ndarray, b: jnp.ndarray, *, trans_a: bool,
                  trans_b: bool, bm: int, bn: int, bk: int,
                  interpret: bool) -> jnp.ndarray:
    """y = A' @ B' with (bm, bn, bk) MXU tiling, f32 VMEM accumulation.

    Operands arrive either pre-quantized in effective orientation (trans
    flag False) or as ``pass``-mode stored arrays read transposed via the
    BlockSpec index maps (no HBM transpose, as before).
    """
    m, k = (a.shape[1], a.shape[0]) if trans_a else a.shape
    kb, n = (b.shape[1], b.shape[0]) if trans_b else b.shape
    assert k == kb, (a.shape, b.shape, trans_a, trans_b)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    kernel = functools.partial(_mm_kernel, n_k=k // bk, trans_a=trans_a,
                               trans_b=trans_b)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bk, bm),
                         (lambda i, j, kk: (kk, i))) if trans_a
            else pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk),
                         (lambda i, j, kk: (j, kk))) if trans_b
            else pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)


# ---------------------------------------------------------------------------
# Single-pass streaming pipeline: quantize fused into the MXU loop
# ---------------------------------------------------------------------------

def _qdq_stream_tile(xt, *, mode, fmt, pow2, qm, block, noise, sacc_ref,
                     lane, gate, row0, col0, m_real, k_real):
    """QDQ one (R, C) quant-orientation tile inside the streaming kernel.

    Mirrors ``_quant_kernel``'s block/tile sub-group loop op-for-op (same
    amax -> scale -> divide -> round -> rescale order on the same 128-aligned
    groups), so every element's QDQ value is bit-identical to the two-pass
    quantize pass.  ``row0``/``col0`` are the tile's global offsets in the
    quant-orientation operand (traced scalars): they key the SR noise and
    mask padding out of the stats.  Stats (from the pre-materialization
    qsub, exactly as ``_quant_kernel``) flush per (block-row, k-slab) into
    ``sacc_ref`` — the canonical order that makes them tiling- and
    pipeline-invariant — under ``gate`` (the once-per-element condition,
    e.g. first operand revisit); ``sacc_ref=None`` skips stats entirely.
    """
    rt, ct = xt.shape
    in_dt = xt.dtype
    mag = jnp.abs(xt)
    per_row = mode == "block"
    if sacc_ref is not None:
        rows_valid = (row0 + jax.lax.broadcasted_iota(
            jnp.int32, (rt, 1), 0)) < m_real
        cols_valid = (col0 + jax.lax.broadcasted_iota(
            jnp.int32, (1, ct), 1)) < k_real
    rows = []
    for i in range(rt // block):
        cols = []
        for j in range(ct // block):
            rs = slice(i * block, (i + 1) * block)
            cs = slice(j * block, (j + 1) * block)
            sub, smag = xt[rs, cs], mag[rs, cs]
            amax = (jnp.max(smag, axis=1, keepdims=True) if per_row
                    else jnp.max(smag))
            scale = group_scale(amax, fmt, pow2, qm)
            sc = scale.astype(in_dt)
            nsub = noise[rs, cs] if noise is not None else None
            qsub = round_to_grid(sub / sc, fmt, nsub) * sc
            cols.append(qsub)
            if sacc_ref is not None:
                if per_row:  # (1 x block) groups: row x k-group
                    gvalid = rows_valid[rs] & (col0 + j * block < k_real)
                else:        # one (block x block) tile group
                    gvalid = ((row0 + i * block < m_real)
                              & (col0 + j * block < k_real))
                st = _stats_init()
                _stats_accum(st, sub, qsub, scale, gvalid, fmt)
                st["cnt"] = (
                    jnp.sum(rows_valid[rs].astype(jnp.float32))
                    * jnp.sum(cols_valid[:, cs].astype(jnp.float32)))
                row = row0 // block + i

                def _flush(row=row, st=st):
                    _stats_slab_flush(sacc_ref, row, lane, st)
                if gate is None:
                    _flush()
                else:
                    pl.when(gate)(_flush)
        rows.append(cols[0] if len(cols) == 1 else
                    jnp.concatenate(cols, axis=1))
    return rows[0] if len(rows) == 1 else jnp.concatenate(rows, axis=0)


def _stream_noise(shape, seed_ref, tile_id, nk, row0, col0, use_hw_rng):
    """SR noise for one streamed tile.

    Interpret mode uses the coordinate-keyed counter hash — bit-identical
    to the two-pass quantize pass AND tiling-invariant, because each
    element's noise depends only on its global (row, col).  On TPU the
    hardware PRNG is reseeded per (tile, K-step) — deterministic across
    revisits of the same tile, but a different stream than the two-pass
    pipeline's panel order (the standing PR-3 TPU-validation caveat).
    """
    if use_hw_rng:
        pltpu.prng_seed(seed_ref[0] + tile_id * nk + pl.program_id(2))
        bits = pltpu.bitcast(pltpu.prng_random_bits(shape), jnp.uint32)
        return uniform_from_bits(bits)
    return hash_uniform(shape, seed_ref[0], row0, col0)


def _stream_kernel(*refs, a_mode, b_mode, fmt_a, fmt_b, a_pow2, b_pow2,
                   sr_a, sr_b, trans_a, trans_b, use_hw_rng, cache_a,
                   cache_b, bm, bn, bk, nk, block, m_real, k_real, n_real,
                   emit_sa, emit_sb):
    """One fused grid step: quantize the (i, kk) / (j, kk) operand tiles in
    VMEM and accumulate their product into the (i, j) output tile.

    Grid (M/bm, N/bn, K/bk), K innermost, sequential ("arbitrary") order.
    The LHS panel is quantized once per ``i`` (at j == 0) into the ``aq``
    VMEM scratch when ``cache_a``, else requantized per revisit (bit-
    identical either way — the codec is deterministic given the element's
    global coordinate).  RHS tiles are quantized once (at i == 0) into the
    ``bq`` VMEM scratch when ``cache_b``, else requantized per ``i``
    revisit — also bit-identical, the SR seed never involves ``i``.  Stats
    accumulate exactly once per element (A gated on j == 0, B on i == 0)
    into per-operand scratch, flushed to the stats outputs at the last step.
    """
    it = iter(refs)
    seed_a_ref = next(it) if sr_a else None
    seed_b_ref = next(it) if sr_b else None
    qmax_a_ref = next(it) if a_mode != "pass" else None
    qmax_b_ref = next(it) if b_mode != "pass" else None
    a_ref, b_ref, o_ref = next(it), next(it), next(it)
    stats_a_ref = next(it) if emit_sa else None
    stats_b_ref = next(it) if emit_sb else None
    acc_ref = next(it)
    aq_ref = next(it) if cache_a else None
    bq_ref = next(it) if cache_b else None
    sa_ref = next(it) if emit_sa else None
    sb_ref = next(it) if emit_sb else None

    i, j, kk = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    first = (i == 0) & (j == 0) & (kk == 0)
    last = ((i == pl.num_programs(0) - 1) & (j == pl.num_programs(1) - 1)
            & (kk == pl.num_programs(2) - 1))
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, STATS_WIDTH), 1)

    if emit_sa or emit_sb:
        @pl.when(first)
        def _():
            init = jnp.where(lane == 5, jnp.float32(_STATS_BIG),
                             jnp.float32(0.0))
            if sa_ref is not None:
                sa_ref[...] = jnp.broadcast_to(init, sa_ref.shape)
            if sb_ref is not None:
                sb_ref[...] = jnp.broadcast_to(init, sb_ref.shape)

    @pl.when(kk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # --- LHS tile -> effective (bm, bk) -----------------------------------
    def _qdq_a(gate):
        xt = a_ref[...]
        if trans_a:
            xt = xt.T  # stored (bk, bm) -> quant orientation (bm, bk)
        noise = (_stream_noise((bm, bk), seed_a_ref, i, nk,
                               i * bm, kk * bk, use_hw_rng)
                 if sr_a else None)
        q = _qdq_stream_tile(xt, mode=a_mode, fmt=fmt_a, pow2=a_pow2,
                             qm=qmax_a_ref[0], block=block, noise=noise,
                             sacc_ref=sa_ref, lane=lane, gate=gate,
                             row0=i * bm, col0=kk * bk,
                             m_real=m_real, k_real=k_real)
        return snap_to_dtype(q)

    if a_mode == "pass":
        at = a_ref[...]
        if trans_a:
            at = at.T
    elif cache_a:
        @pl.when(j == 0)
        def _():
            # The whole call runs once per (i, kk) — stats ungated inside.
            aq_ref[:, pl.ds(kk * bk, bk)] = _qdq_a(gate=None)
        at = aq_ref[:, pl.ds(kk * bk, bk)]
    else:
        # Requantized per j-revisit; stats must still fold exactly once.
        at = _qdq_a(gate=(j == 0))

    # --- RHS tile -> effective (bk, bn) -----------------------------------
    def _qdq_b(gate):
        xt = b_ref[...]
        if not trans_b:
            xt = xt.T  # effective (bk, bn) -> quant orientation (bn, bk)
        noise = (_stream_noise((bn, bk), seed_b_ref, j, nk,
                               j * bn, kk * bk, use_hw_rng)
                 if sr_b else None)
        q = _qdq_stream_tile(xt, mode=b_mode, fmt=fmt_b, pow2=b_pow2,
                             qm=qmax_b_ref[0], block=block, noise=noise,
                             sacc_ref=sb_ref, lane=lane, gate=gate,
                             row0=j * bn, col0=kk * bk,
                             m_real=n_real, k_real=k_real)
        return snap_to_dtype(q).T  # (bk, bn)

    if b_mode == "pass":
        bt = b_ref[...]
        if trans_b:
            bt = bt.T  # stored (bn, bk) -> effective (bk, bn)
    elif cache_b:
        @pl.when(i == 0)
        def _():
            # The whole call runs once per (j, kk) — stats ungated inside.
            bq_ref[pl.ds(kk * bk, bk), pl.ds(j * bn, bn)] = _qdq_b(gate=None)
        bt = bq_ref[pl.ds(kk * bk, bk), pl.ds(j * bn, bn)]
    else:
        # Requantized per i-revisit; stats must still fold exactly once.
        bt = _qdq_b(gate=(i == 0))

    acc_ref[...] += jnp.dot(at, bt, preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    if emit_sa or emit_sb:
        @pl.when(last)
        def _():
            if stats_a_ref is not None:
                stats_a_ref[...] = _stats_fold(sa_ref, lane)
            if stats_b_ref is not None:
                stats_b_ref[...] = _stats_fold(sb_ref, lane)


def _stream_qmm(a: jnp.ndarray, b: jnp.ndarray, *, a_mode, b_mode,
                fmt_a, fmt_b, a_pow2, b_pow2, sr_a, sr_b, seed_a, seed_b,
                trans_a, trans_b, block, bm, bn, bk, m_real, k_real, n_real,
                collect_stats, interpret):
    """Build the single fused pallas_call for the streaming pipeline.

    Returns ``(y, (stats_a, stats_b))`` — stats slots None for pass-mode
    operands or when ``collect_stats`` is off.
    """
    m, k = (a.shape[1], a.shape[0]) if trans_a else a.shape
    _, n = (b.shape[1], b.shape[0]) if trans_b else b.shape
    grid = (m // bm, n // bn, k // bk)
    ni, nj, nk = grid
    # Cache the quantized LHS row panel across output-column revisits when
    # it fits the VMEM budget (weight-stationary flavor: quantize A once).
    cache_a = (a_mode != "pass" and nj > 1
               and bm * k * a.dtype.itemsize <= _AQ_CACHE_BYTES)
    # Cache the full quantized RHS across output-row revisits likewise
    # (quantize B once; the SR seed is (j, kk)-keyed so this is bitwise
    # identical to requantizing).
    cache_b = (b_mode != "pass" and ni > 1
               and k * n * b.dtype.itemsize <= _BQ_CACHE_BYTES)
    emit_sa = collect_stats and a_mode != "pass"
    emit_sb = collect_stats and b_mode != "pass"

    in_specs, operands = [], []
    for sr, seed in ((sr_a, seed_a), (sr_b, seed_b)):
        if sr:
            assert seed is not None, "stochastic rounding needs a seed"
            in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
            operands.append(seed.reshape(1).astype(jnp.int32))
    for mode, fmt in ((a_mode, fmt_a), (b_mode, fmt_b)):
        if mode != "pass":
            # Q_max as a traced SMEM scalar (see _quant_kernel).
            in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
            operands.append(jax.lax.optimization_barrier(
                jnp.full((1,), fmt.max_value, jnp.float32)))
    in_specs.append(
        pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, i)) if trans_a
        else pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)))
    operands.append(a)
    in_specs.append(
        pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)) if trans_b
        else pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)))
    operands.append(b)

    out_specs = [pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))]
    out_shapes = [jax.ShapeDtypeStruct((m, n), a.dtype)]
    for emit in (emit_sa, emit_sb):
        if emit:
            out_specs.append(pl.BlockSpec((1, STATS_WIDTH),
                                          lambda i, j, kk: (0, 0)))
            out_shapes.append(
                jax.ShapeDtypeStruct((1, STATS_WIDTH), jnp.float32))

    scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
    if cache_a:
        scratch.append(pltpu.VMEM((bm, k), a.dtype))
    if cache_b:
        scratch.append(pltpu.VMEM((k, n), b.dtype))
    # Per-block-row stats partials (see _stats_slab_flush): one row per
    # 128-row slab of the quant-orientation operand (A: M rows, B: N rows),
    # tiling-independent so the final fold order is canonical.
    if emit_sa:
        scratch.append(pltpu.VMEM((m // block, STATS_WIDTH), jnp.float32))
    if emit_sb:
        scratch.append(pltpu.VMEM((n // block, STATS_WIDTH), jnp.float32))

    kernel = functools.partial(
        _stream_kernel, a_mode=a_mode, b_mode=b_mode, fmt_a=fmt_a,
        fmt_b=fmt_b, a_pow2=a_pow2, b_pow2=b_pow2, sr_a=sr_a, sr_b=sr_b,
        trans_a=trans_a, trans_b=trans_b, use_hw_rng=not interpret,
        cache_a=cache_a, cache_b=cache_b, bm=bm, bn=bn, bk=bk, nk=nk,
        block=block,
        m_real=m_real, k_real=k_real, n_real=n_real,
        emit_sa=emit_sa, emit_sb=emit_sb)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        scratch_shapes=scratch,
        # Scratch (acc, LHS panel cache, stats) is revisited across grid
        # steps -> sequential order required.
        compiler_params=compiler_params(
            dimension_semantics=("arbitrary",) * 3),
        interpret=interpret,
    )(*operands)
    y = outs[0]
    idx = 1
    stats_a = stats_b = None
    if emit_sa:
        stats_a, idx = outs[idx], idx + 1
    if emit_sb:
        stats_b = outs[idx]
    return y, (stats_a, stats_b)


# ---------------------------------------------------------------------------
# Orchestrator
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "a_mode", "b_mode", "a_fmt", "b_fmt", "a_pow2", "b_pow2", "a_sr", "b_sr",
    "trans_a", "trans_b", "block", "bm", "bn", "bk", "pipeline", "real_dims",
    "collect_stats", "interpret"))
def _fused_qmm(a: jnp.ndarray, b: jnp.ndarray, *,
               a_mode: str, b_mode: str, a_fmt: str, b_fmt: str,
               a_pow2: bool, b_pow2: bool, a_sr: bool, b_sr: bool,
               seed_a: Optional[jnp.ndarray], seed_b: Optional[jnp.ndarray],
               trans_a: bool, trans_b: bool, block: int,
               bm: int, bn: int, bk: int, pipeline: str,
               real_dims: Optional[Tuple[int, int, int]],
               collect_stats: bool, interpret: bool):
    """Jit'd pipeline body — every knob arrives concrete (see fused_qmm)."""
    assert a_mode in QUANT_MODES and b_mode in QUANT_MODES, (a_mode, b_mode)
    assert pipeline in PIPELINES, pipeline
    m, k = (a.shape[1], a.shape[0]) if trans_a else a.shape
    kb, n = (b.shape[1], b.shape[0]) if trans_b else b.shape
    assert k == kb, (a.shape, b.shape, trans_a, trans_b)
    assert m % block == 0 and k % block == 0 and n % block == 0, \
        (m, k, n, block)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    mr, kr, nr = real_dims if real_dims is not None else (m, k, n)

    if pipeline == "stream":
        assert stream_supported(a_mode, b_mode), (a_mode, b_mode)
        y, (stats_a, stats_b) = _stream_qmm(
            a, b, a_mode=a_mode, b_mode=b_mode,
            fmt_a=FORMATS[a_fmt], fmt_b=FORMATS[b_fmt],
            a_pow2=a_pow2, b_pow2=b_pow2,
            sr_a=(a_sr and a_mode != "pass"
                  and not FORMATS[a_fmt].passthrough),
            sr_b=(b_sr and b_mode != "pass"
                  and not FORMATS[b_fmt].passthrough),
            seed_a=seed_a, seed_b=seed_b, trans_a=trans_a, trans_b=trans_b,
            block=block, bm=bm, bn=bn, bk=bk, m_real=mr, k_real=kr,
            n_real=nr, collect_stats=collect_stats, interpret=interpret)
        if collect_stats:
            return y, (stats_a, stats_b)
        return y

    stats_a = stats_b = None
    mm_trans_a, mm_trans_b = trans_a, trans_b
    if a_mode != "pass":
        # LHS quant orientation (M, K) == effective orientation.
        a, stats_a = _quantize_operand(
            a, mode=a_mode, fmt=FORMATS[a_fmt], pow2=a_pow2,
            sr=a_sr and not FORMATS[a_fmt].passthrough, seed=seed_a,
            trans=trans_a, emit_trans=False, block=block, m_real=mr,
            k_real=kr, collect_stats=collect_stats, interpret=interpret)
        mm_trans_a = False
    if b_mode != "pass":
        # RHS quant orientation is (N, K) — groups reduce over K, which is
        # axis 0 of the effective (K, N) — so the pass reads the stored
        # array transposed iff NOT trans_b, and emits (K, N) back.
        b, stats_b = _quantize_operand(
            b, mode=b_mode, fmt=FORMATS[b_fmt], pow2=b_pow2,
            sr=b_sr and not FORMATS[b_fmt].passthrough, seed=seed_b,
            trans=not trans_b, emit_trans=True, block=block, m_real=nr,
            k_real=kr, collect_stats=collect_stats, interpret=interpret)
        mm_trans_b = False

    y = _tiled_matmul(a, b, trans_a=mm_trans_a, trans_b=mm_trans_b,
                      bm=bm, bn=bn, bk=bk, interpret=interpret)
    if collect_stats:
        return y, (stats_a, stats_b)
    return y


def fused_qmm(a: jnp.ndarray, b: jnp.ndarray, *,
              a_mode: str = "block", b_mode: str = "tile",
              a_fmt: str = "fp4_e2m1", b_fmt: str = "fp4_e2m1",
              a_pow2: bool = False, b_pow2: bool = False,
              a_sr: bool = False, b_sr: bool = False,
              seed_a: Optional[jnp.ndarray] = None,
              seed_b: Optional[jnp.ndarray] = None,
              trans_a: bool = False, trans_b: bool = False,
              block: int = 128,
              bm: Optional[int] = None, bn: Optional[int] = None,
              bk: Optional[int] = None,
              pipeline: Optional[str] = None,
              real_dims: Optional[Tuple[int, int, int]] = None,
              collect_stats: bool = False,
              interpret: bool = False):
    """y = Q(A') @ Q(B'); A' = a^T under ``trans_a`` (same for B').
    Effective shapes A': (M, K), B': (K, N); all dims must be multiples of
    ``block`` (the ops.py wrapper pads).

    ``pipeline`` picks the implementation: ``"stream"`` (default via
    ``default_pipeline``/``use_pipeline``) fuses quantize into the MXU loop
    in ONE pallas_call; ``"two_pass"`` is the quantize-pass + matmul-pass
    reference.  Both are bit-identical for the same ``(bm, bn, bk)``;
    token/tensor granularities silently take two_pass (stream_supported).

    Tiling: explicit ``bm``/``bn``/``bk`` win; when ALL are omitted the
    autotuning table (``kernels.autotune``) is consulted, falling back to
    the ``_pick_tile`` heuristic on a miss (partially-specified tiles skip
    the table).  This wrapper is deliberately NOT jit'd: pipeline and tile
    resolution happen per call, outside the jit boundary, so a flipped
    default pipeline or an updated tuning table can never serve a stale jit
    cache — the resolved static knobs key ``_fused_qmm``'s cache.

    ``a_sr``/``b_sr`` enable in-kernel stochastic rounding (seeds
    required); ``real_dims`` = unpadded (M, K, N) for stats masking; with
    ``collect_stats`` returns ``(y, (stats_a, stats_b))`` where pass-mode
    slots are None.
    """
    m, k = (a.shape[1], a.shape[0]) if trans_a else a.shape
    _, n = (b.shape[1], b.shape[0]) if trans_b else b.shape
    pipeline = resolve_pipeline(pipeline, a_mode, b_mode)
    if bm is None and bn is None and bk is None:
        from repro.kernels import autotune  # lazy: autotune imports us
        hit = autotune.resolve_tiles(
            m, n, k, dtypes=(a.dtype.name, b.dtype.name),
            modes=(a_mode, b_mode), trans=(trans_a, trans_b), block=block)
        if hit is not None:
            bm, bn, bk = hit
    bm = bm if bm is not None else _pick_tile(m, block)
    bn = bn if bn is not None else _pick_tile(n, block)
    bk = bk if bk is not None else _pick_tile(k, block)
    return _fused_qmm(a, b, a_mode=a_mode, b_mode=b_mode, a_fmt=a_fmt,
                      b_fmt=b_fmt, a_pow2=a_pow2, b_pow2=b_pow2, a_sr=a_sr,
                      b_sr=b_sr, seed_a=seed_a, seed_b=seed_b,
                      trans_a=trans_a, trans_b=trans_b, block=block,
                      bm=bm, bn=bn, bk=bk, pipeline=pipeline,
                      real_dims=real_dims, collect_stats=collect_stats,
                      interpret=interpret)


def fp4_matmul(x: jnp.ndarray, w: jnp.ndarray, *,
               x_fmt: str = "fp4_e2m1", w_fmt: str = "fp4_e2m1",
               block: int = 128, interpret: bool = False) -> jnp.ndarray:
    """y = Q_blk(x) @ Q_tile(w) (the paper's fwd FFN matmul).

    x: (M, K), w: (K, N); M, K, N must be multiples of ``block``
    (the ops.py wrapper pads).  Returns x.dtype.  Kept as the historical
    fwd-only entry point; a thin specialization of ``fused_qmm`` (and like
    it deliberately un-jit'd, so the pipeline default resolves per call).
    """
    return fused_qmm(x, w, a_mode="block", b_mode="tile", a_fmt=x_fmt,
                     b_fmt=w_fmt, block=block, interpret=interpret)
