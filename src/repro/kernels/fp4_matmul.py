"""Pallas TPU kernels: quantize-once K-panel pipeline for FP4/FP8 matmuls.

The paper's §3.2 hotspot — a linear whose operands are quantized per-group
and whose dot runs on the low-precision unit — used to be ONE fused kernel
that re-quantized every LHS K-tile ``N/bn`` times and every RHS K-tile
``M/bm`` times (once per output-tile visit), with RTN emulated through
``log2``/``ldexp`` transcendentals on the VPU.  Following the
quantize-once operand caching of "FP4 All the Way" (Chmiel et al., 2025)
and "Quartet" (Castro et al., 2025), the pipeline is now two phases:

**Phase 1 — quantize pass** (``quantize_panels`` / ``_quantize_operand``).
One grid sweep over each operand's K-panels QDQs every element exactly
once, in the *effective* (post-transpose) orientation, and writes the
on-grid values back in MXU-ready layout.  In this QDQ simulation the
emitted values are the dequantized grid points (bf16/f32 — what the MXU
consumes); on FP4-native hardware the same layout holds the 4-bit codes
plus per-group scales.  All four paper granularities run in-kernel:

  * ``block`` — per-(1 x 128) groups along the reduction axis;
  * ``tile``  — per-(128 x 128) tiles;
  * ``token`` / ``tensor`` — amax groups spanning the whole reduction axis,
    computed by a two-sweep grid (sweep 0 accumulates amax in scratch,
    sweep 1 quantizes) — this subsumes the old external ``_rank1_scale``
    XLA reduction, so "scaled" modes no longer exist.

Rounding is the **bit-exact integer RTN** of ``kernels.rounding`` (exponent
extracted from the f32 bit pattern, grid step assembled by writing the
exponent field — no transcendentals), verified bit-exact against
``formats.round_to_format``.  ``sr=True`` switches to in-kernel unbiased
stochastic rounding: on TPU via ``pltpu.prng_seed`` +
``pltpu.prng_random_bits``, in interpret mode (no CPU lowering for the TPU
PRNG) via the tiling-invariant counter hash ``rounding.hash_uniform`` —
noise is keyed by each element's *global* coordinate, so results do not
depend on panel sizes.  ``collect_stats=True`` adds a telemetry epilogue:
clip/underflow/rel-err/scale-spread accumulators ride in VMEM scratch and
are emitted as one (1, 8) vector, replacing the full re-QDQ that
``telemetry.tap_matmul`` used to pay (see ``finalize_quant_stats``).

**Phase 2 — matmul pass** (``_tiled_matmul``).  A plain tiled MXU matmul
over the quantize-pass outputs with grid tiling ``(bm, bn, bk)`` fully
**decoupled** from the 128-element quant group — multiple quant groups per
MXU tile, fewer grid steps, zero re-quantization.  K stays innermost and
accumulates into an f32 VMEM scratch; ``pass``-mode (unquantized bf16)
operands skip phase 1 entirely and are read transposed via BlockSpec index
maps, exactly as before.

``fused_qmm`` orchestrates both phases and keeps its role-parameterized
contract: per-operand modes ``pass | block | tile | token | tensor``,
``trans_a``/``trans_b`` stored-layout transposition, per-operand formats
and pow2-scale flags, plus new per-operand ``sr`` flags and seeds.  Tile
knobs: ``block`` (quant group, 128), ``bm``/``bn``/``bk`` (MXU tiling,
defaults auto-picked per shape), quantize-pass panels auto-picked.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import FORMATS
from repro.kernels.rounding import (group_scale, hash_uniform, round_to_grid,
                                    uniform_from_bits)

__all__ = ["fp4_matmul", "fused_qmm", "quantize_panels", "compiler_params",
           "finalize_quant_stats", "QUANT_MODES", "STATS_WIDTH"]

QUANT_MODES = ("pass", "block", "tile", "token", "tensor")

# Telemetry-epilogue accumulator lanes (f32, shape (1, STATS_WIDTH)):
#   0 clip count   1 underflow count   2 nonzero count   3 sum err^2
#   4 sum x^2      5 min group scale   6 max group scale 7 valid-element count
STATS_WIDTH = 8
_STATS_BIG = 3.0e38

# jax renamed TPUCompilerParams -> CompilerParams across versions; the repo
# must run on both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def compiler_params(**kw):
    """Version-portable ``pltpu.CompilerParams`` constructor."""
    return _CompilerParams(**kw)


def _pick_tile(dim: int, block: int = 128) -> int:
    """Largest friendly tile (multiple of ``block``) dividing ``dim``."""
    for c in (4 * block, 3 * block, 2 * block, block):
        if dim % c == 0:
            return c
    raise ValueError(f"dim {dim} not a multiple of block {block}")


def finalize_quant_stats(vec: jnp.ndarray):
    """Reduce a quantize-pass stats vector to the telemetry stat dict.

    Same four signals as ``telemetry.collect.operand_stats`` (clip /
    underflow / rel_err / scale_spread), but computed over the FULL operand
    in the quantization kernel itself (no group subsampling, no second QDQ
    pass).  Padded rows/cols are masked out of counts and scale extrema.
    """
    v = vec.reshape(STATS_WIDTH).astype(jnp.float32)
    clip_c, under, nz, err2, val2, smin, smax, cnt = (v[i] for i in range(8))
    smin = jnp.minimum(smin, smax)  # guard the +inf init if no valid group
    return {
        "clip": clip_c / jnp.maximum(cnt, 1.0),
        "underflow": under / jnp.maximum(nz, 1.0),
        "rel_err": jnp.sqrt(err2 / jnp.maximum(val2, 1e-30)),
        "scale_spread": jnp.log2(jnp.maximum(smax, 1e-30)
                                 / jnp.maximum(smin, 1e-30)),
    }


# ---------------------------------------------------------------------------
# Phase 1: quantize pass
# ---------------------------------------------------------------------------

def _quant_kernel(*refs, mode, fmt, pow2, sr, trans, emit_trans, use_hw_rng,
                  grid_kind, bq, bkq, nk, block, m_real, k_real,
                  collect_stats):
    """QDQ one (bq, bkq) quant-orientation panel tile.

    Quant orientation = (non-reduction rows, reduction cols): (M, K) for
    the LHS, (N, K) for the RHS — groups always reduce along axis 1 here.
    ``trans`` transposes the stored read into that orientation in VMEM;
    ``emit_trans`` transposes the write back out (the RHS emits (K, N) so
    the matmul pass reads it plain).

    ``grid_kind``: 'one' = single sweep, grid (panels, ktiles) — block/tile
    groups live inside a tile.  'token' = grid (panels, 2, ktiles), sweep 0
    accumulates per-row amax in scratch; 'tensor' = grid (2, panels,
    ktiles), sweep 0 accumulates one global amax (the scale group spans the
    whole operand, so amax must complete before any element quantizes).
    """
    it = iter(refs)
    seed_ref = next(it) if sr else None
    # Q_max arrives as a traced SMEM scalar: a compile-time-constant divisor
    # would be strength-reduced to reciprocal-multiply inside the kernel
    # (1 ulp off the QDQ reference's true division, and non-idempotent).
    qmax_ref = next(it)
    x_ref, o_ref = next(it), next(it)
    stats_ref = next(it) if collect_stats else None
    amax_ref = next(it) if grid_kind in ("token", "tensor") else None
    sacc_ref = next(it) if collect_stats else None
    qm = qmax_ref[0]

    if grid_kind == "one":
        p, kt, s = pl.program_id(0), pl.program_id(1), None
        first = (p == 0) & (kt == 0)
        last = ((p == pl.num_programs(0) - 1)
                & (kt == pl.num_programs(1) - 1))
    elif grid_kind == "token":
        p, s, kt = pl.program_id(0), pl.program_id(1), pl.program_id(2)
        first = (p == 0) & (s == 0) & (kt == 0)
        last = ((p == pl.num_programs(0) - 1) & (s == 1)
                & (kt == pl.num_programs(2) - 1))
    else:  # tensor
        s, p, kt = pl.program_id(0), pl.program_id(1), pl.program_id(2)
        first = (s == 0) & (p == 0) & (kt == 0)
        last = ((s == 1) & (p == pl.num_programs(1) - 1)
                & (kt == pl.num_programs(2) - 1))

    xt = x_ref[...]
    if trans:
        xt = xt.T  # stored (bkq, bq) -> effective (bq, bkq)
    in_dt = xt.dtype
    mag = jnp.abs(xt)

    if collect_stats:
        lane = jax.lax.broadcasted_iota(jnp.int32, (1, STATS_WIDTH), 1)

        @pl.when(first)
        def _():
            sacc_ref[...] = jnp.where(lane == 5, _STATS_BIG, 0.0)

    # --- sweep 0: amax accumulation for whole-reduction-axis groups ------
    if grid_kind == "token":
        @pl.when((s == 0) & (kt == 0))
        def _():
            amax_ref[...] = jnp.zeros_like(amax_ref)

        @pl.when(s == 0)
        def _():
            amax_ref[...] = jnp.maximum(
                amax_ref[...],
                jnp.max(mag, axis=1, keepdims=True).astype(jnp.float32))
    elif grid_kind == "tensor":
        @pl.when(first)
        def _():
            amax_ref[...] = jnp.zeros_like(amax_ref)

        @pl.when(s == 0)
        def _():
            amax_ref[...] = jnp.maximum(amax_ref[...],
                                        jnp.max(mag).astype(jnp.float32))

    # --- quantize sweep ---------------------------------------------------
    def _quantize():
        if sr:
            if use_hw_rng:
                # Distinct hardware stream per grid step (TPU path).
                pltpu.prng_seed(seed_ref[0] + p * nk + kt)
                bits = pltpu.bitcast(pltpu.prng_random_bits((bq, bkq)),
                                     jnp.uint32)
                noise = uniform_from_bits(bits)
            else:
                # Interpret mode: tiling-invariant counter hash keyed by the
                # element's global (row, col) in the effective operand.
                noise = hash_uniform((bq, bkq), seed_ref[0],
                                     p * bq, kt * bkq)
        else:
            noise = None

        if collect_stats:
            rows_valid = (p * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, 1), 0)) < m_real
            cols_valid = (kt * bkq + jax.lax.broadcasted_iota(
                jnp.int32, (1, bkq), 1)) < k_real
            st = dict(clip=np.float32(0), under=np.float32(0),
                      nzc=np.float32(0), err2=np.float32(0),
                      val2=np.float32(0), smin=np.float32(_STATS_BIG),
                      smax=np.float32(0))

        def _accum_stats(sub, qsub, scale_f32, gvalid):
            af, qf = sub.astype(jnp.float32), qsub.astype(jnp.float32)
            magf = jnp.abs(af)
            nonzero = magf > 0  # zero-padding never counts as nonzero
            thr = scale_f32 * np.float32(fmt.max_value * (1.0 + 1e-6))
            st["clip"] += jnp.sum((magf > thr).astype(jnp.float32))
            st["under"] += jnp.sum((nonzero & (qf == 0)).astype(jnp.float32))
            st["nzc"] += jnp.sum(nonzero.astype(jnp.float32))
            st["err2"] += jnp.sum((af - qf) ** 2)
            st["val2"] += jnp.sum(af * af)
            st["smin"] = jnp.minimum(
                st["smin"], jnp.min(jnp.where(gvalid, scale_f32, _STATS_BIG)))
            st["smax"] = jnp.maximum(
                st["smax"], jnp.max(jnp.where(gvalid, scale_f32, 0.0)))

        if mode in ("block", "tile"):
            per_row = mode == "block"
            for i in range(bq // block):
                for j in range(bkq // block):
                    rs = slice(i * block, (i + 1) * block)
                    cs = slice(j * block, (j + 1) * block)
                    sub, smag = xt[rs, cs], mag[rs, cs]
                    amax = (jnp.max(smag, axis=1, keepdims=True) if per_row
                            else jnp.max(smag))
                    scale = group_scale(amax, fmt, pow2, qm)
                    sc = scale.astype(in_dt)
                    nsub = noise[rs, cs] if noise is not None else None
                    qsub = round_to_grid(sub / sc, fmt, nsub) * sc
                    if emit_trans:
                        o_ref[cs, rs] = qsub.T
                    else:
                        o_ref[rs, cs] = qsub
                    if collect_stats:
                        if per_row:  # (1 x block) groups: row x k-group
                            gvalid = (rows_valid[rs]
                                      & (kt * bkq + j * block < k_real))
                        else:        # one (block x block) tile group
                            gvalid = ((p * bq + i * block < m_real)
                                      & (kt * bkq + j * block < k_real))
                        _accum_stats(sub, qsub, scale, gvalid)
        else:  # token / tensor: scale broadcast from the amax scratch
            scale = group_scale(amax_ref[...], fmt, pow2, qm)
            sc = scale.astype(in_dt)
            qt = round_to_grid(xt / sc, fmt, noise) * sc
            o_ref[...] = qt.T if emit_trans else qt
            if collect_stats:
                gvalid = rows_valid if grid_kind == "token" else True
                _accum_stats(xt, qt, scale, gvalid)

        if collect_stats:
            cnt = (jnp.sum(rows_valid.astype(jnp.float32))
                   * jnp.sum(cols_valid.astype(jnp.float32)))
            addvec = jnp.stack(
                [st["clip"], st["under"], st["nzc"], st["err2"], st["val2"],
                 jnp.zeros(()), jnp.zeros(()), cnt]).reshape(1, STATS_WIDTH)
            acc = sacc_ref[...]
            new = acc + addvec
            new = jnp.where(lane == 5, jnp.minimum(acc, st["smin"]), new)
            new = jnp.where(lane == 6, jnp.maximum(acc, st["smax"]), new)
            sacc_ref[...] = new

    if grid_kind == "one":
        _quantize()
    else:
        pl.when(s == 1)(_quantize)

    if collect_stats:
        @pl.when(last)
        def _():
            stats_ref[...] = sacc_ref[...]


def _quantize_operand(t: jnp.ndarray, *, mode: str, fmt, pow2: bool,
                      sr: bool, seed: Optional[jnp.ndarray], trans: bool,
                      emit_trans: bool, block: int, m_real: int, k_real: int,
                      collect_stats: bool, interpret: bool
                      ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Run the quantize pass over one padded stored operand.

    ``t`` read in quant orientation (rows, reduction) — transposed from the
    stored layout when ``trans`` — QDQ'd once, and written out as
    (rows, reduction), or (reduction, rows) when ``emit_trans`` (the RHS
    case, so phase 2 reads (K, N) plain).  Also returns the raw stats
    vector when ``collect_stats``.
    """
    if trans:
        k_eff, m_eff = t.shape
    else:
        m_eff, k_eff = t.shape
    bq, bkq = _pick_tile(m_eff, block), _pick_tile(k_eff, block)
    np_, nk = m_eff // bq, k_eff // bkq
    grid_kind = {"block": "one", "tile": "one",
                 "token": "token", "tensor": "tensor"}[mode]

    if grid_kind == "one":
        grid = (np_, nk)
        gids = lambda p, kt: (p, kt)            # noqa: E731
    elif grid_kind == "token":
        grid = (np_, 2, nk)
        gids = lambda p, s, kt: (p, kt)         # noqa: E731
    else:
        grid = (2, np_, nk)
        gids = lambda s, p, kt: (p, kt)         # noqa: E731

    def xmap(*ids):
        p, kt = gids(*ids)
        return (kt, p) if trans else (p, kt)

    in_specs = []
    operands = []
    if sr:
        assert seed is not None, "stochastic quantize pass needs a seed"
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
        operands.append(seed.reshape(1).astype(jnp.int32))
    # Q_max as a traced SMEM scalar (see _quant_kernel); the optimization
    # barrier keeps XLA from constant-folding it back into the kernel
    # (which would re-enable the reciprocal-multiply strength reduction).
    in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
    operands.append(jax.lax.optimization_barrier(
        jnp.full((1,), fmt.max_value, jnp.float32)))
    in_specs.append(pl.BlockSpec((bkq, bq) if trans else (bq, bkq), xmap))
    operands.append(t)

    if emit_trans:
        out_specs = [pl.BlockSpec((bkq, bq),
                                  lambda *ids: tuple(reversed(gids(*ids))))]
        out_shapes = [jax.ShapeDtypeStruct((k_eff, m_eff), t.dtype)]
    else:
        out_specs = [pl.BlockSpec((bq, bkq), lambda *ids: gids(*ids))]
        out_shapes = [jax.ShapeDtypeStruct((m_eff, k_eff), t.dtype)]
    if collect_stats:
        out_specs.append(pl.BlockSpec((1, STATS_WIDTH), lambda *ids: (0, 0)))
        out_shapes.append(jax.ShapeDtypeStruct((1, STATS_WIDTH), jnp.float32))

    scratch = []
    if grid_kind == "token":
        scratch.append(pltpu.VMEM((bq, 1), jnp.float32))
    elif grid_kind == "tensor":
        scratch.append(pltpu.VMEM((1, 1), jnp.float32))
    if collect_stats:
        scratch.append(pltpu.VMEM((1, STATS_WIDTH), jnp.float32))

    kernel = functools.partial(
        _quant_kernel, mode=mode, fmt=fmt, pow2=pow2, sr=sr, trans=trans,
        emit_trans=emit_trans, use_hw_rng=not interpret, grid_kind=grid_kind,
        bq=bq, bkq=bkq, nk=nk, block=block, m_real=m_real, k_real=k_real,
        collect_stats=collect_stats)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        scratch_shapes=scratch,
        # Scratch accumulators (amax sweeps, stats epilogue) need sequential
        # revisiting; the quantize pass is VPU/bandwidth-bound anyway.
        compiler_params=compiler_params(
            dimension_semantics=("arbitrary",) * len(grid)),
        interpret=interpret,
    )(*operands)
    if collect_stats:
        return out[0], out[1]
    return out[0], None


@functools.partial(jax.jit, static_argnames=(
    "mode", "fmt_name", "pow2", "sr", "trans", "block", "real_dims",
    "collect_stats", "interpret"))
def quantize_panels(t: jnp.ndarray, *, mode: str = "block",
                    fmt_name: str = "fp4_e2m1", pow2: bool = False,
                    sr: bool = False, seed: Optional[jnp.ndarray] = None,
                    trans: bool = False, block: int = 128,
                    real_dims: Optional[Tuple[int, int]] = None,
                    collect_stats: bool = False,
                    interpret: Optional[bool] = None):
    """Public quantize-pass entry point (phase 1 standalone).

    ``t``: stored 2-D operand, dims multiples of ``block``; effective
    orientation is ``t.T`` under ``trans``; groups reduce along axis 1 of
    the effective operand (the LHS convention).  Returns the QDQ'd
    effective operand, or ``(values, stats_vec)`` with ``collect_stats``
    (see ``finalize_quant_stats``).  ``real_dims`` = unpadded (rows, cols)
    of the effective operand, used only to mask padding out of the stats.
    """
    assert mode in QUANT_MODES and mode != "pass", mode
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m_eff, k_eff = (t.shape[1], t.shape[0]) if trans else t.shape
    m_real, k_real = real_dims if real_dims is not None else (m_eff, k_eff)
    q, stats = _quantize_operand(
        t, mode=mode, fmt=FORMATS[fmt_name], pow2=pow2, sr=sr, seed=seed,
        trans=trans, emit_trans=False, block=block, m_real=m_real,
        k_real=k_real, collect_stats=collect_stats, interpret=interpret)
    return (q, stats) if collect_stats else q


# ---------------------------------------------------------------------------
# Phase 2: tiled matmul pass (no quantization left in here)
# ---------------------------------------------------------------------------

def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k, trans_a, trans_b):
    """One (bm, bn) output tile at K-step pl.program_id(2)."""
    @pl.when(pl.program_id(2) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    at = a_ref[...]
    if trans_a:
        at = at.T
    bt = b_ref[...]
    if trans_b:
        bt = bt.T
    acc_ref[...] += jnp.dot(at, bt, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _tiled_matmul(a: jnp.ndarray, b: jnp.ndarray, *, trans_a: bool,
                  trans_b: bool, bm: int, bn: int, bk: int,
                  interpret: bool) -> jnp.ndarray:
    """y = A' @ B' with (bm, bn, bk) MXU tiling, f32 VMEM accumulation.

    Operands arrive either pre-quantized in effective orientation (trans
    flag False) or as ``pass``-mode stored arrays read transposed via the
    BlockSpec index maps (no HBM transpose, as before).
    """
    m, k = (a.shape[1], a.shape[0]) if trans_a else a.shape
    kb, n = (b.shape[1], b.shape[0]) if trans_b else b.shape
    assert k == kb, (a.shape, b.shape, trans_a, trans_b)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    kernel = functools.partial(_mm_kernel, n_k=k // bk, trans_a=trans_a,
                               trans_b=trans_b)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bk, bm),
                         (lambda i, j, kk: (kk, i))) if trans_a
            else pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk),
                         (lambda i, j, kk: (j, kk))) if trans_b
            else pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)


# ---------------------------------------------------------------------------
# Orchestrator
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "a_mode", "b_mode", "a_fmt", "b_fmt", "a_pow2", "b_pow2", "a_sr", "b_sr",
    "trans_a", "trans_b", "block", "bm", "bn", "bk", "real_dims",
    "collect_stats", "interpret"))
def fused_qmm(a: jnp.ndarray, b: jnp.ndarray, *,
              a_mode: str = "block", b_mode: str = "tile",
              a_fmt: str = "fp4_e2m1", b_fmt: str = "fp4_e2m1",
              a_pow2: bool = False, b_pow2: bool = False,
              a_sr: bool = False, b_sr: bool = False,
              seed_a: Optional[jnp.ndarray] = None,
              seed_b: Optional[jnp.ndarray] = None,
              trans_a: bool = False, trans_b: bool = False,
              block: int = 128,
              bm: Optional[int] = None, bn: Optional[int] = None,
              bk: Optional[int] = None,
              real_dims: Optional[Tuple[int, int, int]] = None,
              collect_stats: bool = False,
              interpret: bool = False):
    """y = Q(A') @ Q(B') through the two-phase pipeline; A' = a^T under
    ``trans_a`` (same for B').  Effective shapes A': (M, K), B': (K, N);
    all dims must be multiples of ``block`` (the ops.py wrapper pads).

    Each operand is QDQ'd exactly once by the quantize pass (phase 1) —
    ``pass`` operands skip it — then the matmul pass (phase 2) runs with
    ``(bm, bn, bk)`` tiling decoupled from the quant group (auto-picked
    from the shapes when omitted).  ``a_sr``/``b_sr`` enable in-kernel
    stochastic rounding (seeds required); ``real_dims`` = unpadded
    (M, K, N) for stats masking; with ``collect_stats`` returns
    ``(y, (stats_a, stats_b))`` where pass-mode slots are None.
    """
    assert a_mode in QUANT_MODES and b_mode in QUANT_MODES, (a_mode, b_mode)
    m, k = (a.shape[1], a.shape[0]) if trans_a else a.shape
    kb, n = (b.shape[1], b.shape[0]) if trans_b else b.shape
    assert k == kb, (a.shape, b.shape, trans_a, trans_b)
    assert m % block == 0 and k % block == 0 and n % block == 0, \
        (m, k, n, block)
    mr, kr, nr = real_dims if real_dims is not None else (m, k, n)

    stats_a = stats_b = None
    mm_trans_a, mm_trans_b = trans_a, trans_b
    if a_mode != "pass":
        # LHS quant orientation (M, K) == effective orientation.
        a, stats_a = _quantize_operand(
            a, mode=a_mode, fmt=FORMATS[a_fmt], pow2=a_pow2,
            sr=a_sr and not FORMATS[a_fmt].passthrough, seed=seed_a,
            trans=trans_a, emit_trans=False, block=block, m_real=mr,
            k_real=kr, collect_stats=collect_stats, interpret=interpret)
        mm_trans_a = False
    if b_mode != "pass":
        # RHS quant orientation is (N, K) — groups reduce over K, which is
        # axis 0 of the effective (K, N) — so the pass reads the stored
        # array transposed iff NOT trans_b, and emits (K, N) back.
        b, stats_b = _quantize_operand(
            b, mode=b_mode, fmt=FORMATS[b_fmt], pow2=b_pow2,
            sr=b_sr and not FORMATS[b_fmt].passthrough, seed=seed_b,
            trans=not trans_b, emit_trans=True, block=block, m_real=nr,
            k_real=kr, collect_stats=collect_stats, interpret=interpret)
        mm_trans_b = False

    bm = bm if bm is not None else _pick_tile(m, block)
    bn = bn if bn is not None else _pick_tile(n, block)
    bk = bk if bk is not None else _pick_tile(k, block)
    y = _tiled_matmul(a, b, trans_a=mm_trans_a, trans_b=mm_trans_b,
                      bm=bm, bn=bn, bk=bk, interpret=interpret)
    if collect_stats:
        return y, (stats_a, stats_b)
    return y


@functools.partial(jax.jit, static_argnames=("x_fmt", "w_fmt", "block",
                                             "interpret"))
def fp4_matmul(x: jnp.ndarray, w: jnp.ndarray, *,
               x_fmt: str = "fp4_e2m1", w_fmt: str = "fp4_e2m1",
               block: int = 128, interpret: bool = False) -> jnp.ndarray:
    """y = Q_blk(x) @ Q_tile(w) (the paper's fwd FFN matmul).

    x: (M, K), w: (K, N); M, K, N must be multiples of ``block``
    (the ops.py wrapper pads).  Returns x.dtype.  Kept as the historical
    fwd-only entry point; a thin specialization of ``fused_qmm``.
    """
    return fused_qmm(x, w, a_mode="block", b_mode="tile", a_fmt=x_fmt,
                     b_fmt=w_fmt, block=block, interpret=interpret)
