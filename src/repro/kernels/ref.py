"""Pure-jnp oracles for every Pallas kernel (the allclose references).

Rounding comes from the shared helper ``kernels.rounding`` (the same
bit-exact integer RTN/SR codec the kernels lower) — no private
``_round_tile`` copy lives here.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import QuantSpec, _blocked_view, qdq
from repro.kernels.rounding import group_scale, round_to_grid

__all__ = ["quantize_blockwise_ref", "fp4_matmul_ref", "qmm_ref",
           "qdq_grid_ref", "quantize_panels_ref",
           "pallas_qmatmul_grads_ref", "flash_attention_ref"]


def qdq_grid_ref(x2d: jnp.ndarray, spec: QuantSpec, reduction_axis: int,
                 noise: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """QDQ through the SHARED grid codec, with injectable SR noise.

    Same group/scale math as ``core.quantize.quantize_dequantize`` but
    rounding via ``kernels.rounding.round_to_grid`` — given the same
    uniform noise the kernel drew, this reproduces in-kernel stochastic
    rounding bit-exactly (the kernel's noise is keyed by global element
    coordinate, so it is tiling-invariant and reconstructible outside).
    Shapes must already be multiples of ``spec.block`` (no padding here).
    """
    if spec.is_passthrough:
        return x2d
    rows, cols = x2d.shape
    xb, axes, _, _ = _blocked_view(x2d, spec.granularity, spec.block,
                                   reduction_axis)
    mag = jnp.abs(xb)
    if spec.granularity == "tensor":
        amax = jnp.max(mag)
    elif spec.granularity == "token":
        amax = jnp.max(mag, axis=reduction_axis, keepdims=True)
    else:
        amax = jnp.max(mag, axis=axes, keepdims=True)
    scale = group_scale(amax, spec.format, spec.pow2_scale).astype(x2d.dtype)
    nb = noise.reshape(xb.shape) if noise is not None else None
    y = round_to_grid(xb / scale, spec.format, nb) * scale
    return y.reshape(rows, cols).astype(x2d.dtype)


def quantize_panels_ref(t: jnp.ndarray, spec: QuantSpec, *,
                        trans: bool = False,
                        noise: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Oracle for ``kernels.fp4_matmul.quantize_panels``: QDQ of the
    effective (post-transpose) operand, reduction axis 1."""
    eff = t.T if trans else t
    return qdq_grid_ref(eff, spec, 1, noise)


def quantize_blockwise_ref(x: jnp.ndarray, fmt_name: str,
                           block: int = 128) -> jnp.ndarray:
    """Per-(block x block)-tile QDQ of a 2-D array (f32 math)."""
    spec = QuantSpec(fmt_name, "tile", block)
    return qdq(x.astype(jnp.float32), spec, 1).astype(x.dtype)


def fp4_matmul_ref(x: jnp.ndarray, w: jnp.ndarray,
                   x_fmt: str = "fp4_e2m1", w_fmt: str = "fp4_e2m1",
                   block: int = 128) -> jnp.ndarray:
    """Oracle for the fused block-quantized matmul.

    x: (M, K) quantized per-(1 x block) along K;
    w: (K, N) quantized per-(block x block) tiles;
    QDQ in the INPUT dtype (the training path's discipline — the kernel
    matches it elementwise in bf16 too), accumulation in f32 (the MXU
    convention).
    """
    xq = qdq(x, QuantSpec(x_fmt, "block", block), 1)
    wq = qdq(w, QuantSpec(w_fmt, "tile", block), 0)
    return jnp.dot(xq, wq, preferred_element_type=jnp.float32
                   ).astype(x.dtype)


def qmm_ref(a: jnp.ndarray, b: jnp.ndarray,
            spec_a: QuantSpec, spec_b: QuantSpec, *,
            trans_a: bool = False, trans_b: bool = False) -> jnp.ndarray:
    """Oracle for ``kernels.ops.pallas_qmm``: unfused QDQ of the effective
    (possibly transposed) operands + f32-accumulated dot.

    Identical math to ``core.qlinear.dot_qdq`` with the transposes
    materialized — the role-parameterized fused kernel must match this for
    every (spec_a, spec_b) it claims to realize.
    """
    ae = a.T if trans_a else a
    be = b.T if trans_b else b
    aq = qdq(ae, spec_a, 1)
    bq = qdq(be, spec_b, 0)
    return jnp.dot(aq, bq, preferred_element_type=jnp.float32
                   ).astype(a.dtype)


def pallas_qmatmul_grads_ref(x: jnp.ndarray, w: jnp.ndarray, g: jnp.ndarray,
                             recipe) -> tuple:
    """Oracle for ``pallas_qmatmul``'s backward: (dx, dw) under cotangent
    ``g``, with each backward matmul quantized per the recipe in its own
    orientation (dgrad reduces over N, wgrad over M)."""
    dx = qmm_ref(g, w, recipe.dgrad_g, recipe.dgrad_w, trans_b=True)
    dw = qmm_ref(x, g, recipe.wgrad_x, recipe.wgrad_g, trans_a=True)
    return dx.astype(x.dtype), dw.astype(w.dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True) -> jnp.ndarray:
    """Naive softmax attention oracle.  q/k/v: (B, S, H, D) (kv maybe fewer
    heads; repeated here)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
