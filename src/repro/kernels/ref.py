"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import FORMATS, round_to_format
from repro.core.quantize import QuantSpec, qdq

__all__ = ["quantize_blockwise_ref", "fp4_matmul_ref", "qmm_ref",
           "pallas_qmatmul_grads_ref", "flash_attention_ref"]


def quantize_blockwise_ref(x: jnp.ndarray, fmt_name: str,
                           block: int = 128) -> jnp.ndarray:
    """Per-(block x block)-tile QDQ of a 2-D array (f32 math)."""
    spec = QuantSpec(fmt_name, "tile", block)
    return qdq(x.astype(jnp.float32), spec, 1).astype(x.dtype)


def fp4_matmul_ref(x: jnp.ndarray, w: jnp.ndarray,
                   x_fmt: str = "fp4_e2m1", w_fmt: str = "fp4_e2m1",
                   block: int = 128) -> jnp.ndarray:
    """Oracle for the fused block-quantized matmul.

    x: (M, K) quantized per-(1 x block) along K;
    w: (K, N) quantized per-(block x block) tiles;
    QDQ in the INPUT dtype (the training path's discipline — the kernel
    matches it elementwise in bf16 too), accumulation in f32 (the MXU
    convention).
    """
    xq = qdq(x, QuantSpec(x_fmt, "block", block), 1)
    wq = qdq(w, QuantSpec(w_fmt, "tile", block), 0)
    return jnp.dot(xq, wq, preferred_element_type=jnp.float32
                   ).astype(x.dtype)


def qmm_ref(a: jnp.ndarray, b: jnp.ndarray,
            spec_a: QuantSpec, spec_b: QuantSpec, *,
            trans_a: bool = False, trans_b: bool = False) -> jnp.ndarray:
    """Oracle for ``kernels.ops.pallas_qmm``: unfused QDQ of the effective
    (possibly transposed) operands + f32-accumulated dot.

    Identical math to ``core.qlinear.dot_qdq`` with the transposes
    materialized — the role-parameterized fused kernel must match this for
    every (spec_a, spec_b) it claims to realize.
    """
    ae = a.T if trans_a else a
    be = b.T if trans_b else b
    aq = qdq(ae, spec_a, 1)
    bq = qdq(be, spec_b, 0)
    return jnp.dot(aq, bq, preferred_element_type=jnp.float32
                   ).astype(a.dtype)


def pallas_qmatmul_grads_ref(x: jnp.ndarray, w: jnp.ndarray, g: jnp.ndarray,
                             recipe) -> tuple:
    """Oracle for ``pallas_qmatmul``'s backward: (dx, dw) under cotangent
    ``g``, with each backward matmul quantized per the recipe in its own
    orientation (dgrad reduces over N, wgrad over M)."""
    dx = qmm_ref(g, w, recipe.dgrad_g, recipe.dgrad_w, trans_b=True)
    dw = qmm_ref(x, g, recipe.wgrad_x, recipe.wgrad_g, trans_a=True)
    return dx.astype(x.dtype), dw.astype(w.dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True) -> jnp.ndarray:
    """Naive softmax attention oracle.  q/k/v: (B, S, H, D) (kv maybe fewer
    heads; repeated here)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
