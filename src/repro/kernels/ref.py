"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import FORMATS, round_to_format
from repro.core.quantize import QuantSpec, qdq

__all__ = ["quantize_blockwise_ref", "fp4_matmul_ref", "flash_attention_ref"]


def quantize_blockwise_ref(x: jnp.ndarray, fmt_name: str,
                           block: int = 128) -> jnp.ndarray:
    """Per-(block x block)-tile QDQ of a 2-D array (f32 math)."""
    spec = QuantSpec(fmt_name, "tile", block)
    return qdq(x.astype(jnp.float32), spec, 1).astype(x.dtype)


def fp4_matmul_ref(x: jnp.ndarray, w: jnp.ndarray,
                   x_fmt: str = "fp4_e2m1", w_fmt: str = "fp4_e2m1",
                   block: int = 128) -> jnp.ndarray:
    """Oracle for the fused block-quantized matmul.

    x: (M, K) quantized per-(1 x block) along K;
    w: (K, N) quantized per-(block x block) tiles;
    accumulation in f32 (the MXU convention).
    """
    xq = qdq(x.astype(jnp.float32), QuantSpec(x_fmt, "block", block), 1)
    wq = qdq(w.astype(jnp.float32), QuantSpec(w_fmt, "tile", block), 0)
    return jnp.dot(xq, wq, preferred_element_type=jnp.float32
                   ).astype(x.dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True) -> jnp.ndarray:
    """Naive softmax attention oracle.  q/k/v: (B, S, H, D) (kv maybe fewer
    heads; repeated here)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
