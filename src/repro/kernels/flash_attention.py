"""Pallas TPU kernel: causal flash attention forward (online softmax).

The paper keeps the attention math in high precision via FlashAttention
(App. B); this is its TPU-native form.  Tiling:

  grid = (batch*heads, Sq/bq, Sk/bk) with the KV dim innermost; the
  (m, l, acc) running statistics live in VMEM scratch and are revisited
  across KV steps, so each Q tile makes exactly one HBM pass over K/V.
  Causal masking is positional; fully-masked KV tiles are skipped at trace
  time via the grid (bk tiles beyond the causal frontier are not visited
  thanks to the index_map clamping).

Backward runs through the pure-jnp chunked implementation (custom_vjp in
ops.py) — identical math, so gradients are exact w.r.t. this forward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fp4_matmul import compiler_params

__all__ = ["flash_attention_fwd"]

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale, bq, bk, causal):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    safe_m = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    corr = jnp.exp(m_prev - safe_m) * (m_prev > NEG_INF / 2)
    p = jnp.exp(s - safe_m)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, bq: int = 128, bk: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    """q/k/v: (BH, S, D) flattened batch*heads (GQA repeat done by ops.py).
    S must be a multiple of bq/bk; D MXU-aligned (128 ideally)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % bq == 0 and sk % bk == 0
    scale = 1.0 / np.sqrt(d)
    kernel = functools.partial(_fa_kernel, scale=scale, bq=bq, bk=bk,
                               causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
