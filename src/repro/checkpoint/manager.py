"""Atomic, retained, optionally-async checkpointing (no orbax dependency).

Layout:  <dir>/step_<N>/  arrays.npz  +  manifest.json
Writes go to ``<dir>/.tmp_step_<N>`` then ``os.replace`` — a crash mid-save
never corrupts the latest checkpoint (the restore path only considers
directories with a valid manifest).  Retention keeps the newest K.

The saved pytree is flattened to ``path/like/this`` npz keys; restore
rebuilds against a reference pytree structure (so dtypes/Shapes are
validated at load).  ``elastic_reshard`` re-maps arrays onto a new mesh —
see distributed.elastic.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager", "save_pytree", "load_pytree"]


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_pytree(tree, directory: str, extra: Optional[dict] = None) -> None:
    """Atomic save of a pytree (+ json-able ``extra`` metadata)."""
    parent = os.path.dirname(os.path.abspath(directory)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = os.path.join(parent, ".tmp_" + os.path.basename(directory)
                       + f"_{os.getpid()}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {"keys": sorted(flat), "time": time.time(),
                "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.replace(tmp, directory)


def load_pytree(directory: str, like) -> Any:
    """Restore a pytree saved by ``save_pytree`` against a reference
    structure ``like`` (arrays or ShapeDtypeStructs)."""
    with np.load(os.path.join(directory, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, ref in leaves_like:
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {ref.shape}")
        out.append(arr.astype(ref.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


def load_manifest(directory: str) -> dict:
    with open(os.path.join(directory, "manifest.json")) as f:
        return json.load(f)


class CheckpointManager:
    """step-indexed checkpoints with retention and optional async save."""

    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- helpers -----------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.dir):
            if not name.startswith("step_"):
                continue
            full = os.path.join(self.dir, name)
            if not os.path.exists(os.path.join(full, "manifest.json")):
                continue  # incomplete/corrupt -> ignored (fault tolerance)
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save / restore ----------------------------------------------------

    def save(self, step: int, tree, extra: Optional[dict] = None) -> None:
        tree = jax.tree.map(lambda x: np.asarray(x), tree)  # device -> host
        extra = dict(extra or {}, step=step)

        def do_save():
            save_pytree(tree, self._step_dir(step), extra)
            self._retain()

        self.wait()
        if self.async_save:
            self._thread = threading.Thread(target=do_save, daemon=True)
            self._thread.start()
        else:
            do_save()

    def wait(self) -> None:
        """Block until any in-flight async save finishes."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, like, step: Optional[int] = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self._step_dir(step)
        return load_pytree(d, like), load_manifest(d)["extra"]

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
