"""Fault-tolerant checkpointing: atomic, retained, async, reshardable."""
from repro.checkpoint.manager import CheckpointManager, save_pytree, load_pytree

__all__ = ["CheckpointManager", "save_pytree", "load_pytree"]
