import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) cell on the
production meshes, and extract the roofline terms from the compiled module.

The two lines above MUST stay first: jax locks the device count on first
init, and only the dry-run wants 512 placeholder devices.

Modes:
  --mesh multi   2x16x16 (pod,data,model): proves the "pod" axis shards.
                 Layers run under lax.scan (small HLO, bounded compile time).
  --mesh single  16x16 (data,model): the roofline pass.  Layers are
                 UNROLLED so cost_analysis is exact (XLA counts while bodies
                 once); interior scans get analytic corrections
                 (analysis.roofline.scan_flop_corrections).

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax

from repro.analysis.hlo import collective_bytes
from repro.analysis.roofline import (model_flops, roofline_terms,
                                     scan_flop_corrections)
from repro.configs.base import (SHAPE_CELLS, ShapeCell, TrainConfig,
                                get_config)
from repro.distributed.sharding import default_rules
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.nn.layers import set_sharding_context
from repro.train.train_step import make_train_step

ASSIGNED_ARCHS = [
    "nemotron-4-15b", "llama3.2-3b", "h2o-danube-3-4b", "granite-34b",
    "mixtral-8x22b", "olmoe-1b-7b", "llama-3.2-vision-90b", "whisper-base",
    "mamba2-780m", "jamba-1.5-large-398b",
]


def adapt_config(cfg, cell: ShapeCell, mesh_kind: str, unroll: bool):
    """Cell/mode-specific compile strategy knobs (math unchanged)."""
    kw = dict(scan_layers=not unroll)
    # seq-chunked loss for big-vocab training cells
    if cell.kind == "train" and cfg.vocab_size >= 32000:
        kw["loss_chunk"] = 256
    # fewer, larger KV chunks for very long caches (scan trip count)
    if cell.seq_len > 100_000:
        kw["attention_chunk"] = 8192
    elif cell.seq_len > 8192:
        kw["attention_chunk"] = 2048
    if cfg.max_seq_len < cell.seq_len:
        kw["max_seq_len"] = cell.seq_len + 8
    return cfg.replace(**kw)


def lower_cell(arch: str, cell: ShapeCell, mesh_kind: str, *,
               recipe: str = "paper_fp4", unroll: Optional[bool] = None,
               rules_overrides=None, act_overrides=None, fsdp: bool = True,
               seq_parallel: bool = False, free_head_shard: bool = False,
               cfg_patch=None):
    """Returns (lowered, model, cfg, mesh, chips) for one cell."""
    from repro.core.recipe import RECIPES
    multi = mesh_kind == "multi"
    if unroll is None:
        unroll = not multi
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.size
    cfg = adapt_config(get_config(arch), cell, mesh_kind, unroll)
    if cfg_patch is not None:
        cfg = cfg_patch(cfg)
    model = build_model(cfg)
    rules = default_rules(mesh, cfg, fsdp=fsdp, seq_parallel=seq_parallel,
                          free_head_shard=free_head_shard,
                          overrides=rules_overrides,
                          act_overrides=act_overrides)
    rec = RECIPES[recipe]
    set_sharding_context(rules)
    try:
        with mesh:
            if cell.kind == "train":
                tcfg = TrainConfig(recipe=recipe, total_steps=1000,
                                   global_batch=cell.global_batch,
                                   seq_len=cell.seq_len)
                step_fn = make_train_step(model, tcfg, rec, jit=False)
                args, shardings = specs_lib.train_inputs(
                    model, tcfg, cell, rules)
                lowered = jax.jit(step_fn, in_shardings=shardings,
                                  donate_argnums=(0, 1)).lower(*args)
            elif cell.kind == "prefill":
                def prefill(params, batch, cache):
                    return model.prefill(params, batch, cache, rec)
                args, shardings = specs_lib.prefill_inputs(model, cell, rules)
                lowered = jax.jit(prefill,
                                  in_shardings=shardings).lower(*args)
            else:  # decode
                def decode(params, token, cache):
                    return model.decode_step(params, token, cache, rec)
                args, shardings = specs_lib.decode_inputs(model, cell, rules)
                lowered = jax.jit(decode, in_shardings=shardings,
                                  donate_argnums=(2,)).lower(*args)
    finally:
        set_sharding_context(None)
    return lowered, model, cfg, mesh, chips


def _compile_metrics(lowered) -> dict:
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "coll": coll,
        "mem": mem,
    }


def roofline_probe(arch: str, cell: ShapeCell, *, recipe: str = "paper_fp4",
                   **lower_kw) -> dict:
    """Exact per-layer-group cost via calibrated differencing.

    XLA cost_analysis is exact only for unrolled layers, but fully unrolling
    100-layer configs is compile-prohibitive.  Since the stack repeats with
    period p, we lower UNROLLED probes with 1x and 3x period-groups; the
    difference isolates exactly 2 groups worth of FLOPs/bytes/collectives,
    which extrapolates to the full depth:

        total(L) = probe(p) + (L/p - 1) * (probe(3p) - probe(p)) / 2

    Small stacks (<= 12 layers) are fully unrolled instead (exact).
    """
    cfg0 = get_config(arch)
    p = cfg0.scan_period()
    L = cfg0.n_layers
    exact = L <= max(12, 3 * p)
    out = {"mode": "exact_unroll" if exact else "probe_extrapolated",
           "period": p}
    user_patch = lower_kw.pop("cfg_patch", None)
    if exact:
        lowered, model, cfg, mesh, chips = lower_cell(
            arch, cell, "single", recipe=recipe, unroll=True,
            cfg_patch=user_patch, **lower_kw)
        m = _compile_metrics(lowered)
        out.update(flops=m["flops"], bytes=m["bytes"],
                   coll_eff=m["coll"]["effective_total"],
                   coll_eff_bf16eq=m["coll"]["effective_total_bf16eq"],
                   coll_raw=m["coll"]["raw_total"], mem=m["mem"],
                   chips=chips, cfg=cfg, model=model)
        return out
    metrics = {}
    for k in (1, 3):
        def patched(cfg, n=k * p):
            cfg = cfg.replace(n_layers=n)
            return user_patch(cfg) if user_patch else cfg
        lowered, model, cfg, mesh, chips = lower_cell(
            arch, cell, "single", recipe=recipe, unroll=True,
            cfg_patch=patched, **lower_kw)
        metrics[k] = _compile_metrics(lowered)
    n_groups = L // p
    g = {key: (metrics[3][key] - metrics[1][key]) / 2.0
         for key in ("flops", "bytes")}
    ce = (metrics[3]["coll"]["effective_total"]
          - metrics[1]["coll"]["effective_total"]) / 2.0
    cb = (metrics[3]["coll"]["effective_total_bf16eq"]
          - metrics[1]["coll"]["effective_total_bf16eq"]) / 2.0
    cr = (metrics[3]["coll"]["raw_total"]
          - metrics[1]["coll"]["raw_total"]) / 2.0
    out.update(
        flops=metrics[1]["flops"] + g["flops"] * (n_groups - 1),
        bytes=metrics[1]["bytes"] + g["bytes"] * (n_groups - 1),
        coll_eff=metrics[1]["coll"]["effective_total"] + ce * (n_groups - 1),
        coll_eff_bf16eq=(metrics[1]["coll"]["effective_total_bf16eq"]
                         + cb * (n_groups - 1)),
        coll_raw=metrics[1]["coll"]["raw_total"] + cr * (n_groups - 1),
        mem=metrics[3]["mem"], chips=chips,
        per_group={"flops": g["flops"], "bytes": g["bytes"],
                   "coll_eff": ce},
        probes={k: {"flops": m["flops"], "bytes": m["bytes"],
                    "coll_eff": m["coll"]["effective_total"]}
                for k, m in metrics.items()},
        cfg=get_config(arch), model=None)
    return out


def run_cell(arch: str, cell: ShapeCell, mesh_kind: str, *,
             recipe: str = "paper_fp4", verbose: bool = True,
             **lower_kw) -> dict:
    """Lower + compile + extract dry-run artifacts for one cell."""
    import importlib
    from repro.models.model import build_model as _bm
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))
    skip = getattr(mod, "SKIP_CELLS", {})
    if cell.name in skip:
        return {"arch": arch, "cell": cell.name, "mesh": mesh_kind,
                "status": "skipped", "reason": skip[cell.name]}
    t0 = time.time()
    user_patch = lower_kw.get("cfg_patch")
    if mesh_kind == "single":
        pr = roofline_probe(arch, cell, recipe=recipe, **lower_kw)
        t2 = time.time()
        cfg = adapt_config(pr["cfg"], cell, "single", True)
        if user_patch is not None:
            cfg = user_patch(cfg)
        model = _bm(cfg)
        chips = pr["chips"]
        mem = pr["mem"]
        hlo_flops, hlo_bytes = pr["flops"], pr["bytes"]
        coll = {"effective_total": pr["coll_eff"],
                "effective_total_bf16eq": pr.get("coll_eff_bf16eq",
                                                 pr["coll_eff"]),
                "raw_total": pr["coll_raw"]}
        extra = {"probe": {k: v for k, v in pr.items()
                           if k in ("mode", "period", "per_group",
                                    "probes")}}
        t1 = t0
    else:
        lowered, model, cfg, mesh, chips = lower_cell(
            arch, cell, mesh_kind, recipe=recipe, **lower_kw)
        t1 = time.time()
        m = _compile_metrics(lowered)
        t2 = time.time()
        mem = m["mem"]
        hlo_flops, hlo_bytes = m["flops"], m["bytes"]
        coll = m["coll"]
        extra = {"note": "scan mode: cost_analysis counts while bodies "
                         "once; roofline fields informational only"}

    corr = scan_flop_corrections(cfg, cell, chips)
    n_active = model.active_param_count()
    mflops = model_flops(cfg, cell, n_active)
    terms = roofline_terms(
        hlo_flops=hlo_flops,
        hlo_bytes=hlo_bytes,
        collective_bytes_eff=coll["effective_total"],
        chips=chips, flop_correction=corr["total"],
        model_flops_total=mflops)
    terms["collective_s_bf16eq"] = (
        coll.get("effective_total_bf16eq", coll["effective_total"]) / 50e9)

    result = {
        "arch": arch, "cell": cell.name, "mesh": mesh_kind,
        "recipe": recipe, "status": "ok", "chips": chips,
        "params_total": model.param_count(),
        "params_active": n_active,
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_gb": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes)
                / 1e9, 3),
        },
        "collectives": coll,
        "flop_corrections": corr,
        "roofline": terms,
        **extra,
    }
    if verbose:
        print(f"[{arch} / {cell.name} / {mesh_kind}] "
              f"compile {t2-t1:.1f}s  "
              f"mem/chip {result['memory']['peak_estimate_gb']:.2f} GB  "
              f"flops/chip {terms['hlo_flops_per_chip']:.3e}  "
              f"bottleneck {terms['bottleneck']}  "
              f"bound {terms['step_time_lower_bound_s']*1e3:.1f} ms  "
              f"useful-flop ratio {terms.get('useful_flops_ratio', 0):.3f}")
        print("  memory_analysis:", mem)
        print("  collectives:", {k: f"{v:.3e}" for k, v in coll.items()
                                 if isinstance(v, (int, float))})
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=[c.name for c in SHAPE_CELLS])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--recipe", default="paper_fp4")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    args = ap.parse_args()

    cells = {c.name: c for c in SHAPE_CELLS}
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for c in SHAPE_CELLS:
                for m in meshes:
                    todo.append((a, c, m))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for m in meshes:
            todo.append((args.arch, cells[args.shape], m))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, cell, m in todo:
        tag = f"{arch}__{cell.name}__{m}__{args.recipe}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"[skip existing] {tag}")
            continue
        try:
            res = run_cell(arch, cell, m, recipe=args.recipe,
                           fsdp=not args.no_fsdp,
                           seq_parallel=args.seq_parallel)
        except Exception as e:  # record failures as artifacts too
            traceback.print_exc()
            res = {"arch": arch, "cell": cell.name, "mesh": m,
                   "status": "error", "error": f"{type(e).__name__}: {e}"}
            failures += 1
        with open(path, "w") as f:
            json.dump(res, f, indent=2)
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
