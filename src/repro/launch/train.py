"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch llama-125m \
        --recipe paper_fp4 --steps 1000 --batch 16 --seq 256 \
        --ckpt /tmp/ck --resume

On a real cluster this process runs once per host (jax.distributed); the
index-addressed data pipeline and GSPMD sharding need no other coordination.
"""
import argparse


from repro.configs.base import TrainConfig, get_config
from repro.data import make_pipeline
from repro.models import build_model
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config")
    ap.add_argument("--recipe", default="paper_fp4")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--grad-compression", default="none")
    ap.add_argument("--mesh", default="",
                    help="mesh shape, e.g. '4,2' (axes data,model); empty "
                         "= single-device step")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="replicate embed params over the data axes "
                         "(required with --grad-compression fp8)")
    ap.add_argument("--telemetry-jsonl", default="",
                    help="JSONL metrics log (written off the critical "
                         "path by the async writer)")
    ap.add_argument("--cost-calibration", default="",
                    help="measured speed-factor JSON from "
                         "'kernel_bench --measure-speed' (empty = paper "
                         "theory factors)")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.reduced:
        import importlib
        cfg = importlib.import_module(
            "repro.configs."
            + args.arch.replace("-", "_").replace(".", "_")).REDUCED
    else:
        cfg = get_config(args.arch)
    model = build_model(cfg)
    mesh_shape = (tuple(int(d) for d in args.mesh.split(","))
                  if args.mesh else None)
    mesh_axes = (("data", "model")[:len(mesh_shape)]
                 if mesh_shape else None)
    tcfg = TrainConfig(
        recipe=args.recipe, total_steps=args.steps,
        global_batch=args.batch, seq_len=args.seq, learning_rate=args.lr,
        microbatch=args.microbatch, grad_compression=args.grad_compression,
        mesh_shape=mesh_shape, mesh_axes=mesh_axes, fsdp=not args.no_fsdp,
        checkpoint_every=args.ckpt_every, checkpoint_dir=args.ckpt,
        telemetry_jsonl=args.telemetry_jsonl,
        cost_calibration=args.cost_calibration,
        log_every=max(args.steps // 20, 1))
    pipe = make_pipeline(args.data, cfg.vocab_size, args.seq, args.batch)
    trainer = Trainer(model, tcfg, pipe)
    state = trainer.resume() if args.resume else None
    state = trainer.train(state, log=print)
    print("eval:", trainer.evaluate(state))
    summ = trainer.step_time_summary()
    if summ.get("steps"):
        print("step-time: "
              + " ".join(f"{k}={summ[k]:.1f}" for k in
                         ("p50_ms", "p95_ms", "p99_ms") if k in summ)
              + (f" tokens/s={summ['tokens_per_sec']:.0f}"
                 if "tokens_per_sec" in summ else "")
              + (f" mfu={summ['mfu']:.4f}" if "mfu" in summ else ""))


if __name__ == "__main__":
    main()
