"""Production mesh definition (a FUNCTION — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax

from repro.distributed.mesh import make_mesh

__all__ = ["make_production_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=("auto",) * len(axes))
