"""input_specs(): ShapeDtypeStruct stand-ins for every model input, per
(arch x shape-cell), plus matching in_shardings — no device allocation.

Modality frontends are STUBS per the assignment: [audio] cells provide
precomputed frame embeddings, [vlm] cells precomputed patch embeddings.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell, TrainConfig
from repro.distributed.sharding import ShardingRules, opt_state_shardings
from repro.models.model import Model
from repro.train.train_step import compression_state_sharding, make_optimizer

__all__ = ["train_batch_specs", "train_inputs", "prefill_inputs",
           "decode_inputs"]


def train_batch_specs(cfg: ModelConfig, batch: int, seq: int,
                      rules: ShardingRules) -> Tuple[Dict, Dict]:
    """(batch ShapeDtypeStructs, batch shardings) for a training step."""
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    specs: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
        "targets": jax.ShapeDtypeStruct((batch, seq), i32),
    }
    if cfg.family == "vlm":
        specs["vision"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), dt)
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frames, cfg.d_model), dt)
    shardings = {k: rules.activation_sharding(
        ("batch",) + (None,) * (len(v.shape) - 1), v.shape)
        for k, v in specs.items()}
    return specs, shardings


def train_inputs(model: Model, tcfg: TrainConfig, cell: ShapeCell,
                 rules: ShardingRules):
    """Abstract (args, in_shardings) for
    train_step(params, opt_state, comp_state, batch, step)."""
    cfg = model.cfg
    params = model.abstract_params(jnp.float32)
    p_shard = rules.param_shardings(model.param_specs())
    opt = make_optimizer(model, tcfg)
    opt_state = jax.eval_shape(opt.init, params)
    o_shard = opt_state_shardings(opt_state, params, p_shard, rules.mesh)
    if tcfg.grad_compression == "fp8":
        # Error-feedback residuals: with a >1 data axis the manual-DP
        # compressed reduction keeps one residual per data shard (leading
        # replica axis), matching init_compression_state(dp_size=...).
        dp = rules.dp_size
        lead = (dp,) if dp > 1 else ()
        comp = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(lead + p.shape, jnp.float32),
            params)
        c_shard = compression_state_sharding(rules, p_shard)
    else:
        comp = jax.ShapeDtypeStruct((), jnp.float32)
        c_shard = rules.replicated()
    batch, b_shard = train_batch_specs(cfg, cell.global_batch, cell.seq_len,
                                       rules)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    args = (params, opt_state, comp, batch, step)
    shardings = (p_shard, o_shard, c_shard, b_shard, rules.replicated())
    return args, shardings


def _serve_common(model: Model, cell: ShapeCell, rules: ShardingRules,
                  cache_len: int):
    cfg = model.cfg
    dt = jnp.dtype(cfg.dtype)
    params = model.abstract_params(dt)  # serving: weights already in bf16
    p_shard = rules.param_shardings(model.param_specs())
    cache = model.cache_spec(cell.global_batch, cache_len, dt)
    cache_shard = {"stack": rules.cache_shardings(cache["stack"]),
                   "length": rules.replicated()}
    return params, p_shard, cache, cache_shard


def prefill_inputs(model: Model, cell: ShapeCell, rules: ShardingRules):
    """Abstract (args, in_shardings) for prefill(params, batch, cache)."""
    cfg = model.cfg
    params, p_shard, cache, cache_shard = _serve_common(
        model, cell, rules, cell.seq_len)
    batch, b_shard = train_batch_specs(cfg, cell.global_batch, cell.seq_len,
                                       rules)
    batch.pop("targets"), b_shard.pop("targets")
    return (params, batch, cache), (p_shard, b_shard, cache_shard)


def decode_inputs(model: Model, cell: ShapeCell, rules: ShardingRules):
    """Abstract (args, in_shardings) for decode_step(params, token, cache).

    The cache holds ``cell.seq_len`` tokens (the cell's defining property:
    one new token against a seq_len-deep cache).
    """
    params, p_shard, cache, cache_shard = _serve_common(
        model, cell, rules, cell.seq_len)
    token = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
    t_shard = rules.activation_sharding(("batch", None), token.shape)
    return (params, token, cache), (p_shard, t_shard, cache_shard)
