"""Serving launcher CLI: continuous-batched generation.

    PYTHONPATH=src python -m repro.launch.serve --arch tiny --requests 6 \
        --weight-quant fp4_e2m1
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.recipe import RECIPES
from repro.models import build_model
from repro.train.serving_runtime import (ContinuousBatcher,
                                         quantize_weights_for_serving)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--weight-quant", default="none",
                    help="none | fp8_e4m3 | fp4_e2m1 (weight-only serving)")
    args = ap.parse_args()

    if args.reduced:
        import importlib
        cfg = importlib.import_module(
            "repro.configs."
            + args.arch.replace("-", "_").replace(".", "_")).REDUCED
    else:
        cfg = get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.weight_quant != "none":
        params = quantize_weights_for_serving(model, params,
                                              args.weight_quant)
        print(f"weights quantized to {args.weight_quant} (per-block-128)")

    rng = np.random.default_rng(0)
    batcher = ContinuousBatcher(model, params, n_slots=args.slots,
                                max_len=256, recipe=RECIPES["bf16"])
    ids = []
    for _ in range(args.requests):
        n = int(rng.integers(4, 24))
        prompt = rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
        ids.append(batcher.submit(prompt, args.max_new))
    t0 = time.time()
    out = batcher.run()
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    print(f"served {len(out)} requests / {total} tokens in {dt:.1f}s "
          f"({total / dt:.1f} tok/s) with {args.slots} slots")
    for rid in ids[:3]:
        print(f"  req {rid}: {out[rid]}")


if __name__ == "__main__":
    main()
