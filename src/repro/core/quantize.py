"""Scaled quantize-dequantize (QDQ) with the paper's granularities.

The paper (App. A, Eq. 1-7) quantizes each operand of a matmul by (1) choosing
a scale ``alpha = amax / Q_max`` over some *granularity group*, (2) clipping to
``alpha * Q_max`` and (3) rounding on the low-bit grid.  Granularities used in
the paper (§3.2, App. B):

  * ``tensor``  — one scale for the whole operand.
  * ``token``   — one scale per row of the left matmul operand (per-token);
                  the same code gives per-*channel* scaling when applied to a
                  weight along its output dimension.
  * ``block``   — one scale per (1 x B) segment along the reduction dimension
                  (the fine-grained activation scaling; B = 128).
  * ``tile``    — one scale per (B x B) tile (the per-block *weight* scaling;
                  B = 128, matching the TPU MXU tile).

All QDQ here is *simulated* low-precision (quantize -> dequantize in the input
dtype), as in the paper (§6).  The scale can optionally be constrained to a
power of two (hardware-friendly; exact rescaling on exponent-only units).

Conventions: operands are 2-D ``(rows, cols)`` with the *reduction axis given
explicitly*, so the same primitive serves x (M,K), w (K,N), and their
transposes in the backward matmuls.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F

__all__ = ["QuantSpec", "qdq", "quantize_dequantize", "compute_scale",
           "scale_from_amax", "pow2_floor", "underflow_rate", "BF16_SPEC",
           "scale_logical_axes", "qdq_scope_name"]

_EPS = 1e-12


def qdq_scope_name(spec: "QuantSpec") -> str:
    """``jax.named_scope`` label marking a simulated quantize of ``spec``.

    ``qdq_`` + the spec's canonical string with non-identifier characters
    folded to ``_`` (named scopes flow into HLO ``op_name`` metadata, so
    the label stays regex-friendly), e.g. ``fp4_e2m1@block128:sr`` ->
    ``qdq_fp4_e2m1_block128_sr``.  ``analysis.qlint`` keys its
    role-safety checks on this prefix.
    """
    return "qdq_" + re.sub(r"[^0-9A-Za-z_]+", "_", spec.to_str())


def pow2_floor(s: jnp.ndarray) -> jnp.ndarray:
    """Largest power of two <= ``s`` (positive normal f32), exactly.

    Clears the mantissa field of the f32 bit pattern — bit-exact (unlike
    ``exp2(floor(log2(s)))``, whose XLA:CPU lowering is off by >1 ulp at
    some arguments) and free of transcendentals, so the identical code
    lowers inside Pallas kernels.
    """
    bits = jax.lax.bitcast_convert_type(s.astype(jnp.float32), jnp.int32)
    return jax.lax.bitcast_convert_type(bits & np.int32(0x7F800000),
                                        jnp.float32)


def scale_from_amax(amax: jnp.ndarray, fmt: F.FloatFormat,
                    pow2: bool = False,
                    qmax: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Per-group scale ``alpha = amax / Q_max`` (Eq. 3) in f32, eps-floored.

    THE scale formula — the unfused QDQ path, the fused Pallas pipeline and
    the telemetry stats all call this, so their scales agree bitwise.
    ``qmax``: optional *traced* Q_max scalar.  Inside a Pallas kernel the
    divisor must be traced (an SMEM operand): XLA strength-reduces float
    division by a compile-time constant to reciprocal-multiply there (1 ulp
    off, and not idempotent), while a traced divisor lowers to true IEEE
    division — bitwise identical to this formula outside the kernel.
    """
    div = qmax if qmax is not None else np.float32(fmt.max_value)
    s = jnp.maximum(amax.astype(jnp.float32), _EPS) / div
    if pow2:
        s = pow2_floor(s)
    return s


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """How to quantize one matmul operand.

    Attributes:
      fmt: target ``FloatFormat`` name (key into ``formats.FORMATS``).
      granularity: ``tensor`` | ``token`` | ``block`` | ``tile``.
      block: group size along the reduction axis (and both axes for ``tile``).
      pow2_scale: round the scale down to a power of two.
      stochastic: use stochastic rounding (beyond-paper option).
      amax_clip_quantile: None for plain amax scaling. (Hook for clamping
        strategies like Wang et al. 2025; not used by this paper's recipe.)
    """

    fmt: str = "bf16"
    granularity: str = "tensor"
    block: int = 128
    pow2_scale: bool = False
    stochastic: bool = False

    @property
    def format(self) -> F.FloatFormat:
        return F.FORMATS[self.fmt]

    @property
    def is_passthrough(self) -> bool:
        return self.format.passthrough and self.fmt != "fp16"

    def short(self) -> str:
        if self.is_passthrough:
            return self.fmt
        return f"{self.fmt}/{self.granularity}"

    # -- compact string syntax (plans / checkpoints / telemetry) ----------
    #
    #   <fmt>                       passthrough, e.g. "bf16"
    #   <fmt>@<gran>[<block>]       e.g. "fp8_e4m3@token", "fp4_e2m1@block128"
    #   ...[:pow2][:sr]             scale/rounding flags
    #
    # The canonical serialization used by ``core.recipe.PrecisionPlan``'s
    # dict form; ``from_str(to_str(s)) == s`` for every realizable spec
    # (passthrough specs canonicalize their irrelevant granularity away).

    def to_str(self) -> str:
        if self.is_passthrough:
            s = self.fmt
        else:
            s = f"{self.fmt}@{self.granularity}"
            if self.granularity in ("block", "tile"):
                s += str(self.block)
        if self.pow2_scale:
            s += ":pow2"
        if self.stochastic:
            s += ":sr"
        return s

    def with_fmt(self, fmt: str,
                 stochastic: Optional[bool] = None) -> "QuantSpec":
        """Same scaling spec (granularity/block/pow2), different storage
        format — the role-subset plan edits (``PrecisionPlan.demote``)
        lower e.g. an ``fp8_e5m2@token`` gradient operand to its
        ``fp4_e2m1@token`` counterpart without touching how it is scaled.
        ``stochastic`` overrides the rounding mode (None keeps it)."""
        if fmt not in F.FORMATS:
            raise ValueError(f"unknown format {fmt!r}")
        sr = self.stochastic if stochastic is None else stochastic
        out = dataclasses.replace(self, fmt=fmt, stochastic=sr)
        return self if out == self else out

    @classmethod
    def from_str(cls, s: str) -> "QuantSpec":
        head, *flags = s.split(":")
        bad = set(flags) - {"pow2", "sr"}
        if bad:
            raise ValueError(f"unknown QuantSpec flags {sorted(bad)} in {s!r}")
        pow2, sr = "pow2" in flags, "sr" in flags
        if "@" in head:
            fmt, gran = head.split("@", 1)
            m = re.fullmatch(r"([a-z]+)(\d+)?", gran)
            if not m or m.group(1) not in ("tensor", "token", "block",
                                           "tile"):
                raise ValueError(f"bad granularity {gran!r} in {s!r}")
            spec = cls(fmt, m.group(1), int(m.group(2) or 128),
                       pow2_scale=pow2, stochastic=sr)
        else:
            spec = cls(head, pow2_scale=pow2, stochastic=sr)
        if spec.fmt not in F.FORMATS:
            raise ValueError(f"unknown format {spec.fmt!r} in {s!r}")
        return spec


BF16_SPEC = QuantSpec("bf16")


def _blocked_view(x2d: jnp.ndarray, granularity: str, block: int,
                  reduction_axis: int):
    """Reshape x to a blocked layout and return (xb, reduce_axes, orig_rows,
    orig_cols).  Pads the blocked axes up to a block multiple.

    Blocked layouts (scales stay SMALL — never broadcast to full size):
      tensor: x as-is,                 scale ()
      token : x as-is,                 scale keepdims over reduction axis
      block : (rows, nb, B) [red=1] or (nb, B, cols) [red=0]
      tile  : (rb, B, cb, B)
    """
    rows, cols = x2d.shape
    if granularity in ("tensor", "token"):
        return x2d, None, rows, cols
    if granularity == "block":
        axis = reduction_axis
        n = x2d.shape[axis]
        nb = -(-n // block)
        pad = nb * block - n
        if pad:
            pw = [(0, 0), (0, 0)]
            pw[axis] = (0, pad)
            x2d = jnp.pad(x2d, pw)
        if axis == 1:
            return x2d.reshape(rows, nb, block), (2,), rows, cols
        return x2d.reshape(nb, block, cols), (1,), rows, cols
    if granularity == "tile":
        rb, cb = -(-rows // block), -(-cols // block)
        pr, pc = rb * block - rows, cb * block - cols
        if pr or pc:
            x2d = jnp.pad(x2d, ((0, pr), (0, pc)))
        xb = x2d.reshape(rb, block, cb, block)
        return xb, (1, 3), rows, cols
    raise ValueError(f"unknown granularity: {granularity!r}")


def compute_scale(x2d: jnp.ndarray, spec: QuantSpec,
                  reduction_axis: int) -> jnp.ndarray:
    """Per-group scale ``alpha = amax / Q_max`` (Eq. 3) in BLOCKED layout
    (small tensor, broadcastable against the blocked view of x)."""
    fmt = spec.format
    xb, axes, _, _ = _blocked_view(x2d, spec.granularity, spec.block,
                                   reduction_axis)
    mag = jnp.abs(xb)  # amax in input dtype (exact); scale math f32 on the
    if spec.granularity == "tensor":        # small per-group tensor only.
        amax = jnp.max(mag)
    elif spec.granularity == "token":
        amax = jnp.max(mag, axis=reduction_axis, keepdims=True)
    else:
        amax = jnp.max(mag, axis=axes, keepdims=True)
    return scale_from_amax(amax, fmt, spec.pow2_scale)


def scale_logical_axes(granularity: str, reduction_axis: int,
                       axes: Tuple[Optional[str], Optional[str]]):
    """Logical axis names for a blocked scale tensor (SPMD scale placement).

    ``axes`` are the 2-D operand's logical (row, col) names.  The policy
    (mesh-native FP4 training): block/tile scale grids are sharded WITH
    their operand's reduction axis — the per-128-group scale count along a
    dim inherits that dim's logical name, so it partitions wherever the
    operand's K-panels do — while token/tensor scales collapse the
    reduction axis entirely and are replicated along it.
    """
    row_l, col_l = axes
    if granularity == "tensor":
        return ()
    if granularity == "token":
        return (row_l, None) if reduction_axis == 1 else (None, col_l)
    if granularity == "block":
        return ((row_l, col_l, None) if reduction_axis == 1
                else (row_l, None, col_l))
    if granularity == "tile":
        return (row_l, None, col_l, None)
    raise ValueError(f"unknown granularity: {granularity!r}")


def _hint_scale(scale: jnp.ndarray, spec: QuantSpec, reduction_axis: int,
                axes) -> jnp.ndarray:
    """Constrain the scale tensor's sharding when a context is installed.

    The lazy import breaks the core -> nn -> core cycle; it only runs at
    trace time (no context, no ``axes`` -> zero-cost no-op)."""
    if axes is None:
        return scale
    from repro.nn.layers import get_sharding_context
    ctx = get_sharding_context()
    if ctx is None:
        return scale
    logical = scale_logical_axes(spec.granularity, reduction_axis,
                                 tuple(axes))
    if len(logical) != scale.ndim:
        return scale
    sharding = ctx.activation_sharding(logical, scale.shape)
    if sharding is None:
        return scale
    return jax.lax.with_sharding_constraint(scale, sharding)


def quantize_dequantize(
    x2d: jnp.ndarray,
    spec: QuantSpec,
    reduction_axis: int,
    *,
    stochastic_key: Optional[jax.Array] = None,
    axes: Optional[Tuple[Optional[str], Optional[str]]] = None,
) -> jnp.ndarray:
    """Simulated low-precision representation of ``x2d`` (Eq. 1-7).

    All full-size intermediates stay in the input dtype (bf16 end-to-end in
    training); only the small per-group scales are f32.  ``axes`` optionally
    names the operand's logical (row, col) axes for SPMD scale placement
    (see ``scale_logical_axes``); unnamed or context-free calls are
    unchanged.
    """
    if spec.is_passthrough:
        return x2d
    # qdq_<spec> named scope: static metadata marking every simulated
    # quantize in the jaxpr/HLO (analysis.qlint keys role-safety checks on
    # it); the computation is bit-identical with or without the scope.
    with jax.named_scope(qdq_scope_name(spec)):
        fmt = spec.format
        if spec.fmt == "fp16":
            return F.round_to_format(x2d, fmt)
        rows, cols = x2d.shape
        xb, _, _, _ = _blocked_view(x2d, spec.granularity, spec.block,
                                    reduction_axis)
        scale = compute_scale(x2d, spec, reduction_axis)
        scale = _hint_scale(scale, spec, reduction_axis,
                            axes).astype(x2d.dtype)
        key = stochastic_key if spec.stochastic else None
        y = F.round_to_format(xb / scale, fmt, stochastic_key=key) * scale
        if spec.granularity in ("block", "tile"):
            if spec.granularity == "block" and reduction_axis == 1:
                y = y.reshape(-1, y.shape[1] * y.shape[2])
            elif spec.granularity == "block":
                y = y.reshape(y.shape[0] * y.shape[1], -1)
            else:
                y = y.reshape(y.shape[0] * y.shape[1],
                              y.shape[2] * y.shape[3])
            y = y[:rows, :cols]
        return y.astype(x2d.dtype)


# Short alias used throughout the codebase.
qdq = quantize_dequantize


def underflow_rate(x: jnp.ndarray, spec: QuantSpec,
                   reduction_axis: int = -1) -> jnp.ndarray:
    """Fraction of nonzero inputs that quantize to exactly zero.

    Reproduces the Fig. 1(b) diagnostic: the paper reports ~8.6% gradient and
    ~18% activation underflow for FP4 vs FP8/FP16.
    """
    x2d = x.reshape(-1, x.shape[-1])
    ax = reduction_axis % 2
    y = quantize_dequantize(x2d, spec, ax)
    nonzero = jnp.abs(x2d) > 0
    under = nonzero & (y == 0)
    return jnp.sum(under) / jnp.maximum(jnp.sum(nonzero), 1)
