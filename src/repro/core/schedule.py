"""Target-precision training schedule (§3.3), expressed as a plan transform.

Two stages: (1) low-precision pretraining for the first ``1 - frac`` of
steps, (2) a short high-precision ("target precision") continuation for the
final ``frac`` (paper: 5-10%) that lets the model shed quantization-noise
adaptations.  The trainer keeps one jitted train_step per *plan* and
switches at the boundary — switching is a Python-level decision so each
graph stays static.

Since the layer-resolved refactor the schedule operates on
``PrecisionPlan``s: stage 2 is :func:`core.recipe.stage2_plan` applied to
the stage-1 plan (every layer row and the head swap to the target plan's
cells), so a depth-graded stage-1 plan still collapses to the uniform
target at the boundary.  The stage-2 target is configurable
(``TrainConfig.target_recipe`` threads the knob; default the BF16
baseline) so the Table-3 schedule ablations — e.g. an FP8 stage 2 — are
runnable.  ``telemetry.controller`` generalizes the fixed-fraction switch
to a telemetry-driven one.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import recipe as recipe_lib
from repro.core.recipe import PrecisionPlan

__all__ = ["TargetPrecisionSchedule"]


@dataclasses.dataclass(frozen=True)
class TargetPrecisionSchedule:
    plan: PrecisionPlan
    total_steps: int
    target: Optional[PrecisionPlan] = None

    @property
    def switch_step(self) -> int:
        frac = self.plan.target_precision_frac
        if frac <= 0.0:
            return self.total_steps  # never switch
        return int(round(self.total_steps * (1.0 - frac)))

    def plan_at(self, step: int) -> PrecisionPlan:
        """Active plan for ``step`` (0-indexed)."""
        if step >= self.switch_step:
            return self.target_plan
        return self.plan

    @property
    def target_plan(self) -> PrecisionPlan:
        """Stage-2 plan (default: the full-precision BF16 baseline),
        applied as a transform of the stage-1 plan."""
        if self.target is not None:
            tgt = self.target
        else:
            tgt = PrecisionPlan.uniform(recipe_lib.RECIPES["bf16"],
                                        self.plan.n_layers)
        return recipe_lib.stage2_plan(self.plan, tgt)

    def is_switch_boundary(self, step: int) -> bool:
        return step == self.switch_step
