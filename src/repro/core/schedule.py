"""Target-precision training schedule (§3.3).

Two stages: (1) low-precision pretraining for the first ``1 - frac`` of
steps, (2) a short high-precision ("target precision") continuation for the
final ``frac`` (paper: 5-10%) that lets the model shed quantization-noise
adaptations.  The trainer keeps two jitted train_steps (one per recipe) and
switches at the boundary — switching is a Python-level decision so each graph
stays static.

The stage-2 recipe is configurable (``target``, default the BF16 baseline;
``TrainConfig.target_recipe`` threads the knob) so the Table-3 schedule
ablations — e.g. an FP8 stage 2 — are runnable.  ``telemetry.controller``
generalizes the fixed-fraction switch to a telemetry-driven one.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import recipe as recipe_lib

__all__ = ["TargetPrecisionSchedule"]


@dataclasses.dataclass(frozen=True)
class TargetPrecisionSchedule:
    recipe: recipe_lib.PrecisionRecipe
    total_steps: int
    target: Optional[recipe_lib.PrecisionRecipe] = None

    @property
    def switch_step(self) -> int:
        frac = self.recipe.target_precision_frac
        if frac <= 0.0:
            return self.total_steps  # never switch
        return int(round(self.total_steps * (1.0 - frac)))

    def recipe_at(self, step: int) -> recipe_lib.PrecisionRecipe:
        """Active recipe for ``step`` (0-indexed)."""
        if step >= self.switch_step:
            return self.target_recipe
        return self.recipe

    @property
    def target_recipe(self) -> recipe_lib.PrecisionRecipe:
        """Stage-2 recipe (default: the full-precision BF16 baseline)."""
        if self.target is not None:
            return self.target
        return recipe_lib.RECIPES["bf16"]

    def is_switch_boundary(self, step: int) -> bool:
        return step == self.switch_step
