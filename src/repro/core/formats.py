"""Low-bit floating-point formats and round-to-nearest codecs.

Implements the quantization grid of App. A of the paper (Eq. 2-7):
a signed float format with ``e`` exponent bits and ``m`` mantissa bits has

    Q_max = (2 - 2^-m) * 2^emax            (Eq. 2, emax = 2^e - b - 1)

and values are rounded onto the per-binade grid with step ``2^(floor(log2|x|) - m)``
(Eq. 5-7).  Subnormals (exponent below the minimum normal) round on the fixed
grid ``2^(emin - m)``.

Formats follow OCP / FP8-paper conventions the paper cites
(Micikevicius et al. 2022; Liu et al. 2023):

  * FP4  E2M1 : bias 1, max 6.0, min subnormal 0.5  -> {0, .5, 1, 1.5, 2, 3, 4, 6}
  * FP8  E4M3 : bias 7, max 448 (S.1111.111 reserved for NaN -> max mantissa 1.75)
  * FP8  E5M2 : bias 15, max 57344 (IEEE-consistent specials)
  * FP6  E2M3 / E3M2 : OCP MX auxiliary formats (used in ablations)
  * BF16/FP16/FP32 : passthrough (treated as "infinite" grid here; FP16 clips)

Everything is pure jnp and differentiable-free (meant to be wrapped in STE by
``core.qlinear``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "FloatFormat",
    "FP4_E2M1",
    "FP4_E1M2",
    "FP6_E2M3",
    "FP6_E3M2",
    "FP8_E4M3",
    "FP8_E5M2",
    "BF16",
    "FP16",
    "FP32",
    "FORMATS",
    "round_to_format",
    "format_values",
]


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """A signed low-bit float format (no inf; optionally reserved NaN encodings).

    Attributes:
      name: canonical name, e.g. ``fp4_e2m1``.
      ebits / mbits: exponent and mantissa field widths.
      max_value: largest finite magnitude (Q_max in Eq. 2; format-specific
        because E4M3 reserves the top mantissa pattern).
      emin: minimum *normal* exponent (unbiased). Subnormal step is
        ``2^(emin - mbits)``.
      bits: total storage bits (1 + ebits + mbits).
      passthrough: if True the codec is an identity (bf16/fp32 handled by XLA).
    """

    name: str
    ebits: int
    mbits: int
    max_value: float
    emin: int
    passthrough: bool = False

    @property
    def bits(self) -> int:
        return 1 + self.ebits + self.mbits

    @property
    def min_subnormal(self) -> float:
        return 2.0 ** (self.emin - self.mbits)

    @property
    def min_normal(self) -> float:
        return 2.0 ** self.emin

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.name


def _mk(name, e, m, max_value, emin, passthrough=False):
    return FloatFormat(name=name, ebits=e, mbits=m, max_value=max_value,
                       emin=emin, passthrough=passthrough)


# bias convention: bias = 2^(e-1) - 1 except E2M1/E1M2 which use bias=1 (OCP MX).
FP4_E2M1 = _mk("fp4_e2m1", 2, 1, 6.0, 0)          # ±{0,.5,1,1.5,2,3,4,6}
FP4_E1M2 = _mk("fp4_e1m2", 1, 2, 3.5, 0)          # ablation-only variant
FP6_E2M3 = _mk("fp6_e2m3", 2, 3, 7.5, 0)          # OCP MX FP6
FP6_E3M2 = _mk("fp6_e3m2", 3, 2, 28.0, -2)        # OCP MX FP6
FP8_E4M3 = _mk("fp8_e4m3", 4, 3, 448.0, -6)       # OCP FP8 (no inf, 1 NaN)
FP8_E5M2 = _mk("fp8_e5m2", 5, 2, 57344.0, -14)    # OCP FP8 (IEEE-like)
BF16 = _mk("bf16", 8, 7, 3.38953139e38, -126, passthrough=True)
FP16 = _mk("fp16", 5, 10, 65504.0, -14, passthrough=True)
FP32 = _mk("fp32", 8, 23, 3.4028235e38, -126, passthrough=True)

FORMATS = {
    f.name: f
    for f in (FP4_E2M1, FP4_E1M2, FP6_E2M3, FP6_E3M2, FP8_E4M3, FP8_E5M2,
              BF16, FP16, FP32)
}


def format_values_host(fmt: FloatFormat) -> list:
    """Non-negative representable values of a low-bit format as host floats.

    Pure Python — safe to call inside a jit/scan trace (no staged ops), which
    is what lets ``core.packed`` build its codec tables lazily.
    """
    assert not fmt.passthrough and fmt.bits <= 8
    vals = [0.0]
    # subnormals
    step = fmt.min_subnormal
    for i in range(1, 2 ** fmt.mbits):
        vals.append(i * step)
    # normals
    e = fmt.emin
    while True:
        base = 2.0 ** e
        for i in range(2 ** fmt.mbits):
            v = base * (1.0 + i / (2 ** fmt.mbits))
            if v > fmt.max_value:
                return sorted(set(vals))
            vals.append(v)
        e += 1


def format_values(fmt: FloatFormat) -> jnp.ndarray:
    """Enumerate every non-negative representable value of a low-bit format.

    Used by tests to verify that ``round_to_format`` lands exactly on the grid.
    Only sensible for formats with <= 8 bits.
    """
    return jnp.asarray(format_values_host(fmt), dtype=jnp.float32)


def round_to_format(
    x: jnp.ndarray,
    fmt: FloatFormat,
    *,
    stochastic_key: Optional[jax.Array] = None,
) -> jnp.ndarray:
    """Round ``x`` elementwise onto ``fmt``'s grid (Eq. 5-7), with clipping.

    The input is assumed to already be scaled (see ``core.quantize``); values
    beyond ``fmt.max_value`` saturate (the paper's Clip, Eq. 4).

    If ``stochastic_key`` is given, uses unbiased stochastic rounding instead
    of round-to-nearest-even.  (Beyond-paper option — the paper uses RTN.)
    """
    if fmt.passthrough:
        if fmt is FP16:
            return jnp.clip(x, -fmt.max_value, fmt.max_value)
        return x

    # Math follows the input dtype (bf16 in, bf16 through) — intermediate
    # buffers stay half-size and fuse on TPU; grids/steps are exact powers of
    # two so bf16 arithmetic only perturbs near-tie roundings.  f32 inputs
    # get exact f32 rounding (used by tests/oracles).
    orig_dtype = x.dtype
    xf = x if jnp.issubdtype(x.dtype, jnp.floating) else x.astype(jnp.float32)
    sign = jnp.sign(xf)
    mag = jnp.abs(xf)
    mag = jnp.minimum(mag, jnp.asarray(fmt.max_value, xf.dtype))

    # Exponent of the containing binade, floored at the min normal exponent so
    # that subnormals share the fixed grid 2^(emin - m).
    safe = jnp.maximum(mag, jnp.asarray(fmt.min_subnormal * 0.25, xf.dtype))
    e = jnp.floor(jnp.log2(safe))
    e = jnp.maximum(e, jnp.asarray(fmt.emin, xf.dtype))
    # ldexp, not exp2: XLA:CPU's exp2 is off by >1 ulp even at integer
    # arguments, which would knock subnormals off the exact grid.
    step = jnp.ldexp(jnp.asarray(1.0, xf.dtype),
                     (e - fmt.mbits).astype(jnp.int32))

    t = mag / step
    if stochastic_key is not None:
        noise = jax.random.uniform(stochastic_key, shape=x.shape,
                                   dtype=xf.dtype)
        q = jnp.floor(t + noise)
    else:
        q = jnp.round(t)  # round-half-to-even, IEEE default
    out = sign * q * step
    # Rounding up at a binade edge (e.g. 5.9 -> 6) stays on-grid; rounding the
    # max binade up can exceed max_value -> saturate again.
    out = jnp.clip(out, -fmt.max_value, fmt.max_value)
    return out.astype(orig_dtype)
