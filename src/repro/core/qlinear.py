"""Quantized linear primitive with per-role precision (the paper's §3 core).

``qmatmul(x2d, w, key, recipe)`` computes a matmul whose forward and two
backward matmuls each quantize their operands according to an independent
``QuantSpec`` (see ``core.recipe``).  Gradients flow by straight-through
estimation (App. B: the gradient of the quantized weight is passed to the
master weight unchanged).

The public entry point ``qlinear`` folds arbitrary leading batch dims.
Stochastic rounding (beyond-paper option) consumes the ``key`` argument; RTN
recipes ignore it, and passthrough (bf16) recipes lower to a single dot —
important for clean roofline baselines.

Notes on backward quantization orientation: each backward matmul is treated
as a first-class matmul with its own reduction axis, and operand scales are
grouped relative to *that* matmul (per-token = per non-reduction vector;
per-block = (1 x 128) along the reduction axis; per-tile = 128x128).  These
are exactly the groupings an FP4/FP8 tensor-core epilogue can rescale.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantize import QuantSpec, qdq
from repro.core.recipe import MatmulRecipe

__all__ = ["qmatmul", "qlinear", "dot_qdq"]


def _maybe_key(key_data: Optional[jnp.ndarray], spec: QuantSpec,
               salt: int) -> Optional[jax.Array]:
    if key_data is None or not spec.stochastic:
        return None
    key = jax.random.wrap_key_data(key_data.astype(jnp.uint32))
    return jax.random.fold_in(key, salt)


def dot_qdq(a: jnp.ndarray, b: jnp.ndarray,
            spec_a: QuantSpec, spec_b: QuantSpec,
            *, key_data: Optional[jnp.ndarray] = None,
            salt: int = 0, precision=None) -> jnp.ndarray:
    """QDQ both operands of ``a @ b`` then run the dot in the input dtype.

    ``a``: (M, K), ``b``: (K, N).  Reduction axes: 1 for a, 0 for b.
    """
    aq = qdq(a, spec_a, reduction_axis=1,
             stochastic_key=_maybe_key(key_data, spec_a, salt))
    bq = qdq(b, spec_b, reduction_axis=0,
             stochastic_key=_maybe_key(key_data, spec_b, salt + 1))
    return jax.lax.dot(aq, bq, precision=precision)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def qmatmul(x: jnp.ndarray, w: jnp.ndarray, key_data: jnp.ndarray,
            recipe: MatmulRecipe) -> jnp.ndarray:
    """y = Q(x) @ Q(w) with recipe-defined backward quantization.

    x: (M, K) activations, w: (K, N) weights, key_data: uint32[2] raw PRNG
    key material (only consumed by stochastic QuantSpecs), y: (M, N).
    """
    return dot_qdq(x, w, recipe.fwd_x, recipe.fwd_w, key_data=key_data,
                   salt=0)


def _qmatmul_fwd(x, w, key_data, recipe):
    y = qmatmul(x, w, key_data, recipe)
    return y, (x, w, key_data)


def _qmatmul_bwd(recipe, res, g):
    x, w, key_data = res
    # dgrad: dx = Q(g) @ Q(w^T); reduction over N.
    dx = dot_qdq(g, w.T, recipe.dgrad_g, recipe.dgrad_w, key_data=key_data,
                 salt=2)
    # wgrad: dw = Q(x^T) @ Q(g); reduction over M (tokens).
    dw = dot_qdq(x.T, g, recipe.wgrad_x, recipe.wgrad_g, key_data=key_data,
                 salt=4)
    return (dx.astype(x.dtype), dw.astype(w.dtype),
            jnp.zeros_like(key_data))


qmatmul.defvjp(_qmatmul_fwd, _qmatmul_bwd)


def _zero_key() -> jnp.ndarray:
    # NOTE: must be constructed fresh per trace (a cached global would leak
    # tracers out of scan/remat scopes); XLA constant-folds it anyway.
    return jnp.zeros((2,), jnp.uint32)


def qlinear(x: jnp.ndarray, w: jnp.ndarray, recipe: MatmulRecipe,
            *, bias: Optional[jnp.ndarray] = None,
            key_data: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Linear layer over the last axis of ``x`` with per-role quantization.

    ``x``: (..., K), ``w``: (K, N) -> (..., N).
    """
    lead: Tuple[int, ...] = x.shape[:-1]
    k = x.shape[-1]
    if recipe.is_passthrough:
        y = x.reshape(-1, k) @ w
    else:
        if key_data is None:
            key_data = _zero_key()
        y = qmatmul(x.reshape(-1, k), w, key_data, recipe)
    y = y.reshape(*lead, w.shape[-1])
    if bias is not None:
        y = y + bias
    return y
