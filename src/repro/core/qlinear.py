"""Quantized linear primitive with per-role precision (the paper's §3 core).

``qmatmul(x2d, w, key, recipe)`` computes a matmul whose forward and two
backward matmuls each quantize their operands according to an independent
``QuantSpec`` (see ``core.recipe``).  The ``MatmulRecipe`` argument is one
resolved cell of a layer-resolved ``PrecisionPlan`` (layer x class), so
this primitive is depth-agnostic; per-layer precision is decided one level
up, in ``models.stack``.  Gradients flow by straight-through estimation
(App. B: the gradient of the quantized weight is passed to the master
weight unchanged).

Two implementations share the same recipe semantics:

  * ``qmatmul``        — unfused QDQ + ``lax.dot`` (simulation reference);
  * ``pallas_qmatmul`` — fwd, dgrad and wgrad each run through the fused
    Pallas pipeline (``kernels.fp4_matmul.fused_qmm``; streaming
    quantize-into-the-MXU-loop single pass by default, autotuned tiling),
    with transposed-operand variants so the backward matmuls quantize
    relative to their own reduction axes without materializing
    ``w^T``/``x^T`` in HBM.  Stochastic-rounding specs are
    kernel-realizable (in-kernel PRNG noise seeded from ``key_data``);
    roles the kernel cannot realize (fp16 clipping, non-128 blocks) fall
    back to the QDQ path for that role only.
  * ``pallas_qmatmul_two_pass`` — the same contract pinned to the PR-3
    two-pass reference pipeline (bit-identical at equal tiling).

The public entry point ``qlinear`` folds arbitrary leading batch dims and
selects the implementation via ``impl`` ('qdq' | 'pallas' |
'pallas_two_pass', threaded from ``ModelConfig.linear_impl``).  Stochastic rounding (beyond-paper option)
consumes the ``key`` argument; RTN recipes ignore it, and passthrough (bf16)
recipes lower to a single dot — important for clean roofline baselines.

Notes on backward quantization orientation: each backward matmul is treated
as a first-class matmul with its own reduction axis, and operand scales are
grouped relative to *that* matmul (per-token = per non-reduction vector;
per-block = (1 x 128) along the reduction axis; per-tile = 128x128).  These
are exactly the groupings an FP4/FP8 tensor-core epilogue can rescale.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import routing
from repro.core.packed import PackedTensor
from repro.core.quantize import BF16_SPEC, QuantSpec, qdq
from repro.core.recipe import MatmulRecipe
from repro.telemetry import collect as telemetry
from repro.telemetry.profiler import graph_span

__all__ = ["qmatmul", "pallas_qmatmul", "pallas_qmatmul_two_pass",
           "pallas_qmatmul_stats", "qlinear", "packed_linear", "dot_qdq",
           "kernel_quant_mode", "kernel_unsupported_reason", "matmul_impl"]


def _role_scope(role: Optional[str]):
    """``jax.named_scope`` marker attributing ops to a matmul role in the
    jaxpr/HLO (``qrole_fwd`` / ``qrole_dgrad`` / ``qrole_wgrad``).  Pure
    metadata: the computation is bit-identical with or without it."""
    if role is None:
        return contextlib.nullcontext()
    return jax.named_scope(f"qrole_{role}")


def _maybe_key(key_data: Optional[jnp.ndarray], spec: QuantSpec,
               salt: int) -> Optional[jax.Array]:
    if key_data is None or not spec.stochastic:
        return None
    key = jax.random.wrap_key_data(key_data.astype(jnp.uint32))
    return jax.random.fold_in(key, salt)


def dot_qdq(a: jnp.ndarray, b: jnp.ndarray,
            spec_a: QuantSpec, spec_b: QuantSpec,
            *, key_data: Optional[jnp.ndarray] = None,
            salt: int = 0, precision=None,
            axes_a=None, axes_b=None,
            role: Optional[str] = None, route: str = "qdq",
            reasons: Tuple[str, ...] = (), cell=None) -> jnp.ndarray:
    """QDQ both operands of ``a @ b`` then run the dot in the input dtype.

    ``a``: (M, K), ``b``: (K, N).  Reduction axes: 1 for a, 0 for b.
    ``axes_a``/``axes_b``: optional logical (row, col) names for SPMD scale
    placement (see ``quantize.scale_logical_axes``).

    ``role``/``route``/``reasons``/``cell`` are static observability
    metadata: when a routing census is active (``core.routing.capture``)
    the call records one event, and the whole dot is wrapped in a
    ``qrole_<role>`` named scope for jaxpr/HLO attribution.  ``route`` is
    ``"qdq"`` for a configured QDQ impl and ``"qdq_fallback"`` (with
    structured ``reasons``) when a pallas impl could not realize the
    specs; ``cell`` carries the (layer, class) labels captured in scope
    by ``qlinear`` (custom_vjp rules trace out of scope).
    """
    if role is not None and routing.active() is not None:
        routing.record(
            role, route, spec_a.to_str(), spec_b.to_str(), reasons=reasons,
            sr_a=bool(spec_a.stochastic) and key_data is not None,
            sr_b=bool(spec_b.stochastic) and key_data is not None,
            cell=cell)
    with _role_scope(role):
        with graph_span("quantize"):   # phase metadata for attribution
            aq = qdq(a, spec_a, reduction_axis=1,
                     stochastic_key=_maybe_key(key_data, spec_a, salt),
                     axes=axes_a)
            bq = qdq(b, spec_b, reduction_axis=0,
                     stochastic_key=_maybe_key(key_data, spec_b, salt + 1),
                     axes=axes_b)
        return jax.lax.dot(aq, bq, precision=precision)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def qmatmul(x: jnp.ndarray, w: jnp.ndarray, key_data: jnp.ndarray,
            recipe: MatmulRecipe, axes=None, cell=None) -> jnp.ndarray:
    """y = Q(x) @ Q(w) with recipe-defined backward quantization.

    x: (M, K) activations, w: (K, N) weights, key_data: uint32[2] raw PRNG
    key material (only consumed by stochastic QuantSpecs), y: (M, N).
    ``axes``: optional logical names ``(row, k, n)`` of the matmul dims —
    static metadata steering operand/scale sharding in all three matmuls
    (fwd here, dgrad/wgrad in the vjp, each in its own orientation).
    ``cell``: optional static (layer, class) labels for the routing
    census (``core.routing``) — metadata only, no effect on the graph.
    """
    ax = axes or (None, None, None)
    return dot_qdq(x, w, recipe.fwd_x, recipe.fwd_w, key_data=key_data,
                   salt=0, axes_a=(ax[0], ax[1]), axes_b=(ax[1], ax[2]),
                   role="fwd", cell=cell)


def _qmatmul_fwd(x, w, key_data, recipe, axes, cell):
    y = qmatmul(x, w, key_data, recipe, axes, cell)
    return y, (x, w, key_data)


def _qmatmul_bwd(recipe, axes, cell, res, g):
    x, w, key_data = res
    row, k, n = axes or (None, None, None)
    # dgrad: dx = Q(g) @ Q(w^T); reduction over N.
    dx = dot_qdq(g, w.T, recipe.dgrad_g, recipe.dgrad_w, key_data=key_data,
                 salt=2, axes_a=(row, n), axes_b=(n, k), role="dgrad",
                 cell=cell)
    # wgrad: dw = Q(x^T) @ Q(g); reduction over M (tokens).
    dw = dot_qdq(x.T, g, recipe.wgrad_x, recipe.wgrad_g, key_data=key_data,
                 salt=4, axes_a=(k, row), axes_b=(row, n), role="wgrad",
                 cell=cell)
    return (dx.astype(x.dtype), dw.astype(w.dtype),
            jnp.zeros_like(key_data))


qmatmul.defvjp(_qmatmul_fwd, _qmatmul_bwd)


# ---------------------------------------------------------------------------
# Fused-kernel implementation (pallas_qmatmul)
# ---------------------------------------------------------------------------

_KERNEL_BLOCK = 128


def kernel_unsupported_reason(spec: QuantSpec) -> Optional[str]:
    """Why the fused pipeline cannot realize ``spec``, or None if it can.

    Returns a structured ``"<code>: <detail>"`` string — the vocabulary
    the routing census records for QDQ fallbacks and ``analysis.qlint``
    surfaces (and tests assert on):

      ``unsupported_dtype``        fp16 is a clip-only codec (no grid the
                                   integer-RTN kernel can round to);
      ``unsupported_block``        block/tile granularity with a group
                                   size other than the kernel's 128;
      ``unsupported_granularity``  a granularity the kernel has no
                                   quantize mode for.
    """
    if spec.is_passthrough:
        return None
    if spec.fmt == "fp16":
        return ("unsupported_dtype: fp16 is clip-only (no kernel "
                "rounding grid)")
    if spec.granularity in ("block", "tile"):
        if spec.block != _KERNEL_BLOCK:
            return (f"unsupported_block: {spec.granularity}{spec.block} "
                    f"(kernel group size is {_KERNEL_BLOCK})")
        return None
    if spec.granularity in ("token", "tensor"):
        return None
    return f"unsupported_granularity: {spec.granularity!r}"


def kernel_quant_mode(spec: QuantSpec) -> Optional[str]:
    """The fused pipeline's quantization mode realizing ``spec``, or None.

    ``pass``            bf16/fp32 passthrough roles;
    ``block``           per-(1 x 128) groups along the reduction axis;
    ``tile``            per-(128 x 128) tiles;
    ``token``/``tensor`` amax group spans the full reduction axis — the
                        quantize pass computes it with a two-sweep grid
                        (no external scale precompute).

    Stochastic rounding is kernel-realizable since the quantize-once
    rework (in-kernel PRNG noise).  None means unrealizable — the caller
    falls back to QDQ for that role, and
    :func:`kernel_unsupported_reason` says why (the structured reason the
    routing census records).
    """
    if kernel_unsupported_reason(spec) is not None:
        return None
    if spec.is_passthrough:
        return "pass"
    return spec.granularity


def _dot_fused(a: jnp.ndarray, b: jnp.ndarray,
               spec_a: QuantSpec, spec_b: QuantSpec,
               *, trans_a: bool = False, trans_b: bool = False,
               key_data: Optional[jnp.ndarray] = None,
               salt: int = 0, collect_stats: bool = False,
               pipeline: Optional[str] = None,
               axes_a=None, axes_b=None,
               role: Optional[str] = None, cell=None):
    """One matmul role through the fused Pallas pipeline when its specs are
    kernel-realizable, else through ``dot_qdq`` (transposes materialized).

    ``a``/``b`` are the STORED arrays; the effective operands are
    ``a^T``/``b^T`` under the trans flags, and quantization granularities
    apply in effective orientation (reduction-relative).  Stochastic specs
    consume ``key_data`` through the kernel's in-kernel PRNG (different
    stream than the QDQ path's ``jax.random`` — statistically equivalent,
    not bit-equal).  ``pipeline``: None = the process default (streaming
    single-pass unless overridden via ``use_pipeline``, resolved at trace
    time), or an explicit ``kernels.fp4_matmul.PIPELINES`` name.  With
    ``collect_stats`` returns ``(y, (sa, sb))`` raw quantize stat vectors
    (None for pass/fallback operands).
    """
    mode_a, mode_b = kernel_quant_mode(spec_a), kernel_quant_mode(spec_b)
    if mode_a is not None and mode_b is not None:
        # Deferred import: kernels.ops pulls in models.attention (cycle via
        # this module at import time).
        from repro.kernels.ops import pallas_qmm
        with _role_scope(role):
            return pallas_qmm(a, b, spec_a, spec_b,
                              mode_a=mode_a, mode_b=mode_b,
                              trans_a=trans_a, trans_b=trans_b,
                              key_data=key_data, salt=salt,
                              pipeline=pipeline,
                              collect_stats=collect_stats, role=role,
                              cell=cell)
    reasons = tuple(
        f"{operand}: {why}"
        for operand, spec in (("lhs", spec_a), ("rhs", spec_b))
        for why in (kernel_unsupported_reason(spec),) if why is not None)
    ae = a.T if trans_a else a
    be = b.T if trans_b else b
    y = dot_qdq(ae, be, spec_a, spec_b, key_data=key_data, salt=salt,
                axes_a=axes_a, axes_b=axes_b,
                role=role, route="qdq_fallback", reasons=reasons, cell=cell)
    return (y, (None, None)) if collect_stats else y


def _make_pallas_qmatmul(pipeline: Optional[str]):
    """Build a ``qmatmul``-shaped custom_vjp whose three roles all run
    through the fused kernel with a fixed ``pipeline`` choice (None = the
    process default).  Returns ``(qmatmul_fn, bwd_fn)`` — the bwd is shared
    with the stats variant below."""

    @functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
    def _pqm(x: jnp.ndarray, w: jnp.ndarray, key_data: jnp.ndarray,
             recipe: MatmulRecipe, axes=None, cell=None) -> jnp.ndarray:
        ax = axes or (None, None, None)
        return _dot_fused(x, w, recipe.fwd_x, recipe.fwd_w,
                          key_data=key_data, salt=0, pipeline=pipeline,
                          axes_a=(ax[0], ax[1]), axes_b=(ax[1], ax[2]),
                          role="fwd", cell=cell)

    def _fwd(x, w, key_data, recipe, axes, cell):
        return _pqm(x, w, key_data, recipe, axes, cell), (x, w, key_data)

    def _bwd(recipe, axes, cell, res, g):
        x, w, key_data = res
        row, k, n = axes or (None, None, None)
        # dgrad: dx = Q(g) @ Q(w^T); reduction over N (w read transposed
        # in-kernel via the BlockSpec index map).
        dx = _dot_fused(g, w, recipe.dgrad_g, recipe.dgrad_w, trans_b=True,
                        key_data=key_data, salt=2, pipeline=pipeline,
                        axes_a=(row, n), axes_b=(n, k), role="dgrad",
                        cell=cell)
        # wgrad: dw = Q(x^T) @ Q(g); reduction over M (tokens).
        dw = _dot_fused(x, g, recipe.wgrad_x, recipe.wgrad_g, trans_a=True,
                        key_data=key_data, salt=4, pipeline=pipeline,
                        axes_a=(k, row), axes_b=(row, n), role="wgrad",
                        cell=cell)
        return (dx.astype(x.dtype), dw.astype(w.dtype),
                jnp.zeros_like(key_data))

    _pqm.defvjp(_fwd, _bwd)
    return _pqm, _bwd


pallas_qmatmul, _pallas_qmatmul_bwd = _make_pallas_qmatmul(None)
pallas_qmatmul.__doc__ = (
    """``qmatmul`` with all three matmuls (fwd/dgrad/wgrad) running through
    the fused quantize+matmul Pallas kernel (default pipeline: streaming
    single-pass; see ``kernels.fp4_matmul``).  Same signature/semantics.
    ``axes`` only steers the QDQ-fallback roles (kernel scales live in
    kernel-private buffers and need no placement).""")

pallas_qmatmul_two_pass, _ = _make_pallas_qmatmul("two_pass")
pallas_qmatmul_two_pass.__doc__ = (
    """``pallas_qmatmul`` pinned to the two-pass reference pipeline
    (quantize pass + matmul pass) — bit-identical to the streaming default
    at equal tiling; kept selectable for A/B measurement and debugging.""")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def pallas_qmatmul_stats(x: jnp.ndarray, w: jnp.ndarray,
                         key_data: jnp.ndarray, recipe: MatmulRecipe,
                         cell=None):
    """``pallas_qmatmul`` that additionally returns the forward quantize
    pass's telemetry-epilogue vectors ``(y, (stats_x, stats_w))``.

    The stats come from the SAME kernel invocation that quantizes the
    operands for the dot (no second QDQ pass); ``y`` is bit-identical to
    ``pallas_qmatmul``.  Pass/fallback slots are None.  Gradients match
    ``pallas_qmatmul`` (stat outputs carry no cotangent).
    """
    return _dot_fused(x, w, recipe.fwd_x, recipe.fwd_w, key_data=key_data,
                      salt=0, collect_stats=True, role="fwd", cell=cell)


def _pallas_qmatmul_stats_fwd(x, w, key_data, recipe, cell):
    out = pallas_qmatmul_stats(x, w, key_data, recipe, cell)
    return out, (x, w, key_data)


def _pallas_qmatmul_stats_bwd(recipe, cell, res, ct):
    g = ct[0]
    return _pallas_qmatmul_bwd(recipe, None, cell, res, g)


pallas_qmatmul_stats.defvjp(_pallas_qmatmul_stats_fwd,
                            _pallas_qmatmul_stats_bwd)

_IMPLS = {"qdq": qmatmul, "pallas": pallas_qmatmul,
          "pallas_two_pass": pallas_qmatmul_two_pass}


def matmul_impl(impl: str):
    """Resolve a ``linear_impl`` config value to its qmatmul function."""
    try:
        return _IMPLS[impl]
    except KeyError:
        raise ValueError(
            f"unknown linear_impl {impl!r}; have {sorted(_IMPLS)}") from None


def _zero_key() -> jnp.ndarray:
    # NOTE: must be constructed fresh per trace (a cached global would leak
    # tracers out of scan/remat scopes); XLA constant-folds it anyway.
    return jnp.zeros((2,), jnp.uint32)


def _hint2d(arr: jnp.ndarray, axes) -> jnp.ndarray:
    """Sharding hint by logical axis names (lazy import: nn.layers imports
    this module at load time; no context or no names -> no-op)."""
    if axes is None or all(a is None for a in axes):
        return arr
    from repro.nn.layers import shard_hint
    return shard_hint(arr, axes)


def packed_linear(x: jnp.ndarray, w: PackedTensor, recipe: MatmulRecipe,
                  *, bias: Optional[jnp.ndarray] = None,
                  key_data: Optional[jnp.ndarray] = None,
                  impl: str = "qdq",
                  axes: Optional[Tuple[Optional[str], Optional[str],
                                       Optional[str]]] = None
                  ) -> jnp.ndarray:
    """Serving-side linear over a quantize-once ``PackedTensor`` panel.

    The RHS was quantized exactly once at load time (payload + per-tile
    scales); here it is expanded by a table gather — bitwise identical to
    the training QDQ of the same spec — and fed to the matmul as a
    PASSTHROUGH operand, so no per-token weight re-quantization happens:

      * passthrough activation spec -> plain dot (weight-only serving);
      * pallas impls with a kernel-realizable activation spec -> the
        fused stream pipeline via ``kernels.ops``, RHS in mode ``pass``
        (the kernel quantizes only the activations and streams the
        pre-quantized K-panels straight into the MXU loop);
      * otherwise -> QDQ fallback for the activation side only.

    Forward-only by design (serving): gradients, telemetry taps and the
    custom_vjp STE machinery of the training path do not apply here.
    """
    lead: Tuple[int, ...] = x.shape[:-1]
    k = x.shape[-1]
    w_dq = w.dequantize().astype(x.dtype)
    spec_x = recipe.fwd_x
    x2d = _hint2d(x.reshape(-1, k), axes and axes[:2])
    if spec_x.is_passthrough:
        if routing.active() is not None:
            routing.record("fwd", "packed_dot", spec_x.to_str(),
                           recipe.fwd_w.to_str())
        y = x2d @ w_dq
    else:
        if key_data is None:
            key_data = _zero_key()
        cell = routing.current_cell()
        if (impl in ("pallas", "pallas_two_pass")
                and kernel_quant_mode(spec_x) is not None):
            pipeline = "two_pass" if impl == "pallas_two_pass" else None
            ax = axes or (None, None, None)
            y = _dot_fused(x2d, w_dq, spec_x, BF16_SPEC, key_data=key_data,
                           salt=0, pipeline=pipeline,
                           axes_a=(ax[0], ax[1]), axes_b=(ax[1], ax[2]),
                           role="fwd", cell=cell)
        else:
            route, reasons = "qdq", ()
            if impl in ("pallas", "pallas_two_pass"):
                route = "qdq_fallback"
                reasons = (f"lhs: {kernel_unsupported_reason(spec_x)}",)
            ax = axes or (None, None, None)
            y = dot_qdq(x2d, w_dq, spec_x, BF16_SPEC, key_data=key_data,
                        salt=0, axes_a=(ax[0], ax[1]),
                        axes_b=(ax[1], ax[2]),
                        role="fwd", route=route, reasons=reasons,
                        cell=cell)
    y = _hint2d(y, axes and (axes[0], axes[2]))
    y = y.reshape(*lead, w_dq.shape[-1])
    if bias is not None:
        y = y + bias
    return y


def qlinear(x: jnp.ndarray, w: jnp.ndarray, recipe: MatmulRecipe,
            *, bias: Optional[jnp.ndarray] = None,
            key_data: Optional[jnp.ndarray] = None,
            impl: str = "qdq",
            axes: Optional[Tuple[Optional[str], Optional[str],
                                 Optional[str]]] = None) -> jnp.ndarray:
    """Linear layer over the last axis of ``x`` with per-role quantization.

    ``x``: (..., K), ``w``: (K, N) -> (..., N).  ``impl`` selects the
    matmul implementation ('qdq' unfused simulation | 'pallas' fused
    kernel); passthrough recipes lower to a plain dot either way.
    ``axes`` optionally names the logical matmul dims ``(tokens, K, N)``:
    when a sharding context is installed the flattened activation view and
    every per-granularity scale tensor (fwd, dgrad, wgrad — each in its own
    orientation) get ``with_sharding_constraint`` hints so the quantize-once
    K-panels partition cleanly under GSPMD.
    """
    if isinstance(w, PackedTensor):
        # quantize-once serving panels take the forward-only packed path
        return packed_linear(x, w, recipe, bias=bias, key_data=key_data,
                             impl=impl, axes=axes)
    lead: Tuple[int, ...] = x.shape[:-1]
    k = x.shape[-1]
    if recipe.is_passthrough:
        if routing.active() is not None:
            routing.record("fwd", "dot", recipe.fwd_x.to_str(),
                           recipe.fwd_w.to_str())
        y = _hint2d(x.reshape(-1, k), axes and axes[:2]) @ w
    else:
        if key_data is None:
            key_data = _zero_key()
        x2d = _hint2d(x.reshape(-1, k), axes and axes[:2])
        # Telemetry taps (no-ops unless a collector is installed).
        # fwd-computable operand stats go to the active collection frame;
        # grad_tap transports dgrad_g/wgrad_g cotangent stats out via the
        # layer-indexed probe-gradient channel (the collector knows the
        # current layer, so nothing layer-shaped threads through here).  On the pallas impl the fwd_x/fwd_w slots
        # come from the quantize pass's telemetry EPILOGUE — the very kernel
        # that feeds the dot — instead of tap_matmul re-running QDQ math;
        # the remaining fwd-side slots (wgrad_x, dgrad_w: different
        # orientation, only quantized in the backward) keep the tap path.
        fused_fwd = None
        y = None
        cell = routing.current_cell()
        if impl == "pallas" and telemetry.active() is not None:
            ma = kernel_quant_mode(recipe.fwd_x)
            mb = kernel_quant_mode(recipe.fwd_w)
            if (ma is not None and mb is not None
                    and (ma != "pass" or mb != "pass")):
                from repro.kernels.fp4_matmul import finalize_quant_stats
                y, (sa, sb) = pallas_qmatmul_stats(x2d, w, key_data, recipe,
                                                   cell)
                fused_fwd = {
                    "fwd_x": finalize_quant_stats(sa) if sa is not None
                    else None,
                    "fwd_w": finalize_quant_stats(sb) if sb is not None
                    else None,
                }
        telemetry.tap_matmul(x2d, w, recipe, fused_fwd=fused_fwd)
        if y is None:
            y = matmul_impl(impl)(x2d, w, key_data, recipe, axes, cell)
        y = telemetry.grad_tap(y, recipe)
    y = _hint2d(y, axes and (axes[0], axes[2]))
    y = y.reshape(*lead, w.shape[-1])
    if bias is not None:
        y = y + bias
    return y
