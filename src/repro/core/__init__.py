"""Core FP4 mixed-precision machinery (the paper's contribution).

Public API:
  formats          low-bit float grids + RTN/stochastic rounding
  quantize         scaled QDQ with tensor/token/block/tile granularity
  qlinear          custom_vjp quantized matmul / linear (STE)
  recipe           class-template recipes (paper + ablations) and
                   layer-resolved PrecisionPlans (depth-graded presets,
                   per-(layer, class) transforms)
  schedule         two-stage target-precision schedule (plan transform)
  cost_model       the paper's theoretical compute-cost accounting,
                   plan-aware (ModelDims / plan_cost / schedule_cost)
"""
from repro.core.formats import (FORMATS, FP4_E2M1, FP8_E4M3, FP8_E5M2,
                                FloatFormat, round_to_format)
from repro.core.quantize import QuantSpec, qdq, underflow_rate
from repro.core.qlinear import matmul_impl, pallas_qmatmul, qlinear, qmatmul
from repro.core.recipe import (RECIPES, LayerRecipe, MatmulRecipe,
                               PrecisionPlan, PrecisionRecipe, as_plan,
                               named_recipe, stage2_plan)
from repro.core.schedule import TargetPrecisionSchedule

__all__ = [
    "FORMATS", "FP4_E2M1", "FP8_E4M3", "FP8_E5M2", "FloatFormat",
    "round_to_format", "QuantSpec", "qdq", "underflow_rate", "qlinear",
    "qmatmul", "pallas_qmatmul", "matmul_impl", "RECIPES", "MatmulRecipe",
    "PrecisionRecipe", "named_recipe", "LayerRecipe", "PrecisionPlan",
    "as_plan", "stage2_plan",
    "TargetPrecisionSchedule",
]
