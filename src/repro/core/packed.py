"""Packed low-precision tensors: quantize-once weight panels for serving.

Training simulates low precision with QDQ (quantize -> dequantize in the
compute dtype), which is the right tool for studying numerics but stores the
*dequantized* values — no memory is saved and every matmul re-runs the
quantize math.  Serving wants the opposite trade: quantize each weight
exactly ONCE at load time, keep only the low-bit payload plus the per-block
scales in HBM, and have the matmul consume the pre-quantized panel directly.

``PackedTensor`` is that representation:

  * ``payload`` — uint8 codes.  Sign-magnitude: the top bit of each code is
    the sign, the low bits index the format's non-negative value grid
    (``formats.format_values``).  4-bit formats pack two codes per byte
    along the last axis (0.5 B/param); 6/8-bit formats use one byte each.
  * ``scale``   — f32 per-(block x block) tile scales in blocked layout
    ``(..., rows/block, cols/block)`` — the same Eq. 3 scales
    ``core.quantize`` computes, stored instead of re-derived.

``pack_tensor``/``PackedTensor.dequantize`` replicate ``core.quantize.qdq``'s
exact arithmetic (scale computed in f32, cast to the source dtype *before*
the divide/multiply, grid rounding via ``round_to_format``), so

    pack_tensor(w, spec).dequantize()  ==  qdq(w, spec, reduction_axis=1)

**bitwise** — every grid value of a <=8-bit format is exactly representable
in bf16 and f32 (mantissa <= 3 bits), so the decode-side table gather
reproduces the QDQ rounding result bit-for-bit, including negative zeros.
That identity is what lets the packed serving path share parity tests with
the training QDQ reference.

Registered as a jax pytree: payload/scale are children (so PackedTensor
params flow through ``jax.jit``/``vmap``/``tree.map``), while the format
metadata rides in static aux data.  Leading dims (scan-stacked layers, MoE
experts) are vmapped per matrix, so tile blocks never span layers/experts.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.core.quantize import QuantSpec, _blocked_view, compute_scale, \
    scale_from_amax

__all__ = ["PackedTensor", "pack_tensor", "packed_nbytes",
           "kv_quantize", "kv_dequantize"]


@functools.lru_cache(maxsize=None)
def _grid(fmt: str) -> np.ndarray:
    """Sorted non-negative value grid of ``fmt`` as a host f32 array."""
    return np.asarray(F.format_values_host(F.FORMATS[fmt]), np.float32)


@functools.lru_cache(maxsize=None)
def _code_bits(fmt: str) -> int:
    """Bits per stored code: 1 sign bit + index into the non-negative grid."""
    f = F.FORMATS[fmt]
    n = len(_grid(fmt))
    bits = 1 + max(int(np.ceil(np.log2(n))), 1)
    # the storage format's own width always suffices (sign + e + m fields)
    assert bits <= f.bits, (fmt, bits, f.bits)
    return f.bits


def _sign_bit(fmt: str) -> int:
    return 1 << (_code_bits(fmt) - 1)


def _pack2(fmt: str) -> bool:
    """Two codes per byte (4-bit formats only)."""
    return _code_bits(fmt) <= 4


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedTensor:
    """A low-bit weight panel: uint8 codes + per-tile f32 scales.

    Logical shape ``(..., rows, n_cols)``; ``payload`` stores
    ``(..., rows, ceil(n_cols / per_byte))`` code bytes and ``scale``
    ``(..., ceil(rows/block), ceil(n_cols/block))`` tile scales.
    ``ddtype`` is the dtype quantization ran in — ``dequantize()`` returns
    that dtype so the round-trip is bitwise QDQ-identical.
    """

    payload: jnp.ndarray
    scale: jnp.ndarray
    fmt: str
    block: int
    n_cols: int
    ddtype: str

    # -- pytree protocol (payload/scale traced; metadata static) ----------

    def tree_flatten(self):
        return (self.payload, self.scale), (self.fmt, self.block,
                                            self.n_cols, self.ddtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        payload, scale = children
        fmt, block, n_cols, ddtype = aux
        return cls(payload, scale, fmt, block, n_cols, ddtype)

    # -- geometry ---------------------------------------------------------

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.payload.shape[:-1]) + (self.n_cols,)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))

    @property
    def nbytes(self) -> int:
        """Real storage bytes: packed payload + scales."""
        return int(self.payload.size) * self.payload.dtype.itemsize + \
            int(self.scale.size) * self.scale.dtype.itemsize

    @property
    def bits_per_param(self) -> float:
        return 8.0 * self.nbytes / max(self.size, 1)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"PackedTensor({self.fmt}, shape={self.shape}, "
                f"block={self.block}, {self.bits_per_param:.2f} bits/param)")

    # -- decode -----------------------------------------------------------

    def dequantize(self, dtype=None) -> jnp.ndarray:
        """Codes -> values, bitwise identical to ``qdq(w, spec, 1)``.

        Table-gather of the grid value, sign applied from the code's top
        bit (reproducing QDQ's -0.0 exactly), then the per-tile rescale in
        the same blocked layout and cast order as ``quantize_dequantize``.

        The body runs under a ``packed_dequant`` named scope — pure graph
        metadata letting ``analysis.qlint`` tell a serving-panel decode
        apart from a training-path quantize (``qdq_*`` scopes).
        """
        with jax.named_scope("packed_dequant"):
            return self._dequantize_impl(dtype)

    def _dequantize_impl(self, dtype=None) -> jnp.ndarray:
        dt = jnp.dtype(dtype or self.ddtype)
        codes = self.payload
        if _pack2(self.fmt):
            lo = codes & jnp.uint8(0x0F)
            hi = codes >> jnp.uint8(4)
            codes = jnp.stack([lo, hi], axis=-1).reshape(
                *codes.shape[:-1], -1)
        codes = codes[..., :self.n_cols]
        sb = _sign_bit(self.fmt)
        idx = codes & jnp.uint8(sb - 1)
        neg = (codes & jnp.uint8(sb)) != 0
        table = jnp.asarray(_grid(self.fmt), dt)  # grid exact in bf16/f32
        vals = jnp.where(neg, -table[idx], table[idx])

        lead = vals.shape[:-2]
        k, n = vals.shape[-2:]
        b = self.block
        rb, cb = -(-k // b), -(-n // b)
        pr, pc = rb * b - k, cb * b - n
        if pr or pc:
            vals = jnp.pad(vals, [(0, 0)] * len(lead)
                           + [(0, pr), (0, pc)])
        vb = vals.reshape(*lead, rb, b, cb, b)
        # same cast order as qdq: f32 scale -> compute dtype -> multiply
        s = self.scale.reshape(*lead, rb, 1, cb, 1).astype(dt)
        y = (vb * s).reshape(*lead, rb * b, cb * b)[..., :k, :n]
        return y.astype(dt)


def _encode_grid_values(q: jnp.ndarray, fmt: str) -> jnp.ndarray:
    """Exact grid values -> uint8 sign-magnitude codes."""
    grid = jnp.asarray(_grid(fmt), jnp.float32)
    # values are exactly on the grid, so searchsorted lands on the index
    idx = jnp.searchsorted(grid, jnp.abs(q).astype(jnp.float32))
    idx = idx.astype(jnp.uint8)
    neg = jnp.signbit(q)  # keeps QDQ's -0.0 (sign * 0 rounding)
    return jnp.where(neg, idx | jnp.uint8(_sign_bit(fmt)), idx)


def pack_tensor(w: jnp.ndarray, spec: QuantSpec) -> PackedTensor:
    """Quantize ``w`` (..., K, N) once into a ``PackedTensor``.

    Per-(block x block) tile scaling only (the serving weight granularity);
    leading dims — scan-stacked layers, MoE experts — are vmapped so tile
    blocks never cross a layer/expert boundary.
    """
    if spec.granularity != "tile":
        raise ValueError(
            f"pack_tensor packs tile-granular weights; got {spec.short()}")
    if spec.is_passthrough or F.FORMATS[spec.fmt].bits > 8:
        raise ValueError(f"{spec.fmt} is not a packable low-bit format")
    fmt = F.FORMATS[spec.fmt]
    lead = w.shape[:-2]
    k, n = w.shape[-2:]
    w3 = w.reshape((-1, k, n))

    def one(m):
        # exactly core.quantize.qdq's math up to (and including) rounding
        scale = compute_scale(m, spec, 1)            # (rb, 1, cb, 1) f32
        sc = scale.astype(m.dtype)
        xb, _, _, _ = _blocked_view(m, "tile", spec.block, 1)
        qg = F.round_to_format(xb / sc, fmt)         # grid values (blocked)
        rb, bsz, cb, _ = qg.shape
        q2 = qg.reshape(rb * bsz, cb * bsz)[:k, :n]
        codes = _encode_grid_values(q2, spec.fmt)
        if _pack2(spec.fmt):
            if n % 2:
                codes = jnp.pad(codes, ((0, 0), (0, 1)))
            codes = codes[:, 0::2] | (codes[:, 1::2] << jnp.uint8(4))
        return codes, scale.reshape(rb, cb)

    payload, scale = jax.vmap(one)(w3)
    payload = payload.reshape(lead + payload.shape[1:])
    scale = scale.reshape(lead + scale.shape[1:])
    return PackedTensor(payload, scale, spec.fmt, spec.block, n,
                        str(w.dtype))


def packed_nbytes(tree) -> Tuple[int, int]:
    """(packed_bytes, packed_param_count) over all PackedTensor leaves."""
    nbytes = count = 0
    for leaf in jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, PackedTensor)):
        if isinstance(leaf, PackedTensor):
            nbytes += leaf.nbytes
            count += leaf.size
    return nbytes, count


# ---------------------------------------------------------------------------
# Quantized KV-cache codec (FP8 blockwise: one scale per (token, kv-head)
# vector over head_dim — append-time quantize, read-time dequantize).
# ---------------------------------------------------------------------------

def kv_quantize(x: jnp.ndarray, fmt: str
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(..., D) -> (uint8 codes (..., D), f32 scales (...,)).

    Per-vector amax scaling over the trailing head_dim (the KV analogue of
    the paper's blockwise weight scaling), same Eq. 3 scale math as
    training so the codec shares the quantize core.
    """
    f = F.FORMATS[fmt]
    if f.bits != 8:
        raise ValueError(f"kv cache packing supports 8-bit formats; "
                         f"got {fmt}")
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = scale_from_amax(amax, f)                 # f32, eps-floored
    sc = scale[..., None].astype(x.dtype)
    qg = F.round_to_format(x / sc, f)
    return _encode_grid_values(qg, fmt), scale


def kv_dequantize(codes: jnp.ndarray, scale: jnp.ndarray, fmt: str,
                  dtype) -> jnp.ndarray:
    """Inverse of ``kv_quantize`` into ``dtype``."""
    sb = _sign_bit(fmt)
    idx = codes & jnp.uint8(sb - 1)
    neg = (codes & jnp.uint8(sb)) != 0
    table = jnp.asarray(_grid(fmt), dtype)
    vals = jnp.where(neg, -table[idx], table[idx])
    return vals * scale[..., None].astype(dtype)
