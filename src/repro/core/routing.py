"""Trace-time routing census for the precision-flow static analyzer.

Every matmul role in the model goes through exactly one of a small set of
routes (fused Pallas kernel, QDQ simulation, QDQ *fallback* from a pallas
impl, plain dot for passthrough recipes, packed serving dot).  The route
decision happens at trace time in ``core.qlinear`` — which historically
made silent fallbacks invisible: a spec the kernel cannot realize would
quietly take the QDQ path and no test could tell.

This module records those decisions.  ``capture()`` installs a
thread-local :class:`RoutingLog`; while it is active, ``core.qlinear``
and ``kernels.ops`` append one :class:`RouteEvent` per matmul-role
routing decision, tagged with the innermost static layer label (pushed
by ``models.stack``) and plan class (derived from the telemetry module
scope).  Because tracing re-enters functions (custom_vjp forward
re-trace, remat replay, scan bodies traced once per run), raw event
counts are NOT stable — consumers must dedupe by ``RouteEvent.cell()``
identity, which :meth:`RoutingLog.cells` does.

The log costs nothing when inactive (one thread-local attribute read),
and never touches traced values — only static metadata — so capturing a
trace is bit-identical to not capturing it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

__all__ = ["RouteEvent", "RoutingLog", "capture", "active", "record",
           "layer_scope", "class_scope", "plan_class_for_module",
           "current_layer", "current_class", "current_cell"]

# Telemetry module scopes -> plan class (see PrecisionPlan cell classes).
# attn and cross-attn draw from the plan's attn_linear cell; ssm/ffn/moe
# all draw from ffn_linear; the LM head from head_linear.
_MODULE_TO_CLASS = {"attn": "attn", "cross": "attn",
                    "ssm": "ffn", "ffn": "ffn", "moe": "ffn",
                    "head": "head"}


def plan_class_for_module(module: str) -> Optional[str]:
    """Map a telemetry module-scope name to its PrecisionPlan class."""
    return _MODULE_TO_CLASS.get(module)


@dataclasses.dataclass(frozen=True)
class RouteEvent:
    """One matmul-role routing decision observed during tracing.

    ``layer`` is a static label: ``"L3"`` for unrolled layer 3, or the
    slice form ``"L1:8:4"`` for a scan-body position covering
    ``range(1, 8, 4)`` (scan bodies trace once per run, so one event
    stands for every layer the position covers).  ``route``:

      ``pallas``        fused kernel (``mode_a``/``mode_b``/``pipeline``
                        say how each operand is quantized in-kernel);
      ``qdq``           QDQ simulation chosen by config (impl='qdq');
      ``qdq_fallback``  a pallas impl that could NOT realize the specs —
                        ``reasons`` carries one structured string per
                        unrealizable operand;
      ``dot``           passthrough recipe lowered to a plain dot;
      ``packed_dot``    serving: pre-dequantized PackedTensor panel dot.

    ``sr_a``/``sr_b``: stochastic rounding *actually armed* for that
    operand (spec says ``:sr`` AND key material reached the call) — the
    check "SR appears exactly where specs say so" compares these against
    the plan, catching dropped-key bugs as well as spec drift.
    """
    layer: Optional[str]
    cls: Optional[str]
    role: str                      # fwd | dgrad | wgrad
    route: str
    spec_a: str
    spec_b: str
    mode_a: Optional[str] = None
    mode_b: Optional[str] = None
    pipeline: Optional[str] = None
    sr_a: bool = False
    sr_b: bool = False
    reasons: Tuple[str, ...] = ()

    def cell(self) -> Tuple:
        """Dedupe identity: trace-order independent, retrace stable."""
        return (self.layer, self.cls, self.role, self.route,
                self.spec_a, self.spec_b, self.mode_a, self.mode_b,
                self.pipeline, self.sr_a, self.sr_b, self.reasons)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["reasons"] = list(self.reasons)
        return d


class RoutingLog:
    """Accumulates :class:`RouteEvent`s for one captured trace."""

    def __init__(self) -> None:
        self.events: List[RouteEvent] = []

    def add(self, ev: RouteEvent) -> None:
        self.events.append(ev)

    def cells(self) -> List[RouteEvent]:
        """Events deduped by :meth:`RouteEvent.cell`, in first-seen order.

        This is the stable census: retraces (custom_vjp fwd, remat
        replay) re-emit identical events, which collapse here.
        """
        seen = {}
        for ev in self.events:
            seen.setdefault(ev.cell(), ev)
        return list(seen.values())

    def fallbacks(self) -> List[RouteEvent]:
        return [ev for ev in self.cells() if ev.route == "qdq_fallback"]

    def to_dict(self) -> Dict:
        return {"cells": [ev.to_dict() for ev in self.cells()],
                "n_raw_events": len(self.events)}


_STATE = threading.local()


def _log() -> Optional[RoutingLog]:
    return getattr(_STATE, "log", None)


def active() -> Optional[RoutingLog]:
    """The installed RoutingLog, or None (the common, zero-cost case)."""
    return _log()


def current_layer() -> Optional[str]:
    return getattr(_STATE, "layer", None)


def current_class() -> Optional[str]:
    return getattr(_STATE, "cls", None)


def current_cell() -> Optional[Tuple[Optional[str], Optional[str]]]:
    """The (layer, class) attribution at this point of the trace, or None
    when no census is running.

    Captured by ``qlinear`` IN CONTEXT and threaded down to the matmul
    impls as a static argument: custom_vjp forward/backward rules trace
    lazily, outside the ``layer_scope``/``class_scope`` Python contexts,
    so events recorded there must carry the cell explicitly.
    """
    if _log() is None:
        return None
    return (current_layer(), current_class())


@contextlib.contextmanager
def capture():
    """Install a fresh RoutingLog for the duration of a trace."""
    prev = _log()
    log = RoutingLog()
    _STATE.log = log
    try:
        yield log
    finally:
        _STATE.log = prev


@contextlib.contextmanager
def layer_scope(label: Optional[str]):
    """Static layer label for events recorded inside (``"L3"`` or the
    scan-slice form ``"L{start}:{stop}:{step}"``).  No-op when no log is
    installed or ``label`` is None."""
    if _log() is None or label is None:
        yield
        return
    prev = getattr(_STATE, "layer", None)
    _STATE.layer = label
    try:
        yield
    finally:
        _STATE.layer = prev


@contextlib.contextmanager
def class_scope(module: str):
    """Plan-class attribution from a telemetry module scope name."""
    if _log() is None:
        yield
        return
    prev = getattr(_STATE, "cls", None)
    _STATE.cls = plan_class_for_module(module) or prev
    try:
        yield
    finally:
        _STATE.cls = prev


def record(role: str, route: str, spec_a, spec_b, *,
           mode_a: Optional[str] = None, mode_b: Optional[str] = None,
           pipeline: Optional[str] = None,
           sr_a: bool = False, sr_b: bool = False,
           reasons: Tuple[str, ...] = (),
           cell: Optional[Tuple[Optional[str], Optional[str]]] = None
           ) -> None:
    """Append a routing decision (no-op unless a log is installed).

    ``cell`` overrides the ambient (layer, class) attribution — required
    for events recorded from lazily-traced custom_vjp rules (see
    :func:`current_cell`)."""
    log = _log()
    if log is None:
        return
    layer, cls = cell if cell is not None else (current_layer(),
                                                current_class())
    log.add(RouteEvent(
        layer=layer, cls=cls, role=role, route=route,
        spec_a=str(spec_a), spec_b=str(spec_b), mode_a=mode_a, mode_b=mode_b,
        pipeline=pipeline, sr_a=sr_a, sr_b=sr_b, reasons=tuple(reasons)))
