"""Precision recipes: which format/granularity each matmul role uses.

A transformer linear layer ``y = x @ w`` spawns three matmuls per step:

    fwd   :  y  = x    @ w        (M,K)x(K,N)
    dgrad :  dx = g    @ w^T      (M,N)x(N,K)   -- activation gradient
    wgrad :  dw = x^T  @ g        (K,M)x(M,N)   -- weight gradient

The paper's recipe assigns an independent precision to each role *and* each
operand, per module class:

  * attention-class linears (QKV, attn-out, cross-attn) -> FP8 everywhere
    (§3.1 "Attention-protected"); grads in E5M2, non-grads in E4M3.
  * FFN-class linears -> FP4(E2M1) forward with per-block scaling, FP8 wgrad
    (§3.2 "Gradient-sensitive"), dgrad unquantized BF16 (§3.2: quantizing the
    activation-gradient path breaks convergence).
  * router / lm-head / embeddings / norms -> full precision.

``PrecisionRecipe`` captures this; ``named_recipe()`` provides the paper's
configurations plus the Table-2 ablation grid.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.quantize import QuantSpec

__all__ = ["MatmulRecipe", "PrecisionRecipe", "named_recipe", "RECIPES",
           "promote_module_class",
           "MM_BF16", "MM_FP8", "MM_FP4_ALL", "MM_FFN_PAPER"]


@dataclasses.dataclass(frozen=True)
class MatmulRecipe:
    """Per-role quantization of one linear layer (six operand slots)."""

    fwd_x: QuantSpec = QuantSpec()
    fwd_w: QuantSpec = QuantSpec()
    dgrad_g: QuantSpec = QuantSpec()
    dgrad_w: QuantSpec = QuantSpec()
    wgrad_x: QuantSpec = QuantSpec()
    wgrad_g: QuantSpec = QuantSpec()

    def short(self) -> str:
        return (f"fwd[{self.fwd_x.short()}x{self.fwd_w.short()}] "
                f"dgrad[{self.dgrad_g.short()}x{self.dgrad_w.short()}] "
                f"wgrad[{self.wgrad_x.short()}x{self.wgrad_g.short()}]")

    @property
    def is_passthrough(self) -> bool:
        return all(s.is_passthrough for s in (
            self.fwd_x, self.fwd_w, self.dgrad_g, self.dgrad_w,
            self.wgrad_x, self.wgrad_g))


def _mm(fwd: str, bwd_w: str, bwd_d: Optional[str], *,
        fwd_gran: str = "token", wgrad_gran: str = "token",
        block: int = 128) -> MatmulRecipe:
    """Helper: build a MatmulRecipe from format names.

    ``fwd``/``bwd_w``(wgrad)/``bwd_d``(dgrad) are 'fp4', 'fp8', 'bf16'.
    Gradients use E5M2; weights/activations use E4M3 (FP8 convention).
    ``None`` for ``bwd_d`` means keep dgrad unquantized.
    """

    def act(fmtname, gran):
        if fmtname == "bf16":
            return QuantSpec("bf16")
        if fmtname == "fp8":
            return QuantSpec("fp8_e4m3", gran, block)
        if fmtname == "fp4":
            return QuantSpec("fp4_e2m1", gran, block)
        raise ValueError(fmtname)

    def grad(fmtname, gran):
        if fmtname == "bf16":
            return QuantSpec("bf16")
        if fmtname == "fp8":
            return QuantSpec("fp8_e5m2", gran, block)
        if fmtname == "fp4":
            return QuantSpec("fp4_e2m1", gran, block)
        raise ValueError(fmtname)

    # weight-side granularity: 'tile' where activations use 'block',
    # 'token' (== per-channel for weights) otherwise.
    wgran = "tile" if fwd_gran == "block" else "token"
    bwd_d = bwd_d or "bf16"
    return MatmulRecipe(
        fwd_x=act(fwd, fwd_gran),
        fwd_w=act(fwd, wgran),
        dgrad_g=grad(bwd_d, "token"),
        dgrad_w=act(bwd_d, "token"),
        wgrad_x=act(bwd_w, wgrad_gran),
        wgrad_g=grad(bwd_w, wgrad_gran),
    )


MM_BF16 = MatmulRecipe()
MM_FP8 = _mm("fp8", "fp8", "fp8")
MM_FP4_ALL = _mm("fp4", "fp4", "fp4", fwd_gran="block", wgrad_gran="block")
# The paper's final FFN recipe (§3.2 / GPT-774M in App. B): per-block FP4
# forward, FP8 per-block weight gradients, unquantized activation gradients.
MM_FFN_PAPER = _mm("fp4", "fp8", None, fwd_gran="block", wgrad_gran="block")


@dataclasses.dataclass(frozen=True)
class PrecisionRecipe:
    """Module-class -> MatmulRecipe mapping for a whole model."""

    name: str
    attn_linear: MatmulRecipe = MM_BF16   # QKV / out-proj / cross-attn
    ffn_linear: MatmulRecipe = MM_BF16    # MLP & MoE expert matmuls, ssm proj
    head_linear: MatmulRecipe = MM_BF16   # lm head (kept high-precision)
    # Target-precision schedule (§3.3): fraction of final steps retrained at
    # the target (high) precision. 0.0 disables stage 2.
    target_precision_frac: float = 0.0

    def for_class(self, cls: str) -> MatmulRecipe:
        return {"attn": self.attn_linear, "ffn": self.ffn_linear,
                "head": self.head_linear}[cls]

    @property
    def is_passthrough(self) -> bool:
        return (self.attn_linear.is_passthrough
                and self.ffn_linear.is_passthrough
                and self.head_linear.is_passthrough)


_CLASS_FIELD = {"attn": "attn_linear", "ffn": "ffn_linear",
                "head": "head_linear"}


def promote_module_class(recipe: PrecisionRecipe, cls: str,
                         to: Optional[MatmulRecipe] = None
                         ) -> PrecisionRecipe:
    """Derive a recipe with one module class promoted to higher precision
    (default FP8-everywhere for that class — the Table-2 ablation axis).
    Used by the adaptive controller to demote an FP4 class that shows
    sustained quantization overflow.  No-op if the class already runs the
    target MatmulRecipe."""
    field = _CLASS_FIELD[cls]
    to = to if to is not None else MM_FP8
    if getattr(recipe, field) == to:
        return recipe
    return dataclasses.replace(recipe, name=f"{recipe.name}+{cls}=fp8",
                               **{field: to})


def named_recipe(name: str) -> PrecisionRecipe:
    """Paper recipes + Table-2 ablation grid.

    ``paper_fp4``      : §3 final recipe — attn FP8, FFN fwd FP4/per-block,
                         FFN wgrad FP8, FFN dgrad BF16, + 2-stage schedule.
    ``bf16``           : high-precision baseline (Table 1 'FP16-baseline').
    ``fp8``            : FP8-everywhere (Fishman et al.-style reference).
    ``all_fp4``        : Table 2 row 1 (FP4/FP4/FP4) — the failure mode.
    ``t2_*``           : remaining Table 2 rows.
    ``fine_grained_fp4``: beyond-paper — all-FP4 with per-block scaling AND
                         stochastic rounding on gradients.
    """
    if name in RECIPES:
        return RECIPES[name]
    raise KeyError(f"unknown recipe {name!r}; have {sorted(RECIPES)}")


RECIPES = {
    "bf16": PrecisionRecipe("bf16"),
    "fp8": PrecisionRecipe("fp8", attn_linear=MM_FP8, ffn_linear=MM_FP8),
    "paper_fp4": PrecisionRecipe(
        "paper_fp4", attn_linear=MM_FP8, ffn_linear=MM_FFN_PAPER,
        target_precision_frac=0.075),
    "paper_fp4_nosched": PrecisionRecipe(
        "paper_fp4_nosched", attn_linear=MM_FP8, ffn_linear=MM_FFN_PAPER),
    # --- Table 2 ablation grid (attn / ffn / fp4-linear-backward) ---
    "all_fp4": PrecisionRecipe(  # FP4 | FP4 | FP4
        "all_fp4", attn_linear=MM_FP4_ALL, ffn_linear=MM_FP4_ALL),
    "t2_fp4_fp8_fp8": PrecisionRecipe(  # FP4 attn | FP8 ffn | FP8 bwd
        "t2_fp4_fp8_fp8",
        attn_linear=_mm("fp4", "fp8", "fp8", fwd_gran="block"),
        ffn_linear=MM_FP8),
    "t2_fp8_fp4_fp4": PrecisionRecipe(  # FP8 attn | FP4 ffn | FP4 bwd
        "t2_fp8_fp4_fp4", attn_linear=MM_FP8, ffn_linear=MM_FP4_ALL),
    "t2_fp8_fp4_fp8": PrecisionRecipe(  # FP8 attn | FP4 ffn | FP8 bwd
        "t2_fp8_fp4_fp8", attn_linear=MM_FP8,
        ffn_linear=_mm("fp4", "fp8", "fp8", fwd_gran="block")),
    # --- App. B model-size-dependent variants ---
    "gpt125m_fp4": PrecisionRecipe(  # per-token/channel FP4 fwd+wgrad
        "gpt125m_fp4", attn_linear=MM_FP8,
        ffn_linear=_mm("fp4", "fp4", None, fwd_gran="token",
                       wgrad_gran="token"),
        target_precision_frac=0.075),
    "gpt335m_fp4": PrecisionRecipe(  # per-block wgrad
        "gpt335m_fp4", attn_linear=MM_FP8,
        ffn_linear=_mm("fp4", "fp4", None, fwd_gran="token",
                       wgrad_gran="block"),
        target_precision_frac=0.075),
    "all_fp4_sched": PrecisionRecipe(  # schedule demo on the worst recipe
        "all_fp4_sched", attn_linear=MM_FP4_ALL, ffn_linear=MM_FP4_ALL,
        target_precision_frac=0.1),
    # --- beyond-paper ---
    "fine_grained_fp4": PrecisionRecipe(
        "fine_grained_fp4",
        attn_linear=MM_FP8,
        ffn_linear=dataclasses.replace(
            MM_FP4_ALL,
            wgrad_g=QuantSpec("fp4_e2m1", "block", stochastic=True),
            dgrad_g=QuantSpec("fp8_e5m2", "token")),
        target_precision_frac=0.075),
}
