"""Precision recipes and layer-resolved precision plans.

A transformer linear layer ``y = x @ w`` spawns three matmuls per step:

    fwd   :  y  = x    @ w        (M,K)x(K,N)
    dgrad :  dx = g    @ w^T      (M,N)x(N,K)   -- activation gradient
    wgrad :  dw = x^T  @ g        (K,M)x(M,N)   -- weight gradient

The paper's recipe assigns an independent precision to each role *and* each
operand, per module class:

  * attention-class linears (QKV, attn-out, cross-attn) -> FP8 everywhere
    (§3.1 "Attention-protected"); grads in E5M2, non-grads in E4M3.
  * FFN-class linears -> FP4(E2M1) forward with per-block scaling, FP8 wgrad
    (§3.2 "Gradient-sensitive"), dgrad unquantized BF16 (§3.2: quantizing the
    activation-gradient path breaks convergence).
  * router / lm-head / embeddings / norms -> full precision.

``PrecisionRecipe`` captures the depth-independent class template;
``named_recipe()`` provides the paper's configurations plus the Table-2
ablation grid.

``PrecisionPlan`` resolves the template over depth: one
``LayerRecipe`` (class -> ``MatmulRecipe``) per layer, plus the lm-head.
Plans are what the model/trainer actually consume (a ``PrecisionRecipe``
is coerced via :func:`as_plan` to the uniform plan).  Depth-graded
constructors follow the depth-dependence in related FP4-training work
(first/last-K protected — "FP4 All the Way"; trailing-fraction holdout —
"Pretraining LLMs with NVFP4"): :meth:`PrecisionPlan.first_last_k` and
:meth:`PrecisionPlan.ramp`.  Plan *transforms* (:meth:`PrecisionPlan.
promote`, :func:`stage2_plan`) replace the previously scattered knobs:
per-(layer, class) demotion subsumes class-global demotion, and the §3.3
stage-2 switch is "swap every row for the target plan's".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, Union

from repro.core import formats as F
from repro.core.quantize import QuantSpec

__all__ = ["MatmulRecipe", "PrecisionRecipe", "named_recipe", "RECIPES",
           "LayerRecipe", "PrecisionPlan", "as_plan", "stage2_plan",
           "ROLE_SUBSETS",
           "MM_BF16", "MM_FP8", "MM_FP4_ALL", "MM_FFN_PAPER"]

_ROLES = ("fwd_x", "fwd_w", "dgrad_g", "dgrad_w", "wgrad_x", "wgrad_g")


@dataclasses.dataclass(frozen=True)
class MatmulRecipe:
    """Per-role quantization of one linear layer (six operand slots)."""

    fwd_x: QuantSpec = QuantSpec()
    fwd_w: QuantSpec = QuantSpec()
    dgrad_g: QuantSpec = QuantSpec()
    dgrad_w: QuantSpec = QuantSpec()
    wgrad_x: QuantSpec = QuantSpec()
    wgrad_g: QuantSpec = QuantSpec()

    def short(self) -> str:
        return (f"fwd[{self.fwd_x.short()}x{self.fwd_w.short()}] "
                f"dgrad[{self.dgrad_g.short()}x{self.dgrad_w.short()}] "
                f"wgrad[{self.wgrad_x.short()}x{self.wgrad_g.short()}]")

    @property
    def is_passthrough(self) -> bool:
        return all(s.is_passthrough for s in (
            self.fwd_x, self.fwd_w, self.dgrad_g, self.dgrad_w,
            self.wgrad_x, self.wgrad_g))

    def to_dict(self) -> Dict[str, str]:
        """Role -> compact spec string (``QuantSpec.to_str`` syntax)."""
        return {r: getattr(self, r).to_str() for r in _ROLES}

    @classmethod
    def from_dict(cls, d: Dict[str, str]) -> "MatmulRecipe":
        return cls(**{r: QuantSpec.from_str(d[r]) for r in _ROLES})


def _mm(fwd: str, bwd_w: str, bwd_d: Optional[str], *,
        fwd_gran: str = "token", wgrad_gran: str = "token",
        block: int = 128) -> MatmulRecipe:
    """Helper: build a MatmulRecipe from format names.

    ``fwd``/``bwd_w``(wgrad)/``bwd_d``(dgrad) are 'fp4', 'fp8', 'bf16'.
    Gradients use E5M2; weights/activations use E4M3 (FP8 convention).
    ``None`` for ``bwd_d`` means keep dgrad unquantized.
    """

    def act(fmtname, gran):
        if fmtname == "bf16":
            return QuantSpec("bf16")
        if fmtname == "fp8":
            return QuantSpec("fp8_e4m3", gran, block)
        if fmtname == "fp4":
            return QuantSpec("fp4_e2m1", gran, block)
        raise ValueError(fmtname)

    def grad(fmtname, gran):
        if fmtname == "bf16":
            return QuantSpec("bf16")
        if fmtname == "fp8":
            return QuantSpec("fp8_e5m2", gran, block)
        if fmtname == "fp4":
            return QuantSpec("fp4_e2m1", gran, block)
        raise ValueError(fmtname)

    # weight-side granularity: 'tile' where activations use 'block',
    # 'token' (== per-channel for weights) otherwise.
    wgran = "tile" if fwd_gran == "block" else "token"
    bwd_d = bwd_d or "bf16"
    return MatmulRecipe(
        fwd_x=act(fwd, fwd_gran),
        fwd_w=act(fwd, wgran),
        dgrad_g=grad(bwd_d, "token"),
        dgrad_w=act(bwd_d, "token"),
        wgrad_x=act(bwd_w, wgrad_gran),
        wgrad_g=grad(bwd_w, wgrad_gran),
    )


MM_BF16 = MatmulRecipe()
MM_FP8 = _mm("fp8", "fp8", "fp8")
MM_FP4_ALL = _mm("fp4", "fp4", "fp4", fwd_gran="block", wgrad_gran="block")
# The paper's final FFN recipe (§3.2 / GPT-774M in App. B): per-block FP4
# forward, FP8 per-block weight gradients, unquantized activation gradients.
MM_FFN_PAPER = _mm("fp4", "fp8", None, fwd_gran="block", wgrad_gran="block")


@dataclasses.dataclass(frozen=True)
class PrecisionRecipe:
    """Module-class -> MatmulRecipe mapping for a whole model."""

    name: str
    attn_linear: MatmulRecipe = MM_BF16   # QKV / out-proj / cross-attn
    ffn_linear: MatmulRecipe = MM_BF16    # MLP & MoE expert matmuls, ssm proj
    head_linear: MatmulRecipe = MM_BF16   # lm head (kept high-precision)
    # Target-precision schedule (§3.3): fraction of final steps retrained at
    # the target (high) precision. 0.0 disables stage 2.
    target_precision_frac: float = 0.0

    def for_class(self, cls: str) -> MatmulRecipe:
        return {"attn": self.attn_linear, "ffn": self.ffn_linear,
                "head": self.head_linear}[cls]

    @property
    def is_passthrough(self) -> bool:
        return (self.attn_linear.is_passthrough
                and self.ffn_linear.is_passthrough
                and self.head_linear.is_passthrough)


_CLASS_FIELD = {"attn": "attn_linear", "ffn": "ffn_linear",
                "head": "head_linear"}

# Role subsets addressable by the plan transforms: each of the three
# matmuls of a linear owns two operand slots.
ROLE_SUBSETS = {"fwd": ("fwd_x", "fwd_w"),
                "dgrad": ("dgrad_g", "dgrad_w"),
                "wgrad": ("wgrad_x", "wgrad_g")}


def _protect(mm: MatmulRecipe) -> MatmulRecipe:
    """Higher-precision stand-in for a class recipe, role-wise: every
    *quantized* role is raised to its FP8 counterpart; passthrough roles
    are untouched.  Per-role matters: MM_FFN_PAPER keeps dgrad in BF16
    (§3.2 — quantizing the activation-gradient path breaks convergence),
    and a protection preset or demotion must never turn that unquantized
    path INTO a quantized FP8 one."""
    repl = {r: getattr(MM_FP8, r) for r in _ROLES
            if not getattr(mm, r).is_passthrough}
    return dataclasses.replace(mm, **repl) if repl else mm


def _demote_mm(mm: MatmulRecipe, roles: Tuple[str, ...],
               fmt: str = "fp4_e2m1") -> MatmulRecipe:
    """Lower the given role subsets of a cell recipe to their low-precision
    (default FP4) counterparts, keeping each operand's scaling spec
    (granularity/block/pow2) intact.  Asymmetric by design: passthrough
    roles are never quantized (the §3.2 BF16 dgrad path stays BF16 —
    demotion only pushes *already-quantized* operands further down), and
    gradient operands (``*_g``) pick up stochastic rounding at FP4 (the
    unbiased-gradient requirement of Quartet / "Optimizing LLM Training
    Using FP4 Quantization")."""
    repl = {}
    for subset in roles:
        for r in ROLE_SUBSETS[subset]:
            spec = getattr(mm, r)
            if spec.is_passthrough:
                continue
            if F.FORMATS[fmt].bits >= spec.format.bits:
                continue  # demotion strictly lowers; fp4 stays fp4
            sr = True if (r.endswith("_g") and fmt.startswith("fp4")) \
                else None
            tgt = spec.with_fmt(fmt, stochastic=sr)
            if tgt != spec:
                repl[r] = tgt
    return dataclasses.replace(mm, **repl) if repl else mm


def _hybrid(mm: MatmulRecipe) -> MatmulRecipe:
    """Middle rung of the FP8->FP4 depth ramp: the forward runs the target
    (low-precision) specs, both backward matmuls stay at the protected
    (FP8) specs — the §3.2 observation that the gradient path is the
    sensitive one, applied per depth rung."""
    if mm.is_passthrough:
        return mm
    hi = _protect(mm)
    return dataclasses.replace(hi, fwd_x=mm.fwd_x, fwd_w=mm.fwd_w)


# ---------------------------------------------------------------------------
# Layer-resolved precision plans
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerRecipe:
    """One plan row: the class -> MatmulRecipe table of a single layer."""

    attn_linear: MatmulRecipe = MM_BF16
    ffn_linear: MatmulRecipe = MM_BF16

    def for_class(self, cls: str) -> MatmulRecipe:
        return {"attn": self.attn_linear, "ffn": self.ffn_linear}[cls]

    @property
    def is_passthrough(self) -> bool:
        return (self.attn_linear.is_passthrough
                and self.ffn_linear.is_passthrough)

    def to_dict(self) -> Dict[str, Dict[str, str]]:
        return {"attn": self.attn_linear.to_dict(),
                "ffn": self.ffn_linear.to_dict()}

    @classmethod
    def from_dict(cls, d) -> "LayerRecipe":
        return cls(attn_linear=MatmulRecipe.from_dict(d["attn"]),
                   ffn_linear=MatmulRecipe.from_dict(d["ffn"]))


@dataclasses.dataclass(frozen=True)
class PrecisionPlan:
    """Per-layer x module-class x role precision table for a whole model.

    ``layers[i]`` holds layer i's class recipes; the lm-head (outside the
    stack) has its own slot.  Frozen + tuple-backed, so plans are hashable
    — the trainer keys its compiled step graphs on the plan itself, and
    ``models.stack`` partitions scan layers by row equality.
    """

    name: str
    layers: Tuple[LayerRecipe, ...]
    head_linear: MatmulRecipe = MM_BF16
    # Target-precision schedule (§3.3): fraction of final steps retrained at
    # the target (high) precision. 0.0 disables stage 2.
    target_precision_frac: float = 0.0

    # -- lookups -----------------------------------------------------------

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    def layer(self, i: int) -> LayerRecipe:
        return self.layers[i]

    def for_class(self, cls: str, layer: Optional[int] = None
                  ) -> MatmulRecipe:
        if cls == "head":
            return self.head_linear
        if layer is None:
            raise ValueError(f"class {cls!r} is layer-resolved; pass layer=")
        return self.layers[layer].for_class(cls)

    @property
    def is_passthrough(self) -> bool:
        return (self.head_linear.is_passthrough
                and all(r.is_passthrough for r in self.layers))

    @property
    def is_uniform(self) -> bool:
        return all(r == self.layers[0] for r in self.layers)

    def scan_runs(self, period: int) -> List[Tuple[int, int]]:
        """Partition scan groups into maximal contiguous runs whose layers
        share a plan signature: ``[(g0, g1), ...)`` group ranges.  Group g
        covers layers ``[g*period, (g+1)*period)``; a uniform plan yields
        the single run ``[(0, n_groups)]`` (one ``lax.scan``, the
        pre-plan graph)."""
        assert len(self.layers) % period == 0, (len(self.layers), period)
        n_groups = len(self.layers) // period
        runs: List[Tuple[int, int]] = []
        prev_sig = None
        for g in range(n_groups):
            sig = self.layers[g * period:(g + 1) * period]
            if runs and sig == prev_sig:
                runs[-1] = (runs[-1][0], g + 1)
            else:
                runs.append((g, g + 1))
            prev_sig = sig
        return runs

    # -- constructors ------------------------------------------------------

    @classmethod
    def uniform(cls, recipe: PrecisionRecipe, n_layers: int
                ) -> "PrecisionPlan":
        """Every layer runs the recipe's class template (the pre-plan
        semantics; resolves to a single scan run)."""
        row = LayerRecipe(recipe.attn_linear, recipe.ffn_linear)
        return cls(recipe.name, (row,) * n_layers, recipe.head_linear,
                   recipe.target_precision_frac)

    @classmethod
    def first_last_k(cls, recipe: PrecisionRecipe, n_layers: int,
                     k: int = 2, high: Optional[LayerRecipe] = None
                     ) -> "PrecisionPlan":
        """Depth-graded preset: the first and last ``k`` layers run the
        protected (default FP8) row, the middle runs the recipe (cf. "FP4
        All the Way", which keeps first/last blocks in higher precision)."""
        base = cls.uniform(recipe, n_layers)
        hi = high if high is not None else LayerRecipe(
            _protect(recipe.attn_linear), _protect(recipe.ffn_linear))
        rows = tuple(hi if (i < k or i >= n_layers - k) else base.layers[i]
                     for i in range(n_layers))
        return dataclasses.replace(base, name=f"{recipe.name}+fl{k}",
                                   layers=rows)

    @classmethod
    def ramp(cls, recipe: PrecisionRecipe, n_layers: int,
             frac: float = 0.5) -> "PrecisionPlan":
        """Depth-graded preset: linear FP8 -> FP4 ramp over the first
        ``frac`` of the depth.  Three rungs per class — protected (FP8),
        hybrid (FP4 forward / FP8 backward), full recipe — assigned
        linearly over the ramp region; the remaining depth runs the
        recipe unchanged."""
        ramp_n = max(int(round(frac * n_layers)), 0)
        rungs = (
            LayerRecipe(_protect(recipe.attn_linear),
                        _protect(recipe.ffn_linear)),
            LayerRecipe(_hybrid(recipe.attn_linear),
                        _hybrid(recipe.ffn_linear)),
            LayerRecipe(recipe.attn_linear, recipe.ffn_linear),
        )
        rows = []
        for i in range(n_layers):
            if i >= ramp_n:
                rows.append(rungs[-1])
            else:
                rows.append(rungs[min(i * len(rungs) // ramp_n,
                                      len(rungs) - 1)])
        return cls(f"{recipe.name}+ramp{frac:g}", tuple(rows),
                   recipe.head_linear, recipe.target_precision_frac)

    # -- transforms --------------------------------------------------------

    def promote(self, cls: str, layer: Optional[int] = None,
                to: Optional[MatmulRecipe] = None) -> "PrecisionPlan":
        """Plan with one (layer, class) cell — or a whole class when
        ``layer`` is None, or the head — promoted to higher precision.
        The default target is the role-wise FP8 protection of the cell's
        current recipe (quantized roles -> FP8, passthrough roles — e.g.
        the paper's BF16 FFN dgrad — stay unquantized); pass ``to`` for an
        explicit replacement.  The adaptive controller's per-layer
        demotion rule; no-op (same object) if nothing changes."""
        if cls == "head":
            tgt = to if to is not None else _protect(self.head_linear)
            if self.head_linear == tgt:
                return self
            return dataclasses.replace(
                self, name=f"{self.name}+head=fp8", head_linear=tgt)
        field = _CLASS_FIELD[cls]
        idxs = range(self.n_layers) if layer is None else (layer,)
        rows = list(self.layers)
        changed = False
        for i in idxs:
            cur = getattr(rows[i], field)
            tgt = to if to is not None else _protect(cur)
            if cur != tgt:
                rows[i] = dataclasses.replace(rows[i], **{field: tgt})
                changed = True
        if not changed:
            return self
        where = f"l{layer:02d}." if layer is not None else ""
        return dataclasses.replace(
            self, name=f"{self.name}+{where}{cls}=fp8", layers=tuple(rows))

    def demote(self, cls: str, layer: Optional[int] = None,
               roles: Tuple[str, ...] = ("wgrad",),
               fmt: str = "fp4_e2m1") -> "PrecisionPlan":
        """Plan with a role *subset* of one (layer, class) cell — or a
        whole class when ``layer`` is None, or the head — lowered to its
        ``fmt`` (default FP4) counterpart.  The asymmetric counterpart of
        :meth:`promote`: only the named role subsets move (default
        ``("wgrad",)`` — the §3.2 observation that the wgrad path
        tolerates FP4 long before dgrad does), only already-quantized
        operands are lowered (a BF16 dgrad never becomes quantized), each
        operand keeps its scaling spec, and FP4 gradient operands gain
        stochastic rounding.  The plan searcher's cost-freeing move;
        no-op (same object) if nothing changes."""
        bad = set(roles) - set(ROLE_SUBSETS)
        if bad:
            raise ValueError(f"unknown role subsets {sorted(bad)}; "
                             f"have {sorted(ROLE_SUBSETS)}")
        tag = f"{'+'.join(roles)}={fmt.split('_')[0]}"
        if cls == "head":
            tgt = _demote_mm(self.head_linear, roles, fmt)
            if self.head_linear == tgt:
                return self
            return dataclasses.replace(
                self, name=f"{self.name}+head.{tag}", head_linear=tgt)
        field = _CLASS_FIELD[cls]
        idxs = range(self.n_layers) if layer is None else (layer,)
        rows = list(self.layers)
        changed = False
        for i in idxs:
            cur = getattr(rows[i], field)
            tgt = _demote_mm(cur, roles, fmt)
            if cur != tgt:
                rows[i] = dataclasses.replace(rows[i], **{field: tgt})
                changed = True
        if not changed:
            return self
        where = f"l{layer:02d}." if layer is not None else ""
        return dataclasses.replace(
            self, name=f"{self.name}+{where}{cls}.{tag}",
            layers=tuple(rows))

    def resize(self, n_layers: int) -> "PrecisionPlan":
        """Plan for a different depth by proportional row mapping (exact
        for uniform plans; used for the audio encoder stack, whose depth
        differs from the decoder the plan was built for)."""
        if n_layers == self.n_layers:
            return self
        if self.n_layers == 1 or n_layers == 1:
            rows = (self.layers[0],) * n_layers
        else:
            rows = tuple(
                self.layers[round(i * (self.n_layers - 1)
                                  / (n_layers - 1))]
                for i in range(n_layers))
        return dataclasses.replace(self, layers=rows)

    # -- serialization (checkpoints / telemetry) ---------------------------

    def to_dict(self) -> Dict:
        """JSON-able dict form (rows deduplicated by reference table)."""
        table: List[Dict] = []
        index: Dict[LayerRecipe, int] = {}
        idxs = []
        for row in self.layers:
            if row not in index:
                index[row] = len(table)
                table.append(row.to_dict())
            idxs.append(index[row])
        return {"name": self.name,
                "head": self.head_linear.to_dict(),
                "target_precision_frac": self.target_precision_frac,
                "rows": table, "layers": idxs}

    @classmethod
    def from_dict(cls, d: Dict) -> "PrecisionPlan":
        table = [LayerRecipe.from_dict(r) for r in d["rows"]]
        return cls(d["name"], tuple(table[i] for i in d["layers"]),
                   MatmulRecipe.from_dict(d["head"]),
                   float(d.get("target_precision_frac", 0.0)))


def as_plan(p: Union[PrecisionPlan, PrecisionRecipe], n_layers: int
            ) -> PrecisionPlan:
    """Coerce a recipe (class template) or plan to a plan of ``n_layers``.

    The single choke point that lets every entry path — tests and serving
    code passing ``RECIPES[...]``, the trainer passing real plans — feed
    the same plan-resolved model internals.  A plan of the wrong depth is
    an error, not a silent broadcast."""
    if isinstance(p, PrecisionPlan):
        if p.n_layers != n_layers:
            raise ValueError(f"plan {p.name!r} has {p.n_layers} layers, "
                             f"model has {n_layers}")
        return p
    return PrecisionPlan.uniform(p, n_layers)


def stage2_plan(plan: PrecisionPlan, target: PrecisionPlan
                ) -> PrecisionPlan:
    """The §3.3 stage-2 switch as a plan transform: every row and the head
    take the target plan's cells (identity if already equal)."""
    if (plan.layers == target.layers
            and plan.head_linear == target.head_linear):
        return plan
    return dataclasses.replace(
        plan, name=target.name, layers=target.layers,
        head_linear=target.head_linear)


def named_recipe(name: str) -> PrecisionRecipe:
    """Paper recipes + Table-2 ablation grid.

    ``paper_fp4``      : §3 final recipe — attn FP8, FFN fwd FP4/per-block,
                         FFN wgrad FP8, FFN dgrad BF16, + 2-stage schedule.
    ``bf16``           : high-precision baseline (Table 1 'FP16-baseline').
    ``fp8``            : FP8-everywhere (Fishman et al.-style reference).
    ``all_fp4``        : Table 2 row 1 (FP4/FP4/FP4) — the failure mode.
    ``t2_*``           : remaining Table 2 rows.
    ``fine_grained_fp4``: beyond-paper — all-FP4 with per-block scaling AND
                         stochastic rounding on gradients.
    """
    if name in RECIPES:
        return RECIPES[name]
    raise KeyError(f"unknown recipe {name!r}; have {sorted(RECIPES)}")


RECIPES = {
    "bf16": PrecisionRecipe("bf16"),
    "fp8": PrecisionRecipe("fp8", attn_linear=MM_FP8, ffn_linear=MM_FP8),
    "paper_fp4": PrecisionRecipe(
        "paper_fp4", attn_linear=MM_FP8, ffn_linear=MM_FFN_PAPER,
        target_precision_frac=0.075),
    "paper_fp4_nosched": PrecisionRecipe(
        "paper_fp4_nosched", attn_linear=MM_FP8, ffn_linear=MM_FFN_PAPER),
    # --- Table 2 ablation grid (attn / ffn / fp4-linear-backward) ---
    "all_fp4": PrecisionRecipe(  # FP4 | FP4 | FP4
        "all_fp4", attn_linear=MM_FP4_ALL, ffn_linear=MM_FP4_ALL),
    "t2_fp4_fp8_fp8": PrecisionRecipe(  # FP4 attn | FP8 ffn | FP8 bwd
        "t2_fp4_fp8_fp8",
        attn_linear=_mm("fp4", "fp8", "fp8", fwd_gran="block"),
        ffn_linear=MM_FP8),
    "t2_fp8_fp4_fp4": PrecisionRecipe(  # FP8 attn | FP4 ffn | FP4 bwd
        "t2_fp8_fp4_fp4", attn_linear=MM_FP8, ffn_linear=MM_FP4_ALL),
    "t2_fp8_fp4_fp8": PrecisionRecipe(  # FP8 attn | FP4 ffn | FP8 bwd
        "t2_fp8_fp4_fp8", attn_linear=MM_FP8,
        ffn_linear=_mm("fp4", "fp8", "fp8", fwd_gran="block")),
    # --- App. B model-size-dependent variants ---
    "gpt125m_fp4": PrecisionRecipe(  # per-token/channel FP4 fwd+wgrad
        "gpt125m_fp4", attn_linear=MM_FP8,
        ffn_linear=_mm("fp4", "fp4", None, fwd_gran="token",
                       wgrad_gran="token"),
        target_precision_frac=0.075),
    "gpt335m_fp4": PrecisionRecipe(  # per-block wgrad
        "gpt335m_fp4", attn_linear=MM_FP8,
        ffn_linear=_mm("fp4", "fp4", None, fwd_gran="token",
                       wgrad_gran="block"),
        target_precision_frac=0.075),
    "all_fp4_sched": PrecisionRecipe(  # schedule demo on the worst recipe
        "all_fp4_sched", attn_linear=MM_FP4_ALL, ffn_linear=MM_FP4_ALL,
        target_precision_frac=0.1),
    # --- beyond-paper ---
    "fine_grained_fp4": PrecisionRecipe(
        "fine_grained_fp4",
        attn_linear=MM_FP8,
        ffn_linear=dataclasses.replace(
            MM_FP4_ALL,
            wgrad_g=QuantSpec("fp4_e2m1", "block", stochastic=True),
            dgrad_g=QuantSpec("fp8_e5m2", "token")),
        target_precision_frac=0.075),
}
