"""The paper's theoretical compute-cost model (App. B / Tables 2-3),
plan-aware since the layer-resolved refactor.

Counts matmul FLOPs per role (fwd / dgrad / wgrad) and weights them by the
assumed low-precision speedups: FP8 = 2x FP16 throughput, FP4 = 4x.  The
"computation cost" reported in Tables 2/3 is

    cost(plan) / cost(fp16-everything)   (matmul time only).

Two levels of dims:

  * :class:`BlockDims` — one transformer block's shape (the pre-plan
    entry point; Tables 2/3 price a single representative block).
  * :class:`ModelDims` — per-layer resolved flops (one :class:`LayerDims`
    per layer + the lm-head), derived from a ``ModelConfig`` via
    :meth:`ModelDims.from_config`: MoE layers scale FFN flops by the
    router top-k, SSM/hybrid layers price the mamba projections as their
    FFN-class linears, VLM cross-attention sublayers add a second
    attention block, and the lm-head matmul gets its own term.

:func:`plan_cost` prices a whole ``PrecisionPlan`` against ``ModelDims`` —
per-(layer, class, role) — with an exact-parity guarantee: a uniform plan
over uniform per-layer dims degenerates to the *identical* floating-point
arithmetic as the single-block recipe pricing, so
``plan_cost(PrecisionPlan.uniform(r, n), ModelDims.from_block(d, n))``
equals ``theoretical_cost(r, d)`` bit-for-bit (tested for every paper
recipe).  :func:`schedule_cost` integrates the §3.3 stage-2 switch over
the step budget.

Also reproduces Fig. 1(a): the share of block compute held by attention
linears (QKV+O), the attention scores/context matmuls, and the FFN.

**Measured calibration** — the paper factors above are *theory* (bit-width
ratios).  :func:`calibrate` turns a measured speed-factor table (e.g.
``benchmarks.kernel_bench.measure_speed_factors``, wall-clock throughput
of each operand-spec pair relative to the plain matmul) into a
:class:`CostCalibration`, and every pricing entry point
(:func:`speed_factor` / :func:`plan_cost` / :func:`schedule_cost`) takes
an optional ``calibration=`` to price wall clock instead.  The default
(``calibration=None``) is the paper path, bit-exact with the pre-
calibration code (parity-tested), so Tables 2/3 reproduction never moves.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Mapping, Optional, Tuple, Union

from repro.core.quantize import QuantSpec
from repro.core.recipe import (RECIPES, LayerRecipe, MatmulRecipe,
                               PrecisionPlan, PrecisionRecipe, stage2_plan)

__all__ = ["block_flops", "theoretical_cost", "compute_share",
           "speed_factor", "BlockDims", "LayerDims", "ModelDims",
           "plan_cost", "schedule_cost", "schedule_adjusted_cost",
           "paper_calibrated_cost", "CostCalibration", "calibrate"]

_SPEED = {"fp32": 0.5, "fp16": 1.0, "bf16": 1.0,
          "fp8_e4m3": 2.0, "fp8_e5m2": 2.0,
          "fp6_e2m3": 2.0, "fp6_e3m2": 2.0,
          "fp4_e2m1": 4.0, "fp4_e1m2": 4.0}


def _cal_key(spec: QuantSpec) -> str:
    """Calibration key of one operand spec: ``fmt`` for passthrough,
    ``fmt@granularity`` otherwise — scale/rounding flags and block size do
    not change kernel throughput class, granularity does (token/tensor
    scales amortize differently from block/tile)."""
    return spec.fmt if spec.is_passthrough else \
        f"{spec.fmt}@{spec.granularity}"


@dataclasses.dataclass(frozen=True)
class CostCalibration:
    """A measured speed-factor table: ``(key_a, key_b) -> factor`` where a
    key is :func:`_cal_key` of an operand spec and the factor is measured
    matmul throughput relative to the plain (bf16/fp16) matmul at the same
    shape — the same normalization as the paper's ``_SPEED`` theory, so
    calibrated and paper costs share one unit (fp16-matmul time).

    Lookup order: exact ``(a, b)``, swapped ``(b, a)``, then the
    format-only pair (granularity wildcards), then ``None`` — callers fall
    back to the paper factor, so a partial measurement still prices every
    plan.
    """

    table: Mapping[Tuple[str, str], float]
    source: str = "measured"

    def lookup(self, spec_a: QuantSpec,
               spec_b: QuantSpec) -> Optional[float]:
        a, b = _cal_key(spec_a), _cal_key(spec_b)
        for key in ((a, b), (b, a),
                    (spec_a.fmt, spec_b.fmt), (spec_b.fmt, spec_a.fmt)):
            if key in self.table:
                return float(self.table[key])
        return None

    # -- persistence (kernel_bench --measure-speed writes this form) ------

    def to_json(self, path: str) -> None:
        payload = {"schema": "speed_factors.v1", "source": self.source,
                   "factors": {f"{a}|{b}": f
                               for (a, b), f in sorted(self.table.items())}}
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)

    @classmethod
    def from_json(cls, path: str) -> "CostCalibration":
        with open(path) as f:
            payload = json.load(f)
        return calibrate(payload["factors"],
                         source=payload.get("source", path))


def calibrate(measured: Mapping, source: str = "measured"
              ) -> CostCalibration:
    """Build a :class:`CostCalibration` from a measured table whose keys
    are ``(key_a, key_b)`` tuples or ``"key_a|key_b"`` strings (the JSON
    form)."""
    table: Dict[Tuple[str, str], float] = {}
    for k, v in measured.items():
        if isinstance(k, str):
            a, _, b = k.partition("|")
            k = (a, b)
        table[(str(k[0]), str(k[1]))] = float(v)
    return CostCalibration(table, source=source)


def speed_factor(spec_a: QuantSpec, spec_b: QuantSpec,
                 calibration: Optional[CostCalibration] = None) -> float:
    """Throughput multiplier of a matmul: the measured factor when a
    ``calibration`` covers the pair, else the paper theory — min of the
    operand formats' assumed speedups."""
    if calibration is not None:
        f = calibration.lookup(spec_a, spec_b)
        if f is not None:
            return f
    return min(_SPEED[spec_a.fmt], _SPEED[spec_b.fmt])


@dataclasses.dataclass(frozen=True)
class BlockDims:
    d_model: int
    d_ff: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    seq_len: int
    n_ff_matmuls: int = 2  # 2 for gelu MLP, 3 for swiglu
    moe_top_k: int = 1     # active experts per token (1 for dense)


def block_flops(d: BlockDims) -> Dict[str, float]:
    """Forward matmul FLOPs per token for one transformer block, by component.

    Returns {'attn_linear', 'attn_sdpa', 'ffn'} in FLOPs/token (x2 mults+adds).
    """
    dm, hd = d.d_model, d.head_dim
    q_out = d.n_heads * hd
    kv_out = 2 * d.n_kv_heads * hd
    attn_linear = 2 * dm * (q_out + kv_out) + 2 * q_out * dm  # QKV + O
    # scores QK^T + context AV, causal -> seq/2 effective
    attn_sdpa = 2 * 2 * d.n_heads * hd * (d.seq_len / 2)
    ffn = d.n_ff_matmuls * 2 * dm * d.d_ff
    if d.n_ff_matmuls == 3:  # swiglu: gate+up (dm->dff) and down (dff->dm)
        ffn = 2 * (2 * dm * d.d_ff) + 2 * d.d_ff * dm
    ffn *= d.moe_top_k
    return {"attn_linear": attn_linear, "attn_sdpa": attn_sdpa, "ffn": ffn}


def compute_share(d: BlockDims) -> Dict[str, float]:
    """Fig. 1(a): fractional share of block forward compute per component."""
    f = block_flops(d)
    tot = sum(f.values())
    return {k: v / tot for k, v in f.items()}


# ---------------------------------------------------------------------------
# Layer-resolved dims (plan-aware pricing)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerDims:
    """Forward matmul FLOPs/token of one layer, split by plan class.

    ``attn_linear`` prices this layer's attention-class linears, ``ffn``
    its FFN-class ones (dense MLP, MoE experts x top-k, or the mamba
    in/out projections — the same classing ``models`` uses to pick plan
    cells), and ``attn_sdpa`` the scores/context matmuls, which always
    run at FP16 speed (FlashAttention, App. B).
    """

    attn_linear: float
    attn_sdpa: float
    ffn: float

    @classmethod
    def from_block(cls, d: BlockDims) -> "LayerDims":
        f = block_flops(d)
        return cls(f["attn_linear"], f["attn_sdpa"], f["ffn"])


@dataclasses.dataclass(frozen=True)
class ModelDims:
    """Per-layer flops of a whole model: one :class:`LayerDims` row per
    layer (aligned with ``PrecisionPlan.layers``) plus the lm-head matmul
    (``head_flops`` = 0 excludes the head — the single-block Tables-2/3
    accounting)."""

    layers: Tuple[LayerDims, ...]
    head_flops: float = 0.0

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def total_fwd_flops(self) -> float:
        """Forward matmul flops per token, whole model (linears + SDPA +
        lm-head) — the numerator of tokens/sec-based MFU
        (``telemetry.profiler.train_step_flops``)."""
        return sum(ld.attn_linear + ld.attn_sdpa + ld.ffn
                   for ld in self.layers) + self.head_flops

    @classmethod
    def from_block(cls, d: BlockDims, n_layers: int) -> "ModelDims":
        """Uniform depth from a single block's dims, head excluded (the
        pre-plan pricing semantics)."""
        return cls((LayerDims.from_block(d),) * n_layers)

    @classmethod
    def from_config(cls, cfg, seq_len: Optional[int] = None,
                    include_head: bool = True) -> "ModelDims":
        """Resolve a ``configs.base.ModelConfig`` into per-layer dims.

        Walks ``cfg.layer_specs()``: attention mixers price QKV+O and the
        SDPA matmuls (a VLM cross sublayer adds a second set), mamba
        mixers price the in_z/in_x/out_proj projections as FFN-class
        flops (``SCOPE_CLASS`` maps ssm -> ffn, so they run the plan's
        ffn cell), MoE FFNs scale by the router top-k, and the lm-head
        matmul lands in ``head_flops``.
        """
        dm = cfg.d_model
        block = BlockDims(
            d_model=dm, d_ff=cfg.d_ff, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
            seq_len=seq_len or cfg.max_seq_len,
            n_ff_matmuls=3 if cfg.activation == "swiglu" else 2)
        f = block_flops(block)  # the single source of the App.-B formulas
        fm = (block_flops(dataclasses.replace(block,
                                              moe_top_k=cfg.moe.top_k))
              if cfg.moe is not None else None)
        ssm_proj = 0.0
        if cfg.mamba is not None:
            d_inner = cfg.mamba.expand * dm
            # in_z + in_x (dm -> d_inner each) + out_proj (d_inner -> dm)
            ssm_proj = 3 * 2 * dm * d_inner
        rows = []
        for spec in cfg.layer_specs():
            attn = sdpa = ffn = 0.0
            if spec.mixer == "attn":
                attn, sdpa = f["attn_linear"], f["attn_sdpa"]
            else:
                ffn += ssm_proj
            if spec.cross:
                attn += f["attn_linear"]
                sdpa += f["attn_sdpa"]
            if spec.ffn == "dense":
                ffn += f["ffn"]
            elif spec.ffn == "moe":
                ffn += fm["ffn"]
            rows.append(LayerDims(attn, sdpa, ffn))
        head = 2.0 * dm * cfg.vocab_size if include_head else 0.0
        return cls(tuple(rows), head)


# ---------------------------------------------------------------------------
# Pricing
# ---------------------------------------------------------------------------

def _mm_time(flops: float, spec_a: QuantSpec, spec_b: QuantSpec,
             cal: Optional[CostCalibration] = None) -> float:
    return flops / speed_factor(spec_a, spec_b, cal)


def _linear_time(flops_fwd: float, mm: MatmulRecipe,
                 cal: Optional[CostCalibration] = None) -> float:
    """fwd + dgrad + wgrad matmul time for a linear of given forward FLOPs."""
    t = _mm_time(flops_fwd, mm.fwd_x, mm.fwd_w, cal)
    t += _mm_time(flops_fwd, mm.dgrad_g, mm.dgrad_w, cal)
    t += _mm_time(flops_fwd, mm.wgrad_x, mm.wgrad_g, cal)
    return t


def _layer_terms(ld: LayerDims, row: LayerRecipe,
                 cal: Optional[CostCalibration] = None
                 ) -> Tuple[float, float]:
    """(time, fp16-baseline time) of one layer under one plan row."""
    t = _linear_time(ld.attn_linear, row.attn_linear, cal)
    t += _linear_time(ld.ffn, row.ffn_linear, cal)
    t += 3.0 * ld.attn_sdpa  # fwd + bwd at FP16 speed
    baseline = 3.0 * (ld.attn_linear + ld.ffn + ld.attn_sdpa)
    return t, baseline


def _coerce_plan(p: Union[PrecisionPlan, PrecisionRecipe],
                 n_layers: Optional[int] = None) -> PrecisionPlan:
    """Cost entry points accept a plan or a recipe template (uniform plan
    of ``n_layers``, default 1 — the depth cancels for uniform pricing)."""
    if isinstance(p, PrecisionPlan):
        return p
    if isinstance(p, PrecisionRecipe):
        return PrecisionPlan.uniform(p, n_layers or 1)
    raise TypeError(
        f"cost model prices PrecisionPlan / PrecisionRecipe, got "
        f"{type(p).__name__}; the recipe-only entry points are deprecated "
        "— coerce via core.recipe.as_plan")


def plan_cost(plan: Union[PrecisionPlan, PrecisionRecipe],
              dims: ModelDims,
              calibration: Optional[CostCalibration] = None) -> float:
    """Matmul time of a whole plan vs the FP16 baseline (Tables 2/3
    "Computation cost", resolved per (layer, class, role)).

    Layers are grouped by (dims row, plan row) and each unique cell is
    priced once.  Exact-parity guarantee: when everything collapses to a
    single group and the head is excluded, the result is ``t / baseline``
    of that one group — the *identical* float arithmetic as the old
    single-block recipe path, so a uniform plan prices bit-identically to
    ``theoretical_cost`` of its template at any depth.

    ``calibration`` swaps the paper speed factors for a measured table
    (see :func:`calibrate`); ``None`` — the default — keeps the paper
    path, bitwise.
    """
    plan = _coerce_plan(plan, dims.n_layers)
    if plan.n_layers != dims.n_layers:
        raise ValueError(f"plan {plan.name!r} has {plan.n_layers} layers, "
                         f"dims has {dims.n_layers}")
    groups: Dict[Tuple[LayerDims, LayerRecipe], int] = {}
    for ld, row in zip(dims.layers, plan.layers):
        groups[(ld, row)] = groups.get((ld, row), 0) + 1
    terms = [(cnt, *_layer_terms(ld, row, calibration))
             for (ld, row), cnt in groups.items()]
    if dims.head_flops:
        terms.append((1, _linear_time(dims.head_flops, plan.head_linear,
                                      calibration),
                      3.0 * dims.head_flops))
    if len(terms) == 1:  # uniform: depth cancels exactly (parity path)
        _, t, baseline = terms[0]
        return t / baseline
    return (sum(c * t for c, t, _ in terms)
            / sum(c * b for c, _, b in terms))


def theoretical_cost(recipe: Union[PrecisionRecipe, PrecisionPlan],
                     d: BlockDims) -> float:
    """Tables 2/3 "Computation cost": matmul time vs the FP16 baseline for
    one representative block.  Accepts the class-template recipe (the
    historical signature) or a full ``PrecisionPlan`` (priced against
    uniform per-layer dims built from ``d``)."""
    plan = _coerce_plan(recipe)
    return plan_cost(plan, ModelDims.from_block(d, plan.n_layers))


def schedule_cost(plan: Union[PrecisionPlan, PrecisionRecipe],
                  dims: ModelDims, *,
                  target: Optional[PrecisionPlan] = None,
                  total_steps: Optional[int] = None,
                  calibration: Optional[CostCalibration] = None) -> float:
    """Cost with the §3.3 stage-2 switch integrated over the step budget.

    Stage 2 runs ``stage2_plan(plan, target)`` (default: the uniform BF16
    baseline, matching ``TargetPrecisionSchedule``).  With ``total_steps``
    the switch step is quantized exactly as the schedule quantizes it
    (``round(total * (1 - frac))``); without, the continuous fraction is
    used.  ``target_precision_frac <= 0`` disables stage 2."""
    plan = _coerce_plan(plan, dims.n_layers)
    lo = plan_cost(plan, dims, calibration)
    frac = plan.target_precision_frac
    if frac <= 0.0:
        return lo
    tgt = target if target is not None else PrecisionPlan.uniform(
        RECIPES["bf16"], plan.n_layers)
    hi = plan_cost(stage2_plan(plan, tgt), dims, calibration)
    if total_steps:
        switch = int(round(total_steps * (1.0 - frac)))
        return (switch * lo + (total_steps - switch) * hi) / total_steps
    return (1.0 - frac) * lo + frac * hi


def schedule_adjusted_cost(recipe: Union[PrecisionRecipe, PrecisionPlan],
                           d: BlockDims) -> float:
    """Cost including the stage-2 high-precision tail (Table 3 rows).

    Historical single-block form: the stage-2 tail is priced at exactly
    1.0 (the FP16 baseline), as the paper tabulates it."""
    plan = _coerce_plan(recipe)
    frac = plan.target_precision_frac
    lo = theoretical_cost(plan, d)
    return (1.0 - frac) * lo + frac * 1.0


# ---------------------------------------------------------------------------
# Paper-calibrated variant.
#
# The paper's exact accounting is underdetermined (it reports only the final
# percentages).  Fitting shares (attn-linear a, FFN f, FP16-fixed s) and a
# bwd:fwd weight w to the four low-precision Table-2 rows gives
#     a = 0.14, f = 0.43, s = 0.43, w = 1.0      (rmse 0.001)
# — i.e. they hold ~43% of block-adjacent compute at FP16 (SDPA + LM head +
# other non-quantized matmuls for a 125M model) and weight backward equal to
# forward.  ``paper_calibrated_cost`` reproduces Table 2 to 3 decimal places;
# ``theoretical_cost`` above is our from-first-principles version (identical
# ordering, more aggressive savings because it counts dgrad+wgrad = 2x fwd
# and only SDPA as fixed).
# ---------------------------------------------------------------------------

_CAL = {"a": 0.14, "f": 0.43, "w": 1.0}


def paper_calibrated_cost(
        recipe: Union[PrecisionRecipe, PrecisionPlan]) -> float:
    plan = _coerce_plan(recipe)
    a, f, w = _CAL["a"], _CAL["f"], _CAL["w"]
    s = 1.0 - a - f
    fwd, bwd = 1.0 / (1.0 + w), w / (1.0 + w)

    def lin(mm: MatmulRecipe) -> float:
        sf = speed_factor(mm.fwd_x, mm.fwd_w)
        # backward speed: slowest of the two backward matmuls
        sb = min(speed_factor(mm.dgrad_g, mm.dgrad_w),
                 speed_factor(mm.wgrad_x, mm.wgrad_g))
        return fwd / sf + bwd / sb

    def class_mean(field: str) -> float:
        """Depth-mean of lin() over the plan's rows; a single unique row
        returns its value directly (recipe-path parity)."""
        groups: Dict[MatmulRecipe, int] = {}
        for row in plan.layers:
            mm = getattr(row, field)
            groups[mm] = groups.get(mm, 0) + 1
        if len(groups) == 1:
            return lin(next(iter(groups)))
        return (sum(cnt * lin(mm) for mm, cnt in groups.items())
                / plan.n_layers)

    return a * class_mean("attn_linear") + f * class_mean("ffn_linear") + s
