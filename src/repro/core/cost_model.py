"""The paper's theoretical compute-cost model (App. B / Tables 2-3).

Counts matmul FLOPs of a transformer block per role (fwd / dgrad / wgrad) and
weights them by the assumed low-precision speedups: FP8 = 2x FP16 throughput,
FP4 = 4x.  The "computation cost" reported in Tables 2/3 is

    cost(recipe) / cost(fp16-everything)   (matmul time only).

Also reproduces Fig. 1(a): the share of block compute held by attention
linears (QKV+O), the attention scores/context matmuls, and the FFN.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.quantize import QuantSpec
from repro.core.recipe import MatmulRecipe, PrecisionRecipe

__all__ = ["block_flops", "theoretical_cost", "compute_share", "speed_factor"]

_SPEED = {"fp32": 0.5, "fp16": 1.0, "bf16": 1.0,
          "fp8_e4m3": 2.0, "fp8_e5m2": 2.0,
          "fp6_e2m3": 2.0, "fp6_e3m2": 2.0,
          "fp4_e2m1": 4.0, "fp4_e1m2": 4.0}


def speed_factor(spec_a: QuantSpec, spec_b: QuantSpec) -> float:
    """Throughput multiplier of a matmul = min of its operand formats."""
    return min(_SPEED[spec_a.fmt], _SPEED[spec_b.fmt])


@dataclasses.dataclass(frozen=True)
class BlockDims:
    d_model: int
    d_ff: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    seq_len: int
    n_ff_matmuls: int = 2  # 2 for gelu MLP, 3 for swiglu
    moe_top_k: int = 1     # active experts per token (1 for dense)


def block_flops(d: BlockDims) -> Dict[str, float]:
    """Forward matmul FLOPs per token for one transformer block, by component.

    Returns {'attn_linear', 'attn_sdpa', 'ffn'} in FLOPs/token (x2 mults+adds).
    """
    dm, hd = d.d_model, d.head_dim
    q_out = d.n_heads * hd
    kv_out = 2 * d.n_kv_heads * hd
    attn_linear = 2 * dm * (q_out + kv_out) + 2 * q_out * dm  # QKV + O
    # scores QK^T + context AV, causal -> seq/2 effective
    attn_sdpa = 2 * 2 * d.n_heads * hd * (d.seq_len / 2)
    ffn = d.n_ff_matmuls * 2 * dm * d.d_ff
    if d.n_ff_matmuls == 3:  # swiglu: gate+up (dm->dff) and down (dff->dm)
        ffn = 2 * (2 * dm * d.d_ff) + 2 * d.d_ff * dm
    ffn *= d.moe_top_k
    return {"attn_linear": attn_linear, "attn_sdpa": attn_sdpa, "ffn": ffn}


def compute_share(d: BlockDims) -> Dict[str, float]:
    """Fig. 1(a): fractional share of block forward compute per component."""
    f = block_flops(d)
    tot = sum(f.values())
    return {k: v / tot for k, v in f.items()}


def _mm_time(flops: float, spec_a: QuantSpec, spec_b: QuantSpec) -> float:
    return flops / speed_factor(spec_a, spec_b)


def _linear_time(flops_fwd: float, mm: MatmulRecipe) -> float:
    """fwd + dgrad + wgrad matmul time for a linear of given forward FLOPs."""
    t = _mm_time(flops_fwd, mm.fwd_x, mm.fwd_w)
    t += _mm_time(flops_fwd, mm.dgrad_g, mm.dgrad_w)
    t += _mm_time(flops_fwd, mm.wgrad_x, mm.wgrad_g)
    return t


def theoretical_cost(recipe: PrecisionRecipe, d: BlockDims) -> float:
    """Tables 2/3 "Computation cost": matmul time vs the FP16 baseline.

    Attention SDPA always runs at FP16 speed (FlashAttention, §App. B), and
    its backward costs ~2x its forward.
    """
    f = block_flops(d)
    t = _linear_time(f["attn_linear"], recipe.attn_linear)
    t += _linear_time(f["ffn"], recipe.ffn_linear)
    t += 3.0 * f["attn_sdpa"]  # fwd + bwd at FP16 speed
    baseline = 3.0 * (f["attn_linear"] + f["ffn"] + f["attn_sdpa"])
    return t / baseline


def schedule_adjusted_cost(recipe: PrecisionRecipe, d: BlockDims) -> float:
    """Cost including the stage-2 high-precision tail (Table 3 rows)."""
    frac = recipe.target_precision_frac
    lo = theoretical_cost(recipe, d)
    return (1.0 - frac) * lo + frac * 1.0


# ---------------------------------------------------------------------------
# Paper-calibrated variant.
#
# The paper's exact accounting is underdetermined (it reports only the final
# percentages).  Fitting shares (attn-linear a, FFN f, FP16-fixed s) and a
# bwd:fwd weight w to the four low-precision Table-2 rows gives
#     a = 0.14, f = 0.43, s = 0.43, w = 1.0      (rmse 0.001)
# — i.e. they hold ~43% of block-adjacent compute at FP16 (SDPA + LM head +
# other non-quantized matmuls for a 125M model) and weight backward equal to
# forward.  ``paper_calibrated_cost`` reproduces Table 2 to 3 decimal places;
# ``theoretical_cost`` above is our from-first-principles version (identical
# ordering, more aggressive savings because it counts dgrad+wgrad = 2x fwd
# and only SDPA as fixed).
# ---------------------------------------------------------------------------

_CAL = {"a": 0.14, "f": 0.43, "w": 1.0}


def paper_calibrated_cost(recipe: PrecisionRecipe) -> float:
    a, f, w = _CAL["a"], _CAL["f"], _CAL["w"]
    s = 1.0 - a - f
    fwd, bwd = 1.0 / (1.0 + w), w / (1.0 + w)

    def lin(mm: MatmulRecipe) -> float:
        sf = speed_factor(mm.fwd_x, mm.fwd_w)
        # backward speed: slowest of the two backward matmuls
        sb = min(speed_factor(mm.dgrad_g, mm.dgrad_w),
                 speed_factor(mm.wgrad_x, mm.wgrad_g))
        return fwd / sf + bwd / sb

    return a * lin(recipe.attn_linear) + f * lin(recipe.ffn_linear) + s
