"""Module-less functional NN substrate.

Parameters are pytrees of arrays described by ``ParamSpec`` pytrees (shape +
logical sharding axes + initializer).  Layers are pure functions
``f(params, x, ...)``.  This keeps every model compatible with pjit/scan and
makes the logical->physical sharding mapping a pure data transformation
(``repro.distributed.sharding``).
"""
from repro.nn.params import ParamSpec, init_params, param_count, spec_shapes
from repro.nn.layers import (linear, gelu, silu, relu2, layer_norm, rms_norm,
                             apply_norm, rope, sincos_positions, shard_hint,
                             set_sharding_context, get_sharding_context)

__all__ = [
    "ParamSpec", "init_params", "param_count", "spec_shapes",
    "linear", "gelu", "silu", "relu2", "layer_norm", "rms_norm",
    "apply_norm", "rope", "sincos_positions", "shard_hint",
    "set_sharding_context", "get_sharding_context",
]
