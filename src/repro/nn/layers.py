"""Stateless layer math: linears, activations, norms, RoPE, sharding hints."""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qlinear import qlinear
from repro.core.recipe import MatmulRecipe

__all__ = ["linear", "gelu", "silu", "relu2", "layer_norm", "rms_norm",
           "apply_norm", "rope", "sincos_positions", "shard_hint",
           "set_sharding_context", "get_sharding_context"]


def linear(x: jnp.ndarray, w: jnp.ndarray, recipe: MatmulRecipe, cfg,
           *, bias: Optional[jnp.ndarray] = None,
           key_data: Optional[jnp.ndarray] = None,
           axes: Optional[Tuple[Optional[str], Optional[str],
                                Optional[str]]] = None) -> jnp.ndarray:
    """Quantized linear over the last axis of ``x``, selecting the matmul
    implementation from ``cfg.linear_impl`` ('qdq' | 'pallas').

    The single call site models use for every recipe-carrying linear, so the
    config knob reaches fwd, dgrad and wgrad of all of them.  ``recipe`` is
    one cell of the active ``PrecisionPlan`` — the layer-resolved row the
    stack looked up for this layer and module class — so per-layer
    precision requires no plumbing below this point.  ``cfg`` is required:
    a call site that forgot it would otherwise silently ignore the user's
    ``linear_impl`` setting.  ``axes`` names the logical matmul dims
    ``(tokens, K, N)`` for SPMD activation/scale placement (see qlinear).
    """
    return qlinear(x, w, recipe, bias=bias, key_data=key_data,
                   impl=cfg.linear_impl, axes=axes)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)


def relu2(x):
    """Squared ReLU (nemotron-4)."""
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS = {"gelu": gelu, "silu": silu, "relu2": relu2}


def rms_norm(x, scale, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(params: dict, x, kind: str):
    if kind == "rmsnorm":
        return rms_norm(x, params["scale"])
    if kind == "layernorm":
        return layer_norm(x, params["scale"], params["bias"])
    raise ValueError(kind)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary position embedding.

    x: (..., seq, heads, head_dim), positions: (seq,) or (batch, seq).
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    if positions.ndim == 1:
        cos, sin = cos[None], sin[None]  # add batch dim
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sincos_positions(seq_len: int, dim: int) -> jnp.ndarray:
    """Fixed sinusoidal position embeddings (whisper encoder)."""
    pos = np.arange(seq_len, dtype=np.float32)[:, None]
    i = np.arange(dim // 2, dtype=np.float32)[None, :]
    ang = pos / (10000.0 ** (2 * i / dim))
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb)


# ---------------------------------------------------------------------------
# Activation sharding hints.
#
# Model code calls ``shard_hint(x, ('batch', 'seq', 'embed'))``; the launcher
# installs a context mapping logical activation axes to mesh axes.  Without a
# context (unit tests, CPU) this is a no-op, so model code stays portable.
# ---------------------------------------------------------------------------

_CTX = threading.local()


def set_sharding_context(ctx) -> None:
    """Install a sharding context (see distributed.sharding.ShardingRules)."""
    _CTX.value = ctx


def get_sharding_context():
    return getattr(_CTX, "value", None)


@contextlib.contextmanager
def sharding_context(ctx):
    prev = get_sharding_context()
    set_sharding_context(ctx)
    try:
        yield
    finally:
        set_sharding_context(prev)


def shard_hint(x: jnp.ndarray,
               axes: Sequence[Optional[str]]) -> jnp.ndarray:
    """Constrain ``x``'s sharding by logical activation axis names (no-op
    when no sharding context is installed)."""
    ctx = get_sharding_context()
    if ctx is None:
        return x
    sharding = ctx.activation_sharding(tuple(axes), x.shape)
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)
