"""ParamSpec pytrees: declarative parameter shapes + logical sharding axes."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ParamSpec", "init_params", "param_count", "spec_shapes"]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative description of one parameter tensor.

    Attributes:
      shape: full (unsharded) shape.
      axes: logical axis name per dim (see distributed.sharding for the
        logical->mesh mapping); ``None`` entries are replicated.
      init: 'normal' (trunc-normal, fan-in scaled unless ``scale``),
        'zeros', 'ones', 'embed' (normal, scale 1/sqrt(d)), 'a_log'
        (mamba A init), 'const'.
      scale: stddev override for 'normal'/'embed', value for 'const'.
      dtype: parameter dtype; defaults to the init call's dtype.
    """

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"
    scale: Optional[float] = None
    dtype: Optional[Any] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(key: jax.Array, spec: ParamSpec, dtype) -> jnp.ndarray:
    dt = spec.dtype or dtype
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dt)
    if spec.init == "ones":
        return jnp.ones(shape, dt)
    if spec.init == "const":
        return jnp.full(shape, spec.scale, dt)
    if spec.init == "a_log":
        # Mamba2 A in [1, 16), stored as log.
        u = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dt)
    if spec.init == "dt_bias":
        # Mamba2 dt init: softplus(dt_bias) ~ LogUniform[1e-3, 1e-1].
        lo, hi = np.log(1e-3), np.log(1e-1)
        dt_val = jnp.exp(jax.random.uniform(key, shape, jnp.float32, lo, hi))
        dt_val = jnp.maximum(dt_val, 1e-4)
        return (dt_val + jnp.log(-jnp.expm1(-dt_val))).astype(dt)
    if spec.init in ("normal", "embed"):
        if spec.scale is not None:
            std = spec.scale
        elif spec.init == "embed":
            std = 1.0 / np.sqrt(shape[-1])
        else:
            # fan-in scaled: last-but-one dim is the reduction dim for
            # (in, out) weight matrices; stacked layers add leading dims.
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 1.0 / np.sqrt(fan_in)
        x = jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)
        return (x * std).astype(dt)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(key: jax.Array, specs, dtype=jnp.float32):
    """Initialize a pytree of arrays from a pytree of ParamSpec."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def spec_shapes(specs, dtype=jnp.float32):
    """ShapeDtypeStruct pytree matching ``init_params`` output (no alloc)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or dtype), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def param_count(specs) -> int:
    """Total parameter count of a ParamSpec pytree."""
    leaves = jax.tree.leaves(specs,
                             is_leaf=lambda x: isinstance(x, ParamSpec))
    return int(sum(np.prod(s.shape) for s in leaves))
