"""Paper config: LLaMA 1B (Table 4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-1b", family="dense",
    n_layers=48, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=3392, vocab_size=32000,
    activation="swiglu", norm="rmsnorm", pos_emb="rope", rope_theta=10000.0,
    max_seq_len=2048,
)
REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         head_dim=16, d_ff=128, vocab_size=512,
                         max_seq_len=256)
SKIP_CELLS = {}
