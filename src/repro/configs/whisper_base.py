"""whisper-base [audio]: enc-dec; conv frontend STUBBED (input_specs provides
precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    activation="gelu", norm="layernorm", pos_emb="learned",
    max_seq_len=32768 + 8, cross_attn_period=1,
    n_encoder_layers=6, n_frames=1500, tie_embeddings=True,
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         head_dim=16, d_ff=128, vocab_size=512,
                         max_seq_len=256, n_encoder_layers=2, n_frames=16,
                         attention_chunk=64)

SKIP_CELLS = {
    "long_500k": "full-attention decoder: no sub-quadratic mechanism "
                 "(practical whisper decode ceiling is 448 tokens; "
                 "decode_32k lowered structurally)",
}
