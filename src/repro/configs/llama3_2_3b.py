"""llama3.2-3b [dense]: small llama3, GQA, tied embeddings [hf:meta-llama]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=128256,
    activation="swiglu", norm="rmsnorm", pos_emb="rope", rope_theta=500000.0,
    max_seq_len=131072, tie_embeddings=True,
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, vocab_size=512,
                         max_seq_len=256, attention_chunk=64)

SKIP_CELLS = {
    "long_500k": "pure full-attention arch: no sub-quadratic mechanism",
}
