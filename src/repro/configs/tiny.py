"""Tiny test config (CI/examples)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tiny", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    activation="swiglu", norm="rmsnorm", pos_emb="rope", rope_theta=10000.0,
    max_seq_len=512, attention_chunk=64,
)
REDUCED = CONFIG
SKIP_CELLS = {}
