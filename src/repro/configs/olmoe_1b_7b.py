"""olmoe-1b-7b [moe]: 64 experts top-8, MHA [arXiv:2409.02060]."""
from repro.configs.base import ModelConfig, MoESettings

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, vocab_size=50304,
    activation="swiglu", norm="rmsnorm", pos_emb="rope", rope_theta=10000.0,
    max_seq_len=32768,
    moe=MoESettings(num_experts=64, top_k=8, group_size=1024),
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         head_dim=16, d_ff=64, vocab_size=512,
                         max_seq_len=256, attention_chunk=64,
                         moe=MoESettings(num_experts=8, top_k=2,
                                         group_size=64))

SKIP_CELLS = {
    "long_500k": "pure full-attention arch: no sub-quadratic mechanism",
}
