"""jamba-1.5-large-398b [hybrid]: Mamba+attention 1:7 interleave, MoE 16e
top-2 every other layer [arXiv:2403.19887].

Adaptation note (DESIGN.md): Jamba-1.5 uses Mamba-1 blocks; we use the
SSD (Mamba-2) chunked-matmul form as the TPU-native equivalent.  Jamba uses
no positional embeddings (pos_emb='none').
"""
from repro.configs.base import MambaSettings, ModelConfig, MoESettings

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    activation="swiglu", norm="rmsnorm", pos_emb="none",
    max_seq_len=1048576,
    attn_layer_period=8,
    moe=MoESettings(num_experts=16, top_k=2, every_k_layers=2,
                    group_size=2048),
    mamba=MambaSettings(d_state=128, d_conv=4, headdim=64, expand=2,
                        n_groups=8, chunk=256),
    optimizer="adafactor",
)

REDUCED = CONFIG.replace(n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, vocab_size=512,
                         max_seq_len=512, attention_chunk=64,
                         moe=MoESettings(num_experts=4, top_k=2,
                                         every_k_layers=2, group_size=64),
                         mamba=MambaSettings(d_state=16, d_conv=4, headdim=16,
                                             expand=2, n_groups=2, chunk=32),
                         optimizer="adamw")

SKIP_CELLS = {}  # hybrid: mamba states + sharded full KV for 9 attn layers
