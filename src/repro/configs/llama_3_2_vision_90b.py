"""llama-3.2-vision-90b [vlm]: cross-attn image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision scaled to 90B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    activation="swiglu", norm="rmsnorm", pos_emb="rope", rope_theta=500000.0,
    max_seq_len=131072, cross_attn_period=5, n_patches=1601,
    optimizer="adafactor",
)

REDUCED = CONFIG.replace(n_layers=5, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, vocab_size=512,
                         max_seq_len=256, n_patches=16, attention_chunk=64,
                         optimizer="adamw")

SKIP_CELLS = {
    "long_500k": "pure full-attention arch: no sub-quadratic mechanism",
}
