"""mamba2-780m [ssm]: attention-free SSD (state-space duality)
[arXiv:2405.21060]."""
from repro.configs.base import MambaSettings, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280, head_dim=1,
    activation="swiglu", norm="rmsnorm", pos_emb="none",
    max_seq_len=1048576, tie_embeddings=True,
    mamba=MambaSettings(d_state=128, d_conv=4, headdim=64, expand=2,
                        n_groups=1, chunk=256),
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, vocab_size=512,
                         max_seq_len=512,
                         mamba=MambaSettings(d_state=16, d_conv=4, headdim=16,
                                             expand=2, n_groups=1, chunk=32))

SKIP_CELLS = {}  # SSM: constant-size state -> long_500k is the headline cell
