"""granite-34b [dense]: code model, MQA (kv=1), GPT-BigCode-style
[arXiv:2405.04324]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab_size=49152,
    activation="gelu", norm="layernorm", pos_emb="learned",
    max_seq_len=32768,
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
                         head_dim=16, d_ff=128, vocab_size=512,
                         max_seq_len=256, attention_chunk=64)

SKIP_CELLS = {
    "long_500k": "pure full-attention arch: no sub-quadratic mechanism",
}
