"""Paper config: GPT-2 335M (Table 4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gpt2-335m", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=50257,
    activation="gelu", norm="layernorm", pos_emb="learned",
    max_seq_len=1024, tie_embeddings=True,
)
REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         head_dim=16, d_ff=128, vocab_size=512,
                         max_seq_len=256)
SKIP_CELLS = {}
