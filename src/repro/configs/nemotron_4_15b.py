"""nemotron-4-15b [dense]: GQA, squared-ReLU MLP [arXiv:2402.16819]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=256000,
    activation="relu2", norm="layernorm", pos_emb="rope", rope_theta=10000.0,
    max_seq_len=32768,
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, vocab_size=512,
                         max_seq_len=256, attention_chunk=64)

SKIP_CELLS = {
    "long_500k": "pure full-attention arch: no sub-quadratic mechanism "
                 "(see DESIGN.md §Arch-applicability)",
}
