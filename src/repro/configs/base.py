"""Model / training configuration dataclasses and the arch registry."""
from __future__ import annotations

import dataclasses
import importlib
from typing import List, Optional, Tuple

__all__ = ["ModelConfig", "MoESettings", "MambaSettings", "LayerSpec",
           "TrainConfig", "ControllerSettings", "get_config", "list_archs",
           "SHAPE_CELLS", "ShapeCell"]


@dataclasses.dataclass(frozen=True)
class MoESettings:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 2048      # router group size (GShard-style)
    every_k_layers: int = 1     # MoE FFN on layers with i % k == k-1
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class MambaSettings:
    d_state: int = 128
    d_conv: int = 4
    headdim: int = 64
    expand: int = 2
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"        # 'attn' | 'mamba'
    cross: bool = False        # extra cross-attention sublayer
    ffn: str = "dense"         # 'dense' | 'moe' | 'none'


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense|moe|vlm|audio|ssm|hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0          # 0 -> d_model // n_heads
    activation: str = "swiglu"  # gelu|swiglu|relu2
    norm: str = "rmsnorm"      # layernorm|rmsnorm
    pos_emb: str = "rope"      # rope|learned|none
    rope_theta: float = 500000.0
    max_seq_len: int = 8192
    sliding_window: int = 0    # 0 = full attention
    tie_embeddings: bool = False
    qkv_bias: bool = False
    # family extensions
    moe: Optional[MoESettings] = None
    mamba: Optional[MambaSettings] = None
    attn_layer_period: int = 0   # hybrid: attention at i % p == p//2
    cross_attn_period: int = 0   # vlm: cross sublayer at i % p == p-2
    n_encoder_layers: int = 0    # audio enc-dec
    n_frames: int = 1500         # audio frontend stub
    n_patches: int = 1601        # vlm frontend stub
    # numerics / compile strategy
    dtype: str = "bfloat16"
    attention_impl: str = "chunked"   # chunked | pallas (TPU flash kernel)
    linear_impl: str = "qdq"          # qdq (unfused sim) | pallas (fused
    #                                   quantize+matmul kernel, fwd+dgrad+wgrad)
    attention_chunk: int = 1024
    # serving-side KV cache payload format (None = compute dtype; an 8-bit
    # format name, e.g. "fp8_e4m3", stores uint8 codes + per-vector scales)
    kv_cache_format: Optional[str] = None
    scan_layers: bool = True
    unroll_attention: bool = False  # python-loop KV chunks (roofline mode)
    remat: bool = True
    remat_policy: str = "full"   # full | dots | none
    z_loss: float = 0.0
    loss_chunk: int = 0          # seq-chunked head+xent (big-vocab memory)
    optimizer: str = "adamw"     # adamw | adafactor

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_specs(self) -> List[LayerSpec]:
        specs = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                specs.append(LayerSpec("mamba", False, "none"))
                continue
            mixer = "attn"
            if self.attn_layer_period:
                p = self.attn_layer_period
                mixer = "attn" if i % p == p // 2 else "mamba"
            cross = bool(self.cross_attn_period
                         and i % self.cross_attn_period
                         == self.cross_attn_period - 2)
            ffn = "dense"
            if mixer == "mamba" and self.family == "ssm":
                ffn = "none"
            elif self.moe is not None:
                k = self.moe.every_k_layers
                ffn = "moe" if i % k == k - 1 else "dense"
            specs.append(LayerSpec(mixer, cross, ffn))
        return specs

    def scan_period(self) -> int:
        """Smallest repeating period of layer_specs (scan group size)."""
        specs = self.layer_specs()
        n = len(specs)
        for p in range(1, n + 1):
            if n % p == 0 and all(specs[i] == specs[i % p]
                                  for i in range(n)):
                return p
        return n

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ControllerSettings:
    """Adaptive-precision controller thresholds (telemetry.controller).

    All decision rules are opt-in: a threshold of 0.0 disables that rule, so
    the default ``ControllerSettings()`` reproduces the static §3.3 schedule.
    """

    # Dynamic target-precision switch: switch to the stage-2 recipe when the
    # EMA of the forward quant relative error crosses this value (OR at the
    # schedule's fixed fraction, whichever comes first).  0 = fraction only.
    switch_error_threshold: float = 0.0
    error_ema_decay: float = 0.9
    # Per-(layer, class) demotion: sustained overflow (clip rate) above the
    # threshold for ``demote_patience`` consecutive steps promotes that one
    # plan cell to FP8 (a ``PrecisionPlan.promote`` transform — one noisy
    # layer no longer demotes the whole class).  0 = disabled.
    demote_overflow_threshold: float = 0.0
    demote_patience: int = 8
    # Loss-spike rollback: loss > spike_factor * EMA(loss) triggers a restore
    # of the last checkpoint + ``replay_steps`` steps at the target (high)
    # precision before FP4 resumes.  0 = disabled.
    spike_factor: float = 0.0
    loss_ema_decay: float = 0.9
    spike_warmup: int = 20       # steps of EMA warmup before spikes arm
    replay_steps: int = 5
    max_rollbacks: int = 2
    # Controller-driven LR backoff: each rollback multiplies the LR scale by
    # ``lr_backoff`` (e.g. 0.5); the scale then recovers geometrically to
    # 1.0 over ~``lr_recovery_steps`` clean steps.  The scale is a traced
    # scalar input of the step graph (no recompile) and persists in the
    # controller's checkpoint state.  0 = disabled.
    lr_backoff: float = 0.0
    lr_recovery_steps: int = 50
    # Telemetry-driven plan search (telemetry.controller.PlanSearcher):
    # every ``plan_search_every`` steps the searcher finalizes a measured
    # (cost, quant-error) frontier point for the running plan and applies
    # one greedy edit — promote the worst-error (layer, class) cell to FP8,
    # or, when the cost budget is exhausted, demote the healthiest cell's
    # wgrad roles to FP4 (``PrecisionPlan.demote``, the asymmetric
    # role-subset transform; dgrad is never demoted).  Search runs in
    # stage 1 only and its state (per-cell error EMAs, applied edits,
    # frontier) persists in the controller checkpoint state, so resume is
    # bit-exact.  Requires ``TrainConfig.telemetry``.
    plan_search: bool = False
    plan_search_every: int = 10       # steps between search moves
    plan_search_cost_budget: float = 0.0   # max plan_cost (1.0 = BF16
    #                                        baseline); 0 = unbounded
    plan_search_max_edits: int = 8    # total edits before the search stops
    plan_search_demote_threshold: float = 0.0  # demote cells whose error
    #                                    EMA is below this; 0 = never demote


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    recipe: str = "paper_fp4"
    total_steps: int = 200
    global_batch: int = 8
    seq_len: int = 512
    microbatch: int = 0          # 0 = no gradient accumulation
    learning_rate: float = 6e-4
    warmup_frac: float = 0.0015
    min_lr_frac: float = 0.1
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    # fault tolerance
    checkpoint_every: int = 0    # 0 = disabled
    checkpoint_dir: str = ""
    keep_checkpoints: int = 3
    async_checkpoint: bool = False
    # distributed extras
    grad_compression: str = "none"   # none | fp8 (error-feedback)
    # Mesh-native training (Trainer builds the mesh + sharding rules when
    # mesh_shape is set; None keeps the single-device step).  mesh_axes
    # defaults to ('data', 'model') truncated/extended to len(mesh_shape)
    # by Trainer.  fsdp shards the embed params over the data axes — turn
    # it OFF when combining with grad_compression='fp8' (the manual-DP
    # compressed reduction needs data-replicated params).
    mesh_shape: Optional[Tuple[int, ...]] = None
    mesh_axes: Optional[Tuple[str, ...]] = None
    fsdp: bool = True
    log_every: int = 10
    # quantization telemetry + adaptive precision (telemetry subsystem)
    telemetry: bool = False          # in-graph quant-health stats as step aux
    telemetry_every: int = 1         # sample stats every N steps (amortizes
    #                                  the tap cost; both graphs stay static)
    telemetry_jsonl: str = ""        # append per-step rows to this JSONL file
    target_recipe: str = "bf16"      # stage-2 recipe of the §3.3 schedule
    controller: Optional[ControllerSettings] = None  # adaptive controller
    # Layer-resolved precision plan (core.recipe.PrecisionPlan) built from
    # ``recipe``: 'uniform' (every layer runs the class template) |
    # 'first_last_k' (first/last ``plan_k`` layers protected at FP8) |
    # 'ramp' (linear FP8->FP4 ramp over the first ``plan_frac`` of depth).
    plan_preset: str = "uniform"
    plan_k: int = 2                  # first_last_k: protected depth
    plan_frac: float = 0.5           # ramp: ramp fraction of the depth
    # Measured-performance observability (telemetry.profiler):
    # profiler_warmup steps are excluded from step-time statistics
    # (compile + autotune); cost_calibration optionally points at a
    # speed_factors.v1 JSON (kernel_bench --measure-speed) so the plan
    # searcher prices plans by measured wall clock instead of the paper's
    # theoretical bit-width factors.  Empty = paper factors (bit-exact
    # legacy behavior).
    profiler_warmup: int = 2
    cost_calibration: str = ""


# ---------------------------------------------------------------------------
# Assigned input-shape cells (LM-family: seq_len x global_batch).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPE_CELLS: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)

ARCHS = [
    "nemotron-4-15b", "llama3.2-3b", "h2o-danube-3-4b", "granite-34b",
    "mixtral-8x22b", "olmoe-1b-7b", "llama-3.2-vision-90b", "whisper-base",
    "mamba2-780m", "jamba-1.5-large-398b",
    # paper's own configs
    "gpt2-125m", "gpt2-335m", "gpt2-774m", "llama-125m", "llama-1b",
    # test config
    "tiny",
]


def _module_name(arch: str) -> str:
    return "repro.configs." + arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    """Load ``src/repro/configs/<arch>.py`` and return its CONFIG."""
    mod = importlib.import_module(_module_name(arch))
    return mod.CONFIG


def list_archs() -> List[str]:
    return list(ARCHS)
