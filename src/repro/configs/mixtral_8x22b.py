"""mixtral-8x22b [moe]: 8 experts top-2, SWA [arXiv:2401.04088]."""
from repro.configs.base import ModelConfig, MoESettings

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768,
    activation="swiglu", norm="rmsnorm", pos_emb="rope", rope_theta=1000000.0,
    max_seq_len=65536, sliding_window=4096,
    moe=MoESettings(num_experts=8, top_k=2, group_size=2048),
    optimizer="adafactor",
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, vocab_size=512,
                         max_seq_len=256, sliding_window=64,
                         attention_chunk=32,
                         moe=MoESettings(num_experts=4, top_k=2,
                                         group_size=64),
                         optimizer="adamw")

SKIP_CELLS = {}  # SWA ring buffer -> long_500k runnable
