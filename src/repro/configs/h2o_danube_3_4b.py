"""h2o-danube-3-4b [dense]: llama+mistral mix with SWA [arXiv:2401.16818]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
    d_ff=10240, vocab_size=32000,
    activation="swiglu", norm="rmsnorm", pos_emb="rope", rope_theta=10000.0,
    max_seq_len=32768, sliding_window=4096,
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, vocab_size=512,
                         max_seq_len=256, sliding_window=64,
                         attention_chunk=32)

# SWA ring-buffer cache makes 500k decode window-bounded -> runnable.
SKIP_CELLS = {}
