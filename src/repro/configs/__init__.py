"""Architecture configs: 10 assigned archs + the paper's own models.

``get_config(name)`` loads ``CONFIG`` from the arch module; each module also
exposes ``REDUCED`` (a tiny same-family config for CPU smoke tests) and
``SKIP_CELLS`` ({cell_name: reason} for inapplicable input-shape cells).
"""
from repro.configs.base import (ModelConfig, MoESettings, MambaSettings,
                                TrainConfig, ShapeCell, SHAPE_CELLS,
                                get_config, list_archs)

__all__ = ["ModelConfig", "MoESettings", "MambaSettings", "TrainConfig",
           "ShapeCell", "SHAPE_CELLS", "get_config", "list_archs"]
