"""Attention: GQA/MQA, causal/sliding-window/cross, chunked flash, KV caches.

Per the paper (§3.1 + App. B): the QKV and output projections are
"attention-protected" linears (FP8 under the paper recipe; the
``MatmulRecipe`` argument is the layer's attn cell of the active
``PrecisionPlan``), while the attention math itself (softmax(QK^T)V) always
runs in the compute dtype via a FlashAttention-equivalent — here a chunked
online-softmax over KV blocks (O(S * chunk) memory), optionally the Pallas
kernel on TPU.

Cache variants:
  * full ring-less cache  (decode with full attention)
  * ring buffer           (sliding-window attention; the sub-quadratic
                           mechanism for the long_500k cells)
  * cross cache           (K/V precomputed once from encoder/vision states)
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.recipe import MatmulRecipe
from repro.nn.layers import linear, rope, shard_hint
from repro.nn.params import ParamSpec

__all__ = ["attn_param_specs", "cross_attn_param_specs", "attention",
           "cross_attention", "attn_cache_spec", "init_attn_cache",
           "chunked_attention", "NEG_INF"]

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def attn_param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    return {
        "wq": ParamSpec((d, nq * hd), ("embed", "heads")),
        "wk": ParamSpec((d, nkv * hd), ("embed", "kv_heads")),
        "wv": ParamSpec((d, nkv * hd), ("embed", "kv_heads")),
        "wo": ParamSpec((nq * hd, d), ("heads", "embed"),
                        scale=1.0 / np.sqrt(nq * hd * max(cfg.n_layers, 1))),
    }


def cross_attn_param_specs(cfg: ModelConfig,
                           kv_dim: Optional[int] = None) -> Dict[str, ParamSpec]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    kv_dim = kv_dim or d
    return {
        "wq": ParamSpec((d, nq * hd), ("embed", "heads")),
        "wk": ParamSpec((kv_dim, nkv * hd), ("embed", "kv_heads")),
        "wv": ParamSpec((kv_dim, nkv * hd), ("embed", "kv_heads")),
        "wo": ParamSpec((nq * hd, d), ("heads", "embed"),
                        scale=1.0 / np.sqrt(nq * hd * max(cfg.n_layers, 1))),
    }


# ---------------------------------------------------------------------------
# Chunked flash attention (pure-jnp FlashAttention equivalent)
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, causal: bool, window: int):
    """(..., Sq, Sk) additive mask from absolute positions.

    ``k_pos`` entries < 0 denote unwritten cache slots (always masked).
    """
    valid = (k_pos >= 0)[..., None, :]
    if causal:
        valid &= k_pos[..., None, :] <= q_pos[..., :, None]
    if window:
        valid &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return jnp.where(valid, 0.0, NEG_INF)


def _repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, KVH, D) -> (B, S, KVH*n_rep, D)."""
    if n_rep == 1:
        return x
    b, s, kvh, d = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, kvh, n_rep, d))
    return x.reshape(b, s, kvh * n_rep, d)


def chunked_attention(
    q: jnp.ndarray,           # (B, Sq, H, D)
    k: jnp.ndarray,           # (B, Sk, KVH, D)
    v: jnp.ndarray,           # (B, Sk, KVH, D)
    q_pos: jnp.ndarray,       # (Sq,) or (B, Sq) absolute positions
    k_pos: jnp.ndarray,       # (Sk,) or (B, Sk) absolute (-1 = invalid)
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
    unroll: bool = False,
) -> jnp.ndarray:
    """Online-softmax attention over KV chunks; O(Sq * chunk) live scores.

    2-D positions (per-slot decode: each batch row at its own offset)
    broadcast into the mask as (B, 1, S) against the (B, H, Sq, Sk) scores.
    """
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    if q_pos.ndim == 2:
        q_pos = q_pos[:, None]            # (B, 1, Sq)
    if k_pos.ndim == 2:
        k_pos = k_pos[:, None]            # (B, 1, Sk)
    n_rep = h // kvh
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = 1.0 / np.sqrt(d)

    chunk = min(chunk, sk)
    n_chunks = sk // chunk
    rem = sk - n_chunks * chunk

    # Operands stay in the compute dtype; dots accumulate in f32 via
    # preferred_element_type (flash-style — avoids live f32 K/V copies).
    qf = (q * jnp.asarray(scale, q.dtype)).transpose(0, 2, 1, 3)  # (B,H,Sq,D)
    kf = k.transpose(0, 2, 1, 3)
    vf = v.transpose(0, 2, 1, 3)
    qf = shard_hint(qf, ("batch", "heads", "seq_q", None))

    def one_chunk(carry, kc, vc, kpos_c):
        m, l, acc = carry
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kc,
                       preferred_element_type=jnp.float32)
        s = s + _mask_bias(q_pos, kpos_c, causal, window)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # Guard fully-masked rows: exp(-inf - (-inf)) must be 0, not 1.
        safe_m = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        corr = jnp.exp(m - safe_m) * (m > NEG_INF / 2)
        p = jnp.exp(s - safe_m[..., None])
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(v.dtype), vc,
            preferred_element_type=jnp.float32)
        return m_new, l, acc

    # Carry inits must match qf's sharding: lax.scan unifies the carry
    # sharding across iterations, so replicated inits would force GSPMD to
    # re-gather the q-sequence dim inside every chunk step (defeats
    # context-parallel attention).
    m0 = shard_hint(jnp.full((b, h, sq), NEG_INF, jnp.float32),
                    ("batch", "heads", "seq_q"))
    l0 = shard_hint(jnp.zeros((b, h, sq), jnp.float32),
                    ("batch", "heads", "seq_q"))
    a0 = shard_hint(jnp.zeros((b, h, sq, d), jnp.float32),
                    ("batch", "heads", "seq_q", None))
    carry = (m0, l0, a0)

    if n_chunks > 0:
        if unroll:
            for i in range(n_chunks):
                sl = slice(i * chunk, (i + 1) * chunk)
                carry = one_chunk(carry, kf[:, :, sl], vf[:, :, sl],
                                  k_pos[..., sl])
        else:
            kc = kf[:, :, :n_chunks * chunk].reshape(
                b, h, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
            vc = vf[:, :, :n_chunks * chunk].reshape(
                b, h, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
            pc = k_pos[..., :n_chunks * chunk]
            pc = jnp.moveaxis(
                pc.reshape(pc.shape[:-1] + (n_chunks, chunk)), -2, 0)

            def body(c, xs):
                return one_chunk(c, *xs), None

            carry, _ = jax.lax.scan(body, carry, (kc, vc, pc))
    if rem:
        carry = one_chunk(carry, kf[:, :, n_chunks * chunk:],
                          vf[:, :, n_chunks * chunk:],
                          k_pos[..., n_chunks * chunk:])

    m, l, acc = carry
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,Sq,H,D)


# ---------------------------------------------------------------------------
# Full attention sublayer (projections + SDPA [+ cache update])
# ---------------------------------------------------------------------------

def attention(
    params: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    x: jnp.ndarray,                      # (B, Sq, D)
    recipe: MatmulRecipe,
    *,
    positions: Optional[jnp.ndarray] = None,   # (Sq,) or (B, Sq) absolute
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    cache_len: Optional[jnp.ndarray] = None,   # int32 scalar or (B,) cached
    causal: bool = True,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Self-attention sublayer.  Returns (out, updated_cache)."""
    b, sq, _ = x.shape
    hd = cfg.resolved_head_dim
    if positions is None:
        positions = jnp.arange(sq, dtype=jnp.int32)

    q = linear(x, params["wq"], recipe, cfg,
               axes=("tokens", "embed", "heads")
               ).reshape(b, sq, cfg.n_heads, hd)
    k = linear(x, params["wk"], recipe, cfg,
               axes=("tokens", "embed", "kv_heads")).reshape(
        b, sq, cfg.n_kv_heads, hd)
    v = linear(x, params["wv"], recipe, cfg,
               axes=("tokens", "embed", "kv_heads")).reshape(
        b, sq, cfg.n_kv_heads, hd)
    if cfg.pos_emb == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    # 'seq_q' is None by default; mapping it to the TP axis enables
    # context-parallel attention (q-sequence sharding) — the fallback when
    # head counts don't divide TP (e.g. llama3.2-3b 24H on model=16).
    q = shard_hint(q, ("batch", "seq_q", "heads", None))
    k = shard_hint(k, ("batch", "seq", "kv_heads", None))
    v = shard_hint(v, ("batch", "seq", "kv_heads", None))

    window = cfg.sliding_window
    new_cache = None
    if cache is None:
        if (cfg.attention_impl == "pallas" and not window
                and q.shape[1] % 128 == 0):
            # TPU flash kernel (interpret-mode on CPU); bwd runs through the
            # chunked-jnp path (kernels.ops custom_vjp) — identical math.
            from repro.kernels import flash_attention as _flash
            out = _flash(q, k, v, causal=causal, chunk=cfg.attention_chunk)
        else:
            out = chunked_attention(
                q, k, v, positions, positions, causal=causal, window=window,
                chunk=cfg.attention_chunk, unroll=cfg.unroll_attention)
    else:
        new_cache, k_all, v_all, k_pos = _update_cache(
            cache, k, v, cache_len, window, cfg.kv_cache_format)
        out = chunked_attention(
            q, k_all, v_all, positions, k_pos, causal=causal, window=window,
            chunk=cfg.attention_chunk, unroll=cfg.unroll_attention)
    out = out.reshape(b, sq, cfg.n_heads * hd)
    return linear(out, params["wo"], recipe, cfg,
                  axes=("tokens", "heads", "embed")), new_cache


def cross_attention(
    params: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    x: jnp.ndarray,                      # (B, Sq, D)
    recipe: MatmulRecipe,
    *,
    kv_states: Optional[jnp.ndarray] = None,   # (B, Skv, Dkv)
    cache: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Cross-attention over encoder/vision states (non-causal).

    Either ``kv_states`` (training/prefill; K/V computed here and returned as
    a cache) or ``cache`` (decode; K/V reused) must be provided.
    """
    b, sq, _ = x.shape
    hd = cfg.resolved_head_dim
    q = linear(x, params["wq"], recipe, cfg,
               axes=("tokens", "embed", "heads")
               ).reshape(b, sq, cfg.n_heads, hd)
    if cache is None:
        skv = kv_states.shape[1]
        k = linear(kv_states, params["wk"], recipe, cfg,
                   axes=("tokens", None, "kv_heads")).reshape(
            b, skv, cfg.n_kv_heads, hd)
        v = linear(kv_states, params["wv"], recipe, cfg,
                   axes=("tokens", None, "kv_heads")).reshape(
            b, skv, cfg.n_kv_heads, hd)
        new_cache = {"k": k, "v": v}
    else:
        k, v = cache["k"], cache["v"]
        new_cache = cache
    skv = k.shape[1]
    kpos = jnp.arange(skv, dtype=jnp.int32)
    qpos = jnp.zeros((sq,), jnp.int32)
    out = chunked_attention(q, k, v, qpos, kpos, causal=False, window=0,
                            chunk=cfg.attention_chunk,
                            unroll=cfg.unroll_attention)
    out = out.reshape(b, sq, cfg.n_heads * hd)
    return linear(out, params["wo"], recipe, cfg,
                  axes=("tokens", "heads", "embed")), new_cache


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------

def attn_cache_spec(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16, per_slot: bool = False
                    ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Cache spec for ONE attention layer.

    Sliding-window configs get a ring buffer bounded by the window size —
    this is what makes long_500k decode sub-quadratic (and sub-linear in
    memory) for SWA archs.

    ``per_slot`` gives every batch row its own position track
    (pos (batch, size) instead of (size,)) so a continuous-batching engine
    can hold slots at different sequence offsets in one cache.

    ``cfg.kv_cache_format`` (serving-side, 8-bit) swaps the K/V leaves for
    uint8 codes plus per-(token, kv-head) f32 scales — quantize on append,
    dequantize on read (see ``core.packed.kv_quantize``).
    """
    size = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    hd = cfg.resolved_head_dim
    kvh = cfg.n_kv_heads
    pos_shape = (batch, size) if per_slot else (size,)
    spec: Dict[str, jax.ShapeDtypeStruct] = {
        "pos": jax.ShapeDtypeStruct(pos_shape, jnp.int32),
    }
    if cfg.kv_cache_format:
        spec["k"] = jax.ShapeDtypeStruct((batch, size, kvh, hd), jnp.uint8)
        spec["v"] = jax.ShapeDtypeStruct((batch, size, kvh, hd), jnp.uint8)
        spec["k_scale"] = jax.ShapeDtypeStruct((batch, size, kvh),
                                               jnp.float32)
        spec["v_scale"] = jax.ShapeDtypeStruct((batch, size, kvh),
                                               jnp.float32)
    else:
        spec["k"] = jax.ShapeDtypeStruct((batch, size, kvh, hd), dtype)
        spec["v"] = jax.ShapeDtypeStruct((batch, size, kvh, hd), dtype)
    return spec


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16,
                    per_slot: bool = False) -> Dict[str, jnp.ndarray]:
    spec = attn_cache_spec(cfg, batch, max_len, dtype, per_slot)
    cache = {k: jnp.zeros(v.shape, v.dtype) for k, v in spec.items()}
    cache["pos"] = jnp.full(spec["pos"].shape, -1, jnp.int32)
    return cache


def _update_cache(cache, k, v, cache_len, window, kv_format=None):
    """Write new K/V at [cache_len, cache_len+sq) (mod ring size).

    ``cache_len`` is either a scalar (whole batch at one offset) or a
    ``(B,)`` vector (per-slot decode: every batch row advances from its own
    length).  Quantized caches store uint8 codes + f32 scales; the read
    side dequantizes the whole cache back into the compute dtype, so the
    attention math itself is unchanged.
    """
    sq = k.shape[1]
    size = cache["k"].shape[1]
    start = cache_len.astype(jnp.int32)
    if start.ndim:                      # per-slot (B,) lengths
        new_pos = start[:, None] + jnp.arange(sq, dtype=jnp.int32)[None]
        # Ring indexing for windowed caches; identity otherwise.
        idx = new_pos % size if window else new_pos
        bidx = jnp.arange(k.shape[0], dtype=jnp.int32)[:, None]

        def put(dst, src):
            return dst.at[bidx, idx].set(src.astype(dst.dtype))
    else:
        new_pos = start + jnp.arange(sq, dtype=jnp.int32)
        idx = new_pos % size if window else new_pos

        def put(dst, src):
            return dst.at[:, idx].set(src.astype(dst.dtype))

    if kv_format is not None and "k_scale" in cache:
        from repro.core.packed import kv_dequantize, kv_quantize
        kc, ks = kv_quantize(k, kv_format)
        vc, vs = kv_quantize(v, kv_format)
        new_cache = {"k": put(cache["k"], kc), "v": put(cache["v"], vc),
                     "k_scale": put(cache["k_scale"], ks),
                     "v_scale": put(cache["v_scale"], vs)}
        k_all = kv_dequantize(new_cache["k"], new_cache["k_scale"],
                              kv_format, k.dtype)
        v_all = kv_dequantize(new_cache["v"], new_cache["v_scale"],
                              kv_format, v.dtype)
    else:
        new_cache = {"k": put(cache["k"], k), "v": put(cache["v"], v)}
        k_all, v_all = new_cache["k"], new_cache["v"]
    pos_new = (put(cache["pos"], new_pos) if cache["pos"].ndim == 2
               else cache["pos"].at[idx].set(new_pos))
    new_cache["pos"] = pos_new
    return new_cache, k_all, v_all, pos_new
