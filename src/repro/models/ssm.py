"""Mamba2 mixer via SSD (state-space duality), in its chunked matmul form.

TPU adaptation: the SSD formulation (Dao & Gu 2024, arXiv:2405.21060)
re-expresses the selective-scan as block matmuls — intra-chunk "attention-
like" products plus a short inter-chunk state recurrence — which is exactly
what the MXU wants (dense 128-aligned dots) instead of the GPU's warp-level
sequential scan.  The in/out projections are FFN-class linears
(they run the layer's ffn plan cell: FP4 fwd / FP8 wgrad under the paper
recipe); the SSD mixing math itself is the
token-mixing component and stays in the compute dtype, analogous to the
paper's attention protection (§3.1) — see DESIGN.md §Arch-applicability.

Shapes: u (B,S,D); internally x (B,S,H,P) with H = expand*D/headdim heads,
B/C (B,S,G,N) with G broadcast groups, dt (B,S,H).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.recipe import MatmulRecipe
from repro.nn.layers import linear, rms_norm, shard_hint, silu
from repro.nn.params import ParamSpec

__all__ = ["mamba_param_specs", "mamba_mixer", "mamba_cache_spec",
           "init_mamba_cache", "ssd_chunked", "ssd_reference"]


def _dims(cfg: ModelConfig):
    st = cfg.mamba
    d_inner = st.expand * cfg.d_model
    nheads = d_inner // st.headdim
    conv_dim = d_inner + 2 * st.n_groups * st.d_state
    return st, d_inner, nheads, conv_dim


def mamba_param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    """Projection weights are SPLIT per segment (z / x / B / C / dt) rather
    than fused like the CUDA reference.  Mathematically identical, but each
    output dim then shards on its own logical axis: a fused (d, 2*d_inner +
    2GN + H) projection forces GSPMD to slice MID-SHARD at the segment
    boundaries, which lowers to a storm of collective-permutes (observed:
    ~18% of jamba-prefill collective bytes + "involuntary full
    rematerialization" warnings).  The depthwise conv splits the same way
    (exact)."""
    st, d_inner, nheads, conv_dim = _dims(cfg)
    d = cfg.d_model
    gn = st.n_groups * st.d_state
    return {
        "in_z": ParamSpec((d, d_inner), ("embed", "mamba_inner")),
        "in_x": ParamSpec((d, d_inner), ("embed", "mamba_inner")),
        "in_b": ParamSpec((d, gn), ("embed", "mamba_groups")),
        "in_c": ParamSpec((d, gn), ("embed", "mamba_groups")),
        "in_dt": ParamSpec((d, nheads), ("embed", "mamba_heads")),
        "conv_wx": ParamSpec((st.d_conv, d_inner), (None, "mamba_inner"),
                             scale=1.0 / np.sqrt(st.d_conv)),
        "conv_wb": ParamSpec((st.d_conv, gn), (None, "mamba_groups"),
                             scale=1.0 / np.sqrt(st.d_conv)),
        "conv_wc": ParamSpec((st.d_conv, gn), (None, "mamba_groups"),
                             scale=1.0 / np.sqrt(st.d_conv)),
        "conv_bx": ParamSpec((d_inner,), ("mamba_inner",), init="zeros"),
        "conv_bb": ParamSpec((gn,), ("mamba_groups",), init="zeros"),
        "conv_bc": ParamSpec((gn,), ("mamba_groups",), init="zeros"),
        "dt_bias": ParamSpec((nheads,), (None,), init="dt_bias",
                             dtype=jnp.float32),
        "a_log": ParamSpec((nheads,), (None,), init="a_log",
                           dtype=jnp.float32),
        "d_skip": ParamSpec((nheads,), (None,), init="ones",
                            dtype=jnp.float32),
        "norm_scale": ParamSpec((d_inner,), ("mamba_inner",), init="zeros"),
        "out_proj": ParamSpec((d_inner, d), ("mamba_inner", "embed"),
                              scale=1.0 / np.sqrt(d_inner *
                                                  max(cfg.n_layers, 1))),
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def _rep_heads(x: jnp.ndarray, h: int) -> jnp.ndarray:
    """(B,S,G,N) -> (B,S,H,N) by repeating groups."""
    g = x.shape[2]
    if g == h:
        return x
    rep = h // g
    b, s, _, n = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, g, rep, n))
    return x.reshape(b, s, h, n)


def ssd_chunked(x, dt, a, bmat, cmat, *, chunk: int,
                initial_state: Optional[jnp.ndarray] = None,
                unroll: bool = False):
    """Chunked SSD.

    Args:
      x: (B, S, H, P) inputs, dt: (B, S, H) post-softplus step sizes,
      a: (H,) negative decay rates, bmat/cmat: (B, S, G, N).
      chunk: chunk length (S must be divisible; callers pad).
      initial_state: (B, H, P, N) or None.
    Returns: (y (B,S,H,P), final_state (B,H,P,N)).
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    bh = _rep_heads(bmat, h)
    ch = _rep_heads(cmat, h)

    f32 = jnp.float32
    dA = (dt.astype(f32) * a.astype(f32)).reshape(b, nc, chunk, h)
    dA_cs = jnp.cumsum(dA, axis=2)                       # (b,c,q,h)
    xdt = (x.astype(f32) * dt.astype(f32)[..., None]).reshape(
        b, nc, chunk, h, p)
    bh = bh.astype(f32).reshape(b, nc, chunk, h, n)
    ch = ch.astype(f32).reshape(b, nc, chunk, h, n)

    # Intra-chunk ("diagonal block"): attention-like masked matmul.
    seg = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]  # (b,c,q,k,h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcqhn,bckhn->bcqkh", ch, bh)
    y_diag = jnp.einsum("bcqkh,bcqkh,bckhp->bcqhp", cb, L, xdt)

    # Per-chunk end states.
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)      # (b,c,q,h)
    states = jnp.einsum("bckhn,bckh,bckhp->bchpn", bh, decay_states, xdt)

    # Inter-chunk recurrence over the nc chunk states.
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                # (b,c,h)
    s0 = (jnp.zeros((b, h, p, n), f32) if initial_state is None
          else initial_state.astype(f32))

    if unroll:
        prevs = []
        st = s0
        for c in range(nc):
            prevs.append(st)
            st = st * chunk_decay[:, c][:, :, None, None] + states[:, c]
        s_prev = jnp.stack(prevs, axis=1)                    # (b,c,h,p,n)
        s_final = st
    else:
        def body(carry, inp):
            st_c, dec_c = inp
            new = carry * dec_c[:, :, None, None] + st_c
            return new, carry
        s_final, s_prev = jax.lax.scan(
            body, s0, (states.transpose(1, 0, 2, 3, 4),
                       chunk_decay.transpose(1, 0, 2)))
        s_prev = s_prev.transpose(1, 0, 2, 3, 4)             # (b,c,h,p,n)

    # Off-diagonal contribution from the carried-in state.
    state_decay = jnp.exp(dA_cs)                             # (b,c,q,h)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", ch, s_prev, state_decay)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), s_final


def ssd_reference(x, dt, a, bmat, cmat,
                  initial_state: Optional[jnp.ndarray] = None):
    """Sequential recurrence oracle (tests): O(S) scan over single steps."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    bh = _rep_heads(bmat, h).astype(jnp.float32)
    ch = _rep_heads(cmat, h).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    st = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(carry, t):
        dA = jnp.exp(dtf[:, t] * a)                          # (b,h)
        upd = jnp.einsum("bhp,bhn->bhpn", xf[:, t] * dtf[:, t][..., None],
                         bh[:, t])
        new = carry * dA[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", ch[:, t], new)
        return new, y

    st, ys = jax.lax.scan(step, st, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), st


# ---------------------------------------------------------------------------
# Mixer sublayer (projections + conv + SSD [+ cache])
# ---------------------------------------------------------------------------

def mamba_cache_spec(cfg: ModelConfig, batch: int,
                     dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    st, d_inner, nheads, conv_dim = _dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, st.d_conv - 1, conv_dim), dtype),
        "state": jax.ShapeDtypeStruct(
            (batch, nheads, st.headdim, st.d_state), jnp.float32),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    return {k: jnp.zeros(v.shape, v.dtype)
            for k, v in mamba_cache_spec(cfg, batch, dtype).items()}


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 history: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv1d.  xbc: (B,S,C), w: (K,C), history: (B,K-1,C)."""
    k = w.shape[0]
    if history is None:
        history = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    xp = jnp.concatenate([history, xbc], axis=1)
    out = jnp.zeros_like(xbc)
    for i in range(k):  # k is tiny (4); unrolled shifts beat conv_general here
        out = out + xp[:, i:i + xbc.shape[1]] * w[i]
    return out + b


def mamba_mixer(
    params: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    x: jnp.ndarray,                      # (B, S, D)
    recipe: MatmulRecipe,
    *,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    decode: bool = False,
    unroll: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Mamba2 block.  Training: cache=None.  Prefill: cache returned.
    Decode: S==1, cache consumed and updated."""
    st, d_inner, nheads, conv_dim = _dims(cfg)
    b, s, _ = x.shape
    gn = st.n_groups * st.d_state

    z = linear(x, params["in_z"], recipe, cfg,
               axes=("tokens", "embed", "mamba_inner"))
    xr = linear(x, params["in_x"], recipe, cfg,
                axes=("tokens", "embed", "mamba_inner"))
    br = linear(x, params["in_b"], recipe, cfg,
                axes=("tokens", "embed", "mamba_groups"))
    cr = linear(x, params["in_c"], recipe, cfg,
                axes=("tokens", "embed", "mamba_groups"))
    dt_raw = linear(x, params["in_dt"], recipe, cfg,
                    axes=("tokens", "embed", "mamba_heads"))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))

    if decode:
        assert cache is not None and s == 1
        xbc = jnp.concatenate([xr, br, cr], axis=-1)
        hist = cache["conv"].astype(xbc.dtype)
        cw = jnp.concatenate([params["conv_wx"], params["conv_wb"],
                              params["conv_wc"]], axis=-1)
        cb = jnp.concatenate([params["conv_bx"], params["conv_bb"],
                              params["conv_bc"]], axis=-1)
        xbc_c = _causal_conv(xbc, cw, cb, hist)
        new_conv = jnp.concatenate([hist, xbc], axis=1)[:, 1:]
        xbc_c = silu(xbc_c)
        xs = xbc_c[..., :d_inner].reshape(b, nheads, st.headdim)
        bmat = xbc_c[..., d_inner:d_inner + gn].reshape(
            b, st.n_groups, st.d_state)
        cmat = xbc_c[..., d_inner + gn:].reshape(b, st.n_groups, st.d_state)
        rep = nheads // st.n_groups
        bh = jnp.repeat(bmat, rep, axis=1).astype(jnp.float32)
        chh = jnp.repeat(cmat, rep, axis=1).astype(jnp.float32)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                             + params["dt_bias"])            # (b,h)
        dA = jnp.exp(dt * a)                                  # (b,h)
        upd = jnp.einsum("bhp,bhn->bhpn",
                         xs.astype(jnp.float32) * dt[..., None], bh)
        state = cache["state"] * dA[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", chh, state)
        y = y + params["d_skip"][:, None] * xs.astype(jnp.float32)
        y = y.reshape(b, 1, d_inner).astype(x.dtype)
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "state": state}
    else:
        init_state = cache["state"] if cache is not None else None
        if cache is not None:
            hist = cache["conv"].astype(xr.dtype)
            hx, hb, hc = (hist[..., :d_inner],
                          hist[..., d_inner:d_inner + gn],
                          hist[..., d_inner + gn:])
        else:
            hx = hb = hc = None
        # per-segment depthwise convs: identical math to the fused conv,
        # but each segment keeps its own sharding (no mid-shard slicing)
        x_c = silu(_causal_conv(xr, params["conv_wx"], params["conv_bx"],
                                hx))
        b_c = silu(_causal_conv(br, params["conv_wb"], params["conv_bb"],
                                hb))
        c_c = silu(_causal_conv(cr, params["conv_wc"], params["conv_bc"],
                                hc))
        xs = x_c.reshape(b, s, nheads, st.headdim)
        bmat = b_c.reshape(b, s, st.n_groups, st.d_state)
        cmat = c_c.reshape(b, s, st.n_groups, st.d_state)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
        xs = shard_hint(xs, ("batch", "seq", "mamba_heads", None))
        # pad to a chunk multiple
        chunk = min(st.chunk, s)
        pad = (-s) % chunk
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, final_state = ssd_chunked(xs, dt, a, bmat, cmat, chunk=chunk,
                                     initial_state=init_state, unroll=unroll)
        y = y[:, :s].astype(jnp.float32)
        y = y + params["d_skip"][:, None] * xs[:, :s].astype(jnp.float32)
        y = y.reshape(b, s, d_inner).astype(x.dtype)
        new_cache = None
        if cache is not None:  # prefill: produce decode cache
            xbc = jnp.concatenate([xr, br, cr], axis=-1)
            tail = xbc[:, -(st.d_conv - 1):]
            pad_t = st.d_conv - 1 - tail.shape[1]
            if pad_t > 0:
                tail = jnp.pad(tail, ((0, 0), (pad_t, 0), (0, 0)))
            new_cache = {"conv": tail.astype(cache["conv"].dtype),
                         "state": final_state}

    y = rms_norm(y * silu(z), params["norm_scale"])
    return linear(y, params["out_proj"], recipe, cfg,
                  axes=("tokens", "mamba_inner", "embed")), new_cache
