"""Unified decoder-layer stack: attn/mamba mixers, dense/MoE FFNs, cross-attn.

One ``Layer`` = pre-norm mixer sublayer (+ optional cross-attn sublayer)
(+ optional FFN sublayer), covering every assigned architecture:

  dense LMs        : attn + dense FFN
  MoE LMs          : attn + MoE FFN
  VLM              : attn (+ cross every k) + dense FFN
  whisper decoder  : attn + cross + dense FFN
  mamba2           : mamba (no FFN)
  jamba            : {attn|mamba by period} + {dense|MoE alternating}

Stacking strategies:
  * ``scan_layers=True``  : lax.scan over repeating groups (small HLO, used
    by the multi-pod dry-run and training);
  * ``scan_layers=False`` : python-loop unroll (exact cost_analysis for the
    roofline pass).

Precision enters as a layer-resolved ``PrecisionPlan`` (``core.recipe``),
resolved here at trace time: unroll mode indexes the plan row per layer;
scan mode partitions the scan groups into maximal contiguous runs whose
layers share a plan signature and emits one ``lax.scan`` per run (a
uniform plan is a single run, reproducing the pre-plan single-scan graph
bit-identically).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp


def _checkpoint(fn, cfg):
    """cfg.remat_policy: 'full' (recompute everything), 'dots' (save matmul
    outputs — trades activation memory for the remat FLOPs), 'none'."""
    if not cfg.remat or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)

from repro.configs.base import LayerSpec, ModelConfig
from repro.core import routing
from repro.core.recipe import LayerRecipe, PrecisionPlan
from repro.models import attention as attn_lib
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.nn.layers import apply_norm, shard_hint
from repro.nn.params import ParamSpec
from repro.telemetry import collect as telemetry

__all__ = ["layer_param_specs", "stack_param_specs", "run_stack",
           "stack_cache_spec", "init_stack_cache"]


def _norm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = {"scale": ParamSpec((cfg.d_model,), ("embed",), init="zeros")}
    if cfg.norm == "layernorm":
        d["bias"] = ParamSpec((cfg.d_model,), ("embed",), init="zeros")
    return d


def layer_param_specs(cfg: ModelConfig, spec: LayerSpec,
                      *, causal: bool = True,
                      kv_dim: Optional[int] = None) -> Dict[str, Any]:
    p: Dict[str, Any] = {}
    if spec.mixer == "attn":
        p["mixer_norm"] = _norm_specs(cfg)
        p["mixer"] = attn_lib.attn_param_specs(cfg)
    else:
        p["mixer_norm"] = _norm_specs(cfg)
        p["mixer"] = ssm_lib.mamba_param_specs(cfg)
    if spec.cross:
        p["cross_norm"] = _norm_specs(cfg)
        p["cross"] = attn_lib.cross_attn_param_specs(cfg, kv_dim)
        # learned gate (llama-3.2-vision style): cross output ramps in from 0
        p["cross_gate"] = ParamSpec((1,), (None,), init="zeros",
                                    dtype=jnp.float32)
    if spec.ffn == "dense":
        p["ffn_norm"] = _norm_specs(cfg)
        p["ffn"] = mlp_lib.mlp_param_specs(cfg)
    elif spec.ffn == "moe":
        p["ffn_norm"] = _norm_specs(cfg)
        p["ffn"] = moe_lib.moe_param_specs(cfg)
    return p


def _stack_specs(tree, n: int, axis_name: Optional[str] = "layers"):
    """Add a leading (n, ...) dim to every ParamSpec in the tree."""
    def bump(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init,
                         s.scale, s.dtype)
    return jax.tree.map(bump, tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def stack_param_specs(cfg: ModelConfig, *, causal: bool = True,
                      kv_dim: Optional[int] = None,
                      specs: Optional[List[LayerSpec]] = None
                      ) -> Dict[str, Any]:
    """Specs for the whole stack.

    scan mode:   {'groups': stacked specs of one period-group}
    unroll mode: {'layers': [per-layer specs]}
    """
    specs = specs if specs is not None else cfg.layer_specs()
    if not cfg.scan_layers:
        return {"layers": [layer_param_specs(cfg, s, causal=causal,
                                             kv_dim=kv_dim) for s in specs]}
    period = _period(specs)
    n_groups = len(specs) // period
    group = {f"l{i:02d}": layer_param_specs(cfg, specs[i], causal=causal,
                                            kv_dim=kv_dim)
             for i in range(period)}
    return {"groups": _stack_specs(group, n_groups)}


def _period(specs: List[LayerSpec]) -> int:
    n = len(specs)
    for p in range(1, n + 1):
        if n % p == 0 and all(specs[i] == specs[i % p] for i in range(n)):
            return p
    return n


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _layer_cache_spec(cfg: ModelConfig, spec: LayerSpec, batch: int,
                      max_len: int, dtype, per_slot: bool = False):
    c: Dict[str, Any] = {}
    if spec.mixer == "attn":
        c["self"] = attn_lib.attn_cache_spec(cfg, batch, max_len, dtype,
                                             per_slot=per_slot)
    else:
        c["self"] = ssm_lib.mamba_cache_spec(cfg, batch, dtype)
    if spec.cross:
        hd = cfg.resolved_head_dim
        n_kv = cfg.n_kv_heads
        n_cross = (cfg.n_patches if cfg.family == "vlm" else cfg.n_frames)
        c["cross"] = {
            "k": jax.ShapeDtypeStruct((batch, n_cross, n_kv, hd), dtype),
            "v": jax.ShapeDtypeStruct((batch, n_cross, n_kv, hd), dtype),
        }
    return c


def stack_cache_spec(cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16,
                     specs: Optional[List[LayerSpec]] = None,
                     per_slot: bool = False):
    """ShapeDtypeStruct cache pytree matching run_stack's cache layout."""
    specs = specs if specs is not None else cfg.layer_specs()
    if not cfg.scan_layers:
        return {"layers": [_layer_cache_spec(cfg, s, batch, max_len, dtype,
                                             per_slot)
                           for s in specs]}
    period = _period(specs)
    n_groups = len(specs) // period

    def bump(s):
        return jax.ShapeDtypeStruct((n_groups,) + s.shape, s.dtype)

    group = {f"l{i:02d}": _layer_cache_spec(cfg, specs[i], batch, max_len,
                                            dtype, per_slot)
             for i in range(period)}
    return {"groups": jax.tree.map(bump, group)}


def init_stack_cache(cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16,
                     specs: Optional[List[LayerSpec]] = None,
                     per_slot: bool = False):
    spec_tree = stack_cache_spec(cfg, batch, max_len, dtype, specs, per_slot)

    def mk(s: jax.ShapeDtypeStruct):
        return jnp.zeros(s.shape, s.dtype)

    cache = jax.tree.map(mk, spec_tree)
    # attention position slots start at -1 (= unwritten)
    def fix_pos(path, leaf):
        if path[-1].key == "pos":
            return jnp.full(leaf.shape, -1, jnp.int32)
        return leaf
    return jax.tree_util.tree_map_with_path(fix_pos, cache)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _run_layer(params, cfg: ModelConfig, spec: LayerSpec, row:
               LayerRecipe, x, *, positions, cross_states, cache,
               cache_len, decode, causal=True, layer_idx=None,
               audit_label=None):
    """One layer, precision-resolved by its plan ``row``.
    Returns (x, new_cache).

    With telemetry enabled, a collection frame is opened around the whole
    layer: the quantized linears inside push per-operand quant-health stats
    into it, and the drained frame rides out through the ``_telemetry``
    cache slot (same channel as ``_moe_aux``) so per-layer stats survive
    both the scan and the unroll stacking strategies.  ``layer_idx`` (int
    in unroll mode, traced scalar in a scan body) routes backward-side
    probe stats into the layer's row.  ``audit_label`` is the STATIC layer
    label for the routing census (``"L3"`` unrolled, ``"L1:8:4"`` for a
    scan-body position standing for ``range(1, 8, 4)``) — usable where
    ``layer_idx`` may be traced.
    """
    new_cache: Dict[str, Any] = {}
    with routing.layer_scope(audit_label), \
            telemetry.layer_frame(layer_idx) as tel_frame:
        # Pre-norm outputs re-enter TP matmuls replicated on embed; the
        # hints pin each sublayer input so GSPMD gathers exactly once here
        # instead of propagating a model-sharded layout into the norm.
        h = shard_hint(apply_norm(params["mixer_norm"], x, cfg.norm),
                       ("batch", "seq", "embed"))
        if spec.mixer == "attn":
            with telemetry.module_scope("attn"):
                out, c = attn_lib.attention(
                    params["mixer"], cfg, h, row.attn_linear,
                    positions=positions,
                    cache=None if cache is None else cache["self"],
                    cache_len=cache_len, causal=causal)
        else:
            with telemetry.module_scope("ssm"):
                out, c = ssm_lib.mamba_mixer(
                    params["mixer"], cfg, h, row.ffn_linear,
                    cache=None if cache is None else cache["self"],
                    decode=decode, unroll=not cfg.scan_layers)
        if cache is not None:
            new_cache["self"] = c if c is not None else cache["self"]
        x = x + out

        if spec.cross:
            h = shard_hint(apply_norm(params["cross_norm"], x, cfg.norm),
                           ("batch", "seq", "embed"))
            cc = cache.get("cross") if (cache is not None and decode) \
                else None
            with telemetry.module_scope("cross"):
                out, ccache = attn_lib.cross_attention(
                    params["cross"], cfg, h, row.attn_linear,
                    kv_states=cross_states, cache=cc)
            gate = jnp.tanh(params["cross_gate"].astype(jnp.float32))
            x = x + (out.astype(jnp.float32) * gate).astype(x.dtype)
            if cache is not None:
                new_cache["cross"] = ccache

        if spec.ffn == "dense":
            h = shard_hint(apply_norm(params["ffn_norm"], x, cfg.norm),
                           ("batch", "seq", "embed"))
            with telemetry.module_scope("ffn"):
                x = x + mlp_lib.mlp(params["ffn"], cfg, h, row.ffn_linear)
        elif spec.ffn == "moe":
            h = shard_hint(apply_norm(params["ffn_norm"], x, cfg.norm),
                           ("batch", "seq", "embed"))
            with telemetry.module_scope("moe"):
                out, aux = moe_lib.moe(params["ffn"], cfg, h,
                                       row.ffn_linear)
            x = x + out
            new_cache["_moe_aux"] = aux  # surfaced via cache slot in unroll
        x = shard_hint(x, ("batch", "seq", "embed"))
    if tel_frame is not None and tel_frame.stats:
        new_cache["_telemetry"] = tel_frame.stats
    return x, new_cache


def run_stack(params, cfg: ModelConfig, plan: PrecisionPlan,
              x: jnp.ndarray, *,
              positions: Optional[jnp.ndarray] = None,
              cross_states: Optional[jnp.ndarray] = None,
              cache=None, cache_len=None, decode: bool = False,
              specs: Optional[List[LayerSpec]] = None,
              causal: bool = True, indexed_probes: bool = True):
    """Run the full layer stack under a layer-resolved ``PrecisionPlan``.

    Returns (x, new_cache_or_None, aux_losses: dict of scalars).

    ``indexed_probes=False`` disables per-layer backward-probe indexing
    (taps fold into the class-aggregate trailing row).  The audio encoder
    stack uses this: its layer indices would otherwise collide with the
    decoder's rows in the shared probe arrays and could mis-drive the
    controller's per-layer demotion.
    """
    specs = specs if specs is not None else cfg.layer_specs()
    assert plan.n_layers == len(specs), (plan.n_layers, len(specs))
    aux_total: Dict[str, jnp.ndarray] = {}

    def add_aux(aux):
        for k, v in aux.items():
            aux_total[k] = aux_total.get(k, 0.0) + v

    if not cfg.scan_layers:
        layer_params = params["layers"]
        layer_caches = (cache["layers"] if cache is not None
                        else [None] * len(specs))
        new_caches = []
        for i, spec in enumerate(specs):
            fn = functools.partial(
                _run_layer, cfg=cfg, spec=spec, row=plan.layers[i],
                positions=positions, cross_states=cross_states,
                cache_len=cache_len, decode=decode, causal=causal,
                layer_idx=i if indexed_probes else None,
                audit_label=f"L{i}")
            if cfg.remat and cfg.remat_policy != "none" and cache is None:
                ckpt = _checkpoint(
                    lambda p, y, _fn=fn: _fn(p, x=y, cache=None), cfg)
                x, c = ckpt(layer_params[i], x)
            else:
                x, c = fn(layer_params[i], x=x, cache=layer_caches[i])
            if isinstance(c, dict) and "_moe_aux" in c:
                add_aux(c.pop("_moe_aux"))
            if isinstance(c, dict) and "_telemetry" in c:
                for k, v in c.pop("_telemetry").items():
                    aux_total[f"tel/l{i:02d}/{k}"] = v
            new_caches.append(c)
        new_cache = ({"layers": new_caches} if cache is not None else None)
        return x, new_cache, aux_total

    # --- scan mode ---
    #
    # The plan partitions the scan groups into maximal contiguous runs of
    # identical layer rows (``plan.scan_runs``); each run is one lax.scan
    # over its slice of the stacked params/cache.  A uniform plan is a
    # single run over the unsliced trees — the same traced graph as the
    # pre-plan single scan.  When a telemetry collector is installed, the
    # group index rides along as an extra scanned input so backward-side
    # probe stats resolve to absolute layers (the graph with telemetry off
    # carries no such input and stays bit-identical).
    period = _period(specs)
    n_groups = len(specs) // period
    gparams = params["groups"]
    gcache = cache["groups"] if cache is not None else None
    runs = plan.scan_runs(period)
    col_on = telemetry.active() is not None and indexed_probes

    new_gcache_runs = []
    carry = (x, cache_len)
    for g0, g1 in runs:
        rows = plan.layers[g0 * period:(g0 + 1) * period]
        whole = (g0, g1) == (0, n_groups)

        def sl(t):
            return t if whole else jax.tree.map(lambda a: a[g0:g1], t)

        def group_body(carry, xs, rows=rows):
            h, clen = carry
            if col_on:
                p_g, c_g, g_idx = xs
            else:
                p_g, c_g = xs
                g_idx = None
            new_c_g = {} if c_g is not None else None
            aux_g = []
            tel_g = {}
            for i in range(period):
                spec = specs[i]
                pos = positions  # absolute positions already supplied
                lidx = None if g_idx is None else g_idx * period + i
                h, c_i = _run_layer(
                    p_g[f"l{i:02d}"], cfg, spec, rows[i], h,
                    positions=pos, cross_states=cross_states,
                    cache=None if c_g is None else c_g[f"l{i:02d}"],
                    cache_len=clen, decode=decode, causal=causal,
                    layer_idx=lidx,
                    audit_label=(f"L{g0 * period + i}:"
                                 f"{g1 * period}:{period}"))
                if isinstance(c_i, dict) and "_moe_aux" in c_i:
                    aux_g.append(c_i.pop("_moe_aux"))
                if isinstance(c_i, dict) and "_telemetry" in c_i:
                    for k, v in c_i.pop("_telemetry").items():
                        tel_g[f"{i:02d}/{k}"] = v
                if new_c_g is not None:
                    new_c_g[f"l{i:02d}"] = c_i
            aux_stacked = jax.tree.map(lambda *xs: sum(xs), *aux_g) \
                if aux_g else {}
            return (h, clen), (new_c_g, aux_stacked, tel_g)

        body = group_body
        if cache is None:
            body = _checkpoint(group_body, cfg)

        g_ids = (jnp.arange(g0, g1),) if col_on else ()
        if gcache is not None:
            carry, (new_c_g, aux_scan, tel_scan) = jax.lax.scan(
                body, carry, (sl(gparams), sl(gcache)) + g_ids)
            new_gcache_runs.append(new_c_g)
        else:
            if col_on:
                def body_nocache(carry, xs):
                    p_g, g_idx = xs
                    return body(carry, (p_g, None, g_idx))
                scan_xs = (sl(gparams), g_ids[0])
            else:
                def body_nocache(carry, p_g):
                    return body(carry, (p_g, None))
                scan_xs = sl(gparams)
            carry, (_, aux_scan, tel_scan) = jax.lax.scan(
                body_nocache, carry, scan_xs)
        if aux_scan:
            add_aux({k: jnp.sum(v) for k, v in aux_scan.items()})
        # Per-layer telemetry: each scanned value is (g1 - g0,); unstack
        # into absolute layer indices (layer = group*period + position).
        for key, v in tel_scan.items():
            i, rest = int(key[:2]), key[3:]
            for g in range(g1 - g0):
                aux_total[f"tel/l{(g0 + g) * period + i:02d}/{rest}"] = v[g]

    x, _ = carry
    if gcache is not None:
        if len(new_gcache_runs) == 1:
            new_cache = {"groups": new_gcache_runs[0]}
        else:
            new_cache = {"groups": jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0),
                *new_gcache_runs)}
    else:
        new_cache = None
    return x, new_cache, aux_total
