"""Mixture-of-Experts FFN (GShard-style top-k routing, capacity dispatch).

Expert matmuls are FFN-class linears — they run this layer's ffn cell of
the active ``PrecisionPlan`` (FP4 forward / FP8 wgrad under the paper
recipe, possibly demoted per layer by the controller).  The router is a tiny nonlinearity-adjacent matmul and stays in
FP32 — exactly the class §3.2 protects (see DESIGN.md §Arch-applicability).

Dispatch uses the classic GShard one-hot capacity einsums, reshaped into
router groups of ``group_size`` tokens so the dispatch tensors stay bounded
and shard cleanly over the data axes.  Experts shard over the 'experts'
logical axis (EP) when divisible; otherwise d_ff shards within each expert
(TP-in-expert) — see distributed.sharding.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import routing
from repro.core.packed import PackedTensor
from repro.core.qlinear import matmul_impl
from repro.core.recipe import MatmulRecipe
from repro.nn.layers import ACTIVATIONS, shard_hint
from repro.nn.params import ParamSpec
from repro.telemetry import collect as telemetry

__all__ = ["moe_param_specs", "moe", "router_loss"]


def moe_param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    assert cfg.moe is not None
    e, d, f = cfg.moe.num_experts, cfg.d_model, cfg.d_ff
    down_scale = 1.0 / np.sqrt(f * max(cfg.n_layers, 1))
    specs = {
        "router": ParamSpec((d, e), ("embed", None), dtype=jnp.float32),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", "mlp")),
        "w_down": ParamSpec((e, f, d), ("experts", "mlp", "embed"),
                            scale=down_scale),
    }
    if cfg.activation == "swiglu":
        specs["w_gate"] = ParamSpec((e, d, f), ("experts", "embed", "mlp"))
    return specs


def _expert_linear(x: jnp.ndarray, w: jnp.ndarray,
                   recipe: MatmulRecipe, impl: str = "qdq") -> jnp.ndarray:
    """Batched per-expert quantized matmul: (E, C, K) @ (E, K, N)."""
    if isinstance(w, PackedTensor):
        # quantize-once serving panels: expand per expert (tile blocks were
        # packed per expert, so this is the exact per-expert QDQ reference)
        w = w.dequantize().astype(x.dtype)
    if recipe.is_passthrough:
        return jnp.einsum("eck,ekn->ecn", x, w)
    key = jnp.zeros((2,), jnp.uint32)
    mm = matmul_impl(impl)
    cell = routing.current_cell()  # static labels for the routing census
    telemetry.tap_matmul_batched(x, w, recipe)  # no-op unless collecting
    y = jax.vmap(lambda a, b: mm(a, b, key, recipe, None, cell))(x, w)
    return telemetry.grad_tap(y, recipe)


def moe(params: Dict[str, jnp.ndarray], cfg: ModelConfig, x: jnp.ndarray,
        recipe: MatmulRecipe) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, S, D) -> (out (B, S, D), aux losses dict)."""
    st = cfg.moe
    b, s, d = x.shape
    tokens = b * s
    gsz = min(st.group_size, tokens)
    # Pad token count to a multiple of the group size (masked tokens get
    # zero gates and never win capacity slots).
    n_groups = -(-tokens // gsz)
    pad = n_groups * gsz - tokens
    xt = x.reshape(tokens, d)
    if pad:
        xt = jnp.concatenate([xt, jnp.zeros((pad, d), x.dtype)], axis=0)
    xg = xt.reshape(n_groups, gsz, d)
    xg = shard_hint(xg, ("batch", None, "embed"))

    # --- routing (fp32) ---
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                 # (G, T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, st.top_k)  # (G, T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)             # renormalize

    e = st.num_experts
    capacity = int(np.ceil(gsz * st.top_k * st.capacity_factor / e))
    capacity = max(capacity, st.top_k)

    # --- capacity assignment (GShard): position of each (token, k) in its
    # expert's queue; tokens beyond capacity are dropped. ---
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # (G,T,K,E)
    # priority: k slots interleaved in token order
    flat = onehot.transpose(0, 2, 1, 3).reshape(n_groups, st.top_k * gsz, e)
    pos = jnp.cumsum(flat, axis=1) - flat                      # (G, KT, E)
    pos = pos.reshape(n_groups, st.top_k, gsz, e).transpose(0, 2, 1, 3)
    within = (pos < capacity)                                  # (G, T, K, E)
    kept = onehot * within
    slot = jnp.einsum("gtke,gtke->gtk", pos, onehot).astype(jnp.int32)

    # combine[g,t,k,e,c] summed over k -> (G, T, E, C)
    slot_oh = jax.nn.one_hot(slot, capacity, dtype=jnp.float32)
    combine = jnp.einsum("gtke,gtkc->gtec", kept * gate_vals[..., None],
                         slot_oh)
    dispatch = (combine > 0).astype(x.dtype)                   # (G, T, E, C)

    # --- expert computation ---
    xin = jnp.einsum("gtec,gtd->gecd", dispatch, xg)           # (G, E, C, D)
    xin = shard_hint(xin, ("batch", "experts", None, "embed"))
    xe = xin.transpose(1, 0, 2, 3).reshape(e, n_groups * capacity, d)
    impl = cfg.linear_impl
    if cfg.activation == "swiglu":
        g_ = _expert_linear(xe, params["w_gate"], recipe, impl)
        u_ = _expert_linear(xe, params["w_up"], recipe, impl)
        h = ACTIVATIONS["silu"](g_) * u_
    else:
        h = ACTIVATIONS[cfg.activation](
            _expert_linear(xe, params["w_up"], recipe, impl))
    out_e = _expert_linear(h, params["w_down"], recipe, impl)  # (E, G*C, D)
    out_e = out_e.reshape(e, n_groups, capacity, d).transpose(1, 0, 2, 3)
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), out_e)

    out = out.reshape(n_groups * gsz, d)[:tokens].reshape(b, s, d)

    # --- aux losses (Shazeer load balancing + router z-loss) ---
    me = probs.mean(axis=(0, 1))                               # (E,)
    ce = onehot.sum(axis=2).mean(axis=(0, 1))                  # (E,)
    lb = e * jnp.sum(me * ce) * st.load_balance_loss
    zl = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * st.router_z_loss
    frac_dropped = 1.0 - jnp.sum(kept) / (n_groups * gsz * st.top_k)
    aux = {"moe_load_balance": lb, "moe_router_z": zl,
           "moe_frac_dropped": frac_dropped}
    return out, aux


def router_loss(aux: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    return aux["moe_load_balance"] + aux["moe_router_z"]
