"""Dense FFN sublayer — the paper's FP4 target (§3.2 Gradient-sensitive)."""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.recipe import MatmulRecipe
from repro.nn.layers import ACTIVATIONS, linear, shard_hint
from repro.nn.params import ParamSpec

__all__ = ["mlp_param_specs", "mlp"]


def mlp_param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    down_scale = 1.0 / np.sqrt(f * max(cfg.n_layers, 1))
    if cfg.activation == "swiglu":
        return {
            "w_gate": ParamSpec((d, f), ("embed", "mlp")),
            "w_up": ParamSpec((d, f), ("embed", "mlp")),
            "w_down": ParamSpec((f, d), ("mlp", "embed"), scale=down_scale),
        }
    return {
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed"), scale=down_scale),
    }


def mlp(params: Dict[str, jnp.ndarray], cfg: ModelConfig, x: jnp.ndarray,
        recipe: MatmulRecipe) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D).  All matmuls quantized per ``recipe`` —
    this layer's ffn cell of the active ``PrecisionPlan``;
    the nonlinearity stays in the compute dtype (§3.2: there is always a
    nonlinear op between linear layers that needs precise representation)."""
    up_axes = ("tokens", "embed", "mlp")
    if cfg.activation == "swiglu":
        g = linear(x, params["w_gate"], recipe, cfg, axes=up_axes)
        u = linear(x, params["w_up"], recipe, cfg, axes=up_axes)
        h = ACTIVATIONS["silu"](g) * u
    else:
        h = ACTIVATIONS[cfg.activation](
            linear(x, params["w_up"], recipe, cfg, axes=up_axes))
    h = shard_hint(h, ("batch", "seq", "mlp"))
    return linear(h, params["w_down"], recipe, cfg,
                  axes=("tokens", "mlp", "embed"))
