"""Unified model API: build_model(cfg) -> Model with init/loss/prefill/decode.

Families: dense | moe | vlm | audio (enc-dec) | ssm | hybrid — all assembled
from the unified stack (models.stack).  Precision enters exclusively through
the ``plan`` argument: a layer-resolved ``PrecisionPlan`` (or a
``PrecisionRecipe`` class template, coerced to the uniform plan via
``core.recipe.as_plan``) threaded to every linear.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.packed import PackedTensor
from repro.core.recipe import PrecisionPlan, as_plan
from repro.models import stack as stack_lib
from repro.nn.layers import (apply_norm, linear, shard_hint,
                             sincos_positions)
from repro.nn.params import ParamSpec, init_params, param_count, spec_shapes
from repro.telemetry import collect as telemetry

__all__ = ["Model", "build_model"]


def _encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    return cfg.replace(n_layers=cfg.n_encoder_layers, family="dense",
                       cross_attn_period=0, attn_layer_period=0, moe=None,
                       sliding_window=0)


class Model:
    """Functional model wrapper (all methods pure; params passed in)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.is_encdec = cfg.family == "audio"
        self.has_cross_inputs = cfg.family in ("vlm", "audio")

    # ------------------------------------------------------------------
    # Parameters
    # ------------------------------------------------------------------

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        d = cfg.d_model
        specs: Dict[str, Any] = {
            "embed": ParamSpec((cfg.vocab_size, d), ("vocab", "embed"),
                               init="embed"),
            "final_norm": stack_lib._norm_specs(cfg),
            "stack": stack_lib.stack_param_specs(cfg),
        }
        if cfg.pos_emb == "learned":
            specs["pos_embed"] = ParamSpec((cfg.max_seq_len, d),
                                           (None, "embed"), init="embed")
        if not cfg.tie_embeddings:
            specs["head"] = ParamSpec((d, cfg.vocab_size),
                                      ("embed", "vocab"),
                                      scale=1.0 / np.sqrt(d))
        if self.is_encdec:
            enc = _encoder_cfg(cfg)
            specs["encoder"] = {
                "stack": stack_lib.stack_param_specs(enc),
                "final_norm": stack_lib._norm_specs(enc),
            }
        return specs

    def init(self, key: jax.Array, dtype=jnp.float32):
        return init_params(key, self.param_specs(), dtype)

    def cast_params(self, params):
        """FP32 master -> compute-dtype copy (explicit-dtype specs, e.g. the
        FP32 router / mamba dt/A params, keep their dtype).  PackedTensor
        leaves (quantize-once serving panels) pass through unchanged — they
        are expanded to the compute dtype at their consuming matmul."""
        specs = self.param_specs()

        def cast(p, s):
            if isinstance(p, PackedTensor):
                return p
            if s.dtype is None and jnp.issubdtype(p.dtype, jnp.floating):
                return p.astype(self._dt)
            return p

        return jax.tree.map(cast, params, specs,
                            is_leaf=lambda x: isinstance(x, PackedTensor))

    def abstract_params(self, dtype=jnp.float32):
        return spec_shapes(self.param_specs(), dtype)

    def param_count(self) -> int:
        return param_count(self.param_specs())

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        cfg = self.cfg
        total = self.param_count()
        if cfg.moe is None:
            return total
        st = cfg.moe
        expert_leaves = 0
        specs = self.param_specs()
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, ParamSpec))[0]:
            if "experts" in (leaf.axes or ()):
                expert_leaves += int(np.prod(leaf.shape))
        inactive = expert_leaves * (1.0 - st.top_k / st.num_experts)
        return int(total - inactive)

    # ------------------------------------------------------------------
    # Embedding / head
    # ------------------------------------------------------------------

    def _embed(self, params, tokens: jnp.ndarray,
               positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        cfg = self.cfg
        x = params["embed"].astype(self._dt)[tokens]
        if cfg.pos_emb == "learned":
            pos = (jnp.arange(tokens.shape[1], dtype=jnp.int32)
                   if positions is None else positions)
            pe = params["pos_embed"].astype(self._dt)[pos]
            # (Sq,) positions broadcast over batch; (B, Sq) per-slot
            # positions (batched decode engine) index per row directly
            x = x + (pe if pos.ndim == tokens.ndim else pe[None])
        return shard_hint(x, ("batch", "seq", "embed"))

    def _head(self, params, x: jnp.ndarray,
              plan: PrecisionPlan) -> jnp.ndarray:
        cfg = self.cfg
        x = apply_norm(params["final_norm"], x, cfg.norm)
        if cfg.tie_embeddings:
            w = params["embed"].astype(self._dt).T
        else:
            w = params["head"].astype(self._dt)
        with telemetry.module_scope("head"):
            logits = linear(x, w, plan.head_linear, cfg,
                            axes=("tokens", "embed", "vocab"))
        return shard_hint(logits, ("batch", "seq", "vocab"))

    def _plan(self, p) -> PrecisionPlan:
        """Coerce a recipe (class template) or plan to this model's
        depth-resolved plan (see ``core.recipe.as_plan``)."""
        return as_plan(p, self.cfg.n_layers)

    @property
    def _dt(self):
        return jnp.dtype(self.cfg.dtype)

    # ------------------------------------------------------------------
    # Encoder (audio enc-dec)
    # ------------------------------------------------------------------

    def _encode(self, params, frames: jnp.ndarray,
                plan: PrecisionPlan) -> jnp.ndarray:
        """frames: precomputed conv-frontend embeddings (B, F, D) — stub per
        assignment; adds sinusoidal positions and runs the encoder stack.
        The decoder's plan is depth-resized onto the encoder stack
        (proportional row mapping; exact for uniform plans)."""
        enc = _encoder_cfg(self.cfg)
        x = frames.astype(self._dt)
        x = x + sincos_positions(x.shape[1], enc.d_model).astype(self._dt)
        x, _, _ = stack_lib.run_stack(
            params["encoder"]["stack"], enc, plan.resize(enc.n_layers), x,
            causal=False, indexed_probes=False)
        return apply_norm(params["encoder"]["final_norm"], x, enc.norm)

    def _cross_states(self, params, batch, plan) -> Optional[jnp.ndarray]:
        if self.cfg.family == "vlm":
            return batch["vision"].astype(self._dt)
        if self.cfg.family == "audio":
            return self._encode(params, batch["frames"], plan)
        return None

    # ------------------------------------------------------------------
    # Training forward / loss
    # ------------------------------------------------------------------

    def forward(self, params, batch: Dict[str, jnp.ndarray],
                plan) -> Tuple[jnp.ndarray, Dict]:
        """Full training-mode forward.  batch['tokens']: (B, S) int32."""
        cfg = self.cfg
        plan = self._plan(plan)
        params = self.cast_params(params)
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        cross = self._cross_states(params, batch, plan)
        x, _, aux = stack_lib.run_stack(
            params["stack"], cfg, plan, x, cross_states=cross)
        logits = self._head(params, x, plan)
        return logits, aux

    def hidden(self, params, batch: Dict[str, jnp.ndarray],
               plan) -> Tuple[jnp.ndarray, Dict]:
        """Training-mode forward up to (but excluding) the LM head."""
        cfg = self.cfg
        plan = self._plan(plan)
        params = self.cast_params(params)
        x = self._embed(params, batch["tokens"])
        cross = self._cross_states(params, batch, plan)
        x, _, aux = stack_lib.run_stack(
            params["stack"], cfg, plan, x, cross_states=cross)
        return apply_norm(params["final_norm"], x, cfg.norm), aux

    def _head_weight(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].astype(self._dt).T
        return params["head"].astype(self._dt)

    @staticmethod
    def _xent_terms(logits: jnp.ndarray, targets: jnp.ndarray):
        """Returns (sum nll, sum lse^2, n_tokens) over masked positions."""
        mask = (targets >= 0)
        lt = jnp.where(mask, targets, 0)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lt[..., None],
                                   axis=-1).squeeze(-1)
        nll = jnp.sum((lse - gold) * mask)
        z2 = jnp.sum((lse * mask) ** 2)
        return nll, z2, mask.sum()

    def loss(self, params, batch: Dict[str, jnp.ndarray],
             plan) -> Tuple[jnp.ndarray, Dict]:
        """Next-token cross-entropy (fp32).  targets==-1 masks a position.

        With ``cfg.loss_chunk > 0`` the head matmul + xent run seq-chunked
        under remat, so the (B, S, vocab) logits are never materialized —
        required for the 128k-256k-vocab configs at train_4k scale.
        """
        cfg = self.cfg
        plan = self._plan(plan)
        targets = batch["targets"]
        if not cfg.loss_chunk:
            logits, aux = self.forward(params, batch, plan)
            nll, z2, n = self._xent_terms(logits, targets)
        else:
            h, aux = self.hidden(params, batch, plan)
            w = self._head_weight(self.cast_params(params))
            c = cfg.loss_chunk
            s = h.shape[1]
            assert s % c == 0, (s, c)
            hc = h.reshape(h.shape[0], s // c, c, -1).transpose(1, 0, 2, 3)
            tc = targets.reshape(targets.shape[0], s // c, c).transpose(
                1, 0, 2)

            @jax.checkpoint
            def chunk_terms(h_c, t_c):
                # telemetry stays off in here: stats pushed from inside the
                # chunk scan could not legally escape its trace scope.
                with telemetry.suppressed():
                    logits = linear(h_c, w, plan.head_linear, cfg,
                                    axes=("tokens", "embed", "vocab"))
                return self._xent_terms(logits, t_c)

            def body(carry, xs):
                nll, z2, n = carry
                d_nll, d_z2, d_n = chunk_terms(*xs)
                return (nll + d_nll, z2 + d_z2, n + d_n), None

            (nll, z2, n), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.int32)), (hc, tc))
        denom = jnp.maximum(n, 1)
        loss = nll / denom
        metrics = {"loss": loss, "tokens": denom}
        if self.cfg.z_loss:
            zl = self.cfg.z_loss * z2 / denom
            loss = loss + zl
            metrics["z_loss"] = zl
        for k, v in aux.items():
            metrics[k] = v
            if k in ("moe_load_balance", "moe_router_z"):
                loss = loss + v
        metrics["total_loss"] = loss
        return loss, metrics

    # ------------------------------------------------------------------
    # Serving: prefill + decode
    # ------------------------------------------------------------------

    def cache_spec(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   per_slot: bool = False):
        """``per_slot=True`` gives every batch row its own length/position
        tracking (the batched continuous-decode engine's slot cache):
        ``length`` becomes ``(batch,)`` and attention ``pos`` buffers gain a
        leading batch dim, so rows can sit at different decode depths."""
        spec = {
            "stack": stack_lib.stack_cache_spec(self.cfg, batch, max_len,
                                                dtype, per_slot=per_slot),
            "length": jax.ShapeDtypeStruct((batch,) if per_slot else (),
                                           jnp.int32),
        }
        return spec

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   per_slot: bool = False):
        return {
            "stack": stack_lib.init_stack_cache(self.cfg, batch, max_len,
                                                dtype, per_slot=per_slot),
            "length": jnp.zeros((batch,) if per_slot else (), jnp.int32),
        }

    def prefill(self, params, batch: Dict[str, jnp.ndarray], cache,
                plan, *, true_length=None) -> Tuple[jnp.ndarray, Any]:
        """Process the prompt; returns (last-position logits, filled cache).

        ``true_length`` (traced scalar) supports bucket-padded prompts: the
        returned logits come from position ``true_length - 1`` instead of
        the last padded column, and the cache length advances by
        ``true_length``.  The padded tail still writes K/V, but at positions
        ``>= true_length`` — causally masked for every later query until a
        real decode step overwrites them, so full-attention logits are
        unchanged.  (Not valid for SSM recurrences or ring-buffer windows —
        the decode engine falls back to exact-length prefill there.)
        """
        cfg = self.cfg
        plan = self._plan(plan)
        params = self.cast_params(params)
        tokens = batch["tokens"]
        sq = tokens.shape[1]
        # absolute positions continue from whatever is already cached
        # (segmented/streaming prefill passes partially-filled caches)
        length = cache["length"].astype(jnp.int32)
        arange = jnp.arange(sq, dtype=jnp.int32)
        positions = (length[:, None] + arange[None] if length.ndim
                     else length + arange)
        x = self._embed(params, tokens, positions=positions)
        cross = self._cross_states(params, batch, plan)
        x, new_stack, _ = stack_lib.run_stack(
            params["stack"], cfg, plan, x, positions=positions,
            cross_states=cross, cache=cache["stack"],
            cache_len=cache["length"], decode=False)
        if true_length is None:
            x_last = x[:, -1:]
            advance = sq
        else:
            tl = jnp.asarray(true_length, jnp.int32)
            x_last = jax.lax.dynamic_slice_in_dim(x, tl - 1, 1, axis=1)
            advance = tl
        logits = self._head(params, x_last, plan)
        return logits, {"stack": new_stack,
                        "length": cache["length"] + advance}

    def decode_step(self, params, token: jnp.ndarray, cache,
                    plan) -> Tuple[jnp.ndarray, Any]:
        """One decode step.  token: (B, 1) int32 -> logits (B, 1, V).

        A per-slot cache (vector ``length``) decodes all rows batched, each
        at its own position — the batched-engine hot path."""
        cfg = self.cfg
        plan = self._plan(plan)
        params = self.cast_params(params)
        pos = cache["length"]
        positions = (pos[:, None] if pos.ndim else pos[None]
                     ).astype(jnp.int32)
        x = self._embed(params, token, positions=positions)
        x, new_stack, _ = stack_lib.run_stack(
            params["stack"], cfg, plan, x, positions=positions,
            cross_states=None, cache=cache["stack"], cache_len=pos,
            decode=True)
        logits = self._head(params, x, plan)
        return logits, {"stack": new_stack, "length": pos + 1}


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
