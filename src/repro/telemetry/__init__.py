"""Quantization telemetry + adaptive precision control.

Two halves (see ISSUE 2 / ROADMAP):

  * ``telemetry.collect`` — trace-time, in-graph collection of per-layer x
    per-role quantization-health statistics (clip/overflow rate, underflow
    rate, quant relative error, scale spread, grad norms).  Stats ride the
    train step as aux outputs; with telemetry disabled nothing is installed
    and the step graph is bit-identical to a build without telemetry.
  * ``telemetry.controller`` — a Python-level ``PrecisionController`` that
    consumes the per-step telemetry history and drives precision decisions:
    dynamic target-precision switching, per-module-class FP4->FP8 demotion,
    and loss-spike rollback + high-precision replay.

``telemetry.writer`` persists the per-step rows as JSONL for post-hoc
analysis (``benchmarks/telemetry_report.py``).
"""
from repro.telemetry import collect  # noqa: F401
from repro.telemetry.controller import (PlanSearcher,  # noqa: F401
                                        PrecisionController)
from repro.telemetry.writer import JsonlWriter  # noqa: F401

__all__ = ["collect", "PrecisionController", "PlanSearcher", "JsonlWriter"]
