"""Adaptive precision controller driven by live quantization telemetry.

Generalizes the static §3.3 two-stage schedule with decision rules (each
opt-in via ``ControllerSettings``, see ``configs.base``):

  * **Dynamic target-precision switch** — switch to the stage-2 (target)
    plan when the EMA of the forward quant relative error crosses a
    threshold, OR at the schedule's fixed fraction, whichever comes first
    (cf. "FP4 All the Way", arXiv:2505.19115, which switches on measured
    quantization noise).
  * **Per-(layer, class) demotion** — sustained wgrad overflow (clip rate)
    for one layer's module class promotes that single cell FP4 -> FP8 via
    a ``PrecisionPlan`` transform.  Since the layer-resolved refactor one
    noisy layer no longer punishes the whole depth: the per-layer stats
    that ride the scan outputs (and the indexed backward probes) drive a
    plan edit of just that (layer, class) cell.  The lm-head (outside the
    stack) demotes as the ``head`` cell.
  * **Loss-spike rollback** — a loss spike against its EMA restores the
    last checkpoint and replays ``replay_steps`` steps at the target (high)
    precision before FP4 resumes.  With ``lr_backoff`` enabled the
    controller also shrinks the learning rate multiplicatively on each
    rollback and recovers it geometrically over ``lr_recovery_steps``
    steps — the LR scale rides the step graph as a traced scalar, so
    backoff never recompiles.
  * **Plan search** (``plan_search``) — :class:`PlanSearcher` walks the
    stage-1 plan toward the cost-vs-quant-error frontier the paper's
    Tables 2-3 frame as the real objective (cf. Quartet, "Native FP4
    Training Can Be Optimal", 2025): every ``plan_search_every`` steps it
    finalizes a *measured* frontier point for the running plan
    (``core.cost_model.plan_cost`` x the window's mean fwd quant error)
    and applies one greedy edit — promote the worst-error (layer, class)
    cell to FP8, or, when the cost budget is exhausted, demote the
    healthiest cell's wgrad roles to FP4 (``PrecisionPlan.demote``; dgrad
    is never touched).  The frontier is kept Pareto-pruned, so it is
    monotone: sorted by cost, error strictly decreases.

The controller is pure Python consuming per-step history rows (the metrics
emitted by the in-graph taps, ``telemetry.collect``); precision changes stay
Python-level plan swaps, so every step graph remains static — exactly the
mechanism the trainer already uses for the fixed schedule.  All decision
state (demoted cells, LR scale, replay window, searcher EMAs/edits/
frontier) persists in the checkpoint extra, so resume across any decision
boundary is bit-exact.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ControllerSettings
from repro.core import recipe as recipe_lib
from repro.core.cost_model import CostCalibration, ModelDims, plan_cost
from repro.core.schedule import TargetPrecisionSchedule
from repro.telemetry.collect import SCOPE_CLASS, cell_error_signals

__all__ = ["PrecisionController", "PlanSearcher"]

_CLASSES = ("attn", "ffn", "head")
_LAYER_SEG = re.compile(r"^l(\d+)$")


def _fwd_error_signal(row: Dict) -> Optional[float]:
    """Mean forward quant relative error across all layers/slots."""
    vals = [v for k, v in row.items()
            if k.startswith("tel/") and "/fwd_" in k
            and k.endswith("/rel_err") and isinstance(v, (int, float))]
    return sum(vals) / len(vals) if vals else None


def _demote_target(key: str) -> Optional[str]:
    """Map a wgrad-clip metric key to its demotion cell.

    Cells are ``"lNN/<cls>"`` for in-stack layers and ``"head"`` for the
    lm-head.  Key shapes:

      tel/lNN/<scope>/mmJ/wgrad_x/clip   fwd-side per-layer tap
      tel/bwd/lNN/<cls>/wgrad_g/clip     indexed backward probe row
      tel/head/mmJ/wgrad_x/clip          root frame (lm-head)
      tel/bwd/head/wgrad_g/clip          head probe aggregate

    Per-class backward aggregates (``tel/bwd/<cls>/...``) are skipped for
    in-stack classes — their layer-resolved rows carry the signal.
    """
    if not (key.startswith("tel/") and "wgrad" in key
            and key.endswith("/clip")):
        return None
    parts = key.split("/")
    if parts[1] == "bwd":
        m = _LAYER_SEG.match(parts[2])
        if m:
            cls = parts[3] if parts[3] in _CLASSES else None
            return f"l{int(m.group(1)):02d}/{cls}" if cls else None
        return "head" if parts[2] == "head" else None
    m = _LAYER_SEG.match(parts[1])
    if m:
        cls = SCOPE_CLASS.get(parts[2], parts[2] if parts[2] in _CLASSES
                              else None)
        return f"l{int(m.group(1)):02d}/{cls}" if cls else None
    scope = parts[1]
    cls = scope if scope in _CLASSES else SCOPE_CLASS.get(scope)
    return "head" if cls == "head" else None


def _wgrad_overflow_by_cell(row: Dict) -> Dict[str, float]:
    """Mean wgrad-operand clip rate per (layer, class) cell."""
    acc: Dict[str, List[float]] = {}
    for k, v in row.items():
        cell = _demote_target(k)
        if cell is not None and isinstance(v, (int, float)):
            acc.setdefault(cell, []).append(float(v))
    return {c: sum(vs) / len(vs) for c, vs in acc.items()}


def _parse_cell(cell: str) -> Tuple[Optional[int], str]:
    """``"l03/ffn"`` -> (3, "ffn");  ``"head"`` -> (None, "head")."""
    if cell == "head":
        return None, "head"
    lseg, cls = cell.split("/")
    return int(lseg[1:]), cls


def _dominates(a: Dict, b: Dict) -> bool:
    """Pareto dominance on (cost, error): a is no worse on both axes and
    strictly better on at least one."""
    return (a["cost"] <= b["cost"] and a["error"] <= b["error"]
            and (a["cost"] < b["cost"] or a["error"] < b["error"]))


class PlanSearcher:
    """Telemetry-driven greedy walk along the cost-vs-quant-error frontier.

    Consumes per-cell quant-error signals (``collect.cell_error_signals``,
    EMA'd), prices candidate plans with ``core.cost_model.plan_cost``, and
    edits the stage-1 plan one cell at a time: promote the worst cell
    (FP4 -> FP8, ``PrecisionPlan.promote``) while the cost budget allows,
    demote the healthiest cell's wgrad roles (FP8 -> FP4,
    ``PrecisionPlan.demote`` — the asymmetric role-subset transform;
    dgrad never moves) to free budget.  Each applied plan runs for a
    measurement window and lands on the frontier with its *measured* mean
    forward quant error, so the frontier is empirical, not modelled.

    All state is JSON-able and float-exact through a json round-trip, so
    checkpoint resume replays the search bit-exactly.

    ``calibration`` (a ``cost_model.CostCalibration``) swaps the paper
    speed factors for measured wall-clock throughput in every
    ``plan_cost`` the search makes — frontier points, budget checks and
    candidate ranking all price the same way, so the frontier is measured
    on BOTH axes.  It is configuration, not search state: it does not
    persist in ``state_dict`` and a resume must be constructed with the
    same table to replay identically.
    """

    def __init__(self, dims: ModelDims, settings: ControllerSettings,
                 calibration: Optional[CostCalibration] = None):
        self.dims = dims
        self.cfg = settings
        self.calibration = calibration
        self.cell_err: Dict[str, float] = {}   # per-cell rel_err EMA
        self.edits: List[List[str]] = []       # applied [op, cell] pairs
        self.frontier: List[Dict] = []         # Pareto-pruned points
        self.done = False
        self._err_sum = 0.0                    # current window accumulator
        self._err_n = 0
        self._window_start: Optional[int] = None
        self._plan_cache: Dict[tuple, recipe_lib.PrecisionPlan] = {}

    # -- plan derivation ---------------------------------------------------

    @staticmethod
    def _apply_edits(base: recipe_lib.PrecisionPlan,
                     edits) -> recipe_lib.PrecisionPlan:
        p = base
        for op, cell in edits:
            layer, cls = _parse_cell(cell)
            p = (p.promote(cls, layer=layer) if op == "promote"
                 else p.demote(cls, layer=layer))
        return p

    def apply(self, base: recipe_lib.PrecisionPlan
              ) -> recipe_lib.PrecisionPlan:
        """Base plan with every applied search edit, cached by
        (base, edits) so repeated lookups return the same plan object
        (the trainer content-addresses compiled steps by plan)."""
        if not self.edits:
            return base
        key = (base, tuple(tuple(e) for e in self.edits))
        if key not in self._plan_cache:
            self._plan_cache[key] = self._apply_edits(base, self.edits)
        return self._plan_cache[key]

    # -- observation / search ----------------------------------------------

    def reset_window(self) -> None:
        """Discard the current measurement window.  The controller calls
        this when a safety demotion changes the effective plan mid-window
        — the partial measurement belongs to the pre-demotion plan and
        must not be attributed to the post-demotion one."""
        self._err_sum, self._err_n = 0.0, 0
        self._window_start = None

    def observe(self, step: int, row: Dict) -> None:
        if self.done:
            return
        d = self.cfg.error_ema_decay
        for cell, e in cell_error_signals(row).items():
            prev = self.cell_err.get(cell)
            self.cell_err[cell] = (e if prev is None
                                   else d * prev + (1 - d) * e)
        e = _fwd_error_signal(row)
        if e is not None:
            if self._window_start is None:
                self._window_start = step
            self._err_sum += e
            self._err_n += 1

    def maybe_move(self, step: int, base: recipe_lib.PrecisionPlan,
                   overlay=None) -> List[Dict]:
        """Finalize the current plan's frontier point and apply the next
        greedy edit, once the measurement window is full.  Returns
        controller events (``frontier_point`` / ``plan_search`` /
        ``plan_search_done``).

        ``overlay`` (the controller passes its ``_demoted_plan``) maps a
        searcher-edited plan to the plan the steps *actually ran* —
        search edits compose with safety demotions, and both the frontier
        pricing/labels and the candidate evaluation use the effective
        plan, so a cell the controller already protected is never
        re-proposed and a point's cost always matches its measured error."""
        if self.done or self._window_start is None or self._err_n == 0:
            return []
        if step - self._window_start + 1 < max(self.cfg.plan_search_every,
                                               1):
            return []
        overlay = overlay or (lambda p: p)
        cur = overlay(self.apply(base))
        point = {"event": "frontier_point", "step": step,
                 "cost": plan_cost(cur, self.dims, self.calibration),
                 "error": self._err_sum / self._err_n,
                 "plan": cur.name,
                 "edits": [list(e) for e in self.edits]}
        self._push_frontier(point)
        events = [point]
        move = self._next_edit(base, cur, overlay)
        if move is None:
            self.done = True
            events.append({"event": "plan_search_done", "step": step,
                           "edits": len(self.edits),
                           "frontier_size": len(self.frontier)})
            return events
        self.edits.append(list(move))
        new = overlay(self.apply(base))
        self._err_sum, self._err_n = 0.0, 0   # fresh window for the new plan
        self._window_start = None
        events.append({"event": "plan_search", "step": step,
                       "op": move[0], "cell": move[1],
                       "cell_error": self.cell_err.get(move[1]),
                       "cost": plan_cost(new, self.dims, self.calibration),
                       "plan": new.name})
        return events

    def _push_frontier(self, point: Dict) -> None:
        keep = [p for p in self.frontier if not _dominates(point, p)]
        if not any(_dominates(p, point)
                   or (p["cost"] == point["cost"]
                       and p["error"] == point["error"]) for p in keep):
            keep.append(point)
        self.frontier = sorted(keep,
                               key=lambda p: (p["cost"], p["error"]))

    def _next_edit(self, base: recipe_lib.PrecisionPlan,
                   cur: recipe_lib.PrecisionPlan,
                   overlay) -> Optional[Tuple[str, str]]:
        """Candidates are judged by their *effective* plan — edits plus
        the overlay — so an edit the overlay nullifies (e.g. promoting a
        cell the controller already demoted) is skipped, not wasted."""
        if len(self.edits) >= self.cfg.plan_search_max_edits:
            return None
        budget = self.cfg.plan_search_cost_budget
        touched = {e[1] for e in self.edits}
        # Promote the worst-error cell whose promotion is a real change
        # and fits the cost budget.
        for cell, err in sorted(self.cell_err.items(),
                                key=lambda kv: (-kv[1], kv[0])):
            if cell in touched:
                continue
            cand = overlay(self._apply_edits(
                base, self.edits + [["promote", cell]]))
            if cand == cur:
                continue
            if budget <= 0 or plan_cost(cand, self.dims,
                                        self.calibration) <= budget:
                return ("promote", cell)
            break  # worst cell busts the budget: free cost via demotion
        # Demote the healthiest cell's wgrad roles (never dgrad).
        thr = self.cfg.plan_search_demote_threshold
        if thr > 0:
            for cell, err in sorted(self.cell_err.items(),
                                    key=lambda kv: (kv[1], kv[0])):
                if err > thr:
                    break
                if cell in touched:
                    continue
                cand = overlay(self._apply_edits(
                    base, self.edits + [["demote", cell]]))
                if cand != cur:
                    return ("demote", cell)
        return None

    # -- checkpoint persistence --------------------------------------------

    def state_dict(self) -> Dict:
        return {"cell_err": dict(self.cell_err),
                "edits": [list(e) for e in self.edits],
                "frontier": [dict(p) for p in self.frontier],
                "done": self.done,
                "err_sum": self._err_sum,
                "err_n": self._err_n,
                "window_start": self._window_start}

    def load_state(self, state: Dict) -> None:
        self.cell_err = {str(k): float(v)
                         for k, v in state.get("cell_err", {}).items()}
        self.edits = [list(e) for e in state.get("edits", [])]
        self.frontier = [dict(p) for p in state.get("frontier", [])]
        self.done = bool(state.get("done", False))
        self._err_sum = float(state.get("err_sum", 0.0))
        self._err_n = int(state.get("err_n", 0))
        ws = state.get("window_start")
        self._window_start = None if ws is None else int(ws)
        self._plan_cache = {}


class PrecisionController:
    """Consumes per-step telemetry rows; owns the active-plan decision."""

    def __init__(self, schedule: TargetPrecisionSchedule,
                 settings: Optional[ControllerSettings] = None,
                 dims: Optional[ModelDims] = None,
                 calibration: Optional[CostCalibration] = None):
        self.schedule = schedule
        self.cfg = settings or ControllerSettings()
        self.error_ema: Optional[float] = None
        self.loss_ema: Optional[float] = None
        self._loss_n = 0
        self.switched_at: Optional[int] = None
        self.demoted: List[str] = []          # "lNN/<cls>" | "head" cells
        self._streak: Dict[str, int] = {}
        self.replay_until: int = -1
        self.rollbacks = 0
        self.lr_scale: float = 1.0
        self.events: List[Dict] = []
        self._plan_cache: Dict[tuple, recipe_lib.PrecisionPlan] = {}
        self.searcher: Optional[PlanSearcher] = None
        if self.cfg.plan_search:
            if dims is None:
                raise ValueError(
                    "ControllerSettings.plan_search needs the model's "
                    "ModelDims — pass PrecisionController(..., dims=...) "
                    "(the Trainer derives them from ModelConfig)")
            self.searcher = PlanSearcher(dims, self.cfg,
                                         calibration=calibration)

    # -- plan selection ----------------------------------------------------

    def active_plan(self, step: int) -> recipe_lib.PrecisionPlan:
        if step < self.replay_until:
            # post-rollback replay at the target precision
            return self._demoted_plan(self.schedule.target_plan)
        if self.switched_at is not None and step >= self.switched_at:
            # dynamic early switch
            return self._demoted_plan(self.schedule.target_plan)
        base = self.schedule.plan_at(step)    # fixed-fraction switch
        if base is self.schedule.plan and self.searcher is not None:
            base = self.searcher.apply(base)  # search edits: stage 1 only
        return self._demoted_plan(base)

    def _demoted_plan(self, base: recipe_lib.PrecisionPlan
                      ) -> recipe_lib.PrecisionPlan:
        """Re-apply every latched demotion to whichever base plan is
        active.  Demotions survive the §3.3 switch: ``promote`` is a
        role-wise no-op on cells the stage-2 plan no longer quantizes, so
        a demoted cell stays protected exactly when the target plan would
        still quantize it.  The cache is keyed by (base, cells) — keyed by
        cells alone, a plan derived from one base would be served for
        another once ``plan_at(step)`` varies."""
        if not self.demoted:
            return base
        key = (base, ",".join(sorted(self.demoted)))
        if key not in self._plan_cache:
            p = base
            for cell in sorted(self.demoted):
                layer, cls = _parse_cell(cell)
                p = p.promote(cls, layer=layer)
            self._plan_cache[key] = p
        return self._plan_cache[key]

    # -- observation -------------------------------------------------------

    def observe(self, step: int, row: Dict) -> List[Dict]:
        """Digest one history row; returns controller events (possibly
        including a ``rollback`` request the trainer must act on)."""
        events: List[Dict] = []
        in_replay = step < self.replay_until
        events += self._observe_error(step, row)
        events += self._observe_overflow(step, row)
        if not in_replay:
            events += self._observe_loss(step, row)
        if self.searcher is not None:
            if any(e["event"] == "demote" for e in events):
                # the effective plan just changed under the searcher: the
                # partial window measured the pre-demotion plan.  Checked
                # unconditionally (demotions latch during replay too, when
                # the search itself is gated off).
                self.searcher.reset_window()
            elif (not in_replay and self.switched_at is None
                    and step + 1 < self.schedule.switch_step):
                # search only while stage 1 still has steps to run: an
                # edit at ``step`` first applies at ``step + 1``
                self.searcher.observe(step, row)
                events += self.searcher.maybe_move(
                    step, self.schedule.plan, overlay=self._demoted_plan)
        self._observe_lr(events)
        self.events += events
        return events

    def _observe_error(self, step: int, row: Dict) -> List[Dict]:
        e = _fwd_error_signal(row)
        if e is None:
            return []
        d = self.cfg.error_ema_decay
        self.error_ema = (e if self.error_ema is None
                          else d * self.error_ema + (1 - d) * e)
        thr = self.cfg.switch_error_threshold
        if (thr > 0 and self.error_ema > thr and self.switched_at is None
                and step < self.schedule.switch_step):
            self.switched_at = step + 1
            return [{"event": "switch", "step": step,
                     "error_ema": self.error_ema,
                     "to": self.schedule.target_plan.name}]
        return []

    def _observe_overflow(self, step: int, row: Dict) -> List[Dict]:
        thr = self.cfg.demote_overflow_threshold
        if thr <= 0:
            return []
        events = []
        for cell, rate in _wgrad_overflow_by_cell(row).items():
            if rate > thr:
                self._streak[cell] = self._streak.get(cell, 0) + 1
            else:
                self._streak[cell] = 0
            if (self._streak[cell] >= self.cfg.demote_patience
                    and cell not in self.demoted):
                self.demoted.append(cell)
                layer, cls = _parse_cell(cell)
                events.append({"event": "demote", "step": step,
                               "cell": cell, "layer": layer,
                               "module_class": cls, "overflow": rate})
        return events

    def _observe_loss(self, step: int, row: Dict) -> List[Dict]:
        if self.cfg.spike_factor <= 0 or "loss" not in row:
            return []
        loss = float(row["loss"])
        self._loss_n += 1
        if self.loss_ema is None:
            self.loss_ema = loss
            return []
        is_spike = (self._loss_n > self.cfg.spike_warmup
                    and loss > self.cfg.spike_factor * self.loss_ema)
        if is_spike and self.rollbacks < self.cfg.max_rollbacks:
            self.rollbacks += 1
            return [{"event": "rollback", "step": step, "loss": loss,
                     "loss_ema": self.loss_ema}]
        d = self.cfg.loss_ema_decay
        self.loss_ema = d * self.loss_ema + (1 - d) * loss
        return []

    # -- LR backoff (satellite: controller-driven LR backoff) --------------

    def _observe_lr(self, events: List[Dict]) -> None:
        """Shrink the LR scale on each rollback; otherwise recover it
        geometrically so it reaches 1.0 after ~``lr_recovery_steps`` clean
        steps per backoff applied."""
        if self.cfg.lr_backoff <= 0:
            return
        if any(e["event"] == "rollback" for e in events):
            self.lr_scale *= self.cfg.lr_backoff
            for e in events:
                if e["event"] == "rollback":
                    e["lr_scale"] = self.lr_scale
        elif self.lr_scale < 1.0:
            rate = (1.0 / self.cfg.lr_backoff) ** (
                1.0 / max(self.cfg.lr_recovery_steps, 1))
            self.lr_scale = min(1.0, self.lr_scale * rate)

    # -- rollback handshake (trainer-owned checkpoint restore) -------------

    def begin_replay(self, restored_step: int) -> None:
        """Trainer restored a checkpoint at ``restored_step``; replay the
        next ``replay_steps`` steps at the target precision."""
        self.replay_until = restored_step + self.cfg.replay_steps
        self._loss_n = 0  # re-warm spike detection after the rewind

    # -- checkpoint persistence --------------------------------------------

    def state_dict(self) -> Dict:
        out = {"switched_at": self.switched_at,
               "demoted": list(self.demoted),
               "replay_until": self.replay_until,
               "rollbacks": self.rollbacks,
               "lr_scale": self.lr_scale}
        if self.searcher is not None:
            out["plan_search"] = self.searcher.state_dict()
        return out

    def load_state(self, state: Dict) -> None:
        self.switched_at = state.get("switched_at")
        self.demoted = list(state.get("demoted", []))
        self.replay_until = int(state.get("replay_until", -1))
        self.rollbacks = int(state.get("rollbacks", 0))
        self.lr_scale = float(state.get("lr_scale", 1.0))
        if self.searcher is not None and state.get("plan_search"):
            self.searcher.load_state(state["plan_search"])
