"""Adaptive precision controller driven by live quantization telemetry.

Generalizes the static §3.3 two-stage schedule with decision rules (each
opt-in via ``ControllerSettings``, see ``configs.base``):

  * **Dynamic target-precision switch** — switch to the stage-2 (target)
    plan when the EMA of the forward quant relative error crosses a
    threshold, OR at the schedule's fixed fraction, whichever comes first
    (cf. "FP4 All the Way", arXiv:2505.19115, which switches on measured
    quantization noise).
  * **Per-(layer, class) demotion** — sustained wgrad overflow (clip rate)
    for one layer's module class promotes that single cell FP4 -> FP8 via
    a ``PrecisionPlan`` transform.  Since the layer-resolved refactor one
    noisy layer no longer punishes the whole depth: the per-layer stats
    that ride the scan outputs (and the indexed backward probes) drive a
    plan edit of just that (layer, class) cell.  The lm-head (outside the
    stack) demotes as the ``head`` cell.
  * **Loss-spike rollback** — a loss spike against its EMA restores the
    last checkpoint and replays ``replay_steps`` steps at the target (high)
    precision before FP4 resumes.  With ``lr_backoff`` enabled the
    controller also shrinks the learning rate multiplicatively on each
    rollback and recovers it geometrically over ``lr_recovery_steps``
    steps — the LR scale rides the step graph as a traced scalar, so
    backoff never recompiles.

The controller is pure Python consuming per-step history rows (the metrics
emitted by the in-graph taps, ``telemetry.collect``); precision changes stay
Python-level plan swaps, so every step graph remains static — exactly the
mechanism the trainer already uses for the fixed schedule.  All decision
state (demoted cells, LR scale, replay window) persists in the checkpoint
extra, so resume across any decision boundary is bit-exact.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ControllerSettings
from repro.core import recipe as recipe_lib
from repro.core.schedule import TargetPrecisionSchedule
from repro.telemetry.collect import SCOPE_CLASS

__all__ = ["PrecisionController"]

_CLASSES = ("attn", "ffn", "head")
_LAYER_SEG = re.compile(r"^l(\d+)$")


def _fwd_error_signal(row: Dict) -> Optional[float]:
    """Mean forward quant relative error across all layers/slots."""
    vals = [v for k, v in row.items()
            if k.startswith("tel/") and "/fwd_" in k
            and k.endswith("/rel_err") and isinstance(v, (int, float))]
    return sum(vals) / len(vals) if vals else None


def _demote_target(key: str) -> Optional[str]:
    """Map a wgrad-clip metric key to its demotion cell.

    Cells are ``"lNN/<cls>"`` for in-stack layers and ``"head"`` for the
    lm-head.  Key shapes:

      tel/lNN/<scope>/mmJ/wgrad_x/clip   fwd-side per-layer tap
      tel/bwd/lNN/<cls>/wgrad_g/clip     indexed backward probe row
      tel/head/mmJ/wgrad_x/clip          root frame (lm-head)
      tel/bwd/head/wgrad_g/clip          head probe aggregate

    Per-class backward aggregates (``tel/bwd/<cls>/...``) are skipped for
    in-stack classes — their layer-resolved rows carry the signal.
    """
    if not (key.startswith("tel/") and "wgrad" in key
            and key.endswith("/clip")):
        return None
    parts = key.split("/")
    if parts[1] == "bwd":
        m = _LAYER_SEG.match(parts[2])
        if m:
            cls = parts[3] if parts[3] in _CLASSES else None
            return f"l{int(m.group(1)):02d}/{cls}" if cls else None
        return "head" if parts[2] == "head" else None
    m = _LAYER_SEG.match(parts[1])
    if m:
        cls = SCOPE_CLASS.get(parts[2], parts[2] if parts[2] in _CLASSES
                              else None)
        return f"l{int(m.group(1)):02d}/{cls}" if cls else None
    scope = parts[1]
    cls = scope if scope in _CLASSES else SCOPE_CLASS.get(scope)
    return "head" if cls == "head" else None


def _wgrad_overflow_by_cell(row: Dict) -> Dict[str, float]:
    """Mean wgrad-operand clip rate per (layer, class) cell."""
    acc: Dict[str, List[float]] = {}
    for k, v in row.items():
        cell = _demote_target(k)
        if cell is not None and isinstance(v, (int, float)):
            acc.setdefault(cell, []).append(float(v))
    return {c: sum(vs) / len(vs) for c, vs in acc.items()}


def _parse_cell(cell: str) -> Tuple[Optional[int], str]:
    """``"l03/ffn"`` -> (3, "ffn");  ``"head"`` -> (None, "head")."""
    if cell == "head":
        return None, "head"
    lseg, cls = cell.split("/")
    return int(lseg[1:]), cls


class PrecisionController:
    """Consumes per-step telemetry rows; owns the active-plan decision."""

    def __init__(self, schedule: TargetPrecisionSchedule,
                 settings: Optional[ControllerSettings] = None):
        self.schedule = schedule
        self.cfg = settings or ControllerSettings()
        self.error_ema: Optional[float] = None
        self.loss_ema: Optional[float] = None
        self._loss_n = 0
        self.switched_at: Optional[int] = None
        self.demoted: List[str] = []          # "lNN/<cls>" | "head" cells
        self._streak: Dict[str, int] = {}
        self.replay_until: int = -1
        self.rollbacks = 0
        self.lr_scale: float = 1.0
        self.events: List[Dict] = []
        self._plan_cache: Dict[str, recipe_lib.PrecisionPlan] = {}

    # -- plan selection ----------------------------------------------------

    def active_plan(self, step: int) -> recipe_lib.PrecisionPlan:
        if step < self.replay_until:
            return self.schedule.target_plan  # post-rollback replay
        if self.switched_at is not None and step >= self.switched_at:
            return self.schedule.target_plan  # dynamic early switch
        base = self.schedule.plan_at(step)    # fixed-fraction switch
        if base is not self.schedule.plan or not self.demoted:
            return base
        return self._demoted_plan(base)

    def _demoted_plan(self, base: recipe_lib.PrecisionPlan
                      ) -> recipe_lib.PrecisionPlan:
        key = ",".join(sorted(self.demoted))
        if key not in self._plan_cache:
            p = base
            for cell in sorted(self.demoted):
                layer, cls = _parse_cell(cell)
                p = p.promote(cls, layer=layer)
            self._plan_cache[key] = p
        return self._plan_cache[key]

    # -- observation -------------------------------------------------------

    def observe(self, step: int, row: Dict) -> List[Dict]:
        """Digest one history row; returns controller events (possibly
        including a ``rollback`` request the trainer must act on)."""
        events: List[Dict] = []
        in_replay = step < self.replay_until
        events += self._observe_error(step, row)
        events += self._observe_overflow(step, row)
        if not in_replay:
            events += self._observe_loss(step, row)
        self._observe_lr(events)
        self.events += events
        return events

    def _observe_error(self, step: int, row: Dict) -> List[Dict]:
        e = _fwd_error_signal(row)
        if e is None:
            return []
        d = self.cfg.error_ema_decay
        self.error_ema = (e if self.error_ema is None
                          else d * self.error_ema + (1 - d) * e)
        thr = self.cfg.switch_error_threshold
        if (thr > 0 and self.error_ema > thr and self.switched_at is None
                and step < self.schedule.switch_step):
            self.switched_at = step + 1
            return [{"event": "switch", "step": step,
                     "error_ema": self.error_ema,
                     "to": self.schedule.target_plan.name}]
        return []

    def _observe_overflow(self, step: int, row: Dict) -> List[Dict]:
        thr = self.cfg.demote_overflow_threshold
        if thr <= 0:
            return []
        events = []
        for cell, rate in _wgrad_overflow_by_cell(row).items():
            if rate > thr:
                self._streak[cell] = self._streak.get(cell, 0) + 1
            else:
                self._streak[cell] = 0
            if (self._streak[cell] >= self.cfg.demote_patience
                    and cell not in self.demoted):
                self.demoted.append(cell)
                layer, cls = _parse_cell(cell)
                events.append({"event": "demote", "step": step,
                               "cell": cell, "layer": layer,
                               "module_class": cls, "overflow": rate})
        return events

    def _observe_loss(self, step: int, row: Dict) -> List[Dict]:
        if self.cfg.spike_factor <= 0 or "loss" not in row:
            return []
        loss = float(row["loss"])
        self._loss_n += 1
        if self.loss_ema is None:
            self.loss_ema = loss
            return []
        is_spike = (self._loss_n > self.cfg.spike_warmup
                    and loss > self.cfg.spike_factor * self.loss_ema)
        if is_spike and self.rollbacks < self.cfg.max_rollbacks:
            self.rollbacks += 1
            return [{"event": "rollback", "step": step, "loss": loss,
                     "loss_ema": self.loss_ema}]
        d = self.cfg.loss_ema_decay
        self.loss_ema = d * self.loss_ema + (1 - d) * loss
        return []

    # -- LR backoff (satellite: controller-driven LR backoff) --------------

    def _observe_lr(self, events: List[Dict]) -> None:
        """Shrink the LR scale on each rollback; otherwise recover it
        geometrically so it reaches 1.0 after ~``lr_recovery_steps`` clean
        steps per backoff applied."""
        if self.cfg.lr_backoff <= 0:
            return
        if any(e["event"] == "rollback" for e in events):
            self.lr_scale *= self.cfg.lr_backoff
            for e in events:
                if e["event"] == "rollback":
                    e["lr_scale"] = self.lr_scale
        elif self.lr_scale < 1.0:
            rate = (1.0 / self.cfg.lr_backoff) ** (
                1.0 / max(self.cfg.lr_recovery_steps, 1))
            self.lr_scale = min(1.0, self.lr_scale * rate)

    # -- rollback handshake (trainer-owned checkpoint restore) -------------

    def begin_replay(self, restored_step: int) -> None:
        """Trainer restored a checkpoint at ``restored_step``; replay the
        next ``replay_steps`` steps at the target precision."""
        self.replay_until = restored_step + self.cfg.replay_steps
        self._loss_n = 0  # re-warm spike detection after the rewind

    # -- checkpoint persistence --------------------------------------------

    def state_dict(self) -> Dict:
        return {"switched_at": self.switched_at,
                "demoted": list(self.demoted),
                "replay_until": self.replay_until,
                "rollbacks": self.rollbacks,
                "lr_scale": self.lr_scale}

    def load_state(self, state: Dict) -> None:
        self.switched_at = state.get("switched_at")
        self.demoted = list(state.get("demoted", []))
        self.replay_until = int(state.get("replay_until", -1))
        self.rollbacks = int(state.get("rollbacks", 0))
        self.lr_scale = float(state.get("lr_scale", 1.0))
