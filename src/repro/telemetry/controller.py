"""Adaptive precision controller driven by live quantization telemetry.

Generalizes the static §3.3 two-stage schedule with three decision rules
(each opt-in via ``ControllerSettings``, see ``configs.base``):

  * **Dynamic target-precision switch** — switch to the stage-2 (target)
    recipe when the EMA of the forward quant relative error crosses a
    threshold, OR at the schedule's fixed fraction, whichever comes first
    (cf. "FP4 All the Way", arXiv:2505.19115, which switches on measured
    quantization noise).
  * **Per-module-class demotion** — sustained wgrad overflow (clip rate)
    for a module class promotes that class FP4 -> FP8, i.e. moves along the
    Table-2 ablation axis (cf. outlier clamping in arXiv:2501.17116).
  * **Loss-spike rollback** — a loss spike against its EMA restores the
    last checkpoint and replays ``replay_steps`` steps at the target (high)
    precision before FP4 resumes.

The controller is pure Python consuming per-step history rows (the metrics
emitted by the in-graph taps, ``telemetry.collect``); precision changes stay
Python-level recipe swaps, so every step graph remains static — exactly the
mechanism the trainer already uses for the fixed schedule.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional

from repro.configs.base import ControllerSettings
from repro.core import recipe as recipe_lib
from repro.core.schedule import TargetPrecisionSchedule
from repro.telemetry.collect import SCOPE_CLASS

__all__ = ["PrecisionController"]

_CLASSES = ("attn", "ffn", "head")
_LAYER_SEG = re.compile(r"^l\d+$")


def _fwd_error_signal(row: Dict) -> Optional[float]:
    """Mean forward quant relative error across all layers/slots."""
    vals = [v for k, v in row.items()
            if k.startswith("tel/") and "/fwd_" in k
            and k.endswith("/rel_err") and isinstance(v, (int, float))]
    return sum(vals) / len(vals) if vals else None


def _wgrad_overflow_by_class(row: Dict) -> Dict[str, float]:
    """Mean wgrad-operand clip rate per module class (fwd-side wgrad_x taps
    + backward wgrad_g probe stats)."""
    acc: Dict[str, List[float]] = {}
    for k, v in row.items():
        if not (k.startswith("tel/") and "wgrad" in k
                and k.endswith("/clip")):
            continue
        # Key shapes: tel/lNN/<scope>/mmJ/... (layer frames),
        # tel/bwd/<cls>/... (probes), tel/<scope>/mmJ/... (root frame —
        # e.g. the lm-head linear, which has no layer segment).
        parts = k.split("/")
        scope = (parts[2] if parts[1] == "bwd" or _LAYER_SEG.match(parts[1])
                 else parts[1])
        cls = scope if scope in _CLASSES else SCOPE_CLASS.get(scope)
        if cls is not None and isinstance(v, (int, float)):
            acc.setdefault(cls, []).append(float(v))
    return {c: sum(vs) / len(vs) for c, vs in acc.items()}


class PrecisionController:
    """Consumes per-step telemetry rows; owns the active-recipe decision."""

    def __init__(self, schedule: TargetPrecisionSchedule,
                 settings: Optional[ControllerSettings] = None):
        self.schedule = schedule
        self.cfg = settings or ControllerSettings()
        self.error_ema: Optional[float] = None
        self.loss_ema: Optional[float] = None
        self._loss_n = 0
        self.switched_at: Optional[int] = None
        self.demoted: List[str] = []
        self._streak: Dict[str, int] = {}
        self.replay_until: int = -1
        self.rollbacks = 0
        self.events: List[Dict] = []
        self._recipe_cache: Dict[str, recipe_lib.PrecisionRecipe] = {}

    # -- recipe selection --------------------------------------------------

    def active_recipe(self, step: int) -> recipe_lib.PrecisionRecipe:
        if step < self.replay_until:
            return self.schedule.target_recipe   # post-rollback replay
        if self.switched_at is not None and step >= self.switched_at:
            return self.schedule.target_recipe   # dynamic early switch
        base = self.schedule.recipe_at(step)     # fixed-fraction switch
        if base is not self.schedule.recipe or not self.demoted:
            return base
        return self._demoted_recipe(base)

    def _demoted_recipe(self, base: recipe_lib.PrecisionRecipe
                        ) -> recipe_lib.PrecisionRecipe:
        key = ",".join(sorted(self.demoted))
        if key not in self._recipe_cache:
            r = base
            for cls in sorted(self.demoted):
                r = recipe_lib.promote_module_class(r, cls)
            self._recipe_cache[key] = r
        return self._recipe_cache[key]

    # -- observation -------------------------------------------------------

    def observe(self, step: int, row: Dict) -> List[Dict]:
        """Digest one history row; returns controller events (possibly
        including a ``rollback`` request the trainer must act on)."""
        events: List[Dict] = []
        in_replay = step < self.replay_until
        events += self._observe_error(step, row)
        events += self._observe_overflow(step, row)
        if not in_replay:
            events += self._observe_loss(step, row)
        self.events += events
        return events

    def _observe_error(self, step: int, row: Dict) -> List[Dict]:
        e = _fwd_error_signal(row)
        if e is None:
            return []
        d = self.cfg.error_ema_decay
        self.error_ema = (e if self.error_ema is None
                          else d * self.error_ema + (1 - d) * e)
        thr = self.cfg.switch_error_threshold
        if (thr > 0 and self.error_ema > thr and self.switched_at is None
                and step < self.schedule.switch_step):
            self.switched_at = step + 1
            return [{"event": "switch", "step": step,
                     "error_ema": self.error_ema,
                     "to": self.schedule.target_recipe.name}]
        return []

    def _observe_overflow(self, step: int, row: Dict) -> List[Dict]:
        thr = self.cfg.demote_overflow_threshold
        if thr <= 0:
            return []
        events = []
        for cls, rate in _wgrad_overflow_by_class(row).items():
            if rate > thr:
                self._streak[cls] = self._streak.get(cls, 0) + 1
            else:
                self._streak[cls] = 0
            if (self._streak[cls] >= self.cfg.demote_patience
                    and cls not in self.demoted):
                self.demoted.append(cls)
                events.append({"event": "demote", "step": step,
                               "module_class": cls, "overflow": rate})
        return events

    def _observe_loss(self, step: int, row: Dict) -> List[Dict]:
        if self.cfg.spike_factor <= 0 or "loss" not in row:
            return []
        loss = float(row["loss"])
        self._loss_n += 1
        if self.loss_ema is None:
            self.loss_ema = loss
            return []
        is_spike = (self._loss_n > self.cfg.spike_warmup
                    and loss > self.cfg.spike_factor * self.loss_ema)
        if is_spike and self.rollbacks < self.cfg.max_rollbacks:
            self.rollbacks += 1
            return [{"event": "rollback", "step": step, "loss": loss,
                     "loss_ema": self.loss_ema}]
        d = self.cfg.loss_ema_decay
        self.loss_ema = d * self.loss_ema + (1 - d) * loss
        return []

    # -- rollback handshake (trainer-owned checkpoint restore) -------------

    def begin_replay(self, restored_step: int) -> None:
        """Trainer restored a checkpoint at ``restored_step``; replay the
        next ``replay_steps`` steps at the target precision."""
        self.replay_until = restored_step + self.cfg.replay_steps
        self._loss_n = 0  # re-warm spike detection after the rewind

    # -- checkpoint persistence --------------------------------------------

    def state_dict(self) -> Dict:
        return {"switched_at": self.switched_at,
                "demoted": list(self.demoted),
                "replay_until": self.replay_until,
                "rollbacks": self.rollbacks}

    def load_state(self, state: Dict) -> None:
        self.switched_at = state.get("switched_at")
        self.demoted = list(state.get("demoted", []))
        self.replay_until = int(state.get("replay_until", -1))
        self.rollbacks = int(state.get("rollbacks", 0))
