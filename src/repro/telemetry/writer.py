"""Append-only JSONL metrics writer for telemetry rows + controller events.

One JSON object per line.  Step rows are the trainer's history rows
(``{"step": int, "recipe": str, "loss": float, "tel/...": float, ...}``);
controller events carry ``{"event": "switch"|"demote"|"rollback", ...}``.
``benchmarks/telemetry_report.py`` consumes this format.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List, Optional


def _jsonable(v):
    if hasattr(v, "item"):
        v = v.item()
    if isinstance(v, float):
        return v
    return v


class JsonlWriter:
    def __init__(self, path: str, append: bool = True):
        self.path = path
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        self._f = open(path, "a" if append else "w")

    def write(self, row: Dict[str, Any]) -> None:
        self._f.write(json.dumps({k: _jsonable(v) for k, v in row.items()})
                      + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "JsonlWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows
