"""JSONL metrics writers for telemetry rows + controller events.

One JSON object per line.  Step rows are the trainer's history rows
(``{"step": int, "recipe": str, "loss": float, "tel/...": float, ...}``);
controller events carry ``{"event": "switch"|"demote"|"rollback"|
"straggler"|..., ...}``.  ``benchmarks/telemetry_report.py`` consumes this
format.

Two writers:

  * :class:`JsonlWriter` — synchronous append + flush per row.  Fine for
    reports and tests; on the training hot path every ``write`` is a
    blocking ``fsync``-adjacent syscall in step time.
  * :class:`AsyncJsonlWriter` — the host-offloaded pipeline the trainer
    uses: ``write`` enqueues onto a bounded queue and returns immediately;
    a daemon thread drains rows to disk off the critical path.  A full
    queue **drops** the row (counted in :attr:`AsyncJsonlWriter.dropped`)
    rather than ever blocking the step; ``close()`` flushes everything
    enqueued so far and appends a ``{"event": "telemetry_writer_drops"}``
    row when anything was lost, so the log is self-describing.

All rows pass through :func:`_jsonable` first: numpy/jax scalars become
Python scalars, arrays become (nested) lists, and non-finite floats become
``null`` — ``json.dumps`` would otherwise emit bare ``NaN``/``Infinity``
tokens, which are not valid JSON and break strict parsers downstream.
"""
from __future__ import annotations

import json
import math
import os
import queue
import threading
from typing import Any, Dict, List

__all__ = ["JsonlWriter", "AsyncJsonlWriter", "read_jsonl"]


def _jsonable(v):
    """Coerce one value to strict-JSON form.

    numpy/jax scalars -> Python scalars, arrays -> nested lists, dicts and
    sequences recursed, NaN/Inf -> ``None`` (strict JSON has no non-finite
    literals; a null metric reads as "not measured", which is the honest
    rendering of an overflowed stat).
    """
    if hasattr(v, "shape") and hasattr(v, "tolist"):
        # ndarray-like (numpy or jax); 0-d arrays give a scalar via tolist
        v = v.tolist()
    elif hasattr(v, "item"):
        v = v.item()
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, float) and not math.isfinite(v):
        return None
    return v


def _dumps(row: Dict[str, Any]) -> str:
    # allow_nan=False makes any sanitizer gap a loud error here, not a
    # corrupt line discovered by a downstream parser.
    return json.dumps(_jsonable(dict(row)), allow_nan=False)


class JsonlWriter:
    """Synchronous JSONL writer (append + flush per row)."""

    def __init__(self, path: str, append: bool = True):
        self.path = path
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        self._f = open(path, "a" if append else "w")

    def write(self, row: Dict[str, Any]) -> None:
        self._f.write(_dumps(row) + "\n")
        self._f.flush()

    def flush(self) -> None:
        if self._f is not None:
            self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "JsonlWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


_CLOSE = object()   # queue sentinel


class AsyncJsonlWriter:
    """Bounded-queue background-thread JSONL writer (never blocks a step).

    * ``write(row)`` copies the row, enqueues, returns.  When the queue is
      full the row is dropped and counted — backpressure from a slow disk
      must never stall the train step (ROADMAP item 5's host-offloaded
      telemetry posture).
    * ``flush()`` blocks until every row enqueued so far is on disk (the
      trainer calls it at the end of ``train()`` so readers see a complete
      log without closing the writer).
    * ``close()`` drains the queue, appends the drop-count event if any
      rows were lost, and closes the file.  Clean close therefore loses
      nothing that was accepted into the queue.

    The drain thread is a daemon: an un-closed writer never prevents
    interpreter exit (rows still queued at hard exit are lost, like any
    buffered writer).
    """

    def __init__(self, path: str, append: bool = True,
                 queue_size: int = 4096):
        self.path = path
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        self._f = open(path, "a" if append else "w")
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, queue_size))
        self.dropped = 0
        self._closed = False
        self._thread = threading.Thread(target=self._drain,
                                        name="telemetry-jsonl-writer",
                                        daemon=True)
        self._thread.start()

    def write(self, row: Dict[str, Any]) -> None:
        if self._closed:
            self.dropped += 1
            return
        try:
            self._q.put_nowait(dict(row))
        except queue.Full:
            self.dropped += 1

    def _drain(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is _CLOSE:
                    return
                self._write_row(item)
            finally:
                self._q.task_done()

    def _write_row(self, row: Dict[str, Any]) -> None:
        """Runs on the writer thread — the injectable sink (tests wrap it
        with an artificially slow version)."""
        self._f.write(_dumps(row) + "\n")
        self._f.flush()

    def flush(self) -> None:
        """Block until everything currently enqueued has hit the sink."""
        self._q.join()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._q.put(_CLOSE)   # blocking put: always delivered
        self._thread.join()
        if self.dropped:
            self._f.write(_dumps({"event": "telemetry_writer_drops",
                                  "dropped": self.dropped}) + "\n")
        self._f.close()

    def __enter__(self) -> "AsyncJsonlWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows
