"""Measured-performance profiling: phase spans, step timing, MFU.

The paper's efficiency claims are theoretical (speed factors derived from
bit-widths); this module produces the *measured* side of that argument —
per-phase trace annotations, wall-clock step-time percentiles, tokens/sec
and MFU — so every perf change in the repo can be judged by wall clock
(cf. Quartet's measured-throughput optimality argument, PAPERS.md).

Three pieces:

  * **Spans** — :func:`phase_span` wraps a *host-side* region of the train
    loop in a ``jax.profiler.TraceAnnotation`` (visible as a named slice in
    a captured trace); :func:`graph_span` is the *trace-time* counterpart
    (``jax.named_scope``) used inside jitted code, so the quantize / fwd /
    bwd / optim / collective regions carry their phase name into the HLO
    metadata and any xprof / perfetto trace.
  * **StepTimer** — rolling step-time statistics with correct device-sync
    discipline: callers time ``fn(...)`` to the ``block_until_ready`` of
    its outputs (``time_call`` does this for you), the first ``warmup``
    records are excluded (compile + autotune), and :meth:`summary` reports
    p50/p95/p99/mean over a bounded rolling window plus throughput
    (tokens/sec) and MFU when given the model's flop count.
  * **Flops/MFU helpers** — :func:`train_step_flops` turns
    ``core.cost_model.ModelDims`` into a per-step training-flop count
    (fwd + dgrad + wgrad = 3x forward matmul flops);
    :func:`device_peak_flops` provides the peak-flops denominator (known
    TPU generations, ``REPRO_PEAK_FLOPS`` env override, a nominal CPU
    figure so smoke runs still produce a number).

Capturing a real trace around the annotated regions:

    with jax.profiler.trace("/tmp/trace"):   # or profiler server + xprof
        trainer.train(num_steps=20)

then open the trace in TensorBoard/xprof — the ``data``/``step``/``host``
host spans and the ``quantize``/``fwd``/``bwd``/``optim``/``grad_comms``
graph scopes appear by name.
"""
from __future__ import annotations

import collections
import contextlib
import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax

__all__ = ["phase_span", "graph_span", "percentiles", "StepTimer",
           "train_step_flops", "device_peak_flops", "PHASES"]

# Canonical phase names used by the trainer / train_step wiring; free-form
# names are fine too, these just keep traces and reports consistent.
PHASES = ("data", "quantize", "fwd", "bwd", "optim", "collective", "host")


@contextlib.contextmanager
def phase_span(name: str):
    """Host-side span: annotate a region of host code (data loading, the
    dispatch+sync of one step, controller/writer work) so it shows as a
    named slice in a ``jax.profiler`` trace.  No-op overhead when no trace
    is being captured (~sub-microsecond), so it is always on."""
    ann = getattr(jax.profiler, "TraceAnnotation", None)
    if ann is None:  # very old jax: annotation API absent
        yield
        return
    with ann(name):
        yield


def graph_span(name: str):
    """Trace-time span for *jitted* code: a ``jax.named_scope`` context.
    Ops traced under it carry ``name`` in their HLO metadata, which xprof
    uses to attribute device time to phases (quantize/fwd/bwd/optim/...).
    Pure metadata — the compiled computation is unchanged."""
    return jax.named_scope(name)


def percentiles(xs: Sequence[float],
                qs: Sequence[float] = (50.0, 95.0, 99.0)) -> Dict[str, float]:
    """Nearest-rank percentiles of ``xs`` as ``{"p50": ..., ...}``.

    Deterministic (no interpolation) and dependency-free so report code and
    tests agree exactly; empty input yields NaNs.
    """
    out: Dict[str, float] = {}
    s = sorted(xs)
    for q in qs:
        key = f"p{int(q) if float(q).is_integer() else q}"
        if not s:
            out[key] = float("nan")
            continue
        rank = max(1, -(-len(s) * q // 100))  # ceil(n*q/100), 1-based
        out[key] = float(s[int(rank) - 1])
    return out


class StepTimer:
    """Rolling wall-clock step statistics with warmup exclusion.

    Record either with :meth:`record` (caller already blocked on device
    outputs — the trainer's path) or :meth:`time_call`, which runs
    ``fn(*args)``, blocks via ``jax.block_until_ready`` on the result (the
    device-sync discipline: without it you time the dispatch, not the
    step), records, and returns the result.

    The first ``warmup`` records are counted (``n_total``) but excluded
    from statistics — they measure compilation, not steady state.  Kept
    times live in a bounded rolling window (``window`` entries) so a long
    run's summary reflects recent behavior and memory stays constant.

    Post-warmup **recompile spikes** are excluded too: a shape change (or a
    controller plan edit) can trigger a recompilation long after warmup,
    and one multi-second compile landing in the window drags p95/p99 orders
    of magnitude away from steady state (the old baseline showed p95=3.27s
    against p50=103ms from exactly this).  A record more than
    ``spike_factor`` x the current window median is counted and reported
    separately (``spikes`` / ``spike_max_ms`` in :meth:`summary`) instead
    of polluting the percentiles.  The first 3 post-warmup records are
    always kept (no median to judge against yet); ``spike_factor=None``
    disables the filter.
    """

    def __init__(self, warmup: int = 2, window: int = 1024,
                 spike_factor: Optional[float] = 20.0):
        self.warmup = warmup
        self.window = window
        self.spike_factor = spike_factor
        self.n_total = 0
        self.n_spikes = 0
        self._times: collections.deque = collections.deque(maxlen=window)
        self._spike_times: collections.deque = collections.deque(maxlen=16)

    def record(self, seconds: float) -> None:
        self.n_total += 1
        if self.n_total <= self.warmup:
            return
        t = float(seconds)
        if self.spike_factor is not None and len(self._times) >= 3:
            med = percentiles(self._times, qs=(50.0,))["p50"]
            if t > self.spike_factor * med:
                self.n_spikes += 1
                self._spike_times.append(t)
                return
        self._times.append(t)

    def time_call(self, fn: Callable, *args: Any, **kw: Any) -> Any:
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        self.record(time.perf_counter() - t0)
        return out

    @property
    def times(self) -> List[float]:
        """Post-warmup step times (seconds), oldest first."""
        return list(self._times)

    def summary(self, tokens_per_step: Optional[float] = None,
                flops_per_step: Optional[float] = None,
                peak_flops: Optional[float] = None) -> Dict[str, float]:
        """Step-time stats: ``steps`` (post-warmup count), ``warmup``,
        ``spikes`` (excluded recompile-spike count, plus ``spike_max_ms``
        when any), ``mean_ms``/``p50_ms``/``p95_ms``/``p99_ms``, and — when
        the caller supplies the model numbers — ``tokens_per_sec`` and
        ``mfu``, both computed at the p50 step time (median: robust to
        straggler steps).
        """
        ts = self.times
        out: Dict[str, float] = {"steps": len(ts), "warmup": self.warmup,
                                 "spikes": self.n_spikes}
        if self.n_spikes:
            out["spike_max_ms"] = max(self._spike_times) * 1e3
        if not ts:
            return out
        pct = percentiles(ts)
        out["mean_ms"] = sum(ts) / len(ts) * 1e3
        for k, v in pct.items():
            out[f"{k}_ms"] = v * 1e3
        p50 = pct["p50"]
        if tokens_per_step is not None and p50 > 0:
            out["tokens_per_sec"] = tokens_per_step / p50
        if flops_per_step is not None and p50 > 0:
            out["flops_per_sec"] = flops_per_step / p50
            if peak_flops is None:
                peak_flops = device_peak_flops()
            out["mfu"] = flops_per_step / p50 / peak_flops
        return out


# ---------------------------------------------------------------------------
# Flops / MFU
# ---------------------------------------------------------------------------

def train_step_flops(dims, tokens_per_step: float) -> float:
    """Training matmul flops of one step from ``cost_model.ModelDims``.

    ``dims.total_fwd_flops`` is forward matmul flops per token (already
    2x mult+add); training runs fwd + dgrad + wgrad = 3x forward.  This is
    the model-flops convention of the PaLM MFU definition — rematerialized
    recompute is deliberately NOT counted, so MFU measures useful work.
    """
    return 3.0 * dims.total_fwd_flops * tokens_per_step


# Peak dense matmul throughput (flops/sec, bf16) by TPU device kind, for
# the MFU denominator.  The CPU fallback is a nominal figure (one AVX-512
# core's ~100 GF/s) — CPU "MFU" is only meaningful as a run-to-run trend,
# which is exactly how BENCH_step.json uses it.
_PEAK_FLOPS = {
    "TPU v4": 275e12,
    "TPU v5 lite": 197e12,
    "TPU v5e": 197e12,
    "TPU v5p": 459e12,
    "TPU v6 lite": 918e12,
    "TPU v6e": 918e12,
    "cpu": 1e11,
}


def device_peak_flops(device=None) -> float:
    """Peak flops/sec of ``device`` (default: the first local device).

    Resolution order: ``REPRO_PEAK_FLOPS`` env var (authoritative — set it
    when your part's spec is known), the known-TPU table, the CPU nominal
    figure.  Unknown accelerators fall back to the CPU figure rather than
    raising: MFU should degrade to "trend-only", never crash a report.
    """
    env = os.environ.get("REPRO_PEAK_FLOPS")
    if env:
        return float(env)
    device = device or jax.devices()[0]
    kind = getattr(device, "device_kind", "cpu")
    for name, peak in _PEAK_FLOPS.items():
        if name != "cpu" and kind.lower().startswith(name.lower()):
            return peak
    return _PEAK_FLOPS["cpu"]
