"""In-graph quantization telemetry collection (trace-time tap mechanism).

The tap lives in ``core.qlinear.qlinear`` (covering both the QDQ and the
fused-Pallas implementations, whose quantization semantics are identical)
and in ``models.moe._expert_linear``.  It is driven by a thread-local
*collector* installed by the train step's loss function — no collector
installed means every hook below is a no-op and the traced graph is
bit-identical to a telemetry-free build.

Two transport channels move stats out of the traced graph:

  * **Forward-computable stats** (the four operand slots whose tensors exist
    in the forward pass: fwd_x, fwd_w, wgrad_x, dgrad_w).  Each
    ``_run_layer`` call opens a :func:`layer_frame`; qlinear taps push
    ``{scope}/mm{j}/{slot}/{stat}`` scalars into the current frame, and the
    stack drains the frame *inside* the same scan/remat scope, returning the
    stats as scan outputs (per-layer resolution survives ``lax.scan``).
  * **Gradient-side stats** (dgrad_g / wgrad_g — the cotangent only exists
    in the backward pass).  :func:`grad_tap` wraps each quantized linear's
    output in a custom_vjp identity whose backward rule emits the stats of
    the incoming cotangent as the "gradient" of a zero-valued *probe* row.
    Probes are **indexed**: one ``(n_layers + 1, PROBE_SIZE)`` array per
    module class, and each tap dynamically indexes its layer's row (the
    trailing row collects out-of-stack taps, i.e. the lm-head).  Inside
    ``lax.scan`` the layer index is a traced scalar, so the transpose of
    the row-gather scatter-adds each iteration's stats into the right
    row — per-layer resolution survives the scan, unlike the previous
    per-class shared probes.  A trailing tap-count slot per row keeps the
    stats self-normalizing under scan and grad-accumulation.

Statistics per operand slot (all f32 scalars):

  ``clip``         fraction of elements above the per-group clip point
                   (nonzero only for pow2 scales; amax scaling never clips);
  ``underflow``    fraction of nonzero elements that quantize to exactly 0
                   (the Fig-1b signal);
  ``rel_err``      relative quantization error ||x - Q(x)|| / ||x||
                   (1/SNR — the §3 health signal the controller EMAs);
  ``scale_spread`` log2(max/min) of the per-group scales (dynamic-range
                   pressure on the scale format).
"""
from __future__ import annotations

import contextlib
import functools
import re
import threading
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import routing
from repro.core.quantize import QuantSpec, _blocked_view
from repro.core.recipe import MatmulRecipe

__all__ = ["TelemetryCollector", "collecting", "active", "suppressed",
           "module_scope", "layer_frame", "tap_matmul", "tap_matmul_batched",
           "grad_tap", "make_probes", "probe_metrics", "grad_norm_metrics",
           "operand_stats", "cell_error_signals", "PROBE_CLASSES",
           "GRAD_STATS"]

_TLS = threading.local()

# Cap on sampled scale-groups per operand stat (see ``operand_stats``).
_SAMPLE_GROUPS = 128

# Gradient-side stats carried per probe class; the final slot counts taps so
# rates stay self-normalizing when cotangents accumulate across scan
# iterations and microbatches.
GRAD_STATS = ("dgrad_g/clip", "dgrad_g/underflow", "dgrad_g/rel_err",
              "wgrad_g/clip", "wgrad_g/underflow", "wgrad_g/rel_err",
              "gnorm_sq")
PROBE_SIZE = len(GRAD_STATS) + 1

PROBE_CLASSES = ("attn", "ffn", "head", "other")
# module scope -> probe/recipe class; the controller classifies metric keys
# with the same map (single source of truth).
SCOPE_CLASS = {"attn": "attn", "cross": "attn",
               "ffn": "ffn", "moe": "ffn", "ssm": "ffn",
               "head": "head"}


# ---------------------------------------------------------------------------
# Collector / context plumbing
# ---------------------------------------------------------------------------

class _Frame:
    """One collection frame (per layer, or the loss-level root)."""

    def __init__(self) -> None:
        self.stats: Dict[str, jnp.ndarray] = {}
        self._mm: Dict[str, int] = {}

    def next_index(self, scope: str) -> int:
        i = self._mm.get(scope, 0)
        self._mm[scope] = i + 1
        return i


class TelemetryCollector:
    """Holds the frame stack, scope stack and probe tracers for one trace."""

    def __init__(self) -> None:
        self.probes: Optional[Dict[str, jnp.ndarray]] = None
        self._frames = [_Frame()]
        self._scopes: list = []
        self._layers: list = []

    def reset(self, probes) -> None:
        self.probes = probes
        self._frames = [_Frame()]
        self._scopes = []
        self._layers = []

    @property
    def frame(self) -> _Frame:
        return self._frames[-1]

    @property
    def layer_index(self):
        """Current layer index: a python int (unroll), a traced scalar
        (scan body), or None outside any layer frame (lm-head/root)."""
        return self._layers[-1] if self._layers else None

    @property
    def scope_path(self) -> str:
        return "/".join(self._scopes) if self._scopes else "top"

    @property
    def scope_root(self) -> str:
        return self._scopes[0] if self._scopes else "top"

    def drain_root(self) -> Dict[str, jnp.ndarray]:
        """Loss-level stats (e.g. the lm-head linear), 'tel/'-prefixed."""
        root = self._frames[0]
        out = {f"tel/{k}": v for k, v in root.stats.items()}
        root.stats = {}
        return out


def active() -> Optional[TelemetryCollector]:
    if getattr(_TLS, "suppress", 0):
        return None
    return getattr(_TLS, "collector", None)


@contextlib.contextmanager
def collecting(collector: TelemetryCollector, probes):
    """Install ``collector`` for the duration of one loss trace."""
    collector.reset(probes)
    prev = getattr(_TLS, "collector", None)
    _TLS.collector = collector
    try:
        yield collector
    finally:
        _TLS.collector = prev


@contextlib.contextmanager
def suppressed():
    """Disable taps inside (used around inner scan/remat scopes whose
    tracers could not legally escape, e.g. the seq-chunked loss head)."""
    _TLS.suppress = getattr(_TLS, "suppress", 0) + 1
    try:
        yield
    finally:
        _TLS.suppress -= 1


@contextlib.contextmanager
def module_scope(name: str):
    """Label taps inside with a module scope ('attn', 'ffn', ...).

    Also feeds the routing census (``core.routing.class_scope``) so the
    qlint audit attributes matmul routes to plan classes even with no
    telemetry collector installed — both sides are no-ops when their
    respective context is absent.
    """
    with routing.class_scope(name):
        col = active()
        if col is None:
            yield
            return
        col._scopes.append(name)
        try:
            yield
        finally:
            col._scopes.pop()


@contextlib.contextmanager
def layer_frame(index=None):
    """Open a per-layer collection frame.  Yields the frame (or None when
    telemetry is off); the caller drains ``frame.stats`` *within the same
    trace scope* and ships them out as layer outputs.

    ``index`` is the absolute layer index — a python int in unroll mode, a
    traced scalar inside a scan body — consumed by :func:`grad_tap` to
    route backward-side stats into the layer's probe row."""
    col = active()
    if col is None:
        yield None
        return
    fr = _Frame()
    col._frames.append(fr)
    col._layers.append(index)
    try:
        yield fr
    finally:
        col._frames.pop()
        col._layers.pop()


# ---------------------------------------------------------------------------
# Operand statistics
# ---------------------------------------------------------------------------

def _statable(spec: QuantSpec) -> bool:
    return not spec.is_passthrough and spec.fmt != "fp16"


def operand_stats(a2d: jnp.ndarray, spec: QuantSpec,
                  reduction_axis: int) -> Dict[str, jnp.ndarray]:
    """Quant-health stats of one matmul operand under ``spec`` (f32 scalars).

    ``reduction_axis`` is relative to the stored 2-D layout; block/tile
    group *contents* are orientation-invariant, so stats for transposed
    roles (wgrad_x, dgrad_w) are computed on the stored array with the
    reduction axis mapped accordingly.

    Everything is computed in ONE blocked pass (view + scale + simulated
    rounding shared across the four stats) — the taps sit next to every
    quantized matmul, so redundant QDQ work here is step-time overhead.
    For ``token``/``block`` granularities the scale groups lie entirely
    along the reduction axis, so the operand is strided-subsampled along
    the *other* axis first: per-group math stays exact, and the reported
    rates become an unbiased sample mean — this caps the tap cost at
    O(``_SAMPLE_GROUPS`` * reduction-dim) per operand regardless of batch.
    """
    from repro.core import formats as F
    fmt = spec.format
    if spec.granularity in ("token", "block"):
        axis = 1 - reduction_axis
        stride = a2d.shape[axis] // _SAMPLE_GROUPS
        if stride > 1:
            a2d = a2d[::stride] if axis == 0 else a2d[:, ::stride]
    ab, axes, rows, cols = _blocked_view(a2d, spec.granularity, spec.block,
                                         reduction_axis)
    af = ab.astype(jnp.float32)
    mag = jnp.abs(af)
    if spec.granularity == "tensor":
        amax = jnp.max(mag)
    elif spec.granularity == "token":
        amax = jnp.max(mag, axis=reduction_axis, keepdims=True)
    else:
        amax = jnp.max(mag, axis=axes, keepdims=True)
    from repro.core.quantize import scale_from_amax
    scale = scale_from_amax(amax, fmt, spec.pow2_scale)   # Eq. 3
    q = F.round_to_format(af / scale, fmt) * scale     # simulated QDQ
    n = rows * cols  # padding contributes zero to every numerator below
    nonzero = mag > 0
    underflow = (jnp.sum(nonzero & (q == 0))
                 / jnp.maximum(jnp.sum(nonzero), 1))
    rel_err = jnp.sqrt(jnp.sum((af - q) ** 2)
                       / jnp.maximum(jnp.sum(af * af), 1e-30))
    clip = jnp.sum(mag > scale * (fmt.max_value * (1.0 + 1e-6))) / n
    spread = jnp.log2(jnp.maximum(jnp.max(scale), 1e-30)
                      / jnp.maximum(jnp.min(scale), 1e-30))
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    return {"clip": f32(clip), "underflow": f32(underflow),
            "rel_err": f32(rel_err), "scale_spread": f32(spread)}


# The forward pass holds both operands of all three matmuls except the
# cotangent g: slot -> (operand selector, spec selector, reduction axis in
# the *stored* (M,K) x / (K,N) w layout).
_FWD_SLOTS = (
    ("fwd_x", 0, "fwd_x", 1),     # x quantized over K
    ("fwd_w", 1, "fwd_w", 0),     # w quantized over K
    ("wgrad_x", 0, "wgrad_x", 0),  # x^T quantized over M  == x over axis 0
    ("dgrad_w", 1, "dgrad_w", 1),  # w^T quantized over N  == w over axis 1
)


def tap_matmul(x2d: jnp.ndarray, w: jnp.ndarray,
               recipe: MatmulRecipe,
               fused_fwd: Optional[Dict[str, Optional[Dict]]] = None
               ) -> None:
    """Record forward-computable operand stats for one quantized matmul
    into the current collection frame.  No-op without a collector.

    ``fused_fwd`` (pallas impl): already-finalized stat dicts for the
    ``fwd_x``/``fwd_w`` slots, produced by the quantize pass's telemetry
    epilogue inside the very kernel that fed the dot — those slots then
    skip the QDQ re-run here.  Epilogue stats cover the FULL operand;
    ``operand_stats`` subsamples large group sets, so the two agree exactly
    only up to sampling.
    """
    col = active()
    if col is None:
        return
    fr = col.frame
    scope = col.scope_path
    j = fr.next_index(scope)
    ops = (x2d, w)
    for slot, op_i, spec_name, axis in _FWD_SLOTS:
        spec = getattr(recipe, spec_name)
        if not _statable(spec):
            continue
        pre = fused_fwd.get(slot) if fused_fwd else None
        stats = pre if pre is not None else operand_stats(
            ops[op_i], spec, axis)
        for stat, v in stats.items():
            fr.stats[f"{scope}/mm{j}/{slot}/{stat}"] = v


def tap_matmul_batched(x3: jnp.ndarray, w3: jnp.ndarray,
                       recipe: MatmulRecipe) -> None:
    """Batched (per-expert) variant: stats vmapped over the leading dim and
    averaged.  The internal vmap is self-contained, so this is safe to call
    at the caller's trace level (unlike tapping inside the matmul vmap)."""
    col = active()
    if col is None:
        return
    fr = col.frame
    scope = col.scope_path
    j = fr.next_index(scope)
    ops = (x3, w3)
    for slot, op_i, spec_name, axis in _FWD_SLOTS:
        spec = getattr(recipe, spec_name)
        if not _statable(spec):
            continue
        per_e = jax.vmap(lambda a: operand_stats(a, spec, axis))(ops[op_i])
        for stat, v in per_e.items():
            fr.stats[f"{scope}/mm{j}/{slot}/{stat}"] = jnp.mean(v)


# ---------------------------------------------------------------------------
# Gradient-side taps (probe-gradient transport)
# ---------------------------------------------------------------------------

def make_probes(n_layers: int) -> Dict[str, jnp.ndarray]:
    """Zero-valued ``(n_layers + 1, PROBE_SIZE)`` probe array per module
    class; differentiate the loss w.r.t. these to receive layer-resolved
    backward-side stats.  Row ``n_layers`` collects taps fired outside any
    layer frame (the lm-head linear)."""
    return {c: jnp.zeros((n_layers + 1, PROBE_SIZE), jnp.float32)
            for c in PROBE_CLASSES}


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _grad_tap(y, probe, recipe: MatmulRecipe):
    return y


def _grad_tap_fwd(y, probe, recipe):
    return y, None


def _grad_tap_bwd(recipe, _res, g):
    g2 = g.reshape(-1, g.shape[-1])
    vals = []
    # dgrad: g reduced over N (axis 1); wgrad: g reduced over M (axis 0).
    for spec, axis in ((recipe.dgrad_g, 1), (recipe.wgrad_g, 0)):
        if _statable(spec):
            s = operand_stats(g2, spec, axis)
            vals += [s["clip"], s["underflow"], s["rel_err"]]
        else:
            vals += [jnp.zeros((), jnp.float32)] * 3
    vals.append(jnp.sum(g2.astype(jnp.float32) ** 2))
    vals.append(jnp.ones((), jnp.float32))  # tap count
    return g, jnp.stack(vals)


_grad_tap.defvjp(_grad_tap_fwd, _grad_tap_bwd)


def grad_tap(y: jnp.ndarray, recipe: MatmulRecipe) -> jnp.ndarray:
    """Identity wrapper whose VJP emits cotangent quant stats into the
    current layer's row of the module-class probe.  Forward value (and the
    cotangent passed upstream) are untouched, so training math is
    unchanged.  With a traced layer index (scan body) the row gather's
    transpose scatter-adds each iteration's stats into its own row."""
    col = active()
    if col is None or col.probes is None:
        return y
    if not (_statable(recipe.dgrad_g) or _statable(recipe.wgrad_g)):
        return y
    probe = col.probes[SCOPE_CLASS.get(col.scope_root, "other")]
    idx = col.layer_index
    if idx is None:
        row = probe[probe.shape[0] - 1]
    elif isinstance(idx, int):
        row = probe[min(idx, probe.shape[0] - 1)]
    else:
        idx = jnp.minimum(idx, probe.shape[0] - 1)
        row = jax.lax.dynamic_index_in_dim(probe, idx, keepdims=False)
    return _grad_tap(y, row, recipe)


def _vec_metrics(vec: jnp.ndarray, prefix: str,
                 out: Dict[str, jnp.ndarray]) -> None:
    cnt = vec[-1]
    denom = jnp.maximum(cnt, 1.0)
    for i, name in enumerate(GRAD_STATS):
        if name == "gnorm_sq":
            out[f"{prefix}/gout_norm"] = jnp.sqrt(vec[i] / denom)
        else:
            out[f"{prefix}/{name}"] = vec[i] / denom
    out[f"{prefix}/taps"] = cnt


def probe_metrics(probe_grads: Dict[str, jnp.ndarray]
                  ) -> Dict[str, jnp.ndarray]:
    """Normalize accumulated probe cotangents into metrics.

    Emits per-class aggregates (``tel/bwd/<cls>/<stat>``, the rows summed
    — identical semantics to the pre-indexed probes) plus layer-resolved
    ``tel/bwd/lNN/<cls>/<stat>`` rows for the in-stack classes, the keys
    the per-(layer, class) controller demotion and the telemetry-report
    heatmap consume.  The head/root row only feeds the aggregates (the
    lm-head has no layer index)."""
    out: Dict[str, jnp.ndarray] = {}
    for cls, arr in probe_grads.items():
        if arr.ndim == 1:  # defensive: legacy flat probe
            _vec_metrics(arr, f"tel/bwd/{cls}", out)
            continue
        _vec_metrics(arr.sum(axis=0), f"tel/bwd/{cls}", out)
        if cls == "head":
            continue  # head taps land in the trailing row; aggregate only
        for l in range(arr.shape[0] - 1):
            _vec_metrics(arr[l], f"tel/bwd/l{l:02d}/{cls}", out)
    return out


# ---------------------------------------------------------------------------
# Per-cell error signals (pure-Python aggregation over a history row)
# ---------------------------------------------------------------------------

_FWD_CELL_RE = re.compile(r"^tel/l(\d+)/([^/]+)/mm\d+/[^/]+/rel_err$")
_BWD_CELL_RE = re.compile(
    r"^tel/bwd/l(\d+)/([^/]+)/(?:dgrad_g|wgrad_g)/rel_err$")
_HEAD_FWD_RE = re.compile(r"^tel/head/mm\d+/[^/]+/rel_err$")
_HEAD_BWD_RE = re.compile(r"^tel/bwd/head/(?:dgrad_g|wgrad_g)/rel_err$")


def cell_error_signals(row: Dict) -> Dict[str, float]:
    """Mean quant relative error per plan cell from one history row.

    Cells use the controller/plan addressing — ``"lNN/<cls>"`` for
    in-stack layers, ``"head"`` for the lm-head — joining the forward-side
    per-layer taps (all slots, all mm call sites) with the backward-side
    layer-indexed probe rows.  Probe rows with a zero tap count are
    skipped (an untapped row reads 0.0, which is absence of signal, not a
    perfect quantizer).  This is the plan searcher's per-cell health
    signal; the classing is ``SCOPE_CLASS``, the same map the controller
    uses for demotion keys.
    """
    acc: Dict[str, list] = {}
    for k, v in row.items():
        if not isinstance(v, (int, float)):
            continue
        m = _FWD_CELL_RE.match(k)
        if m:
            cls = SCOPE_CLASS.get(m.group(2))
            if cls in ("attn", "ffn"):
                acc.setdefault(f"l{int(m.group(1)):02d}/{cls}",
                               []).append(float(v))
            continue
        m = _BWD_CELL_RE.match(k)
        if m:
            layer, cls = int(m.group(1)), m.group(2)
            if cls not in ("attn", "ffn"):
                continue
            if float(row.get(f"tel/bwd/l{layer:02d}/{cls}/taps", 0.0)) <= 0:
                continue
            acc.setdefault(f"l{layer:02d}/{cls}", []).append(float(v))
            continue
        if _HEAD_FWD_RE.match(k):
            acc.setdefault("head", []).append(float(v))
        elif (_HEAD_BWD_RE.match(k)
              and float(row.get("tel/bwd/head/taps", 0.0)) > 0):
            acc.setdefault("head", []).append(float(v))
    return {c: sum(vs) / len(vs) for c, vs in acc.items()}


# ---------------------------------------------------------------------------
# Per-layer gradient norms (computed on the grads pytree in the train step)
# ---------------------------------------------------------------------------

def grad_norm_metrics(grads) -> Dict[str, jnp.ndarray]:
    """Per-layer gradient norms from the stacked/unrolled params tree."""
    out: Dict[str, jnp.ndarray] = {}
    stack = grads.get("stack") if isinstance(grads, dict) else None
    if not isinstance(stack, dict):
        return out
    if "groups" in stack:
        groups = stack["groups"]
        names = sorted(groups)
        period = len(names)
        for i, lname in enumerate(names):
            leaves = jax.tree.leaves(groups[lname])
            ss = sum(jnp.sum(l.astype(jnp.float32) ** 2,
                             axis=tuple(range(1, l.ndim)))
                     for l in leaves)  # (n_groups,)
            for g in range(ss.shape[0]):
                out[f"tel/gnorm/l{g * period + i:02d}"] = jnp.sqrt(ss[g])
    elif "layers" in stack:
        for i, sub in enumerate(stack["layers"]):
            ss = sum(jnp.sum(l.astype(jnp.float32) ** 2)
                     for l in jax.tree.leaves(sub))
            out[f"tel/gnorm/l{i:02d}"] = jnp.sqrt(ss)
    return out
