"""Three-term roofline from compiled dry-run artifacts (TPU v5e targets).

    compute term    = HLO_FLOPs   / (chips * peak_FLOPs)
    memory term     = HLO_bytes   / (chips * HBM_bw)
    collective term = coll_bytes  / (chips * link_bw)

``cost_analysis()`` under SPMD reports ~global/chips (verified empirically),
and HLO shard shapes are per-device, so all terms below are *per-chip
seconds* directly.

Scan-undercount corrections: XLA cost analysis counts a while-loop body
once.  The roofline pass unrolls the *layer* loop (exact), but three interior
scans remain for compile-time sanity: the attention KV-chunk scan, the
seq-chunked LM-head loss, and the Mamba inter-chunk state scan.  Their
missing FLOPs are analytic (we know the einsum shapes exactly) and are added
via ``scan_flop_corrections`` — flagged in the output so corrected and raw
values are both visible.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


from repro.configs.base import ModelConfig, ShapeCell

__all__ = ["HW_V5E", "roofline_terms", "model_flops",
           "scan_flop_corrections"]


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float      # bf16 FLOP/s per chip
    hbm_bw: float          # bytes/s per chip
    link_bw: float         # ICI bytes/s per chip (per-link figure)


HW_V5E = HW("tpu_v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)


def model_flops(cfg: ModelConfig, cell: ShapeCell, n_active: int) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N per generated token for decode
    (N = active params; D = tokens processed)."""
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch


def _attention_flops(cfg: ModelConfig, bsz: int, sq: int, skv: int) -> float:
    """Global SDPA flops for one attention layer fwd (scores+context+softmax).

    Our chunked implementation computes the full (non-causal-skipped)
    rectangle, like masked FlashAttention without block skipping.
    """
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    mm = 2 * 2 * bsz * h * sq * skv * hd
    soft = 5 * bsz * h * sq * skv
    return mm + soft


def scan_flop_corrections(cfg: ModelConfig, cell: ShapeCell,
                          chips: int) -> Dict[str, float]:
    """Per-chip FLOPs missed by interior scans (see module docstring).

    Returns {'attn': f, 'head': f, 'ssd': f, 'total': f} per-chip.
    """
    train = cell.kind == "train"
    factor = 4.0 if train else 1.0     # fwd + remat + bwd(2x)  vs  fwd
    bsz = cell.global_batch
    sq = cell.seq_len if cell.kind != "decode" else 1
    skv = cell.seq_len
    if cell.kind == "decode" and cfg.sliding_window:
        skv = min(skv, cfg.sliding_window)   # ring-buffer cache

    specs = cfg.layer_specs()
    n_attn = sum(1 for s in specs if s.mixer == "attn")
    n_cross = sum(1 for s in specs if s.cross)
    n_mamba = sum(1 for s in specs if s.mixer == "mamba")

    out = {"attn": 0.0, "head": 0.0, "ssd": 0.0}

    # attention KV-chunk scan
    chunk = min(cfg.attention_chunk, skv)
    n_chunks = max(skv // chunk, 1)
    if n_chunks > 1 and not cfg.unroll_attention:
        per_layer = _attention_flops(cfg, bsz, sq, skv)
        out["attn"] += (n_attn * factor * per_layer
                        * (n_chunks - 1) / n_chunks)
    # cross-attention scan (kv = patches/frames)
    skv_cross = cfg.n_patches if cfg.family == "vlm" else cfg.n_frames
    cch = min(cfg.attention_chunk, skv_cross)
    ncc = max(skv_cross // cch, 1)
    if n_cross and ncc > 1 and not cfg.unroll_attention:
        per_layer = _attention_flops(cfg, bsz, sq, skv_cross)
        out["attn"] += n_cross * factor * per_layer * (ncc - 1) / ncc

    # seq-chunked LM head (train only; serve heads are last-position only)
    if train and cfg.loss_chunk and cfg.loss_chunk < cell.seq_len:
        n = cell.seq_len // cfg.loss_chunk
        head = 2.0 * bsz * cell.seq_len * cfg.d_model * cfg.vocab_size
        out["head"] += factor * head * (n - 1) / n

    # mamba inter-chunk state scan (tiny, included for completeness)
    if n_mamba and cfg.mamba is not None and cell.kind != "decode":
        st = cfg.mamba
        d_inner = st.expand * cfg.d_model
        nheads = d_inner // st.headdim
        nc = max(sq // st.chunk, 1)
        per_chunk = 3.0 * bsz * nheads * st.headdim * st.d_state
        out["ssd"] += n_mamba * factor * per_chunk * max(nc - 1, 0)

    total = sum(out.values())
    out = {k: v / chips for k, v in out.items()}
    out["total"] = total / chips
    return out


def roofline_terms(*, hlo_flops: float, hlo_bytes: float,
                   collective_bytes_eff: float, chips: int,
                   flop_correction: float = 0.0,
                   hw: HW = HW_V5E,
                   model_flops_total: Optional[float] = None
                   ) -> Dict[str, float]:
    """All inputs per-chip except model_flops_total (global)."""
    flops = hlo_flops + flop_correction
    compute_s = flops / hw.peak_flops
    memory_s = hlo_bytes / hw.hbm_bw
    collective_s = collective_bytes_eff / hw.link_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s,
             "hlo_flops_per_chip": flops,
             "hlo_flops_raw": hlo_flops,
             "flop_correction": flop_correction,
             "hlo_bytes_per_chip": hlo_bytes,
             "collective_bytes_eff": collective_bytes_eff,
             "chips": chips}
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["bottleneck"] = dom.replace("_s", "")
    bound = max(compute_s, memory_s, collective_s)
    terms["step_time_lower_bound_s"] = bound
    if model_flops_total is not None:
        terms["model_flops_total"] = model_flops_total
        terms["useful_flops_ratio"] = (
            model_flops_total / max(flops * chips, 1.0))
        # MFU at the roofline bound: useful flops / (chips*peak*bound)
        terms["mfu_at_bound"] = (model_flops_total
                                 / max(chips * hw.peak_flops * bound, 1e-30))
    return terms
