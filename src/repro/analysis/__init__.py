"""Roofline analysis from compiled dry-run artifacts."""
from repro.analysis.hlo import collective_bytes, parse_collectives
from repro.analysis.roofline import HW_V5E, roofline_terms, model_flops

__all__ = ["collective_bytes", "parse_collectives", "HW_V5E",
           "roofline_terms", "model_flops"]
