"""qlint: static precision-flow analyzer for compiled train/decode steps.

Traces a step (jaxpr + compiled per-device HLO — no execution) and audits
it against the resolved :class:`~repro.core.recipe.PrecisionPlan`.  Four
check families:

  * **kernel presence** — every (layer, class, role) cell the plan routes
    through the fused Pallas pipeline has matching ``pallas_call``
    equations in the graph (and ``qrole_*``-scoped ops in the per-device
    HLO); QDQ fallbacks are enumerated with their structured reasons
    (``core.qlinear.kernel_unsupported_reason`` vocabulary);
  * **role safety** — cells a protection preset keeps in BF16 are never
    fed through a quantize op (a ``qdq_*`` marker under a ``qrole_*``
    scope must be explained by the routing census, and every census cell's
    specs must match the plan's resolved cell), stochastic rounding is
    armed exactly where specs say ``:sr`` (dropped-key bugs included), and
    no f32 operand reaches a kernel-routed matmul (the model computes in
    ``cfg.dtype``);
  * **comms** — with a mesh and fp8 gradient compression, the gradient
    all-reduce payload dtype matches the quantize-before-communicate
    policy (``f8e4m3fn``, or its ``f16`` XLA:CPU legalization), and the
    block/tile quant-scale placement table still shards scales with their
    operand's reduction axis (the PR-6 policy,
    ``core.quantize.scale_logical_axes``);
  * **recompile budget** — a census over ``Trainer``-compiled step graphs
    flags step-cache keys outside the expected plan set (unexpected
    retraces).

Ground truth comes from three independent layers that must agree: the
trace-time routing census (``core.routing``, recorded at the exact dot
call), the jaxpr (``pallas_call`` equations + ``qrole_*``/``qdq_*``
named-scope markers), and the compiled HLO text (shared walker in
``analysis.hlo``).  The census says what the code *decided*; the graphs
say what was actually *staged*; the plan says what was *asked for* —
qlint cross-checks all three.

CLI::

    python -m repro.analysis.qlint --config tiny --plan fine_grained_fp4 \
        [--impl pallas] [--mesh 2,1] [--decode] [--json out.json] \
        [--expect FILE] [--update-expectations]

``--expect`` compares the normalized findings against a committed
expectations JSON (CI gate); ``--update-expectations`` rewrites that file
from the current audit (run it after an intentional routing change and
commit the diff).
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import re
import sys
from collections import Counter
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.hlo import collective_bytes, shape_bytes, walk_hlo
from repro.core import routing
from repro.core.quantize import QuantSpec, qdq_scope_name, scale_logical_axes
from repro.core.recipe import ROLE_SUBSETS, PrecisionPlan

__all__ = ["Finding", "QlintReport", "graph_census", "audit_cells",
           "audit_hlo_comms", "audit_scale_placement", "recompile_census",
           "audit_train_graph", "audit_decode_graph", "audit_decode_engine",
           "audit_trainer", "expectations_payload", "compare_expectations",
           "main"]

_TRAIN_ROLES = ("fwd", "dgrad", "wgrad")
# Payload dtypes acceptable for the fp8 gradient all-reduce: the real
# thing, or what XLA:CPU legalizes float8 collectives to (see
# analysis.hlo._WIRE_SCALE).
_FP8_WIRE_DTYPES = {"f8e4m3fn", "f8e5m2", "f16"}
# all-reduce payloads at or below this are shared-scale scalars (the fp8
# compressor's per-leaf global-amax reductions), not gradient bytes
_SCALE_AR_BYTES = 256

_QROLE_RE = re.compile(r"qrole_([a-z]+)")
_QDQ_RE = re.compile(r"qdq_[0-9A-Za-z_]+")


# ---------------------------------------------------------------------------
# Findings / report
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Finding:
    """One audit observation.

    ``severity``: ``violation`` (gate-failing), ``fallback`` (a pallas impl
    cell that took the QDQ path — counted separately because the tiny-
    config gate requires zero of them), or ``info``.
    """
    check: str          # kernel_presence | role_safety | comms | recompile
    severity: str       # violation | fallback | info
    where: str          # cell / op / key identifier
    message: str

    def to_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)


class QlintReport:
    """Findings + census for one audited graph (or graph family)."""

    def __init__(self, label: str):
        self.label = label
        self.cells: List[Dict[str, Any]] = []
        self.summary: Dict[str, Any] = {}
        self.findings: List[Finding] = []

    # -- accounting --------------------------------------------------------

    def add(self, finding: Finding) -> None:
        self.findings.append(finding)

    def extend(self, findings: Sequence[Finding]) -> None:
        self.findings.extend(findings)

    def violations(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "violation"]

    def fallbacks(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "fallback"]

    @property
    def ok(self) -> bool:
        return not self.violations()

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {"label": self.label,
                "cells": self.cells,
                "summary": self.summary,
                "findings": [f.to_dict() for f in self.findings],
                "n_violations": len(self.violations()),
                "n_fallbacks": len(self.fallbacks())}

    def human_report(self) -> str:
        out = [f"== qlint: {self.label} =="]
        s = self.summary
        if s:
            out.append("  " + ", ".join(f"{k}={v}" for k, v in s.items()
                                        if not isinstance(v, dict)))
        for c in self.cells:
            bits = [f"{c['layer'] or '-':>8} {c['cls'] or '-':>5}",
                    f"{c['role']:>5} -> {c['route']:<12}",
                    f"{c['spec_a']} | {c['spec_b']}"]
            extras = []
            if c.get("pipeline"):
                extras.append(c["pipeline"])
            if c.get("sr_a") or c.get("sr_b"):
                extras.append("sr=" + ("a" if c["sr_a"] else "")
                              + ("b" if c["sr_b"] else ""))
            if c.get("reasons"):
                extras.append("; ".join(c["reasons"]))
            out.append("  " + "  ".join(bits)
                       + (("  [" + ", ".join(extras) + "]") if extras
                          else ""))
        if not self.findings:
            out.append("  findings: none")
        for f in self.findings:
            out.append(f"  [{f.severity.upper():>9}] {f.check}: "
                       f"{f.where}: {f.message}")
        out.append(f"  => {len(self.violations())} violation(s), "
                   f"{len(self.fallbacks())} fallback(s)")
        return "\n".join(out)


# ---------------------------------------------------------------------------
# Jaxpr walking
# ---------------------------------------------------------------------------

def _as_jaxprs(v) -> List[Any]:
    name = type(v).__name__
    if name == "ClosedJaxpr":
        return [v.jaxpr]
    if name == "Jaxpr":
        return [v]
    if isinstance(v, (tuple, list)):
        return [j for x in v for j in _as_jaxprs(x)]
    return []


def _name_stack(eqn) -> str:
    try:
        return str(eqn.source_info.name_stack)
    except AttributeError:
        return ""


def _iter_eqns(jaxpr, prefix: str = "") -> Iterator[Tuple[Any, str]]:
    """(equation, full name-stack path) pairs, recursing into sub-jaxprs
    (scan bodies, pjit calls, custom_vjp call jaxprs, remat).

    Name stacks are RELATIVE to their enclosing jaxpr — an equation inside
    a pjit/remat/scan sub-jaxpr only carries the scopes entered since that
    call, while the call equation itself carries the outer scopes.  The
    walk therefore accumulates the ancestor call equations' stacks into
    ``prefix`` so e.g. a ``pallas_call`` staged under ``qrole_wgrad`` is
    attributable even though its own stack is empty.
    """
    for eqn in jaxpr.eqns:
        stack = _name_stack(eqn)
        full = f"{prefix}/{stack}" if prefix and stack else (prefix or stack)
        yield eqn, full
        for v in eqn.params.values():
            for sub in _as_jaxprs(v):
                yield from _iter_eqns(sub, full)


def graph_census(closed_jaxpr, compute_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Census of precision-flow markers in a (post-autodiff) jaxpr.

    Returns ``pallas_calls`` (role -> count, attributed via the
    ``qrole_*`` named scopes), ``qdq_markers`` ((role, scope-name) ->
    count; role ``"-"`` for quantize ops outside any matmul role, e.g. the
    KV-cache codec), and ``f32_kernel_operands`` — pallas_call equations
    with a floating operand wider than ``compute_dtype`` (the "no f32
    upcast into fp4-routed matmuls" check).
    """
    pallas = Counter()
    qdq = Counter()
    wide = []
    n_eqns = 0
    wide_bits = jnp.finfo(compute_dtype).bits
    for eqn, stack in _iter_eqns(closed_jaxpr.jaxpr):
        n_eqns += 1
        roles = _QROLE_RE.findall(stack)
        role = roles[-1] if roles else None
        if eqn.primitive.name == "pallas_call":
            pallas[role or "-"] += 1
            for var in eqn.invars:
                aval = getattr(var, "aval", None)
                dt = getattr(aval, "dtype", None)
                # Matrix operands only: scalar/vector kernel parameters
                # (seeds, eps floors) are legitimately f32.
                if (dt is not None and len(getattr(aval, "shape", ())) >= 2
                        and jnp.issubdtype(dt, jnp.floating)
                        and jnp.finfo(dt).bits > wide_bits):
                    wide.append(f"qrole_{role or '?'}: {dt} operand "
                                f"{getattr(aval, 'shape', '?')}")
        for marker in _QDQ_RE.findall(stack):
            qdq[(role or "-", marker)] += 1
    return {"pallas_calls": dict(pallas),
            "qdq_markers": {f"{r}/{m}": c for (r, m), c in qdq.items()},
            "f32_kernel_operands": wide,
            "n_eqns": n_eqns}


# ---------------------------------------------------------------------------
# Census-vs-plan audit (kernel presence + role safety)
# ---------------------------------------------------------------------------

def _label_layers(label: Optional[str], n_layers: int) -> List[int]:
    """Layer indices a census label covers ('L3' -> [3]; the scan-slice
    form 'L1:8:4' -> [1, 5]; None (the lm-head) -> [])."""
    if label is None:
        return []
    body = label[1:]
    parts = body.split(":")
    if len(parts) == 1:
        return [int(parts[0])]
    start, stop, step = (int(p) for p in parts)
    return [i for i in range(start, stop, step) if i < n_layers]


def _role_specs(mm, role: str) -> Tuple[QuantSpec, QuantSpec]:
    sa, sb = ROLE_SUBSETS[role]
    return getattr(mm, sa), getattr(mm, sb)


def _expected_routes(mm, role: str, impl: str, packed: bool) -> Tuple[str, ...]:
    from repro.core.qlinear import kernel_quant_mode
    if packed:
        if mm.fwd_x.is_passthrough:
            # protected params (lm head, embeddings) are never packed, so
            # a passthrough cell may be a plain dot over the bf16 weight
            return ("packed_dot", "dot")
        if impl in ("pallas", "pallas_two_pass"):
            return (("pallas",) if kernel_quant_mode(mm.fwd_x) is not None
                    else ("qdq_fallback",))
        return ("qdq",)
    if mm.is_passthrough:
        return ("dot",)
    if impl in ("pallas", "pallas_two_pass"):
        sa, sb = _role_specs(mm, role)
        ok = (kernel_quant_mode(sa) is not None
              and kernel_quant_mode(sb) is not None)
        return ("pallas",) if ok else ("qdq_fallback",)
    return ("qdq",)


def audit_cells(cells: Sequence[routing.RouteEvent], plan: PrecisionPlan,
                impl: str, *, roles: Sequence[str] = _TRAIN_ROLES,
                classes: Sequence[str] = ("attn", "ffn"),
                packed: bool = False) -> List[Finding]:
    """Role-safety + kernel-presence audit of the routing census vs the
    resolved plan.

    Checks per census cell: operand specs match the plan's resolved
    (layer, class, role) cell (a quantized spec on a role the plan keeps
    passthrough is the "protected BF16 cell fed through quantize"
    violation), SR armed exactly per spec, route matches what ``impl``
    should produce, fallbacks enumerated.  Coverage: every (layer, class)
    cell of the plan must be traced for every expected role.
    """
    findings: List[Finding] = []
    n_layers = plan.n_layers
    seen: Dict[Tuple[int, str, str], routing.RouteEvent] = {}
    head_seen = False

    for ev in cells:
        where = f"{ev.layer or 'head'}/{ev.cls or '?'}/{ev.role}"
        if ev.cls is None:
            findings.append(Finding(
                "role_safety", "violation", where,
                "census event with no class attribution — a matmul ran "
                "outside the module scopes"))
            continue
        if ev.cls == "head":
            head_seen = True
            mms = [("head", plan.for_class("head"))]
        else:
            layers = _label_layers(ev.layer, n_layers)
            if not layers:
                findings.append(Finding(
                    "role_safety", "violation", where,
                    f"census event with unparseable layer label "
                    f"{ev.layer!r}"))
                continue
            mms = [(i, plan.layer(i).for_class(ev.cls)) for i in layers]
        for layer_i, mm in mms:
            if isinstance(layer_i, int):
                seen[(layer_i, ev.cls, ev.role)] = ev
            if packed and ev.role == "fwd":
                # serving panel: census rhs is the pre-dequantized operand
                want_a, want_b = mm.fwd_x, None
            else:
                want_a, want_b = _role_specs(mm, ev.role)
            for op, want, got, sr in (("lhs", want_a, ev.spec_a, ev.sr_a),
                                      ("rhs", want_b, ev.spec_b, ev.sr_b)):
                if want is None:
                    continue
                if want.to_str() != got:
                    sev = "violation"
                    if want.is_passthrough:
                        msg = (f"protected (passthrough {want.to_str()}) "
                               f"{op} operand fed through quantize as "
                               f"{got}")
                    else:
                        msg = (f"{op} operand spec {got} does not match "
                               f"the plan's {want.to_str()}")
                    findings.append(Finding("role_safety", sev,
                                            f"{where}:{op}", msg))
                    continue
                if bool(want.stochastic) != bool(sr):
                    msg = ("plan spec says :sr but stochastic rounding is "
                           "not armed (dropped key?)"
                           if want.stochastic else
                           "stochastic rounding armed on a non-:sr spec")
                    findings.append(Finding("role_safety", "violation",
                                            f"{where}:{op}", msg))
            expects = _expected_routes(mm, ev.role, impl, packed)
            if ev.route not in expects:
                want = (repr(expects[0]) if len(expects) == 1
                        else f"one of {sorted(expects)}")
                findings.append(Finding(
                    "kernel_presence", "violation", where,
                    f"routed via {ev.route!r}, expected {want} for "
                    f"impl={impl!r}"))
            if ev.route == "qdq_fallback":
                findings.append(Finding(
                    "kernel_presence", "fallback", where,
                    "pallas impl fell back to QDQ: "
                    + ("; ".join(ev.reasons) or "no reason recorded")))

    # Coverage: every plan cell must have been traced.
    for i in range(n_layers):
        for cls in classes:
            mm = plan.layer(i).for_class(cls)
            need = roles if not mm.is_passthrough else ("fwd",)
            if packed:
                need = ("fwd",)
            for role in need:
                if (i, cls, role) not in seen:
                    findings.append(Finding(
                        "kernel_presence", "violation",
                        f"L{i}/{cls}/{role}",
                        "plan cell never traced — no routing event"))
    if not head_seen:
        findings.append(Finding("kernel_presence", "violation",
                                "head/fwd",
                                "lm-head matmul never traced"))
    return findings


def audit_graph_vs_census(graph: Dict[str, Any],
                          cells: Sequence[routing.RouteEvent]
                          ) -> List[Finding]:
    """Cross-check the jaxpr census against the routing census.

    Every role with pallas-routed cells must stage at least as many
    ``pallas_call`` equations as it has distinct cells (remat/unroll can
    only add replays, never remove calls); every ``qdq_*`` marker under a
    ``qrole_*`` scope must be explained by a QDQ-routed census cell of
    that role (an unexplained one means a quantize op reached a path the
    census never sanctioned); f32 operands on kernel calls are
    violations.
    """
    findings: List[Finding] = []
    pallas_cells = Counter()
    allowed_markers = set()
    for ev in cells:
        if ev.route == "pallas":
            pallas_cells[ev.role] += 1
        if ev.route in ("qdq", "qdq_fallback", "dot", "packed_dot"):
            for spec_str in (ev.spec_a, ev.spec_b):
                spec = QuantSpec.from_str(spec_str)
                if not spec.is_passthrough:
                    allowed_markers.add((ev.role, qdq_scope_name(spec)))

    calls = graph.get("pallas_calls", {})
    for role, n_cells in pallas_cells.items():
        n_calls = calls.get(role, 0)
        if n_calls < n_cells:
            findings.append(Finding(
                "kernel_presence", "violation", f"qrole_{role}",
                f"census routes {n_cells} cell(s) through pallas but the "
                f"jaxpr stages only {n_calls} pallas_call(s)"))
    for role in calls:
        if role != "-" and role not in pallas_cells:
            findings.append(Finding(
                "kernel_presence", "violation", f"qrole_{role}",
                "pallas_call in the graph with no pallas-routed census "
                "cell for that role"))

    for key, count in graph.get("qdq_markers", {}).items():
        role, marker = key.split("/", 1)
        if role == "-":
            continue  # codec outside matmul roles (KV cache, serving)
        if (role, marker) not in allowed_markers:
            findings.append(Finding(
                "role_safety", "violation", f"qrole_{role}/{marker}",
                f"quantize op ({count}x) under qrole_{role} that no "
                "census cell sanctions — quantize fed into a protected "
                "path?"))

    for msg in graph.get("f32_kernel_operands", []):
        findings.append(Finding(
            "role_safety", "violation", msg.split(":")[0],
            "operand wider than the compute dtype reaches a kernel-routed "
            "matmul: " + msg))
    return findings


# ---------------------------------------------------------------------------
# HLO-level checks (kernel evidence + comms)
# ---------------------------------------------------------------------------

def hlo_role_ops(hlo_text: str) -> Dict[str, int]:
    """ops-per-role census of ``qrole_*`` markers surviving into the
    compiled per-device HLO (kernel-presence evidence after fusion)."""
    counts = Counter()
    for op in walk_hlo(hlo_text):
        opn = op.op_name
        if not opn:
            continue
        for role in _QROLE_RE.findall(opn):
            counts[role] += 1
    return dict(counts)


def audit_hlo_comms(hlo_text: str, *, expect_fp8: bool) -> Tuple[
        Dict[str, Any], List[Finding]]:
    """Gradient all-reduce payload audit over the compiled HLO.

    ``expect_fp8``: the step was built with ``grad_compression='fp8'`` and
    a data axis > 1, so every gradient-payload all-reduce inside the
    ``collective`` graph span must carry an fp8-class payload
    (``f8e4m3fn``, or ``f16`` — its XLA:CPU legalization); a bf16/f32
    payload there means the gradient bytes went uncompressed.  The fp8
    compressor also emits one tiny f32 amax reduction per gradient leaf
    (the shared-scale ``reduce_max`` collectives); those are scale
    metadata, not payload, and are censused separately rather than
    flagged.  Returns (census, findings); the census also carries the
    shared walker's per-dtype byte counts.
    """
    findings: List[Finding] = []
    grad_ars: List[Tuple[str, str]] = []
    scale_ars: List[Tuple[str, str]] = []
    for op in walk_hlo(hlo_text):
        if op.base != "all-reduce" or op.variant == "-done":
            continue
        shape = op.payload_shape()
        dtype = shape[0] if shape else "?"
        opn = op.op_name or ""
        if "collective" not in opn:
            continue
        nbytes = shape_bytes(*shape) if shape else 0
        if "reduce_max" in opn or nbytes <= _SCALE_AR_BYTES:
            scale_ars.append((dtype, opn))
        else:
            grad_ars.append((dtype, opn))
    census = {"grad_allreduce_dtypes":
              dict(Counter(d for d, _ in grad_ars)),
              "scale_allreduce_dtypes":
              dict(Counter(d for d, _ in scale_ars)),
              "bytes": {k: v for k, v in collective_bytes(hlo_text).items()
                        if k.startswith("raw_all-reduce")}}
    if expect_fp8:
        if not grad_ars:
            findings.append(Finding(
                "comms", "violation", "all-reduce",
                "fp8 gradient compression expected but no payload "
                "all-reduce in the 'collective' span"))
        for dtype, opn in grad_ars:
            if dtype not in _FP8_WIRE_DTYPES:
                findings.append(Finding(
                    "comms", "violation", opn[:80],
                    f"gradient all-reduce payload is {dtype}, not the "
                    f"compressed fp8 wire dtype "
                    f"({sorted(_FP8_WIRE_DTYPES)})"))
    return census, findings


def audit_scale_placement(plan: PrecisionPlan) -> List[Finding]:
    """The PR-6 quant-scale placement policy, checked against the resolved
    plan: block/tile scale grids must shard WITH their operand's reduction
    axis (the per-128-group count inherits the reduction dim's logical
    name), token/tensor scales must collapse/replicate it.  Catches policy
    -table drift for exactly the granularities the plan actually uses.
    """
    findings = []
    grans = set()
    for i in range(plan.n_layers):
        for cls in ("attn", "ffn"):
            mm = plan.layer(i).for_class(cls)
            for role in _TRAIN_ROLES:
                for spec in _role_specs(mm, role):
                    if not spec.is_passthrough:
                        grans.add(spec.granularity)
    for gran in sorted(grans):
        for red_axis, red_name in ((1, "col"), (0, "row")):
            logical = scale_logical_axes(gran, red_axis, ("row", "col"))
            with_red = red_name in logical
            if gran in ("block", "tile") and not with_red:
                findings.append(Finding(
                    "comms", "violation", f"scale[{gran}]",
                    f"{gran} scales no longer shard with the reduction "
                    f"axis (axis {red_axis} -> {logical})"))
            if gran in ("token", "tensor") and with_red:
                findings.append(Finding(
                    "comms", "violation", f"scale[{gran}]",
                    f"{gran} scales must replicate along the reduction "
                    f"axis but got {logical}"))
    return findings


# ---------------------------------------------------------------------------
# Recompile budget
# ---------------------------------------------------------------------------

def _plan_fingerprint(plan) -> str:
    blob = json.dumps(plan.to_dict(), sort_keys=True).encode()
    return hashlib.md5(blob).hexdigest()[:10]


def recompile_census(trainer, extra_plans: Sequence[PrecisionPlan] = ()
                     ) -> Tuple[Dict[str, Any], List[Finding]]:
    """Cache-key census over the trainer's compiled step graphs.

    Expected plan set: the stage-1 plan, the schedule's stage-2 target,
    every plan the controller has materialized, plus ``extra_plans``.
    Keys are content-addressed ``(plan, telemetry)`` tuples, so a key
    whose plan is outside that set — or more compiled graphs than
    |plans| x |telemetry variants| — is an unexpected retrace.
    """
    findings: List[Finding] = []
    target = trainer.schedule.target_plan
    if callable(target):
        target = target()
    expected = {_plan_fingerprint(trainer.plan), _plan_fingerprint(target)}
    if trainer.controller is not None:
        cache = getattr(trainer.controller, "_plan_cache", {})
        expected |= {_plan_fingerprint(p) for p in cache.values()}
    expected |= {_plan_fingerprint(p) for p in extra_plans}
    observed = [(_plan_fingerprint(plan), tel)
                for (plan, tel) in trainer._steps]
    tel_variants = {tel for _, tel in observed}
    budget = len(expected) * max(1, len(tel_variants))
    for fp, tel in observed:
        if fp not in expected:
            findings.append(Finding(
                "recompile", "violation", f"step[{fp},tel={tel}]",
                "compiled step graph for a plan outside the expected set "
                "(unexpected retrace)"))
    if len(observed) > budget:
        findings.append(Finding(
            "recompile", "violation", "steps",
            f"{len(observed)} compiled step graphs exceed the budget of "
            f"{budget} ({len(expected)} plan(s) x "
            f"{max(1, len(tel_variants))} telemetry variant(s))"))
    census = {"n_compiled": len(observed),
              "budget": budget,
              "keys": [f"{fp}:tel={tel}" for fp, tel in observed]}
    return census, findings


# ---------------------------------------------------------------------------
# Graph drivers
# ---------------------------------------------------------------------------

def _synth_batch(cfg, batch: int, seq: int) -> Dict[str, jnp.ndarray]:
    toks = jax.random.randint(jax.random.PRNGKey(0), (batch, seq), 0,
                              cfg.vocab_size)
    return {"tokens": toks, "targets": toks}


def _finish_report(report: QlintReport, log: routing.RoutingLog,
                   plan: PrecisionPlan, impl: str, graph: Dict[str, Any],
                   *, roles=_TRAIN_ROLES, packed=False,
                   hlo_text: Optional[str] = None,
                   expect_fp8: bool = False) -> QlintReport:
    cells = log.cells()
    report.cells = [ev.to_dict() for ev in cells]
    report.extend(audit_cells(cells, plan, impl, roles=roles,
                              packed=packed))
    report.extend(audit_graph_vs_census(graph, cells))
    report.extend(audit_scale_placement(plan))
    report.summary = {
        "n_cells": len(cells),
        "n_fallback_cells": len(log.fallbacks()),
        "pallas_calls": graph["pallas_calls"],
        "qdq_markers": graph["qdq_markers"],
        "n_eqns": graph["n_eqns"],
    }
    if hlo_text is not None:
        role_ops = hlo_role_ops(hlo_text)
        report.summary["hlo_role_ops"] = role_ops
        pallas_roles = {ev.role for ev in cells if ev.route == "pallas"}
        for role in sorted(pallas_roles - set(role_ops)):
            report.add(Finding(
                "kernel_presence", "violation", f"hlo/qrole_{role}",
                "no op with this role's scope marker survives into the "
                "per-device HLO"))
        comms, findings = audit_hlo_comms(hlo_text, expect_fp8=expect_fp8)
        report.summary["comms"] = comms
        report.extend(findings)
    return report


def audit_train_graph(cfg, tcfg, *, label: str = "train",
                      batch: Optional[int] = None,
                      seq: Optional[int] = None,
                      compile_hlo: bool = True,
                      plan: Optional[PrecisionPlan] = None) -> QlintReport:
    """Trace one jitted train step (no execution) and audit it.

    ``plan`` overrides the trainer-resolved plan as the AUDIT REFERENCE
    only — the traced graph still runs the trainer's plan.  That is the
    seeded-violation hook: trace plan B, audit against plan A, and the
    role-safety checks must fire.
    """
    from repro.models import build_model
    from repro.train.trainer import Trainer

    model = build_model(cfg)
    trainer = Trainer(model, tcfg, pipeline=None, jit=True)
    audit_plan = plan if plan is not None else trainer.plan
    state = trainer.init_state()
    b = _synth_batch(cfg, batch or tcfg.global_batch, seq or tcfg.seq_len)
    step = trainer._step_fn(trainer.plan)
    args = (state.params, state.opt_state, state.comp_state, b,
            jnp.zeros((), jnp.int32), jnp.ones((), jnp.float32))
    report = QlintReport(label)
    with routing.capture() as log:
        jaxpr = jax.make_jaxpr(step)(*args)
        hlo_text = None
        if compile_hlo:
            hlo_text = step.lower(*args).compile().as_text()
    graph = graph_census(jaxpr, jnp.dtype(cfg.dtype))
    dp = trainer.rules.dp_size if trainer.rules is not None else 1
    expect_fp8 = tcfg.grad_compression == "fp8" and dp > 1
    _finish_report(report, log, audit_plan, cfg.linear_impl, graph,
                   hlo_text=hlo_text, expect_fp8=expect_fp8)
    census, findings = recompile_census(trainer)
    report.summary["recompile"] = census
    report.extend(findings)
    return report


def audit_decode_graph(cfg, recipe, *, label: str = "decode",
                       n_slots: int = 2, max_len: int = 64,
                       kv_format: Optional[str] = "fp8_e4m3",
                       fmt: str = "fp4_e2m1",
                       compile_hlo: bool = True) -> QlintReport:
    """Build a packed-weight :class:`DecodeEngine` and audit its batched
    generate-step graph (quantize-once panels -> ``packed_dot``/fused
    activation-quant routes; forward role only)."""
    from repro.models import build_model
    from repro.train.serving_runtime import (DecodeEngine,
                                             quantize_weights_for_serving)

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_weights_for_serving(model, params, fmt, packed=True)
    engine = DecodeEngine(model, qparams, n_slots=n_slots, max_len=max_len,
                          recipe=recipe, kv_format=kv_format, jit=True)
    return audit_decode_engine(engine, label=label, compile_hlo=compile_hlo)


def audit_decode_engine(engine, *, label: str = "decode",
                        compile_hlo: bool = True) -> QlintReport:
    """Audit an existing engine's generate-step graph (its ``qlint_report``
    hook).  Fwd-only: the serving path has no backward matmuls."""
    cfg = engine.model.cfg
    plan = PrecisionPlan.uniform(engine.recipe, cfg.n_layers)
    toks = jnp.zeros((engine.n_slots, 1), jnp.int32)
    live = jnp.zeros((engine.n_slots,), bool)
    args = (engine.params, engine.cache, toks, live)
    report = QlintReport(label)
    packed = any(type(p).__name__ == "PackedTensor"
                 for p in jax.tree.leaves(
                     engine.params,
                     is_leaf=lambda x: type(x).__name__ == "PackedTensor"))
    with routing.capture() as log:
        jaxpr = jax.make_jaxpr(engine._generate_impl)(*args)
        hlo_text = None
        if compile_hlo:
            hlo_text = (jax.jit(engine._generate_impl).lower(*args)
                        .compile().as_text())
    graph = graph_census(jaxpr, jnp.dtype(cfg.dtype))
    return _finish_report(report, log, plan, cfg.linear_impl, graph,
                          roles=("fwd",), packed=packed,
                          hlo_text=hlo_text, expect_fp8=False)


def audit_trainer(trainer, *, label: str = "trainer",
                  compile_hlo: bool = False) -> QlintReport:
    """The :meth:`Trainer.qlint_report` backend: audit the trainer's
    ACTIVE plan's step graph plus the recompile-budget census over every
    step graph the trainer has compiled so far."""
    cfg = trainer.model.cfg
    tcfg = trainer.tcfg
    b = _synth_batch(cfg, tcfg.global_batch, tcfg.seq_len)
    state = trainer.init_state()
    step = trainer._step_fn(trainer.plan)
    args = (state.params, state.opt_state, state.comp_state, b,
            jnp.zeros((), jnp.int32), jnp.ones((), jnp.float32))
    report = QlintReport(label)
    with routing.capture() as log:
        jaxpr = jax.make_jaxpr(step)(*args)
        hlo_text = (step.lower(*args).compile().as_text()
                    if compile_hlo else None)
    graph = graph_census(jaxpr, jnp.dtype(cfg.dtype))
    dp = trainer.rules.dp_size if trainer.rules is not None else 1
    expect_fp8 = tcfg.grad_compression == "fp8" and dp > 1
    _finish_report(report, log, trainer.plan, cfg.linear_impl, graph,
                   hlo_text=hlo_text, expect_fp8=expect_fp8)
    census, findings = recompile_census(trainer)
    report.summary["recompile"] = census
    report.extend(findings)
    return report


# ---------------------------------------------------------------------------
# Expectations (CI gate)
# ---------------------------------------------------------------------------

def expectations_payload(reports: Sequence[QlintReport]) -> Dict[str, Any]:
    """The normalized, diff-stable subset committed as the CI gate: the
    deduped cell census plus marker counts per graph, and the global
    violation/fallback totals (which the gate requires to be zero)."""
    out: Dict[str, Any] = {"version": 1, "graphs": {}}
    for r in reports:
        cells = sorted(
            ({k: v for k, v in c.items()} for c in r.cells),
            key=lambda c: (c["layer"] or "", c["cls"] or "", c["role"],
                           c["route"]))
        out["graphs"][r.label] = {
            "cells": cells,
            "pallas_calls": r.summary.get("pallas_calls", {}),
            "qdq_markers": r.summary.get("qdq_markers", {}),
            "n_violations": len(r.violations()),
            "n_fallbacks": len(r.fallbacks()),
        }
    out["n_violations"] = sum(len(r.violations()) for r in reports)
    out["n_fallbacks"] = sum(len(r.fallbacks()) for r in reports)
    return out


def compare_expectations(payload: Dict[str, Any],
                         expected: Dict[str, Any]) -> List[str]:
    """Differences between the current audit and the committed
    expectations, as human-readable strings (empty = gate passes)."""
    diffs: List[str] = []
    for key in ("n_violations", "n_fallbacks"):
        if payload.get(key) != expected.get(key):
            diffs.append(f"{key}: expected {expected.get(key)}, got "
                         f"{payload.get(key)}")
    exp_graphs = expected.get("graphs", {})
    got_graphs = payload.get("graphs", {})
    for label in sorted(set(exp_graphs) | set(got_graphs)):
        if label not in got_graphs:
            diffs.append(f"graph {label!r}: missing from this audit")
            continue
        if label not in exp_graphs:
            diffs.append(f"graph {label!r}: not in the expectations file "
                         "(run --update-expectations)")
            continue
        e, g = exp_graphs[label], got_graphs[label]
        for key in ("cells", "pallas_calls", "qdq_markers",
                    "n_violations", "n_fallbacks"):
            if e.get(key) != g.get(key):
                diffs.append(f"graph {label!r}: {key} drifted\n"
                             f"    expected: {json.dumps(e.get(key))[:400]}\n"
                             f"    got:      {json.dumps(g.get(key))[:400]}")
    return diffs


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _parse_mesh(s: Optional[str]) -> Optional[Tuple[int, ...]]:
    if not s:
        return None
    return tuple(int(p) for p in s.split(","))


def build_reports(config: str, plan_name: str, *, impl: str = "pallas",
                  mesh: Optional[Tuple[int, ...]] = None,
                  decode: bool = False, seq: int = 32, batch: int = 4,
                  compile_hlo: bool = True) -> List[QlintReport]:
    """The CLI's graph family: unrolled + scanned train steps, optionally
    a data-sharded step with fp8 gradient comms, optionally the packed
    decode graph."""
    from repro.configs.base import TrainConfig, get_config
    from repro.core.recipe import RECIPES

    base = get_config(config).replace(linear_impl=impl)
    tcfg = TrainConfig(recipe=plan_name, total_steps=8, global_batch=batch,
                       seq_len=seq)
    reports = [
        audit_train_graph(base.replace(scan_layers=False), tcfg,
                          label="train_unroll", compile_hlo=compile_hlo),
        audit_train_graph(base.replace(scan_layers=True), tcfg,
                          label="train_scan", compile_hlo=compile_hlo),
    ]
    if mesh is not None:
        import numpy as np
        need = int(np.prod(mesh))
        have = len(jax.devices())
        if have < need:
            raise SystemExit(
                f"--mesh {mesh} needs {need} devices but only {have} are "
                f"visible; on CPU set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
        dp = mesh[0]
        tcfg_m = dataclasses.replace(
            tcfg, mesh_shape=mesh, fsdp=False,
            grad_compression="fp8" if dp > 1 else "none")
        reports.append(audit_train_graph(
            base.replace(scan_layers=True), tcfg_m,
            label=f"train_mesh{'x'.join(map(str, mesh))}",
            compile_hlo=compile_hlo))
    if decode:
        reports.append(audit_decode_graph(
            base, RECIPES[plan_name], label="decode_packed",
            compile_hlo=compile_hlo))
    return reports


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.qlint",
        description="Static precision-flow audit of compiled step graphs")
    ap.add_argument("--config", default="tiny")
    ap.add_argument("--plan", default="fine_grained_fp4",
                    help="recipe name (core.recipe.RECIPES)")
    ap.add_argument("--impl", default="pallas",
                    choices=["qdq", "pallas", "pallas_two_pass"])
    ap.add_argument("--mesh", default=None,
                    help="comma mesh shape, e.g. 2,1 (data,model); adds a "
                         "sharded train graph with fp8 gradient comms")
    ap.add_argument("--decode", action="store_true",
                    help="also audit the packed-weight decode graph")
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip compile; jaxpr-level checks only")
    ap.add_argument("--json", default=None,
                    help="write the full findings JSON here")
    ap.add_argument("--expect", default=None,
                    help="expectations JSON to gate against")
    ap.add_argument("--update-expectations", action="store_true",
                    help="rewrite --expect from this audit instead of "
                         "gating")
    args = ap.parse_args(argv)

    reports = build_reports(args.config, args.plan, impl=args.impl,
                            mesh=_parse_mesh(args.mesh), decode=args.decode,
                            seq=args.seq, batch=args.batch,
                            compile_hlo=not args.no_hlo)

    for r in reports:
        print(r.human_report())
        print()

    n_viol = sum(len(r.violations()) for r in reports)
    n_fall = sum(len(r.fallbacks()) for r in reports)
    print(f"qlint: {len(reports)} graph(s), {n_viol} violation(s), "
          f"{n_fall} fallback(s)")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"reports": [r.to_dict() for r in reports]}, f,
                      indent=1, sort_keys=True)
        print(f"qlint: findings JSON -> {args.json}")

    payload = expectations_payload(reports)
    if args.expect:
        if args.update_expectations:
            with open(args.expect, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
                f.write("\n")
            print(f"qlint: expectations updated -> {args.expect}")
        else:
            with open(args.expect) as f:
                expected = json.load(f)
            diffs = compare_expectations(payload, expected)
            for d in diffs:
                print(f"qlint: EXPECTATION DRIFT: {d}")
            if diffs:
                return 2
            print("qlint: expectations match")
    return 1 if n_viol else 0


if __name__ == "__main__":
    sys.exit(main())
