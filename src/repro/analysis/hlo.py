"""Shared HLO-text walker + passes over it (collective bytes, qlint).

``walk_hlo`` parses post-optimization HLO text (``compiled.as_text()``)
into one :class:`HloOp` record per instruction line — ONE parser that
every analysis pass shares:

  * :func:`parse_collectives` / :func:`collective_bytes` — the roofline's
    wire-byte census (PR-6), output bit-for-bit what the pre-walker
    implementation produced;
  * ``analysis.qlint`` — kernel-presence / payload-dtype / op-metadata
    checks over the same records.

Shapes in post-SPMD HLO are per-device shard shapes, so the sums here are
per-chip bytes moved, matching the roofline convention
``collective_bytes / (chips * link_bw)`` when collective_bytes is global.

Ring-model cost factors (bytes actually crossing links per operand byte):
  all-reduce        2(N-1)/N  ~ 2   (reduce-scatter + all-gather)
  all-gather         (N-1)/N  ~ 1   (operand = the gathered result)
  reduce-scatter     (N-1)/N  ~ 1
  all-to-all         (N-1)/N  ~ 1
  collective-permute        1

While-loop bodies appear once in HLO text but execute trip-count times; the
roofline pass therefore unrolls layer loops (see launch.dryrun) and applies
analytic corrections for the remaining interior scans (analysis.roofline).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

__all__ = ["HloOp", "walk_hlo", "parse_collectives", "collective_bytes",
           "COLLECTIVE_FACTORS", "shape_bytes", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}
_DTYPE_BYTES = DTYPE_BYTES  # historic private alias

COLLECTIVE_FACTORS = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# An instruction call after the '=': the first `mnemonic(` token, e.g.
#   %ag.3 = bf16[4,1024,512]{2,1,0} all-gather(%param.1), ...
#   %ags = (bf16[8],bf16[8]) all-gather-start(...)
# Result-shape tokens (`bf16[4,...]`) never match (no '(' follows), and
# the lhs name sits before the '=' so it is never scanned.
_CALL_RE = re.compile(r"[\s)]([a-z][a-z0-9\-]*)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_NAME_RE = re.compile(r'op_name="([^"]*)"')


def shape_bytes(dtype: str, dims: str) -> int:
    """Payload bytes of one ``dtype[dims]`` result shape (0 if unknown)."""
    nb = DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    if not dims:
        return nb
    return int(np.prod([int(d) for d in dims.split(",")])) * nb


_shape_bytes = shape_bytes  # historic private alias


@dataclasses.dataclass(frozen=True)
class HloOp:
    """One parsed HLO instruction line.

    ``mnemonic`` is the instruction as written (``all-gather-start``);
    ``base``/``variant`` split the async suffix (``all-gather``,
    ``-start``).  ``shapes`` are the (dtype, dims) result-shape tokens
    between the ``=`` and the call — for async ``-start`` tuples that
    includes operand AND destination buffers, so payload accounting takes
    the max-byte element (see :meth:`payload_shape`).  ``line`` keeps the
    raw text for pass-specific regexes (shardings, metadata).
    """
    mnemonic: str
    base: str
    variant: str
    shapes: Tuple[Tuple[str, str], ...]
    line: str

    def payload_shape(self) -> Optional[Tuple[str, str]]:
        """The largest-byte result shape, or None if no shape parsed."""
        if not self.shapes:
            return None
        return max(self.shapes, key=lambda s: shape_bytes(*s))

    @property
    def op_name(self) -> Optional[str]:
        """The ``metadata={op_name="..."}`` path (named-scope trail), if
        present on the line."""
        m = _OP_NAME_RE.search(self.line)
        return m.group(1) if m else None


def walk_hlo(hlo_text: str) -> Iterator[HloOp]:
    """Yield one :class:`HloOp` per instruction line of ``hlo_text``.

    Lines without an ``=`` or without a recognizable ``mnemonic(`` call
    (module/computation headers, braces) are skipped.
    """
    for line in hlo_text.splitlines():
        eq = line.find("=")
        if eq < 0:
            continue
        m = _CALL_RE.search(line, eq)
        if not m:
            continue
        mnemonic = m.group(1)
        base, variant = mnemonic, ""
        for suf in ("-start", "-done"):
            if mnemonic.endswith(suf):
                base, variant = mnemonic[: -len(suf)], suf
                break
        yield HloOp(mnemonic=mnemonic, base=base, variant=variant,
                    shapes=tuple(_SHAPE_RE.findall(line[eq:m.start()])),
                    line=line)


def parse_collectives(hlo_text: str) -> List[Tuple[str, str, int]]:
    """[(op_kind, result_type, per_shard_bytes)] for every collective.

    ``-start`` ops count once (their tuple result holds operand+destination
    buffers; the payload is the largest element); the paired ``-done`` is
    skipped.  Bytes are per-shard (post-SPMD HLO shapes).
    """
    out = []
    for op in walk_hlo(hlo_text):
        if op.base not in COLLECTIVE_FACTORS or op.variant == "-done":
            continue
        shape = op.payload_shape()
        if shape is None:
            continue
        dtype, dims = shape
        out.append((op.base, f"{dtype}[{dims}]", shape_bytes(dtype, dims)))
    return out


# XLA:CPU legalizes payload dtypes the backend cannot reduce natively:
# bf16 collectives run as f32 (4B for 2B) and float8 collectives run as
# f16 (2B for 1B).  ``_WIRE_SCALE`` undoes both for the accelerator-
# faithful figure: this framework communicates activations/gradients in
# bf16 and compressed gradients in fp8 — it never moves genuine
# f32/f16 tensors — so those payloads are legalization artifacts.
_WIRE_SCALE = {"f32": 0.5, "f16": 0.5}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-op-kind raw and ring-model effective per-chip bytes.

    Raw figures count HLO payload bytes as written.  Two adjusted totals:
      * ``effective_total_bf16eq`` halves f32 payloads only (the historic
        metric: f32 is the bf16-legalization artifact of XLA:CPU);
      * ``effective_total_wire`` applies the full ``_WIRE_SCALE``
        legalization map (f32 -> bf16 AND f16 -> fp8), the figure to use
        when quantized collectives are in play.
    Per-(kind, dtype) raw bytes are reported as ``raw_<kind>_<dtype>`` so
    callers can isolate e.g. the fp8 gradient reduction from the bf16
    activation traffic.
    """
    ops = parse_collectives(hlo_text)
    raw = defaultdict(float)
    by_dtype = defaultdict(float)
    eff_bf16 = 0.0
    eff_wire = 0.0
    for kind, shape, b in ops:
        raw[kind] += b
        dtype = shape.split("[", 1)[0]
        by_dtype[(kind, dtype)] += b
        f = COLLECTIVE_FACTORS[kind] * b
        eff_bf16 += f * (0.5 if dtype == "f32" else 1.0)
        eff_wire += f * _WIRE_SCALE.get(dtype, 1.0)
    eff = sum(COLLECTIVE_FACTORS[k] * v for k, v in raw.items())
    out = {f"raw_{k}": v for k, v in raw.items()}
    out.update({f"raw_{k}_{d}": v for (k, d), v in by_dtype.items()})
    out["raw_total"] = sum(raw.values())
    out["effective_total"] = eff
    out["effective_total_bf16eq"] = eff_bf16
    out["effective_total_wire"] = eff_wire
    out["n_ops"] = len(ops)
    return out
