"""Elastic scaling: re-map a checkpoint onto a different mesh.

When nodes are lost (or added) mid-run, the job restarts with a different
device count.  Because checkpoints store full (unsharded) arrays and the
sharding rules are pure functions of (mesh, config), elastic resume is just:

    params = load_pytree(ckpt, like)
    rules  = default_rules(new_mesh, cfg)
    params = reshard(params, rules.param_shardings(model.param_specs()))

``reshard`` also handles live arrays (device_put re-distributes across the
new mesh).  Divisibility-aware rules guarantee a valid layout exists for
any mesh the job restarts on (worst case: replication).
"""
from __future__ import annotations

from typing import Any

import jax

__all__ = ["reshard", "choose_mesh_shape"]


def reshard(tree: Any, shardings: Any) -> Any:
    """Re-distribute every array onto the given shardings."""
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)


def choose_mesh_shape(n_devices: int, *, prefer_model: int = 16):
    """Pick a (data, model) shape for an arbitrary surviving device count.

    Keeps TP at ``prefer_model`` when divisible, else the largest power-of-2
    divisor <= prefer_model — deterministic across hosts, so every worker
    derives the same mesh without coordination.
    """
    model = prefer_model
    while model > 1 and n_devices % model:
        model //= 2
    return (n_devices // model, model)
