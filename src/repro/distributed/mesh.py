"""Mesh helpers (device-count agnostic; see launch.mesh for production)."""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np

__all__ = ["make_mesh"]


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...],
              devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    """Build a mesh over the first prod(shape) devices."""
    n = int(np.prod(shape))
    devices = list(devices if devices is not None else jax.devices())[:n]
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    arr = np.asarray(devices).reshape(shape)
    return jax.sharding.Mesh(arr, axes)
