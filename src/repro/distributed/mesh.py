"""Mesh helpers (device-count agnostic; see launch.mesh for production)."""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np

__all__ = ["make_mesh"]


def _resolve_axis_types(axis_types: Sequence[Union[str, object]],
                        n_axes: int):
    """Map 'auto'/'explicit'/'manual' strings (or AxisType values) to
    ``jax.sharding.AxisType``; returns None when this jax predates
    AxisType (every axis is implicitly Auto there, so requesting 'auto'
    degrades gracefully instead of failing)."""
    AxisType = getattr(jax.sharding, "AxisType", None)
    if len(axis_types) != n_axes:
        raise ValueError(f"axis_types has {len(axis_types)} entries for "
                         f"{n_axes} mesh axes")
    if AxisType is None:
        if any(str(t).lower().split(".")[-1] != "auto" for t in axis_types):
            raise ValueError(
                f"axis_types {axis_types!r} need jax.sharding.AxisType, "
                "which this jax version does not provide (only 'auto' is "
                "representable as the implicit default)")
        return None
    by_name = {"auto": AxisType.Auto, "explicit": AxisType.Explicit,
               "manual": getattr(AxisType, "Manual", AxisType.Auto)}
    out = []
    for t in axis_types:
        if isinstance(t, AxisType):
            out.append(t)
        else:
            try:
                out.append(by_name[str(t).lower()])
            except KeyError:
                raise ValueError(f"unknown axis type {t!r}; "
                                 f"have {sorted(by_name)}") from None
    return tuple(out)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...],
              devices: Optional[Sequence] = None,
              axis_types: Optional[Sequence[Union[str, object]]] = None
              ) -> jax.sharding.Mesh:
    """Build a mesh over the first prod(shape) devices.

    The single mesh constructor (``launch.mesh.make_production_mesh``
    routes through here).  ``axis_types`` optionally names each axis's
    GSPMD mode ('auto' | 'explicit' | 'manual', or ``jax.sharding.AxisType``
    values); omitted or 'auto' works on every supported jax version.
    """
    n = int(np.prod(shape))
    devices = list(devices if devices is not None else jax.devices())[:n]
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    arr = np.asarray(devices).reshape(shape)
    if axis_types is not None:
        resolved = _resolve_axis_types(axis_types, len(axes))
        if resolved is not None:
            return jax.sharding.Mesh(arr, axes, axis_types=resolved)
    return jax.sharding.Mesh(arr, axes)
