"""Distribution: mesh construction, logical->physical sharding, elasticity."""
from repro.distributed.sharding import (ShardingRules, default_rules,
                                        opt_state_shardings)
from repro.distributed.mesh import make_mesh

__all__ = ["ShardingRules", "default_rules", "opt_state_shardings",
           "make_mesh"]
