"""Logical->physical sharding rules (MaxText-style, divisibility-aware).

Every ParamSpec carries logical axis names; activations use ``shard_hint``
with logical names.  ``ShardingRules`` maps those names onto mesh axes with
two safety properties needed across 10 heterogeneous architectures:

  * divisibility-aware: an assignment is dropped (replicated) when the dim
    is not divisible by the mesh-axis size — e.g. whisper's vocab 51865 on
    model=16, or GQA kv_heads=8 on model=16 (Megatron-style KV duplication);
  * granule-aware: flattened head dims (n_heads*head_dim) are only sharded
    when the *head count* divides the axis, so heads never split across
    devices (``granules``).
  * conflict-free: a mesh axis is used at most once per PartitionSpec
    (first dim wins; later dims fall back to replication).

Default mapping (the paper-faithful Megatron-esque layout):
  params:  embed->fsdp axes, heads/kv_heads/mlp/vocab/experts/mamba_*->model
  acts:    batch->(pod,data), heads/mlp/vocab/experts->model, seq->None
           (seq->model when sequence parallelism is enabled)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn.params import ParamSpec

__all__ = ["ShardingRules", "default_rules", "opt_state_shardings"]

AxisAssignment = Optional[Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    param_rules: Dict[str, AxisAssignment]
    act_rules: Dict[str, AxisAssignment]
    granules: Dict[str, int]

    # -- core assignment ----------------------------------------------------

    def _axis_size(self, names: Tuple[str, ...]) -> int:
        return int(np.prod([self.mesh.shape[n] for n in names]))

    def _assign(self, rules: Dict[str, AxisAssignment],
                logical: Optional[str], dim: int,
                used: set) -> AxisAssignment:
        if logical is None:
            return None
        want = rules.get(logical)
        if want is None:
            return None
        want = (want,) if isinstance(want, str) else tuple(want)
        granule = self.granules.get(logical, dim)
        # try the full tuple, then prefixes (e.g. ('pod','data')->('pod',))
        for k in range(len(want), 0, -1):
            cand = want[:k]
            if any(a in used for a in cand):
                continue
            size = self._axis_size(cand)
            if dim % size == 0 and granule % size == 0:
                used.update(cand)
                return cand
        return None

    def _spec(self, rules, logicals: Sequence[Optional[str]],
              shape: Sequence[int]) -> P:
        used: set = set()
        parts = [self._assign(rules, l, d, used)
                 for l, d in zip(logicals, shape)]
        parts = [p if p is None else (p[0] if len(p) == 1 else p)
                 for p in parts]
        return P(*parts)

    # -- public -------------------------------------------------------------

    def param_sharding(self, spec: ParamSpec) -> NamedSharding:
        return NamedSharding(
            self.mesh, self._spec(self.param_rules, spec.axes, spec.shape))

    def param_shardings(self, spec_tree) -> Any:
        return jax.tree.map(self.param_sharding, spec_tree,
                            is_leaf=lambda x: isinstance(x, ParamSpec))

    def activation_sharding(self, axes: Sequence[Optional[str]],
                            shape: Sequence[int]) -> Optional[NamedSharding]:
        spec = self._spec(self.act_rules, axes, shape)
        return NamedSharding(self.mesh, spec)

    def batch_sharding(self, ndim: int) -> NamedSharding:
        """Standard input-batch sharding: dim0 over the data axes."""
        spec = self._spec(self.act_rules, ["batch"] + [None] * (ndim - 1),
                          [0] * ndim)  # dim sizes unused for 'batch'
        return NamedSharding(self.mesh, spec)

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    # -- data-parallel structure --------------------------------------------

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        """Mesh axes the batch shards over (the gradient-reduction group)."""
        want = self.act_rules.get("batch") or ()
        want = (want,) if isinstance(want, str) else tuple(want)
        return tuple(a for a in want if a in self.mesh.axis_names)

    @property
    def dp_size(self) -> int:
        return self._axis_size(self.dp_axes) if self.dp_axes else 1

    def manual_over(self, axes: Sequence[str]) -> "ShardingRules":
        """Rules for code whose ``axes`` placement is handled elsewhere —
        inside a shard_map manual region, or a vmapped per-data-shard body
        whose stacked leading dim already carries the data axes.

        Every rule assignment referencing those mesh axes is stripped (the
        remaining axes — e.g. 'model' under a data-manual region — keep
        working as GSPMD-auto ``with_sharding_constraint`` targets)."""
        drop = set(axes)

        def strip(rules: Dict[str, AxisAssignment]) -> Dict[str, Any]:
            out: Dict[str, Any] = {}
            for k, v in rules.items():
                if v is None:
                    out[k] = None
                    continue
                t = (v,) if isinstance(v, str) else tuple(v)
                t = tuple(a for a in t if a not in drop)
                out[k] = t or None
            return out

        return dataclasses.replace(self, param_rules=strip(self.param_rules),
                                   act_rules=strip(self.act_rules))

    # -- caches ---------------------------------------------------------------

    def cache_shardings(self, cache_spec_tree) -> Any:
        """Shardings for a serve cache pytree (path-dispatch by leaf name)."""

        def by_path(path, leaf):
            keys = [str(getattr(p, "key", "")) for p in path]
            scan_stacked = "groups" in keys
            name = keys[-1] if keys else ""
            ndim = len(leaf.shape)
            lead = ["layers"] if scan_stacked else []
            if name in ("k", "v"):       # (B, S, KVH, HD)
                ax = lead + ["batch", None, "kv_heads", None]
            elif name == "pos":
                ax = lead + [None]
            elif name == "conv":         # (B, K-1, conv_dim)
                ax = lead + ["batch", None, "mamba_inner"]
            elif name == "state":        # (B, H, P, N)
                ax = lead + ["batch", "mamba_heads", None, None]
            elif name == "length":
                ax = [None] * ndim
            else:
                ax = lead + ["batch"] + [None] * (ndim - len(lead) - 1)
            ax = (ax + [None] * ndim)[:ndim]
            return self.activation_sharding(ax, leaf.shape)

        return jax.tree_util.tree_map_with_path(by_path, cache_spec_tree)


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def default_rules(mesh: Mesh, cfg=None, *, fsdp: bool = True,
                  seq_parallel: bool = False,
                  free_head_shard: bool = False,
                  overrides: Optional[Dict[str, AxisAssignment]] = None,
                  act_overrides: Optional[Dict[str, AxisAssignment]] = None
                  ) -> ShardingRules:
    """The default FSDP+TP(+EP) layout for a model config."""
    dp = _dp_axes(mesh)
    tp = ("model",) if "model" in mesh.axis_names else ()
    param_rules: Dict[str, AxisAssignment] = {
        "embed": dp if fsdp else None,
        "mlp": tp or None,
        "heads": tp or None,
        "kv_heads": tp or None,
        "vocab": tp or None,
        "experts": tp or None,
        "mamba_inner": tp or None,
        "mamba_groups": tp or None,
        "mamba_heads": tp or None,
        "layers": None,
    }
    act_rules: Dict[str, AxisAssignment] = {
        "batch": dp or None,
        # flattened (batch*seq) matmul rows — qlinear's x2d view and the
        # per-granularity quantization-scale tensors riding it
        "tokens": dp or None,
        "seq": tp if seq_parallel else None,
        "seq_q": None,  # context-parallel attention (hillclimb override)
        "embed": None,
        "mlp": tp or None,
        "heads": tp or None,
        "kv_heads": tp or None,
        "vocab": tp or None,
        "experts": tp or None,
        "mamba_heads": tp or None,
        "mamba_inner": tp or None,
        "mamba_groups": tp or None,
    }
    granules: Dict[str, int] = {}
    if cfg is not None:
        hd = cfg.resolved_head_dim
        if not free_head_shard:
            granules["heads"] = max(cfg.n_heads, 1)
            granules["kv_heads"] = max(cfg.n_kv_heads, 1)
        # free_head_shard: pair with context-parallel attention
        # (seq_q->model) — the SDPA no longer needs whole heads per device,
        # so QKV/O weight dims shard as plain matrices (granule defaults to
        # the dim); activation head dims (= head COUNTS, e.g. 24) still
        # fail plain divisibility and replicate, re-gathering qkv before
        # the seq-sharded attention math.
        if cfg.moe is not None:
            granules["experts"] = cfg.moe.num_experts
        if cfg.mamba is not None:
            d_inner = cfg.mamba.expand * cfg.d_model
            granules["mamba_heads"] = d_inner // cfg.mamba.headdim
            # split projections: x/z shard on head boundaries; B/C on group
            # boundaries (replicate when n_groups < TP — they are narrow).
            granules["mamba_inner"] = d_inner // cfg.mamba.headdim
            granules["mamba_groups"] = cfg.mamba.n_groups
    param_rules.update(overrides or {})
    act_rules.update(act_overrides or {})
    return ShardingRules(mesh=mesh, param_rules=param_rules,
                         act_rules=act_rules, granules=granules)


def opt_state_shardings(opt_state, params_abstract, param_shardings,
                        mesh: Mesh):
    """Shardings for AdamW/Adafactor states, derived from param shardings.

    mu/nu mirror params; adafactor row/col factors drop the corresponding
    trailing spec entries; scalars replicate.
    """
    from repro.optim.adamw import AdamWState
    from repro.optim.adafactor import AdafactorState

    rep = NamedSharding(mesh, P())

    if isinstance(opt_state, AdamWState):
        return AdamWState(count=rep, mu=param_shardings, nu=param_shardings)
    if isinstance(opt_state, AdafactorState):
        def padded(sh: NamedSharding, nd: int):
            return (tuple(sh.spec) + (None,) * nd)[:nd]

        def vr_sh(sh, p):
            nd = len(p.shape)
            spec = padded(sh, nd)
            if nd >= 2:
                return NamedSharding(mesh, P(*spec[:-1]))
            return NamedSharding(mesh, P(*spec))

        def vc_sh(sh, p):
            nd = len(p.shape)
            if nd < 2:
                return rep
            spec = padded(sh, nd)
            return NamedSharding(mesh, P(*(spec[:-2] + (spec[-1],))))

        vr = jax.tree.map(vr_sh, param_shardings, params_abstract)
        vc = jax.tree.map(vc_sh, param_shardings, params_abstract)
        return AdafactorState(count=rep, vr=vr, vc=vc)
    raise TypeError(type(opt_state))
