"""Serving runtime: quantize-once weight panels, batched continuous decode,
FP8 KV caches, streaming long-context prefill.

Four production-serving features that reuse the paper's quantization core:

* ``quantize_weights_for_serving`` — FP4/FP8 weight-only compression of a
  trained checkpoint.  The default ``packed=True`` quantizes every eligible
  linear weight exactly ONCE at load into a ``core.packed.PackedTensor``
  (uint8 codes + per-block-128 scales), which really shrinks serving HBM
  (~0.25x / ~0.5x of bf16 for FP4 / FP8 plus scale overhead — see
  ``serving_memory_report``).  ``packed=False`` keeps the legacy simulated
  path: per-block QDQ that stores the *dequantized* bf16/f32 values — it
  measures quantization accuracy but saves no memory.
* ``DecodeEngine`` — slot-indexed batched decode: one per-slot KV cache
  holds all slots, prefill runs per request (bucket-padded so prompt
  lengths don't retrace), ``insert`` splices a prefilled slot in, and a
  single jitted ``generate_step`` decodes ALL live slots in one batched
  forward (maxtext-style prefill/insert/generate split).
* ``ContinuousBatcher`` — request-queue bookkeeping over the engine
  (Orca/vLLM-style continuous batching, static-shape variant).
* ``streaming_prefill`` — long-context prefill in fixed-size segments
  (SSM state and KV cache carry across segments), bounding activation
  memory for 500k-token prompts.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.core.packed import PackedTensor, pack_tensor, packed_nbytes
from repro.core.quantize import QuantSpec, qdq
from repro.core.recipe import PrecisionRecipe, RECIPES
from repro.models.model import Model, build_model
from repro.nn.params import ParamSpec
from repro.telemetry.profiler import phase_span

__all__ = ["quantize_weights_for_serving", "serving_memory_report",
           "DecodeEngine", "ContinuousBatcher", "streaming_prefill"]


# Eligible-looking (>=2-D, dtype-None, no vocab axis) params that are NOT
# consumed by a linear matmul, so the packed representation can't feed them:
# pos_embed is indexed per position, the mamba short-conv weights are used
# elementwise.  The legacy QDQ path quantizes them (values only change
# within format tolerance); the packed path must leave them dense.
_NOT_LINEAR_CONSUMED = {"pos_embed", "conv_wx", "conv_wb", "conv_wc"}


def quantize_weights_for_serving(model: Model, params,
                                 fmt: str = "fp4_e2m1",
                                 block: int = 128,
                                 packed: bool = True):
    """Weight-only quantization of every >=2-D linear weight for serving.

    ``packed=True`` (default): quantize once into ``PackedTensor`` panels —
    uint8 codes + per-(block x block) f32 scales.  This is a real storage
    change (FP4 ~4 bits/param, FP8 ~8 bits/param vs bf16's 16); the
    serving matmuls (``core.qlinear.packed_linear``) consume the panel
    directly and expand it to the compute dtype on the fly.  Decoded
    values are bitwise identical to the ``packed=False`` QDQ output.

    ``packed=False``: legacy simulated path — per-block QDQ that stores the
    dequantized values in the original dtype.  Accuracy-equivalent, but it
    saves NO memory (the array is still bf16/f32-sized); use it only to
    study quantization error or as the bitwise reference for the packed
    path.

    Norm scales, biases, routers, embeddings/LM head and mamba dt/A stay
    untouched (the same sensitive classes the training recipe protects).
    """
    spec = QuantSpec(fmt, "tile", block)
    specs = model.param_specs()

    if not packed:
        def q(p, s: ParamSpec):
            if s.dtype is not None or len(s.shape) < 2:
                return p  # protected / vector param
            if "vocab" in (s.axes or ()):
                return p  # embeddings / LM head stay high-precision
            if len(s.shape) > 2:
                # scan-stacked (layers, K, N): quantize per layer so tile
                # blocks never span layer boundaries
                lead = int(np.prod(s.shape[:-2]))
                mat = p.reshape(lead, s.shape[-2], s.shape[-1])
                out = jax.vmap(lambda m: qdq(m, spec, 1))(mat)
                return out.reshape(p.shape)
            return qdq(p, spec, 1)

        return jax.tree.map(q, params, specs)

    def qp(path, p, s: ParamSpec):
        name = getattr(path[-1], "key", None)
        if s.dtype is not None or len(s.shape) < 2:
            return p
        if "vocab" in (s.axes or ()) or name in _NOT_LINEAR_CONSUMED:
            return p
        # A packable leaf must end in a true (K, N) matmul panel.  Strip
        # the scan-stack leading axis before the rank test: a stacked norm
        # scale is (layers, d) — 2-D, but not a matrix.  (The legacy QDQ
        # path quantizes those; dense values tolerate that, packed panels
        # would break ``apply_norm``.)
        axes = list(s.axes or ())
        rank = len(s.shape)
        if axes and axes[0] == "layers":
            rank -= 1
        if rank < 2:
            return p
        # pack_tensor vmaps over leading dims (scan-stacked layers, MoE
        # experts), so tile blocks never span a layer/expert boundary —
        # same isolation as the legacy path's per-layer vmap.
        return pack_tensor(p, spec)

    return jax.tree_util.tree_map_with_path(qp, params, specs)


def serving_memory_report(params) -> Dict[str, float]:
    """Measured storage of a (possibly packed) serving param tree.

    ``bytes_per_packed_param`` counts payload + scales over the packed
    leaves only; ``vs_bf16`` is that figure relative to 2 B/param.
    """
    packed_bytes, packed_params = packed_nbytes(params)
    dense_bytes = dense_params = 0
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda x: isinstance(x, PackedTensor)):
        if not isinstance(leaf, PackedTensor):
            dense_bytes += int(leaf.size) * leaf.dtype.itemsize
            dense_params += int(leaf.size)
    bpp = packed_bytes / max(packed_params, 1)
    return {
        "packed_bytes": int(packed_bytes),
        "packed_params": int(packed_params),
        "dense_bytes": int(dense_bytes),
        "dense_params": int(dense_params),
        "total_bytes": int(packed_bytes + dense_bytes),
        "bytes_per_packed_param": float(bpp),
        "vs_bf16": float(bpp / 2.0),
    }


def streaming_prefill(model: Model, params, tokens: jnp.ndarray, cache,
                      recipe: Optional[PrecisionRecipe] = None,
                      segment: int = 2048,
                      extras: Optional[Dict[str, jnp.ndarray]] = None):
    """Prefill a long prompt in fixed segments; returns (logits, cache).

    Activation memory is O(segment) instead of O(prompt): SSM states and the
    KV cache carry across segments (exactness tested against one-shot
    prefill).  The final partial segment is processed at its natural length.
    """
    recipe = recipe or RECIPES["bf16"]
    s = tokens.shape[1]
    logits = None
    for start in range(0, s, segment):
        chunk = tokens[:, start:start + segment]
        batch = dict(extras or {}, tokens=chunk)
        logits, cache = model.prefill(params, batch, cache, recipe)
    return logits, cache


# ---------------------------------------------------------------------------
# Batched decode engine (prefill / insert / generate split)
# ---------------------------------------------------------------------------

class DecodeEngine:
    """Slot-indexed batched decode over one per-slot KV cache.

    The serving hot loop splits into three jitted stages:

      * ``prefill(prompt)``   — run one prompt through the model into a
        fresh single-slot cache.  Prompts are right-padded to power-of-two
        buckets (``min_bucket`` .. ``max_len``) so arbitrary lengths hit a
        bounded set of compiled shapes; the padded tail writes K/V at
        positions >= the true length, which stay causally masked until
        decode overwrites them (full-attention only — SSM recurrences and
        ring-window caches fall back to exact-length prefill and pay the
        retrace).
      * ``insert(c1, tok, slot)`` — splice the prefilled cache into slot
        ``slot`` of the engine cache (one ``dynamic_update_slice`` per
        leaf; the slot index is traced, so refill never retraces).
      * ``generate_step()``   — ONE batched forward decodes every slot at
        its own position (vector ``length`` cache).  Dead slots run too —
        their logits are ignored and their lengths frozen via the traced
        ``live`` mask, so occupancy changes never retrace.

    ``kv_format`` ("fp8_e4m3" / "fp8_e5m2") switches the engine's cache to
    quantized K/V storage (uint8 codes + per-(token, head) scales —
    quantize on append, dequantize on read; ~half the cache HBM of bf16).
    """

    def __init__(self, model: Model, params, *, n_slots: int = 4,
                 max_len: int = 512,
                 recipe: Optional[PrecisionRecipe] = None,
                 kv_format: Optional[str] = None,
                 cache_dtype=None, jit: bool = True,
                 min_bucket: int = 16):
        if kv_format is not None:
            if F.FORMATS[kv_format].bits != 8:
                raise ValueError(
                    f"kv_format must be an 8-bit format, got {kv_format}")
            model = build_model(model.cfg.replace(kv_cache_format=kv_format))
        self.model = model
        self.params = params
        self.recipe = recipe or RECIPES["bf16"]
        self.n_slots = n_slots
        self.max_len = max_len
        self.min_bucket = min_bucket
        self.cache_dtype = cache_dtype or jnp.bfloat16
        specs = model.cfg.layer_specs()
        # Bucket-padded prefill relies on padded K/V staying causally
        # masked; SSM recurrences and ring-buffer windows consume the pad.
        self._can_bucket = (all(s.mixer == "attn" and not s.cross
                                for s in specs)
                            and not model.cfg.sliding_window)
        self.cache = model.init_cache(n_slots, max_len, self.cache_dtype,
                                      per_slot=True)
        self.live = np.zeros(n_slots, bool)
        self.last_tok = np.zeros(n_slots, np.int32)
        if jit:
            self._prefill = jax.jit(self._prefill_impl)
            self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
            self._generate = jax.jit(self._generate_impl,
                                     donate_argnums=(1,))
        else:
            self._prefill = self._prefill_impl
            self._insert = self._insert_impl
            self._generate = self._generate_impl

    # -- jitted stage bodies (bound methods; self rides in the closure) ----

    def _prefill_impl(self, params, toks, true_len):
        cache = self.model.init_cache(1, self.max_len, self.cache_dtype,
                                      per_slot=True)
        logits, cache = self.model.prefill(
            params, {"tokens": toks}, cache, self.recipe,
            true_length=true_len)
        tok = jnp.argmax(logits[0, -1].astype(jnp.float32))
        return tok.astype(jnp.int32), cache

    def _insert_impl(self, cache, c1, slot):
        def put(dst, src):
            src = src.astype(dst.dtype)
            if dst.shape == src.shape:
                return src
            ax = next(i for i, (a, b) in enumerate(zip(dst.shape, src.shape))
                      if a != b)
            return jax.lax.dynamic_update_slice_in_dim(dst, src, slot, ax)

        return jax.tree.map(put, cache, c1)

    def _generate_impl(self, params, cache, toks, live):
        logits, new_cache = self.model.decode_step(params, toks, cache,
                                                   self.recipe)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        # Dead slots decode too (their logits are ignored) but must not
        # advance — freeze their lengths so a later insert starts clean.
        new_cache["length"] = jnp.where(live, new_cache["length"],
                                        cache["length"])
        return nxt.astype(jnp.int32), new_cache

    def qlint_report(self, *, compile_hlo: bool = True):
        """Static precision-flow audit (``analysis.qlint``) of this
        engine's batched generate-step graph: packed-panel routes,
        activation-quant kernel presence, zero-fallback serving.  Trace-
        only — the engine's cache and slots are untouched."""
        from repro.analysis import qlint
        return qlint.audit_decode_engine(self, compile_hlo=compile_hlo)

    # -- public stages -----------------------------------------------------

    def prefill(self, prompt) -> Tuple[int, Any]:
        """Run one prompt; returns (first generated token, slot cache)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        n = int(prompt.size)
        assert 0 < n <= self.max_len, (n, self.max_len)
        if self._can_bucket:
            bucket = self.min_bucket
            while bucket < n:
                bucket *= 2
            bucket = min(bucket, self.max_len)
        else:
            bucket = n  # exact-length fallback (SSM / ring caches)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :n] = prompt
        with phase_span("decode_prefill"):
            tok, c1 = self._prefill(self.params, jnp.asarray(padded),
                                    jnp.int32(n))
        return int(tok), c1

    def insert(self, c1, first_tok: int, slot: int) -> None:
        """Splice a prefilled single-slot cache into ``slot``."""
        assert 0 <= slot < self.n_slots and not self.live[slot]
        with phase_span("decode_insert"):
            self.cache = self._insert(self.cache, c1, jnp.int32(slot))
        self.live[slot] = True
        self.last_tok[slot] = first_tok

    def release(self, slot: int) -> None:
        self.live[slot] = False

    def generate_step(self) -> np.ndarray:
        """One batched decode step; returns next token per slot (n_slots,).

        Entries for dead slots are garbage — callers gate on their own
        liveness bookkeeping.
        """
        with phase_span("decode_generate"):
            toks = jnp.asarray(self.last_tok[:, None])
            live = jnp.asarray(self.live)
            nxt, self.cache = self._generate(self.params, self.cache, toks,
                                             live)
            nxt = np.asarray(nxt)
        self.last_tok = np.where(self.live, nxt, self.last_tok)
        return nxt


# ---------------------------------------------------------------------------
# Continuous batching (request bookkeeping over the engine)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Slot:
    request_id: Optional[int] = None
    remaining: int = 0
    generated: Optional[List[int]] = None


class ContinuousBatcher:
    """Static-shape continuous batching over a fixed slot count.

    Requests are (prompt, max_new_tokens).  Prefill runs per request into a
    single-slot cache which is spliced into the shared per-slot cache;
    every step then decodes ALL live slots in one batched ``generate_step``
    (no per-slot Python loop on the hot path).  Finished slots are refilled
    from the queue immediately.
    """

    def __init__(self, model: Model, params, n_slots: int = 4,
                 max_len: int = 512,
                 recipe: Optional[PrecisionRecipe] = None,
                 kv_format: Optional[str] = None, jit: bool = True):
        self.engine = DecodeEngine(model, params, n_slots=n_slots,
                                   max_len=max_len, recipe=recipe,
                                   kv_format=kv_format, jit=jit)
        self.n_slots = n_slots
        self.max_len = max_len
        self.queue: Deque[Tuple[int, np.ndarray, int]] = deque()
        self.slots = [_Slot() for _ in range(n_slots)]
        self.finished: Dict[int, List[int]] = {}
        self._next_id = 0

    @property
    def model(self) -> Model:
        return self.engine.model

    @property
    def params(self):
        return self.engine.params

    @property
    def recipe(self) -> PrecisionRecipe:
        return self.engine.recipe

    # -- client API ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append((rid, np.asarray(prompt), max_new_tokens))
        return rid

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drive until queue and slots drain; returns {request_id: tokens}."""
        steps = 0
        while (self.queue or any(s.request_id is not None
                                 for s in self.slots)):
            self._refill()
            self._decode_step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("batcher did not drain")
        return self.finished

    # -- internals ----------------------------------------------------------

    def _refill(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.request_id is not None or not self.queue:
                continue
            rid, prompt, max_new = self.queue.popleft()
            tok, c1 = self.engine.prefill(prompt)
            self.slots[i] = _Slot(rid, max_new - 1, [tok])
            if max_new - 1 <= 0:
                self._finish(i)
            else:
                self.engine.insert(c1, tok, i)

    def _decode_step(self) -> None:
        live = [i for i, s in enumerate(self.slots)
                if s.request_id is not None]
        if not live:
            return
        nxt = self.engine.generate_step()
        for i in live:
            slot = self.slots[i]
            slot.generated.append(int(nxt[i]))
            slot.remaining -= 1
            if slot.remaining <= 0:
                self._finish(i)

    def _finish(self, i: int) -> None:
        slot = self.slots[i]
        self.finished[slot.request_id] = slot.generated
        self.slots[i] = _Slot()
        self.engine.release(i)
