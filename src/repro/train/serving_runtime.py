"""Serving runtime: continuous batching, FP4 weight-only serving weights,
streaming long-context prefill.

Three production-serving features that reuse the paper's quantization core:

* ``quantize_weights_for_serving`` — FP4/FP8 weight-only compression of a
  trained checkpoint (per-block QDQ via the same grids as training).  Halves
  (FP8) or quarters (FP4) serving HBM per chip; the paper's per-block-128
  scaling keeps matmul accuracy (logits stay close — tested).
* ``ContinuousBatcher`` — slot-based continuous batching: a fixed decode
  batch of S slots; finished/empty slots are refilled from a request queue
  with per-slot prefill, while live slots keep decoding.  The classic
  serving-throughput mechanism (Orca/vLLM-style, static-shape variant).
* ``streaming_prefill`` — long-context prefill in fixed-size segments
  (SSM state and KV cache carry across segments), bounding activation
  memory for 500k-token prompts.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import QuantSpec, qdq
from repro.core.recipe import PrecisionRecipe, RECIPES
from repro.models.model import Model
from repro.nn.params import ParamSpec

__all__ = ["quantize_weights_for_serving", "ContinuousBatcher",
           "streaming_prefill"]


def quantize_weights_for_serving(model: Model, params,
                                 fmt: str = "fp4_e2m1",
                                 block: int = 128):
    """Per-(block x block) weight-only QDQ of every >=2-D linear weight.

    Norm scales, biases, routers and mamba dt/A stay untouched (the same
    sensitive classes the training recipe protects).
    """
    spec = QuantSpec(fmt, "tile", block)
    specs = model.param_specs()

    def q(p, s: ParamSpec):
        if s.dtype is not None or len(s.shape) < 2:
            return p  # protected / vector param
        if "vocab" in (s.axes or ()):
            return p  # embeddings / LM head stay high-precision
        if len(s.shape) > 2:
            # scan-stacked (layers, K, N): quantize per layer so tile
            # blocks never span layer boundaries
            lead = int(np.prod(s.shape[:-2]))
            mat = p.reshape(lead, s.shape[-2], s.shape[-1])
            out = jax.vmap(lambda m: qdq(m, spec, 1))(mat)
            return out.reshape(p.shape)
        return qdq(p, spec, 1)

    return jax.tree.map(q, params, specs)


def streaming_prefill(model: Model, params, tokens: jnp.ndarray, cache,
                      recipe: Optional[PrecisionRecipe] = None,
                      segment: int = 2048,
                      extras: Optional[Dict[str, jnp.ndarray]] = None):
    """Prefill a long prompt in fixed segments; returns (logits, cache).

    Activation memory is O(segment) instead of O(prompt): SSM states and the
    KV cache carry across segments (exactness tested against one-shot
    prefill).  The final partial segment is processed at its natural length.
    """
    recipe = recipe or RECIPES["bf16"]
    s = tokens.shape[1]
    logits = None
    for start in range(0, s, segment):
        chunk = tokens[:, start:start + segment]
        batch = dict(extras or {}, tokens=chunk)
        logits, cache = model.prefill(params, batch, cache, recipe)
    return logits, cache


@dataclasses.dataclass
class _Slot:
    request_id: Optional[int] = None
    remaining: int = 0
    generated: Optional[List[int]] = None


class ContinuousBatcher:
    """Static-shape continuous batching over a fixed slot count.

    Requests are (prompt, max_new_tokens).  Each step decodes ALL slots in
    one batched decode; finished slots are refilled immediately.  Per-slot
    KV isolation uses one cache per slot (batch=1 caches), which keeps the
    implementation exact for every cache family (ring/SSM/cross) at the cost
    of a python loop over slots for prefill — the decode hot loop is fully
    batched per slot group.
    """

    def __init__(self, model: Model, params, n_slots: int = 4,
                 max_len: int = 512,
                 recipe: Optional[PrecisionRecipe] = None):
        self.model = model
        self.params = params
        self.recipe = recipe or RECIPES["bf16"]
        self.n_slots = n_slots
        self.max_len = max_len
        self.queue: Deque[Tuple[int, np.ndarray, int]] = deque()
        self.slots = [_Slot() for _ in range(n_slots)]
        self.caches: List[Any] = [None] * n_slots
        self.last_tok = [None] * n_slots
        self.finished: Dict[int, List[int]] = {}
        self._next_id = 0

    # -- client API ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append((rid, np.asarray(prompt), max_new_tokens))
        return rid

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drive until queue and slots drain; returns {request_id: tokens}."""
        steps = 0
        while (self.queue or any(s.request_id is not None
                                 for s in self.slots)):
            self._refill()
            self._decode_step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("batcher did not drain")
        return self.finished

    # -- internals ----------------------------------------------------------

    def _refill(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.request_id is not None or not self.queue:
                continue
            rid, prompt, max_new = self.queue.popleft()
            cache = self.model.init_cache(1, self.max_len)
            logits, cache = self.model.prefill(
                self.params, {"tokens": jnp.asarray(prompt[None])}, cache,
                self.recipe)
            tok = int(jnp.argmax(logits[0, -1]))
            self.slots[i] = _Slot(rid, max_new - 1, [tok])
            self.caches[i] = cache
            self.last_tok[i] = tok
            if max_new - 1 <= 0:
                self._finish(i)

    def _decode_step(self) -> None:
        live = [i for i, s in enumerate(self.slots)
                if s.request_id is not None]
        if not live:
            return
        for i in live:  # per-slot decode (exact for heterogeneous caches)
            tok = jnp.asarray([[self.last_tok[i]]], jnp.int32)
            logits, self.caches[i] = self.model.decode_step(
                self.params, tok, self.caches[i], self.recipe)
            nxt = int(jnp.argmax(logits[0, -1]))
            slot = self.slots[i]
            slot.generated.append(nxt)
            slot.remaining -= 1
            self.last_tok[i] = nxt
            if slot.remaining <= 0:
                self._finish(i)

    def _finish(self, i: int) -> None:
        slot = self.slots[i]
        self.finished[slot.request_id] = slot.generated
        self.slots[i] = _Slot()
        self.caches[i] = None
        self.last_tok[i] = None
