"""jit-able train/eval steps: loss, grad-accum, clip, compression, update.

The precision plan is baked into the compiled graph (it changes the math),
so the trainer holds one compiled step per active plan — switching at the
§3.3 schedule boundary or after a controller demotion is a Python-level
swap, not a recompile of anything else.  ``step`` is a traced scalar so the
LR schedule lives inside the graph; ``lr_scale`` is a traced scalar so the
controller's LR backoff does not recompile either.

Mesh-native mode: passing ``rules`` (a ``distributed.sharding.
ShardingRules``) makes the step mesh-first — the model body runs under the
rules' sharding context (every ``shard_hint`` / quantization-scale
placement hint becomes a real ``with_sharding_constraint``), jit gets
``NamedSharding`` in/out specs derived from the rules
(``train_step_shardings``), and with ``grad_compression='fp8'`` on a
multi-shard data axis the gradient reduction runs quantize-before-
communicate: per-data-shard gradients come from a ``vmap`` over batch
slices (the leading replica axis sharded over the data axes) and the fp8
sum over that axis lowers to a real ``float8_e4m3fn``-payload all-reduce
with per-shard error feedback
(``optim.compression.compressed_reduce_dp``).  Model axes keep their
ordinary GSPMD propagation — a shard_map manual over data was rejected
because ``lax.scan`` over model-sharded operands inside a partial-auto
region crashes XLA (jax 0.4.x) and the layer stack scans.  On a 1x1 mesh
every constraint is a no-op and the step is bit-exact with the rules-free
path.
"""
from __future__ import annotations

import contextlib
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import TrainConfig
from repro.core.qlinear import matmul_impl
from repro.core.recipe import as_plan
from repro.models.model import Model
from repro.nn import layers
from repro.optim import (clip_by_global_norm, compressed_reduce_dp,
                         fp8_compress_grads, get_optimizer, warmup_cosine)
from repro.telemetry import collect as telemetry
from repro.telemetry.profiler import graph_span

__all__ = ["make_train_step", "make_eval_step", "make_optimizer",
           "train_step_shardings"]


def make_optimizer(model: Model, tcfg: TrainConfig):
    return get_optimizer(
        model.cfg.optimizer, weight_decay=tcfg.weight_decay,
        beta1=tcfg.beta1, beta2=tcfg.beta2, eps=tcfg.eps)


def _split_microbatches(batch: Dict[str, jnp.ndarray], k: int):
    def sp(x):
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape(k, b // k, *x.shape[1:])
    return jax.tree.map(sp, batch)


# ---------------------------------------------------------------------------
# Mesh-native sharding derivation
# ---------------------------------------------------------------------------

def _uses_axes(sharding: NamedSharding, axes) -> bool:
    flat = []
    for e in sharding.spec:
        if e is None:
            continue
        flat.extend((e,) if isinstance(e, str) else e)
    return any(a in axes for a in flat)


def compression_state_sharding(rules, param_shardings):
    """Shardings for the error-feedback residuals.

    Under the manual-DP compressed path the residual tree carries a
    leading replica axis (``init_compression_state(dp_size=...)``) sharded
    over the data axes — each data shard owns its slice — while the
    per-parameter trailing dims keep the parameter's (model-axis) layout.
    Without a multi-shard data axis residuals mirror the params exactly.
    """
    dp = rules.dp_axes
    if rules.dp_size <= 1:
        return param_shardings

    def shift(sh: NamedSharding) -> NamedSharding:
        if _uses_axes(sh, dp):
            raise ValueError(
                "fp8 grad compression's per-shard residuals need params "
                "replicated over the data axes, but a param shards over "
                f"{sh.spec}.  Build rules with default_rules(..., "
                "fsdp=False) (TrainConfig.fsdp = False).")
        return NamedSharding(rules.mesh, P(dp, *sh.spec))

    return jax.tree.map(shift, param_shardings)


def train_step_shardings(model: Model, tcfg: TrainConfig, rules):
    """(in_shardings, out_shardings) for the 6-arg mesh-native train step
    ``(params, opt_state, comp_state, batch, step, lr_scale)``.

    Params/opt state follow ``rules.param_shardings`` /
    ``opt_state_shardings``; the batch shards its leading dim over the
    data axes (``rules.batch_sharding``, applied as a pytree prefix);
    step/lr_scale/metrics replicate.
    """
    from repro.distributed.sharding import opt_state_shardings

    params_abs = model.abstract_params(jnp.float32)
    p_shard = rules.param_shardings(model.param_specs())
    opt = make_optimizer(model, tcfg)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    o_shard = opt_state_shardings(opt_abs, params_abs, p_shard, rules.mesh)
    if tcfg.grad_compression == "fp8":
        c_shard = compression_state_sharding(rules, p_shard)
    else:
        c_shard = rules.replicated()
    rep = rules.replicated()
    in_shardings = (p_shard, o_shard, c_shard, rules.batch_sharding(2),
                    rep, rep)
    out_shardings = (p_shard, o_shard, c_shard, rep)
    return in_shardings, out_shardings


def make_train_step(model: Model, tcfg: TrainConfig,
                    plan, *,
                    jit: bool = True,
                    donate: bool = True,
                    in_shardings=None, out_shardings=None,
                    rules=None):
    """Returns train_step(params, opt_state, comp_state, batch, step,
    lr_scale=1.0) -> (params, opt_state, comp_state, metrics).

    ``plan`` is a ``PrecisionPlan`` or a ``PrecisionRecipe`` template
    (coerced to the uniform plan).  The model's linear layers run through
    ``cfg.linear_impl`` ('qdq' unfused simulation | 'pallas' fused
    quantize+matmul kernel for fwd/dgrad/wgrad); validated here so a
    typo'd config fails at step-build time, not deep inside a jit trace.
    ``lr_scale`` multiplies the scheduled LR (the controller's rollback
    backoff); callers that never back off can omit it.

    ``rules`` (a ``ShardingRules``) turns on mesh-native mode: the step
    body traces under the rules' sharding context, jit derives
    ``NamedSharding`` in/out specs from them when the caller supplies none
    (callers then pass all six args, ``lr_scale`` included), and fp8
    gradient compression over a multi-shard data axis becomes the real
    quantize-before-communicate reduction (vmap over batch slices + an
    fp8-payload all-reduce).  ``rules=None`` is byte-for-byte the old
    single-device step.
    """
    matmul_impl(model.cfg.linear_impl)
    plan = as_plan(plan, model.cfg.n_layers)
    opt = make_optimizer(model, tcfg)
    lr_fn = warmup_cosine(tcfg.learning_rate, tcfg.total_steps,
                          tcfg.warmup_frac, tcfg.min_lr_frac)
    use_compression = tcfg.grad_compression == "fp8"
    spmd_dp = (rules is not None and use_compression and rules.dp_size > 1)
    if spmd_dp:
        p_shard = rules.param_shardings(model.param_specs())
        bad = [s.spec for s in jax.tree.leaves(p_shard)
               if _uses_axes(s, rules.dp_axes)]
        if bad:
            raise ValueError(
                "fp8 grad compression's manual-DP reduction needs params "
                "replicated over the data axes (each shard applies the "
                f"same compressed update), but these specs use them: "
                f"{bad[:3]}...  Build rules with default_rules(..., "
                "fsdp=False).")
    # Telemetry: when enabled, a trace-time collector is installed around
    # the loss (per-layer forward-side stats ride the loss aux; backward
    # cotangent stats arrive as gradients of zero-valued probes).  When
    # disabled, the code below is exactly the telemetry-free step — no
    # collector, no probes, bit-identical graph.
    collector = telemetry.TelemetryCollector() if tcfg.telemetry else None

    # Phase scopes (telemetry.profiler.graph_span = jax.named_scope) are
    # pure HLO metadata: xprof attributes device time to fwd/bwd/optim/
    # collective by name, and the compiled computation is unchanged.  The
    # forward trace runs under bwd/fwd (value_and_grad traces it there);
    # backward-only ops carry bwd alone.
    def loss_fn(params, batch):
        with graph_span("fwd"):
            return model.loss(params, batch, plan)

    def loss_fn_tel(params, batch, probes):
        with graph_span("fwd"), telemetry.collecting(collector, probes):
            loss, metrics = model.loss(params, batch, plan)
            metrics = dict(metrics)
            metrics.update(collector.drain_root())
        return loss, metrics

    n_layers = model.cfg.n_layers

    def compute_grads(params, batch):
        probes = (telemetry.make_probes(n_layers)
                  if collector is not None else None)
        if collector is not None:
            vg = jax.value_and_grad(loss_fn_tel, argnums=(0, 2),
                                    has_aux=True)
        if tcfg.microbatch and tcfg.microbatch > 1:
            mbs = _split_microbatches(batch, tcfg.microbatch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + loss), metrics

            def acc_tel(carry, mb):
                (g_acc, pg_acc), l_acc = carry
                (loss, metrics), (g, pg) = vg(params, mb, probes)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                # probe stats are sums with a tap-count slot, so plain
                # accumulation keeps them self-normalizing
                pg_acc = jax.tree.map(jnp.add, pg_acc, pg)
                return ((g_acc, pg_acc), l_acc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            if collector is None:
                (g, loss_sum), metrics = jax.lax.scan(
                    acc, (g0, jnp.zeros((), jnp.float32)), mbs)
            else:
                ((g, pg), loss_sum), metrics = jax.lax.scan(
                    acc_tel, ((g0, telemetry.make_probes(n_layers)),
                              jnp.zeros((), jnp.float32)), mbs)
            k = tcfg.microbatch
            grads = jax.tree.map(lambda x: x / k, g)
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
            metrics["loss"] = loss_sum / k
            if collector is not None:
                metrics.update(telemetry.probe_metrics(pg))
            return grads, metrics
        if collector is not None:
            (loss, metrics), (grads, pg) = vg(params, batch, probes)
            metrics.update(telemetry.probe_metrics(pg))
            return grads, metrics
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    # Quantize-before-communicate: per-data-shard gradients via a vmap
    # over batch slices (leading replica axis sharded over the data
    # axes); the fp8 sum over that axis IS the gradient all-reduce, with
    # a real float8_e4m3fn payload and per-shard error feedback.  Model
    # (TP) axes keep their ordinary GSPMD propagation throughout — a
    # shard_map manual over data was rejected because lax.scan over
    # model-sharded operands inside a partial-auto region crashes XLA
    # (jax 0.4.x) and the layer stack scans.
    if spmd_dp:
        dp_axes = rules.dp_axes
        dp = rules.dp_size
        batch_dp_sharding = NamedSharding(rules.mesh, P(dp_axes))
        c_shards = compression_state_sharding(rules, p_shard)

        def _reduce_metric(m):
            m = jnp.asarray(m)
            if jnp.issubdtype(m.dtype, jnp.integer):
                return jnp.sum(m, axis=0)    # counts sum globally
            return jnp.mean(m, axis=0)

        def _split_dp(a):
            if a.shape[0] % dp:
                raise ValueError(
                    f"batch dim {a.shape[0]} not divisible by the "
                    f"data-parallel degree {dp}")
            a = a.reshape((dp, a.shape[0] // dp) + a.shape[1:])
            return jax.lax.with_sharding_constraint(a, batch_dp_sharding)

        # Inside the vmapped body the per-slice batch dim must NOT carry
        # the data axes (dim 0 of the stacked view already does), so the
        # slice traces under rules with the dp axes stripped — model (TP)
        # hints survive, data hints become no-ops.
        inner_rules = rules.manual_over(dp_axes)

        def sharded_grads(params, comp_state, batch):
            batch_dp = jax.tree.map(_split_dp, batch)
            with graph_span("bwd"), layers.sharding_context(inner_rules):
                grads_dp, metrics_dp = jax.vmap(
                    compute_grads, in_axes=(None, 0))(params, batch_dp)
            with graph_span("collective"):
                # pin the replica axis to the data shards so quantization
                # and error feedback stay local (one slice per shard)
                grads_dp = jax.tree.map(jax.lax.with_sharding_constraint,
                                        grads_dp, c_shards)
                grads, comp_state = compressed_reduce_dp(grads_dp,
                                                         comp_state)
            return grads, comp_state, jax.tree.map(_reduce_metric,
                                                   metrics_dp)

    def train_step(params, opt_state, comp_state, batch, step,
                   lr_scale=1.0):
        ctx = (contextlib.nullcontext() if rules is None
               else layers.sharding_context(rules))
        with ctx:
            if spmd_dp:
                # Reduction (fp8, error-fed) happens where the physical
                # all-reduce is — before clipping, as on a real system.
                grads, comp_state, metrics = sharded_grads(
                    params, comp_state, batch)
                if collector is not None:
                    metrics.update(telemetry.grad_norm_metrics(grads))
                grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
            else:
                with graph_span("bwd"):
                    grads, metrics = compute_grads(params, batch)
                if collector is not None:
                    metrics.update(telemetry.grad_norm_metrics(grads))
                grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
                if use_compression:
                    with graph_span("collective"):
                        grads, comp_state = fp8_compress_grads(grads,
                                                               comp_state)
            with graph_span("optim"):
                lr = lr_fn(step) * lr_scale
                params, opt_state = opt.update(grads, opt_state, params, lr)
            metrics = dict(metrics)
            metrics["grad_norm"] = gnorm
            metrics["lr"] = lr
            return params, opt_state, comp_state, metrics

    if not jit:
        return train_step
    if rules is not None and in_shardings is None and out_shardings is None:
        in_shardings, out_shardings = train_step_shardings(model, tcfg,
                                                           rules)
    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    return jax.jit(train_step,
                   donate_argnums=(0, 1, 2) if donate else (), **kw)


def make_eval_step(model: Model, plan, *, jit=True, rules=None):
    plan = as_plan(plan, model.cfg.n_layers)

    def eval_step(params, batch):
        ctx = (contextlib.nullcontext() if rules is None
               else layers.sharding_context(rules))
        with ctx:
            loss, metrics = model.loss(params, batch, plan)
            return metrics
    return jax.jit(eval_step) if jit else eval_step
