"""jit-able train/eval steps: loss, grad-accum, clip, compression, update.

The precision plan is baked into the compiled graph (it changes the math),
so the trainer holds one compiled step per active plan — switching at the
§3.3 schedule boundary or after a controller demotion is a Python-level
swap, not a recompile of anything else.  ``step`` is a traced scalar so the
LR schedule lives inside the graph; ``lr_scale`` is a traced scalar so the
controller's LR backoff does not recompile either.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core.qlinear import matmul_impl
from repro.core.recipe import as_plan
from repro.models.model import Model
from repro.optim import (clip_by_global_norm, fp8_compress_grads,
                         get_optimizer, warmup_cosine)
from repro.telemetry import collect as telemetry

__all__ = ["make_train_step", "make_eval_step", "make_optimizer"]


def make_optimizer(model: Model, tcfg: TrainConfig):
    return get_optimizer(
        model.cfg.optimizer, weight_decay=tcfg.weight_decay,
        beta1=tcfg.beta1, beta2=tcfg.beta2, eps=tcfg.eps)


def _split_microbatches(batch: Dict[str, jnp.ndarray], k: int):
    def sp(x):
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape(k, b // k, *x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(model: Model, tcfg: TrainConfig,
                    plan, *,
                    jit: bool = True,
                    donate: bool = True,
                    in_shardings=None, out_shardings=None):
    """Returns train_step(params, opt_state, comp_state, batch, step,
    lr_scale=1.0) -> (params, opt_state, comp_state, metrics).

    ``plan`` is a ``PrecisionPlan`` or a ``PrecisionRecipe`` template
    (coerced to the uniform plan).  The model's linear layers run through
    ``cfg.linear_impl`` ('qdq' unfused simulation | 'pallas' fused
    quantize+matmul kernel for fwd/dgrad/wgrad); validated here so a
    typo'd config fails at step-build time, not deep inside a jit trace.
    ``lr_scale`` multiplies the scheduled LR (the controller's rollback
    backoff); callers that never back off can omit it.
    """
    matmul_impl(model.cfg.linear_impl)
    plan = as_plan(plan, model.cfg.n_layers)
    opt = make_optimizer(model, tcfg)
    lr_fn = warmup_cosine(tcfg.learning_rate, tcfg.total_steps,
                          tcfg.warmup_frac, tcfg.min_lr_frac)
    use_compression = tcfg.grad_compression == "fp8"
    # Telemetry: when enabled, a trace-time collector is installed around
    # the loss (per-layer forward-side stats ride the loss aux; backward
    # cotangent stats arrive as gradients of zero-valued probes).  When
    # disabled, the code below is exactly the telemetry-free step — no
    # collector, no probes, bit-identical graph.
    collector = telemetry.TelemetryCollector() if tcfg.telemetry else None

    def loss_fn(params, batch):
        return model.loss(params, batch, plan)

    def loss_fn_tel(params, batch, probes):
        with telemetry.collecting(collector, probes):
            loss, metrics = model.loss(params, batch, plan)
            metrics = dict(metrics)
            metrics.update(collector.drain_root())
        return loss, metrics

    n_layers = model.cfg.n_layers

    def compute_grads(params, batch):
        probes = (telemetry.make_probes(n_layers)
                  if collector is not None else None)
        if collector is not None:
            vg = jax.value_and_grad(loss_fn_tel, argnums=(0, 2),
                                    has_aux=True)
        if tcfg.microbatch and tcfg.microbatch > 1:
            mbs = _split_microbatches(batch, tcfg.microbatch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + loss), metrics

            def acc_tel(carry, mb):
                (g_acc, pg_acc), l_acc = carry
                (loss, metrics), (g, pg) = vg(params, mb, probes)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                # probe stats are sums with a tap-count slot, so plain
                # accumulation keeps them self-normalizing
                pg_acc = jax.tree.map(jnp.add, pg_acc, pg)
                return ((g_acc, pg_acc), l_acc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            if collector is None:
                (g, loss_sum), metrics = jax.lax.scan(
                    acc, (g0, jnp.zeros((), jnp.float32)), mbs)
            else:
                ((g, pg), loss_sum), metrics = jax.lax.scan(
                    acc_tel, ((g0, telemetry.make_probes(n_layers)),
                              jnp.zeros((), jnp.float32)), mbs)
            k = tcfg.microbatch
            grads = jax.tree.map(lambda x: x / k, g)
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
            metrics["loss"] = loss_sum / k
            if collector is not None:
                metrics.update(telemetry.probe_metrics(pg))
            return grads, metrics
        if collector is not None:
            (loss, metrics), (grads, pg) = vg(params, batch, probes)
            metrics.update(telemetry.probe_metrics(pg))
            return grads, metrics
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    def train_step(params, opt_state, comp_state, batch, step,
                   lr_scale=1.0):
        grads, metrics = compute_grads(params, batch)
        if collector is not None:
            metrics.update(telemetry.grad_norm_metrics(grads))
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        if use_compression:
            grads, comp_state = fp8_compress_grads(grads, comp_state)
        lr = lr_fn(step) * lr_scale
        params, opt_state = opt.update(grads, opt_state, params, lr)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return params, opt_state, comp_state, metrics

    if not jit:
        return train_step
    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    return jax.jit(train_step,
                   donate_argnums=(0, 1, 2) if donate else (), **kw)


def make_eval_step(model: Model, plan, *, jit=True):
    plan = as_plan(plan, model.cfg.n_layers)

    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch, plan)
        return metrics
    return jax.jit(eval_step) if jit else eval_step
