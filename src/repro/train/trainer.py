"""Fault-tolerant training loop with the paper's two-stage schedule.

Responsibilities:
  * target-precision schedule (§3.3): low-precision step graph for stage 1,
    high-precision graph for the final 5-10% of steps (stage-2 recipe
    configurable via ``TrainConfig.target_recipe``);
  * adaptive precision (``TrainConfig.controller``): the telemetry-driven
    ``PrecisionController`` picks the active recipe per step (dynamic early
    switch, module-class demotion) and can request a loss-spike rollback —
    restore the last checkpoint and replay at the target precision;
  * checkpoint/restart: atomic step-indexed checkpoints of params + optimizer
    + compression residuals + step (+ controller state); index-addressed data
    needs no iterator state — ``resume()`` continues bit-exact (tested,
    including across the precision-switch boundary);
  * straggler monitoring: per-step wall-time EMA outlier detection with a
    pluggable action; flags are folded into the history rows;
  * eval + metrics history; optional JSONL telemetry log
    (``TrainConfig.telemetry_jsonl``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import TrainConfig
from repro.core.recipe import PrecisionRecipe, RECIPES
from repro.core.schedule import TargetPrecisionSchedule
from repro.models.model import Model
from repro.optim import init_compression_state
from repro.telemetry.controller import PrecisionController
from repro.telemetry.writer import JsonlWriter
from repro.train.train_step import make_optimizer, make_train_step

__all__ = ["Trainer", "TrainState", "StepTimeMonitor"]


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    comp_state: Any
    step: int


class StepTimeMonitor:
    """EMA-based straggler detector (distributed-runtime hook)."""

    def __init__(self, factor: float = 2.5, warmup: int = 5,
                 action: Optional[Callable[[int, float, float], None]] = None):
        self.factor = factor
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.n = 0
        self.flagged: List[int] = []
        self.action = action

    def record(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = (self.n > self.warmup
                        and dt > self.factor * self.ema)
        if is_straggler:
            self.flagged.append(step)
            if self.action:
                self.action(step, dt, self.ema)
        # EMA updated with clipped dt so one outlier doesn't poison it.
        self.ema = 0.9 * self.ema + 0.1 * min(dt, 3 * self.ema)
        return is_straggler


class Trainer:
    def __init__(self, model: Model, tcfg: TrainConfig,
                 pipeline, *, jit: bool = True,
                 eval_pipeline=None):
        self.model = model
        self.tcfg = tcfg
        self.pipeline = pipeline
        self.eval_pipeline = eval_pipeline
        self.recipe: PrecisionRecipe = RECIPES[tcfg.recipe]
        self.schedule = TargetPrecisionSchedule(
            self.recipe, tcfg.total_steps,
            target=RECIPES[tcfg.target_recipe])
        self._steps: Dict[tuple, Callable] = {}
        self._jit = jit
        self.monitor = StepTimeMonitor()
        self.history: List[Dict[str, float]] = []
        self.ckpt: Optional[CheckpointManager] = None
        if tcfg.checkpoint_every and tcfg.checkpoint_dir:
            self.ckpt = CheckpointManager(tcfg.checkpoint_dir,
                                          keep=tcfg.keep_checkpoints,
                                          async_save=tcfg.async_checkpoint)
        self.controller: Optional[PrecisionController] = None
        if tcfg.controller is not None:
            self.controller = PrecisionController(self.schedule,
                                                  tcfg.controller)
        self.writer: Optional[JsonlWriter] = None
        if tcfg.telemetry_jsonl:
            self.writer = JsonlWriter(tcfg.telemetry_jsonl)

    # ------------------------------------------------------------------

    def init_state(self, seed: Optional[int] = None) -> TrainState:
        key = jax.random.PRNGKey(self.tcfg.seed if seed is None else seed)
        params = self.model.init(key, jnp.float32)
        opt = make_optimizer(self.model, self.tcfg)
        opt_state = opt.init(params)
        comp_state = (init_compression_state(params)
                      if self.tcfg.grad_compression == "fp8" else
                      jnp.zeros((), jnp.float32))
        return TrainState(params, opt_state, comp_state, 0)

    def _step_fn(self, recipe: PrecisionRecipe,
                 telemetry: Optional[bool] = None) -> Callable:
        tel = self.tcfg.telemetry if telemetry is None else telemetry
        key = (recipe.name, tel)
        if key not in self._steps:
            tcfg = (self.tcfg if tel == self.tcfg.telemetry
                    else dataclasses.replace(self.tcfg, telemetry=tel))
            self._steps[key] = make_train_step(
                self.model, tcfg, recipe, jit=self._jit, donate=False)
        return self._steps[key]

    # ------------------------------------------------------------------

    def resume(self) -> Optional[TrainState]:
        """Restore latest intact checkpoint, or None if there is none.

        The active recipe is *re-derived* from the restored step (schedule
        fraction + persisted controller state), so resuming across the
        precision-switch boundary continues with the correct graph.
        """
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return None
        ref = self.init_state()
        tree = {"params": ref.params, "opt_state": ref.opt_state,
                "comp_state": ref.comp_state}
        restored, extra = self.ckpt.restore(tree)
        if self.controller is not None and "controller" in extra:
            self.controller.load_state(extra["controller"])
        return TrainState(restored["params"], restored["opt_state"],
                          restored["comp_state"], int(extra["step"]))

    def save(self, state: TrainState) -> None:
        if self.ckpt is None:
            return
        tree = {"params": state.params, "opt_state": state.opt_state,
                "comp_state": state.comp_state}
        extra = {"recipe": self.recipe.name}
        if self.controller is not None:
            extra["controller"] = self.controller.state_dict()
        self.ckpt.save(state.step, tree, extra=extra)

    # ------------------------------------------------------------------

    def train(self, state: Optional[TrainState] = None,
              num_steps: Optional[int] = None,
              log: Optional[Callable[[str], None]] = None) -> TrainState:
        state = state or (self.resume() or self.init_state())
        total = self.tcfg.total_steps
        end = min(total, state.step + (num_steps or total))
        log = log or (lambda s: None)
        while state.step < end:
            step = state.step
            recipe = self._active_recipe(step)
            if self.controller is None and self.schedule.is_switch_boundary(
                    step):
                log(f"[schedule] step {step}: switching to target precision "
                    f"({self.schedule.target_recipe.name})")
            # telemetry sampling: every N-th step runs the instrumented
            # graph, the rest run the stat-free one (both static graphs)
            tel_on = self.tcfg.telemetry and (
                self.tcfg.telemetry_every <= 1
                or step % self.tcfg.telemetry_every == 0)
            fn = self._step_fn(recipe, telemetry=tel_on)
            batch = {k: jnp.asarray(v)
                     for k, v in self.pipeline.batch(step).items()}
            t0 = time.time()
            params, opt_state, comp_state, metrics = fn(
                state.params, state.opt_state, state.comp_state, batch,
                jnp.asarray(step, jnp.int32))
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            straggler = self.monitor.record(step, dt)
            if straggler:
                log(f"[straggler] step {step} took {dt:.2f}s "
                    f"(ema {self.monitor.ema:.2f}s)")
            state = TrainState(params, opt_state, comp_state, step + 1)
            row = {k: float(np.asarray(v)) for k, v in metrics.items()}
            row["step"] = step
            row["recipe"] = recipe.name
            row["dt"] = dt
            row["straggler"] = straggler
            self.history.append(row)
            if self.writer is not None:
                self.writer.write(row)
            if self.tcfg.log_every and step % self.tcfg.log_every == 0:
                log(f"step {step:5d} loss {row['loss']:.4f} "
                    f"gnorm {row['grad_norm']:.3f} lr {row['lr']:.2e} "
                    f"[{recipe.name}] {dt*1000:.0f}ms")
            # controller first: a loss-spike rollback must restore a
            # checkpoint from BEFORE the spiked update, so the boundary
            # save below happens only after the row was judged healthy
            # (or after the restore, persisting the armed replay window).
            if self.controller is not None:
                state = self._apply_controller_events(
                    state, self.controller.observe(step, row), log)
            if (self.ckpt is not None and self.tcfg.checkpoint_every
                    and (step + 1) % self.tcfg.checkpoint_every == 0):
                self.save(state)
        if self.ckpt is not None:
            self.ckpt.wait()
        return state

    # ------------------------------------------------------------------

    def _active_recipe(self, step: int) -> PrecisionRecipe:
        if self.controller is not None:
            return self.controller.active_recipe(step)
        return self.schedule.recipe_at(step)

    def _apply_controller_events(self, state: TrainState, events,
                                 log: Callable[[str], None]) -> TrainState:
        """Apply controller decisions.  switch/demote only alter which
        recipe ``_active_recipe`` selects next step; rollback restores the
        last checkpoint and arms the high-precision replay window."""
        ctrl = self.controller
        for ev in events:
            if self.writer is not None:
                self.writer.write(ev)
            if ev["event"] == "switch":
                log(f"[controller] step {ev['step']}: quant-error EMA "
                    f"{ev['error_ema']:.4f} crossed threshold -> early "
                    f"switch to {ev['to']}")
            elif ev["event"] == "demote":
                log(f"[controller] step {ev['step']}: sustained overflow "
                    f"({ev['overflow']:.4f}) -> demoting "
                    f"{ev['module_class']} to FP8")
            elif ev["event"] == "rollback":
                # keep the attempt counter across the checkpointed
                # controller state resume() reloads (guards infinite loops)
                attempts = ctrl.rollbacks
                restored = self.resume()
                if restored is None:
                    log(f"[controller] step {ev['step']}: loss spike "
                        f"({ev['loss']:.3f} vs ema {ev['loss_ema']:.3f}) "
                        "but no checkpoint to roll back to")
                    continue
                ctrl.rollbacks = max(ctrl.rollbacks, attempts)
                ctrl.begin_replay(restored.step)
                log(f"[controller] step {ev['step']}: loss spike "
                    f"({ev['loss']:.3f} vs ema {ev['loss_ema']:.3f}) -> "
                    f"rollback to step {restored.step}, replaying "
                    f"{ctrl.cfg.replay_steps} steps at "
                    f"{self.schedule.target_recipe.name}")
                state = restored
        return state

    # ------------------------------------------------------------------

    def evaluate(self, state: TrainState, n_batches: int = 8,
                 recipe: Optional[PrecisionRecipe] = None) -> Dict[str, float]:
        from repro.train.train_step import make_eval_step
        recipe = recipe or RECIPES["bf16"]
        pipeline = self.eval_pipeline or self.pipeline
        fn = make_eval_step(self.model, recipe, jit=self._jit)
        losses = []
        for i in range(n_batches):
            batch = {k: jnp.asarray(v)
                     for k, v in pipeline.batch(10_000_000 + i).items()}
            m = fn(state.params, batch)
            losses.append(float(np.asarray(m["loss"])))
        val_loss = float(np.mean(losses))
        return {"val_loss": val_loss, "val_ppl": float(np.exp(val_loss))}
