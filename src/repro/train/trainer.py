"""Fault-tolerant training loop with the paper's two-stage schedule.

Responsibilities:
  * precision plans: the trainer resolves ``TrainConfig.recipe`` into a
    layer-resolved ``PrecisionPlan`` (``TrainConfig.plan_preset`` selects a
    depth-graded constructor: uniform | first_last_k | ramp) and holds one
    jitted step graph per active plan;
  * target-precision schedule (§3.3): low-precision step graph for stage 1,
    high-precision graph for the final 5-10% of steps (stage-2 recipe
    configurable via ``TrainConfig.target_recipe``; the switch is a plan
    transform);
  * adaptive precision (``TrainConfig.controller``): the telemetry-driven
    ``PrecisionController`` picks the active plan per step (dynamic early
    switch, per-(layer, class) demotion, LR backoff, and — with
    ``plan_search`` — the greedy cost-vs-quant-error plan searcher, whose
    ``ModelDims`` pricing the trainer derives from the model config) and
    can request a loss-spike rollback — restore the last checkpoint and
    replay at the target precision;
  * checkpoint/restart: atomic step-indexed checkpoints of params + optimizer
    + compression residuals + step (+ controller state + active plan); the
    plan is re-derived from the restored step and controller state, so
    ``resume()`` continues bit-exact across the switch boundary AND across
    a per-layer demotion boundary (both tested);
  * straggler monitoring: per-step wall-time EMA outlier detection with a
    pluggable action; flags are folded into the history rows and flagged
    steps are emitted as ``{"event": "straggler", ...}`` JSONL events;
  * measured-performance observability: every step is timed with device
    sync into a ``telemetry.profiler.StepTimer`` (``step_time_summary()``
    reports p50/p95/p99, tokens/sec, MFU), the loop's data/step/host
    regions carry ``jax.profiler`` phase spans, and the JSONL stream goes
    through the host-offloaded ``AsyncJsonlWriter`` (bounded queue +
    writer thread) so logging never blocks the step;
  * eval + metrics history; optional JSONL telemetry log
    (``TrainConfig.telemetry_jsonl``);
  * mesh-native SPMD: pass ``rules=ShardingRules(...)`` (or set
    ``TrainConfig.mesh_shape`` and the trainer builds the mesh +
    ``default_rules`` itself) and every step graph is jitted with
    ``NamedSharding`` in/out specs; ``init_state`` places params, optimizer
    state and compression residuals on the mesh.  With
    ``grad_compression='fp8'`` and a data axis > 1, the step runs the
    quantize-before-communicate gradient reduction (requires
    ``fsdp=False``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import TrainConfig
from repro.core.cost_model import CostCalibration, ModelDims
from repro.core.recipe import RECIPES, PrecisionPlan
from repro.core.schedule import TargetPrecisionSchedule
from repro.distributed.sharding import ShardingRules, default_rules
from repro.models.model import Model
from repro.optim import init_compression_state
from repro.telemetry.controller import PrecisionController
from repro.telemetry.profiler import StepTimer, phase_span, train_step_flops
from repro.telemetry.writer import AsyncJsonlWriter
from repro.train.train_step import (make_optimizer, make_train_step,
                                    train_step_shardings)

__all__ = ["Trainer", "TrainState", "StepTimeMonitor"]


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    comp_state: Any
    step: int


class StepTimeMonitor:
    """EMA-based straggler detector (distributed-runtime hook)."""

    def __init__(self, factor: float = 2.5, warmup: int = 5,
                 action: Optional[Callable[[int, float, float], None]] = None):
        self.factor = factor
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.n = 0
        self.flagged: List[int] = []
        self.action = action

    def record(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = (self.n > self.warmup
                        and dt > self.factor * self.ema)
        if is_straggler:
            self.flagged.append(step)
            if self.action:
                self.action(step, dt, self.ema)
        # EMA updated with clipped dt so one outlier doesn't poison it.
        self.ema = 0.9 * self.ema + 0.1 * min(dt, 3 * self.ema)
        return is_straggler


class Trainer:
    def __init__(self, model: Model, tcfg: TrainConfig,
                 pipeline, *, jit: bool = True,
                 eval_pipeline=None,
                 rules: Optional[ShardingRules] = None):
        self.model = model
        self.tcfg = tcfg
        self.pipeline = pipeline
        self.eval_pipeline = eval_pipeline
        self.rules = rules if rules is not None else self._build_rules()
        self.recipe = RECIPES[tcfg.recipe]   # class template (for reports)
        n_layers = model.cfg.n_layers
        self.plan: PrecisionPlan = self._build_plan(n_layers)
        self.schedule = TargetPrecisionSchedule(
            self.plan, tcfg.total_steps,
            target=PrecisionPlan.uniform(RECIPES[tcfg.target_recipe],
                                         n_layers))
        self._steps: Dict[tuple, Callable] = {}
        self._jit = jit
        self.monitor = StepTimeMonitor()
        self.history: List[Dict[str, float]] = []
        self.ckpt: Optional[CheckpointManager] = None
        if tcfg.checkpoint_every and tcfg.checkpoint_dir:
            self.ckpt = CheckpointManager(tcfg.checkpoint_dir,
                                          keep=tcfg.keep_checkpoints,
                                          async_save=tcfg.async_checkpoint)
        # layer-resolved flops: plan-searcher cost pricing + MFU accounting
        self.dims = ModelDims.from_config(model.cfg, seq_len=tcfg.seq_len)
        # measured wall-clock speed factors (kernel_bench --measure-speed);
        # None keeps the paper's theoretical factors bit-exact
        self.calibration: Optional[CostCalibration] = None
        if tcfg.cost_calibration:
            self.calibration = CostCalibration.from_json(tcfg.cost_calibration)
        self.controller: Optional[PrecisionController] = None
        if tcfg.controller is not None:
            self.controller = PrecisionController(self.schedule,
                                                  tcfg.controller,
                                                  dims=self.dims,
                                                  calibration=self.calibration)
        # Host-offloaded metrics pipeline: rows/events go through a bounded
        # queue to a writer thread so disk latency never lands in step time.
        self.writer: Optional[AsyncJsonlWriter] = None
        if tcfg.telemetry_jsonl:
            self.writer = AsyncJsonlWriter(tcfg.telemetry_jsonl)
        self.timer = StepTimer(warmup=tcfg.profiler_warmup)

    # ------------------------------------------------------------------

    def _build_rules(self) -> Optional[ShardingRules]:
        """Mesh + default sharding rules from TrainConfig.mesh_shape."""
        shape = self.tcfg.mesh_shape
        if shape is None:
            return None
        from repro.distributed.mesh import make_mesh
        axes = self.tcfg.mesh_axes or ("data", "model")[:len(shape)]
        if len(axes) != len(shape):
            raise ValueError(f"mesh_axes {axes} does not match "
                             f"mesh_shape {shape}")
        mesh = make_mesh(tuple(shape), tuple(axes))
        return default_rules(mesh, self.model.cfg, fsdp=self.tcfg.fsdp)

    def _build_plan(self, n_layers: int) -> PrecisionPlan:
        """Resolve TrainConfig.recipe/plan_preset into a PrecisionPlan."""
        preset = self.tcfg.plan_preset
        if preset == "uniform":
            return PrecisionPlan.uniform(self.recipe, n_layers)
        if preset == "first_last_k":
            return PrecisionPlan.first_last_k(self.recipe, n_layers,
                                              k=self.tcfg.plan_k)
        if preset == "ramp":
            return PrecisionPlan.ramp(self.recipe, n_layers,
                                      frac=self.tcfg.plan_frac)
        raise ValueError(f"unknown plan_preset {preset!r}")

    # ------------------------------------------------------------------

    def init_state(self, seed: Optional[int] = None) -> TrainState:
        key = jax.random.PRNGKey(self.tcfg.seed if seed is None else seed)
        params = self.model.init(key, jnp.float32)
        opt = make_optimizer(self.model, self.tcfg)
        opt_state = opt.init(params)
        use_fp8 = self.tcfg.grad_compression == "fp8"
        dp_size = self.rules.dp_size if self.rules is not None else 1
        comp_state = (init_compression_state(params, dp_size=dp_size)
                      if use_fp8 else jnp.zeros((), jnp.float32))
        if self.rules is not None:
            # Place the state on the mesh up front so the first step does
            # not pay a resharding transfer (and so donation stays legal).
            p_sh, o_sh, c_sh, _, _, _ = train_step_shardings(
                self.model, self.tcfg, self.rules)[0]
            params = jax.device_put(params, p_sh)
            opt_state = jax.device_put(opt_state, o_sh)
            if use_fp8:
                comp_state = jax.device_put(comp_state, c_sh)
        return TrainState(params, opt_state, comp_state, 0)

    def _step_fn(self, plan: PrecisionPlan,
                 telemetry: Optional[bool] = None) -> Callable:
        tel = self.tcfg.telemetry if telemetry is None else telemetry
        key = (plan, tel)   # plans are frozen/hashable; content-addressed
        if key not in self._steps:
            tcfg = (self.tcfg if tel == self.tcfg.telemetry
                    else dataclasses.replace(self.tcfg, telemetry=tel))
            self._steps[key] = make_train_step(
                self.model, tcfg, plan, jit=self._jit, donate=False,
                rules=self.rules)
        return self._steps[key]

    def qlint_report(self, *, compile_hlo: bool = False):
        """Static precision-flow audit (``analysis.qlint``) of the active
        plan's step graph plus a recompile-budget census over every step
        graph this trainer has compiled.  Trace-only — nothing executes.
        """
        from repro.analysis import qlint
        return qlint.audit_trainer(self, compile_hlo=compile_hlo)

    # ------------------------------------------------------------------

    def resume(self) -> Optional[TrainState]:
        """Restore latest intact checkpoint, or None if there is none.

        The active plan is *re-derived* from the restored step (schedule
        fraction + persisted controller state, including per-layer
        demotions), so resuming across the precision-switch boundary or a
        demotion boundary continues with the correct graph.
        """
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return None
        ref = self.init_state()
        tree = {"params": ref.params, "opt_state": ref.opt_state,
                "comp_state": ref.comp_state}
        restored, extra = self.ckpt.restore(tree)
        if self.controller is not None and "controller" in extra:
            self.controller.load_state(extra["controller"])
        return TrainState(restored["params"], restored["opt_state"],
                          restored["comp_state"], int(extra["step"]))

    def save(self, state: TrainState) -> None:
        if self.ckpt is None:
            return
        tree = {"params": state.params, "opt_state": state.opt_state,
                "comp_state": state.comp_state}
        # The active plan's full table is persisted for forensics /
        # external tooling; resume() re-derives it from step + controller
        # state (the authoritative source), so the two can never diverge.
        extra = {"recipe": self.recipe.name,
                 "plan": self._active_plan(state.step).to_dict()}
        if self.controller is not None:
            extra["controller"] = self.controller.state_dict()
        self.ckpt.save(state.step, tree, extra=extra)

    # ------------------------------------------------------------------

    def train(self, state: Optional[TrainState] = None,
              num_steps: Optional[int] = None,
              log: Optional[Callable[[str], None]] = None) -> TrainState:
        state = state or (self.resume() or self.init_state())
        total = self.tcfg.total_steps
        end = min(total, state.step + (num_steps or total))
        log = log or (lambda s: None)
        while state.step < end:
            step = state.step
            plan = self._active_plan(step)
            if self.controller is None and self.schedule.is_switch_boundary(
                    step):
                log(f"[schedule] step {step}: switching to target precision "
                    f"({self.schedule.target_plan.name})")
            # telemetry sampling: every N-th step runs the instrumented
            # graph, the rest run the stat-free one (both static graphs)
            tel_on = self.tcfg.telemetry and (
                self.tcfg.telemetry_every <= 1
                or step % self.tcfg.telemetry_every == 0)
            fn = self._step_fn(plan, telemetry=tel_on)
            with phase_span("data"):
                batch = {k: jnp.asarray(v)
                         for k, v in self.pipeline.batch(step).items()}
            lr_scale = (self.controller.lr_scale
                        if self.controller is not None else 1.0)
            # dispatch + device sync is the measured step: block on an
            # output before reading the clock so dt is the device step
            # time, not just the host dispatch.
            with phase_span("step"):
                t0 = time.perf_counter()
                params, opt_state, comp_state, metrics = fn(
                    state.params, state.opt_state, state.comp_state, batch,
                    jnp.asarray(step, jnp.int32),
                    jnp.asarray(lr_scale, jnp.float32))
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
            self.timer.record(dt)
            straggler = self.monitor.record(step, dt)
            state = TrainState(params, opt_state, comp_state, step + 1)
            # everything below is host-side bookkeeping, off the device
            # critical path (the async writer never blocks here)
            with phase_span("host"):
                if straggler:
                    log(f"[straggler] step {step} took {dt:.2f}s "
                        f"(ema {self.monitor.ema:.2f}s)")
                    if self.writer is not None:
                        self.writer.write({"event": "straggler",
                                           "step": step, "dt": dt,
                                           "ema": self.monitor.ema,
                                           "factor": self.monitor.factor})
                row = {k: float(np.asarray(v)) for k, v in metrics.items()}
                row["step"] = step
                row["recipe"] = plan.name
                row["dt"] = dt
                row["straggler"] = straggler
                self.history.append(row)
                if self.writer is not None:
                    self.writer.write(row)
                if self.tcfg.log_every and step % self.tcfg.log_every == 0:
                    log(f"step {step:5d} loss {row['loss']:.4f} "
                        f"gnorm {row['grad_norm']:.3f} lr {row['lr']:.2e} "
                        f"[{plan.name}] {dt*1000:.0f}ms")
                # controller first: a loss-spike rollback must restore a
                # checkpoint from BEFORE the spiked update, so the boundary
                # save below happens only after the row was judged healthy
                # (or after the restore, persisting the armed replay window).
                if self.controller is not None:
                    state = self._apply_controller_events(
                        state, self.controller.observe(step, row), log)
                if (self.ckpt is not None and self.tcfg.checkpoint_every
                        and (step + 1) % self.tcfg.checkpoint_every == 0):
                    self.save(state)
        if self.ckpt is not None:
            self.ckpt.wait()
        if self.writer is not None:
            self.writer.flush()   # log is complete once train() returns
        return state

    def step_time_summary(self) -> Dict[str, float]:
        """Measured step-time statistics for this trainer's run so far:
        p50/p95/p99/mean (ms), tokens/sec at the median step, and MFU from
        the model's ``ModelDims`` flops (``telemetry.profiler`` summary)."""
        tokens = self.tcfg.global_batch * self.tcfg.seq_len
        return self.timer.summary(
            tokens_per_step=tokens,
            flops_per_step=train_step_flops(self.dims, tokens))

    # ------------------------------------------------------------------

    def _active_plan(self, step: int) -> PrecisionPlan:
        if self.controller is not None:
            return self.controller.active_plan(step)
        return self.schedule.plan_at(step)

    def _apply_controller_events(self, state: TrainState, events,
                                 log: Callable[[str], None]) -> TrainState:
        """Apply controller decisions.  switch/demote only alter which
        plan ``_active_plan`` selects next step; rollback restores the
        last checkpoint and arms the high-precision replay window (plus
        the LR backoff, which the controller already folded into its
        ``lr_scale``)."""
        ctrl = self.controller
        for ev in events:
            if self.writer is not None:
                self.writer.write(ev)
            if ev["event"] == "switch":
                log(f"[controller] step {ev['step']}: quant-error EMA "
                    f"{ev['error_ema']:.4f} crossed threshold -> early "
                    f"switch to {ev['to']}")
            elif ev["event"] == "demote":
                log(f"[controller] step {ev['step']}: sustained overflow "
                    f"({ev['overflow']:.4f}) -> demoting "
                    f"{ev['cell']} to FP8")
            elif ev["event"] == "frontier_point":
                log(f"[controller] step {ev['step']}: frontier point "
                    f"cost {ev['cost']:.3f} / quant-err {ev['error']:.4f} "
                    f"({ev['plan']})")
            elif ev["event"] == "plan_search":
                log(f"[controller] step {ev['step']}: plan search "
                    f"{ev['op']} {ev['cell']} -> cost {ev['cost']:.3f}")
            elif ev["event"] == "plan_search_done":
                log(f"[controller] step {ev['step']}: plan search done "
                    f"({ev['edits']} edits, "
                    f"{ev['frontier_size']}-point frontier)")
            elif ev["event"] == "rollback":
                # keep the attempt counter (guards infinite loops) and the
                # just-applied LR backoff across the checkpointed
                # controller state resume() reloads
                attempts = ctrl.rollbacks
                backed_off = ctrl.lr_scale
                restored = self.resume()
                if restored is None:
                    log(f"[controller] step {ev['step']}: loss spike "
                        f"({ev['loss']:.3f} vs ema {ev['loss_ema']:.3f}) "
                        "but no checkpoint to roll back to")
                    continue
                ctrl.rollbacks = max(ctrl.rollbacks, attempts)
                ctrl.lr_scale = min(ctrl.lr_scale, backed_off)
                ctrl.begin_replay(restored.step)
                log(f"[controller] step {ev['step']}: loss spike "
                    f"({ev['loss']:.3f} vs ema {ev['loss_ema']:.3f}) -> "
                    f"rollback to step {restored.step}, replaying "
                    f"{ctrl.cfg.replay_steps} steps at "
                    f"{self.schedule.target_plan.name}"
                    + (f", lr_scale {ctrl.lr_scale:.3f}"
                       if ctrl.cfg.lr_backoff > 0 else ""))
                state = restored
        return state

    # ------------------------------------------------------------------

    def evaluate(self, state: TrainState, n_batches: int = 8,
                 recipe=None) -> Dict[str, float]:
        """``recipe`` may be a PrecisionRecipe template or a PrecisionPlan
        (default: the BF16 baseline)."""
        from repro.train.train_step import make_eval_step
        recipe = recipe or RECIPES["bf16"]
        pipeline = self.eval_pipeline or self.pipeline
        fn = make_eval_step(self.model, recipe, jit=self._jit,
                            rules=self.rules)
        losses = []
        for i in range(n_batches):
            batch = {k: jnp.asarray(v)
                     for k, v in pipeline.batch(10_000_000 + i).items()}
            m = fn(state.params, batch)
            losses.append(float(np.asarray(m["loss"])))
        val_loss = float(np.mean(losses))
        return {"val_loss": val_loss, "val_ppl": float(np.exp(val_loss))}
