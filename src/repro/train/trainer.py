"""Fault-tolerant training loop with the paper's two-stage schedule.

Responsibilities:
  * target-precision schedule (§3.3): low-precision step graph for stage 1,
    high-precision graph for the final 5-10% of steps;
  * checkpoint/restart: atomic step-indexed checkpoints of params + optimizer
    + compression residuals + step; index-addressed data needs no iterator
    state — ``resume()`` continues bit-exact (tested);
  * straggler monitoring: per-step wall-time EMA outlier detection with a
    pluggable action (on a real cluster: trigger hot-spare swap / skip-host);
  * eval + metrics history.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import TrainConfig
from repro.core.recipe import PrecisionRecipe, RECIPES
from repro.core.schedule import TargetPrecisionSchedule
from repro.models.model import Model
from repro.optim import init_compression_state
from repro.train.train_step import make_optimizer, make_train_step

__all__ = ["Trainer", "TrainState", "StepTimeMonitor"]


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    comp_state: Any
    step: int


class StepTimeMonitor:
    """EMA-based straggler detector (distributed-runtime hook)."""

    def __init__(self, factor: float = 2.5, warmup: int = 5,
                 action: Optional[Callable[[int, float, float], None]] = None):
        self.factor = factor
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.n = 0
        self.flagged: List[int] = []
        self.action = action

    def record(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = (self.n > self.warmup
                        and dt > self.factor * self.ema)
        if is_straggler:
            self.flagged.append(step)
            if self.action:
                self.action(step, dt, self.ema)
        # EMA updated with clipped dt so one outlier doesn't poison it.
        self.ema = 0.9 * self.ema + 0.1 * min(dt, 3 * self.ema)
        return is_straggler


class Trainer:
    def __init__(self, model: Model, tcfg: TrainConfig,
                 pipeline, *, jit: bool = True,
                 eval_pipeline=None):
        self.model = model
        self.tcfg = tcfg
        self.pipeline = pipeline
        self.eval_pipeline = eval_pipeline
        self.recipe: PrecisionRecipe = RECIPES[tcfg.recipe]
        self.schedule = TargetPrecisionSchedule(self.recipe,
                                                tcfg.total_steps)
        self._steps: Dict[str, Callable] = {}
        self._jit = jit
        self.monitor = StepTimeMonitor()
        self.history: List[Dict[str, float]] = []
        self.ckpt: Optional[CheckpointManager] = None
        if tcfg.checkpoint_every and tcfg.checkpoint_dir:
            self.ckpt = CheckpointManager(tcfg.checkpoint_dir,
                                          keep=tcfg.keep_checkpoints,
                                          async_save=tcfg.async_checkpoint)

    # ------------------------------------------------------------------

    def init_state(self, seed: Optional[int] = None) -> TrainState:
        key = jax.random.PRNGKey(self.tcfg.seed if seed is None else seed)
        params = self.model.init(key, jnp.float32)
        opt = make_optimizer(self.model, self.tcfg)
        opt_state = opt.init(params)
        comp_state = (init_compression_state(params)
                      if self.tcfg.grad_compression == "fp8" else
                      jnp.zeros((), jnp.float32))
        return TrainState(params, opt_state, comp_state, 0)

    def _step_fn(self, recipe: PrecisionRecipe) -> Callable:
        if recipe.name not in self._steps:
            self._steps[recipe.name] = make_train_step(
                self.model, self.tcfg, recipe, jit=self._jit, donate=False)
        return self._steps[recipe.name]

    # ------------------------------------------------------------------

    def resume(self) -> Optional[TrainState]:
        """Restore latest intact checkpoint, or None if there is none."""
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return None
        ref = self.init_state()
        tree = {"params": ref.params, "opt_state": ref.opt_state,
                "comp_state": ref.comp_state}
        restored, extra = self.ckpt.restore(tree)
        return TrainState(restored["params"], restored["opt_state"],
                          restored["comp_state"], int(extra["step"]))

    def save(self, state: TrainState) -> None:
        if self.ckpt is None:
            return
        tree = {"params": state.params, "opt_state": state.opt_state,
                "comp_state": state.comp_state}
        self.ckpt.save(state.step, tree,
                       extra={"recipe": self.recipe.name})

    # ------------------------------------------------------------------

    def train(self, state: Optional[TrainState] = None,
              num_steps: Optional[int] = None,
              log: Optional[Callable[[str], None]] = None) -> TrainState:
        state = state or (self.resume() or self.init_state())
        total = self.tcfg.total_steps
        end = min(total, state.step + (num_steps or total))
        log = log or (lambda s: None)
        while state.step < end:
            step = state.step
            recipe = self.schedule.recipe_at(step)
            if self.schedule.is_switch_boundary(step):
                log(f"[schedule] step {step}: switching to target precision "
                    f"({self.schedule.target_recipe.name})")
            fn = self._step_fn(recipe)
            batch = {k: jnp.asarray(v)
                     for k, v in self.pipeline.batch(step).items()}
            t0 = time.time()
            params, opt_state, comp_state, metrics = fn(
                state.params, state.opt_state, state.comp_state, batch,
                jnp.asarray(step, jnp.int32))
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            if self.monitor.record(step, dt):
                log(f"[straggler] step {step} took {dt:.2f}s "
                    f"(ema {self.monitor.ema:.2f}s)")
            state = TrainState(params, opt_state, comp_state, step + 1)
            row = {k: float(np.asarray(v)) for k, v in metrics.items()}
            row["step"] = step
            row["recipe"] = recipe.name
            row["dt"] = dt
            self.history.append(row)
            if self.tcfg.log_every and step % self.tcfg.log_every == 0:
                log(f"step {step:5d} loss {row['loss']:.4f} "
                    f"gnorm {row['grad_norm']:.3f} lr {row['lr']:.2e} "
                    f"[{recipe.name}] {dt*1000:.0f}ms")
            if (self.ckpt is not None and self.tcfg.checkpoint_every
                    and (step + 1) % self.tcfg.checkpoint_every == 0):
                self.save(state)
        if self.ckpt is not None:
            self.ckpt.wait()
        return state

    # ------------------------------------------------------------------

    def evaluate(self, state: TrainState, n_batches: int = 8,
                 recipe: Optional[PrecisionRecipe] = None) -> Dict[str, float]:
        from repro.train.train_step import make_eval_step
        recipe = recipe or RECIPES["bf16"]
        pipeline = self.eval_pipeline or self.pipeline
        fn = make_eval_step(self.model, recipe, jit=self._jit)
        losses = []
        for i in range(n_batches):
            batch = {k: jnp.asarray(v)
                     for k, v in pipeline.batch(10_000_000 + i).items()}
            m = fn(state.params, batch)
            losses.append(float(np.asarray(m["loss"])))
        val_loss = float(np.mean(losses))
        return {"val_loss": val_loss, "val_ppl": float(np.exp(val_loss))}
