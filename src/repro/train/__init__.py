"""Training loop, train/serve steps, fault-tolerant trainer."""
from repro.train.train_step import make_train_step, make_eval_step
from repro.train.trainer import Trainer, TrainState
from repro.train.serve import generate, make_decode_fn, make_prefill_fn

__all__ = ["make_train_step", "make_eval_step", "Trainer", "TrainState",
           "generate", "make_decode_fn", "make_prefill_fn"]
