"""Batched serving: prefill + greedy/temperature decode loops."""
from __future__ import annotations

import weakref
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.recipe import PrecisionRecipe, RECIPES
from repro.models.model import Model

__all__ = ["make_prefill_fn", "make_decode_fn", "generate"]

# Compiled serving fns, keyed per model instance (weak — dropping the model
# drops its cache) by (kind, recipe, jit).  Recipes are frozen dataclasses,
# so they hash; repeated `generate` calls reuse the jitted fn instead of
# rebuilding a fresh jax.jit wrapper (and its compile cache) every call.
_FN_CACHE: "weakref.WeakKeyDictionary[Model, Dict[Any, Any]]" = \
    weakref.WeakKeyDictionary()


def _cached(model: Model, key, build):
    try:
        hash(key)
    except TypeError:
        return build()
    per_model = _FN_CACHE.setdefault(model, {})
    if key not in per_model:
        per_model[key] = build()
    return per_model[key]


def make_prefill_fn(model: Model, recipe: PrecisionRecipe, *, jit=True):
    def build():
        def prefill(params, batch, cache):
            return model.prefill(params, batch, cache, recipe)
        return jax.jit(prefill) if jit else prefill
    return _cached(model, ("prefill", recipe, jit), build)


def make_decode_fn(model: Model, recipe: PrecisionRecipe, *, jit=True):
    def build():
        def decode(params, token, cache):
            return model.decode_step(params, token, cache, recipe)
        return jax.jit(decode, donate_argnums=(2,)) if jit else decode
    return _cached(model, ("decode", recipe, jit), build)


def generate(model: Model, params, prompts: jnp.ndarray, *,
             max_new_tokens: int = 32,
             recipe: Optional[PrecisionRecipe] = None,
             extras: Optional[Dict[str, jnp.ndarray]] = None,
             temperature: float = 0.0,
             key: Optional[jax.Array] = None,
             jit: bool = True) -> jnp.ndarray:
    """Greedy (or sampled) generation.  prompts: (B, S) int32 -> (B, S+N)."""
    recipe = recipe or RECIPES["bf16"]
    b, s = prompts.shape
    cache = model.init_cache(b, s + max_new_tokens)
    batch = dict(extras or {}, tokens=prompts)
    prefill = make_prefill_fn(model, recipe, jit=jit)
    decode = make_decode_fn(model, recipe, jit=jit)
    logits, cache = prefill(params, batch, cache)

    toks = [prompts]
    cur = None
    for i in range(max_new_tokens):
        lg = logits[:, -1].astype(jnp.float32)
        if temperature > 0:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(sub, lg / temperature)[:, None]
        else:
            cur = jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
        toks.append(cur)
        if i < max_new_tokens - 1:
            logits, cache = decode(params, cur, cache)
    return jnp.concatenate(toks, axis=1)
