"""Data pipeline: deterministic, stateless (index-addressed), shardable."""
from repro.data.pipeline import SyntheticLM, ByteCorpus, make_pipeline

__all__ = ["SyntheticLM", "ByteCorpus", "make_pipeline"]
