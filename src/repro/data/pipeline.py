"""Deterministic LM data pipelines.

Design for fault tolerance and scale: batches are a *pure function of the
step index* (stateless / index-addressed).  Resume-after-failure therefore
needs only the step counter from the checkpoint; any host can compute its own
shard ``batch(step)[host_lo:host_hi]`` without coordination — the standard
trick for elastic data loading on 1000+ nodes.

Two sources:
  * SyntheticLM — periodic-pattern sequences with noise: genuinely learnable
    next-token structure (loss drops fast), no external data needed.
  * ByteCorpus — byte-level tokenization of any text blob (a built-in
    sample is included); windows are drawn deterministically per step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

__all__ = ["SyntheticLM", "ByteCorpus", "make_pipeline"]


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Periodic-pattern language: each sequence repeats a pattern drawn from
    a fixed bank, with occasional noise tokens."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_patterns: int = 512
    noise: float = 0.02

    def _bank(self) -> np.ndarray:
        rng = np.random.Generator(np.random.Philox(self.seed))
        maxp = 8
        bank = rng.integers(2, self.vocab_size,
                            size=(self.n_patterns, maxp), dtype=np.int64)
        return bank

    def batch(self, step: int, host_id: int = 0,
              num_hosts: int = 1) -> Dict[str, np.ndarray]:
        assert self.global_batch % num_hosts == 0
        per_host = self.global_batch // num_hosts
        rng = np.random.Generator(
            np.random.Philox(key=[self.seed * 2654435761 + step,
                                  host_id + 1]))
        bank = self._bank()
        maxp = bank.shape[1]
        n = per_host
        pat_idx = rng.integers(0, self.n_patterns, size=n)
        periods = 3 + (pat_idx % (maxp - 3))
        offs = rng.integers(0, maxp, size=n)
        pos = np.arange(self.seq_len + 1)[None, :]
        idx = (pos + offs[:, None]) % periods[:, None]
        toks = bank[pat_idx[:, None], idx]
        if self.noise > 0:
            mask = rng.random(toks.shape) < self.noise
            toks = np.where(mask, rng.integers(2, self.vocab_size,
                                               size=toks.shape), toks)
        tokens = toks[:, :-1].astype(np.int32)
        targets = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "targets": targets}


_SAMPLE_TEXT = (
    "The burgeoning computational demands for training large language "
    "models necessitate efficient methods, including quantized training, "
    "which leverages low-bit arithmetic operations to reduce costs. "
    "While FP8 precision has shown potential, leveraging FP4 remains "
    "challenging due to inherent quantization errors and limited "
    "representation capability. Mixed-precision quantization strategies "
    "tailored for different modules and training stages allow the "
    "precision level suitable to distinct components within the model. "
) * 64


@dataclasses.dataclass(frozen=True)
class ByteCorpus:
    """Byte-level LM over a text blob; windows sampled per (seed, step)."""

    seq_len: int
    global_batch: int
    seed: int = 0
    text: Optional[str] = None
    vocab_size: int = 256

    def _data(self) -> np.ndarray:
        return np.frombuffer((self.text or _SAMPLE_TEXT).encode("utf-8"),
                             dtype=np.uint8)

    def batch(self, step: int, host_id: int = 0,
              num_hosts: int = 1) -> Dict[str, np.ndarray]:
        assert self.global_batch % num_hosts == 0
        per_host = self.global_batch // num_hosts
        data = self._data()
        rng = np.random.Generator(
            np.random.Philox(key=[self.seed * 2654435761 + step,
                                  host_id + 1]))
        starts = rng.integers(0, len(data) - self.seq_len - 1, size=per_host)
        win = np.stack([data[s:s + self.seq_len + 1] for s in starts])
        return {"tokens": win[:, :-1].astype(np.int32),
                "targets": win[:, 1:].astype(np.int32)}


def make_pipeline(kind: str, vocab_size: int, seq_len: int,
                  global_batch: int, seed: int = 0):
    if kind == "synthetic":
        return SyntheticLM(vocab_size, seq_len, global_batch, seed)
    if kind == "bytes":
        return ByteCorpus(seq_len, global_batch, seed)
    raise ValueError(f"unknown pipeline {kind!r}")
