"""Optimizers (FP32 master weights), LR schedules, clipping, compression."""
from repro.optim.adamw import adamw
from repro.optim.adafactor import adafactor
from repro.optim.schedule import warmup_cosine
from repro.optim.clip import clip_by_global_norm, global_norm
from repro.optim.compression import (compressed_psum, compressed_psum_grads,
                                     compressed_reduce_dp,
                                     fp8_compress_grads,
                                     init_compression_state)

__all__ = ["adamw", "adafactor", "warmup_cosine", "clip_by_global_norm",
           "global_norm", "fp8_compress_grads", "init_compression_state",
           "compressed_psum", "compressed_psum_grads",
           "compressed_reduce_dp", "get_optimizer"]


def get_optimizer(name: str, **kw):
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        kw.pop("beta1", None)
        kw.pop("beta2", None)
        kw.pop("eps", None)
        return adafactor(**kw)
    raise ValueError(f"unknown optimizer {name!r}")
