"""Global-norm gradient clipping."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["global_norm", "clip_by_global_norm"]


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm
