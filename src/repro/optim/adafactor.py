"""Adafactor (Shazeer & Stern 2018): factored second moments, no momentum.

Memory per parameter is ~O(rows+cols) instead of AdamW's 2x full-size FP32 —
this is what lets the 90B/140B/398B assigned configs train on 16 GB/chip at
256 chips (see DESIGN.md §5).  Factored over the last two dims for >=2-D
params; full second moment for vectors.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer

__all__ = ["adafactor"]


class AdafactorState(NamedTuple):
    count: jnp.ndarray
    vr: Any     # row factors (or full v for 1-D params)
    vc: Any     # col factors (zeros-dim placeholder for 1-D params)


def adafactor(decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        def vr_of(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc_of(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((0,), jnp.float32)

        return AdafactorState(count=jnp.zeros((), jnp.int32),
                              vr=jax.tree.map(vr_of, params),
                              vc=jax.tree.map(vc_of, params))

    def update(grads, state, params, lr):
        count = state.count + 1
        # beta2 ramps toward 1 (Shazeer & Stern eq. 7)
        beta2 = 1.0 - count.astype(jnp.float32) ** (-decay)

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta2 * vr + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * vc + (1 - beta2) * g2.mean(axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps))
                cfac = jax.lax.rsqrt(vc)
                u = g * rfac[..., None] * cfac[..., None, :]
            else:
                vr = beta2 * vr + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(vr)
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            pf = p.astype(jnp.float32)
            if weight_decay and p.ndim >= 2:
                u = u + weight_decay * pf
            return (pf - lr * u).astype(p.dtype), vr, vc

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        is_t = lambda x: isinstance(x, tuple)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
        vr = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
        vc = jax.tree.map(lambda o: o[2], out, is_leaf=is_t)
        return new_params, AdafactorState(count, vr, vc)

    return Optimizer(init=init, update=update, name="adafactor")
