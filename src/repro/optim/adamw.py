"""AdamW with FP32 master weights (paper App. B: 'master copy ... in FP32').

Minimal optax-style interface: ``opt.init(params) -> state``;
``opt.update(grads, state, params, lr) -> (new_params, new_state)``.
Weight decay is decoupled and skipped for 1-D params (norms, biases).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["adamw", "Optimizer"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]
    name: str = "opt"


class AdamWState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


def adamw(beta1: float = 0.9, beta2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(count=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(f32, params),
                          nu=jax.tree.map(f32, params))

    def update(grads, state, params, lr):
        count = state.count + 1
        b1c = 1.0 - beta1 ** count.astype(jnp.float32)
        b2c = 1.0 - beta2 ** count.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = beta1 * m + (1 - beta1) * g
            v = beta2 * v + (1 - beta2) * g * g
            mhat = m / b1c
            vhat = v / b2c
            step = mhat / (jnp.sqrt(vhat) + eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                step = step + weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * step
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(count, mu, nu)

    return Optimizer(init=init, update=update, name="adamw")
