"""FP8 error-feedback gradient compression (beyond-paper distributed trick).

Large-scale data-parallel training is often ICI/DCN-bound on the gradient
all-reduce.  Reusing the paper's quantization core, gradients are compressed
to FP8-E4M3 (per-tensor scale) before the cross-replica reduction, with the
quantization error fed back into the next step (error feedback keeps the
scheme unbiased in the long run; Seide et al. 2014, Karimireddy et al. 2019).

Two entry points:
  * ``fp8_compress_grads`` — numerics-level hook used inside train_step
    (models the compressed all-reduce; works under GSPMD where the reduction
    itself is implicit in backward).
  * ``compressed_psum`` — explicit shard_map collective for the manual-DP
    path: quantize -> psum over the data axes -> dequantize.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantize import QuantSpec, qdq

__all__ = ["init_compression_state", "fp8_compress_grads", "compressed_psum"]

_SPEC = QuantSpec("fp8_e4m3", "tensor")


def init_compression_state(grads_like) -> Any:
    """Error-feedback residual, same pytree/f32 as the gradients."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def _compress_one(g: jnp.ndarray, r: jnp.ndarray):
    gf = g.astype(jnp.float32) + r
    g2d = gf.reshape(-1, gf.shape[-1]) if gf.ndim > 1 else gf.reshape(1, -1)
    q = qdq(g2d, _SPEC, reduction_axis=1).reshape(gf.shape)
    return q.astype(g.dtype), gf - q


def fp8_compress_grads(grads, residuals) -> Tuple[Any, Any]:
    """Returns (compressed grads, new residuals)."""
    out = jax.tree.map(_compress_one, grads, residuals)
    is_t = lambda x: isinstance(x, tuple)
    comp = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
    res = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
    return comp, res


def compressed_psum(x: jnp.ndarray, axis_name) -> jnp.ndarray:
    """FP8-quantize then psum (for shard_map manual-DP reductions)."""
    x2d = x.reshape(-1, x.shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
    q = qdq(x2d, _SPEC, reduction_axis=1).reshape(x.shape)
    return jax.lax.psum(q, axis_name)
