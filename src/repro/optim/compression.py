"""FP8 error-feedback gradient compression (beyond-paper distributed trick).

Large-scale data-parallel training is often ICI/DCN-bound on the gradient
all-reduce.  Reusing the paper's quantization core, gradients are compressed
to FP8-E4M3 (per-tensor scale) before the cross-replica reduction, with the
quantization error fed back into the next step (error feedback keeps the
scheme unbiased in the long run; Seide et al. 2014, Karimireddy et al. 2019).

Three entry points:
  * ``fp8_compress_grads`` — numerics-level hook used inside train_step
    (models the compressed all-reduce; works under GSPMD where the reduction
    itself is implicit in backward).
  * ``compressed_psum`` — explicit shard_map collective for manual-DP
    regions: the all-reduce payload is REAL ``float8_e4m3fn`` on the wire.
    Scales are shared across the replica group (a scalar pmax) so the sum
    of codes is well-defined, with an N-device headroom factor so the ring
    accumulation cannot overflow the format; each shard keeps a local
    error-feedback residual exactly like ``fp8_compress_grads``.
  * ``compressed_reduce_dp`` — the same scheme expressed in plain GSPMD
    for the mesh-native train step: gradients arrive with a leading
    replica axis sharded over the data axes (one slice per data shard,
    via ``vmap`` over batch slices) and the fp8 sum over that axis lowers
    to an fp8 all-reduce.  Used instead of ``compressed_psum`` because
    ``lax.scan`` over model-sharded operands inside a partial-auto
    shard_map crashes XLA (jax 0.4.x), and the layer stack scans.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.core.quantize import QuantSpec, qdq

__all__ = ["init_compression_state", "fp8_compress_grads",
           "compressed_psum", "compressed_psum_grads",
           "compressed_reduce_dp"]

_SPEC = QuantSpec("fp8_e4m3", "tensor")
_EPS = 1e-12


def init_compression_state(grads_like, *, dp_size: int = 1) -> Any:
    """Error-feedback residual, same pytree/f32 as the gradients.

    ``dp_size > 1`` prepends a leading replica axis: under the manual-DP
    sharded step each data shard keeps its OWN residual, so the state is
    ``(dp, *shape)`` sharded over the data axes (shard i holds slice i).
    """
    lead = () if dp_size <= 1 else (dp_size,)
    return jax.tree.map(
        lambda g: jnp.zeros(lead + tuple(g.shape), jnp.float32), grads_like)


def _compress_one(g: jnp.ndarray, r: jnp.ndarray):
    gf = g.astype(jnp.float32) + r
    g2d = gf.reshape(-1, gf.shape[-1]) if gf.ndim > 1 else gf.reshape(1, -1)
    q = qdq(g2d, _SPEC, reduction_axis=1).reshape(gf.shape)
    return q.astype(g.dtype), gf - q


def fp8_compress_grads(grads, residuals) -> Tuple[Any, Any]:
    """Returns (compressed grads, new residuals)."""
    out = jax.tree.map(_compress_one, grads, residuals)
    is_t = lambda x: isinstance(x, tuple)
    comp = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
    res = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
    return comp, res


def compressed_psum(x: jnp.ndarray, residual: jnp.ndarray, axis_name,
                    *, mean: bool = True
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """FP8 all-reduce with error feedback (shard_map manual-DP reductions).

    Per replica group (``axis_name``, a name or tuple of names):
      1. fold the residual in:          gf = x + r
      2. shared scale (scalar pmax):    s  = pmax(amax(gf)) * N / fp8_max
         — the N-headroom guarantees |sum of codes| <= fp8_max, so the
         ring accumulation cannot overflow the format;
      3. quantize and psum IN FP8:      tot = psum(f8(gf / s)) * s
      4. local error feedback:          r' = gf - dequant(f8(gf / s))

    Returns ``(reduced, new_residual)`` with ``reduced`` the group mean
    (``mean=False`` for sum semantics).  The residual captures each
    shard's own quantization error (not the group's summation error), the
    same contract as ``fp8_compress_grads`` — over steps the time-average
    of the applied reduction converges to the true mean.
    """
    fp8_max = jnp.float32(F.FORMATS["fp8_e4m3"].max_value)
    gf = x.astype(jnp.float32) + residual
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
    s = jnp.maximum(amax, _EPS) * n / fp8_max
    q = (gf / s).astype(jnp.float8_e4m3fn)
    deq = q.astype(jnp.float32) * s
    tot = jax.lax.psum(q, axis_name).astype(jnp.float32) * s
    out = tot / n if mean else tot
    return out.astype(x.dtype), gf - deq


def compressed_psum_grads(grads, residuals, axis_name) -> Tuple[Any, Any]:
    """Tree-map ``compressed_psum`` over a gradient pytree.

    Returns (mean-reduced grads, new residuals)."""
    out = jax.tree.map(
        lambda g, r: compressed_psum(g, r, axis_name), grads, residuals)
    is_t = lambda x: isinstance(x, tuple)
    red = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
    res = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
    return red, res


def _reduce_dp_one(g: jnp.ndarray, r: jnp.ndarray, mean: bool):
    fp8_max = jnp.float32(F.FORMATS["fp8_e4m3"].max_value)
    gf = g.astype(jnp.float32) + r
    n = jnp.float32(gf.shape[0])
    amax = jnp.max(jnp.abs(gf))       # cross-shard: a scalar all-reduce
    s = jnp.maximum(amax, _EPS) * n / fp8_max
    q = (gf / s).astype(jnp.float8_e4m3fn)
    deq = q.astype(jnp.float32) * s
    # fp8 sum over the (data-sharded) replica axis == fp8 all-reduce
    tot = jnp.sum(q, axis=0).astype(jnp.float32) * s
    out = tot / n if mean else tot
    return out.astype(g.dtype), gf - deq


def compressed_reduce_dp(grads_dp, residuals, *, mean: bool = True
                         ) -> Tuple[Any, Any]:
    """GSPMD fp8 error-feedback reduction over a leading replica axis.

    Leaves of ``grads_dp``/``residuals`` are ``(dp, *shape)`` with dim 0
    sharded over the data axes (each data shard holds its slice).  Same
    scheme as ``compressed_psum``: shared scale from the global amax with
    N-slice headroom, quantize to fp8, sum IN FP8 over the replica axis —
    which XLA partitions into a local reduce + an fp8-payload all-reduce —
    then dequantize.  Each slice keeps its own local quantization error
    as the new residual, so the returned residual tree keeps the leading
    replica axis.

    Returns ``(reduced, new_residuals)`` with ``reduced`` shaped like one
    slice (the group mean; ``mean=False`` for sum semantics).
    """
    out = jax.tree.map(lambda g, r: _reduce_dp_one(g, r, mean),
                       grads_dp, residuals)
    is_t = lambda x: isinstance(x, tuple)
    red = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
    res = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
    return red, res
