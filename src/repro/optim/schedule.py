"""LR schedule from the paper (App. B): linear warmup (0.15% of steps) then
cosine decay to 10% of peak."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine"]


def warmup_cosine(peak_lr: float, total_steps: int,
                  warmup_frac: float = 0.0015, min_frac: float = 0.1):
    warmup = max(int(total_steps * warmup_frac), 1)

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * (step + 1) / warmup
        t = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1),
                     0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, peak_lr * cos)

    return lr
