"""No-dependency approximation of the repo's ruff gate.

CI runs the real thing (``ruff check .`` with the ``[tool.ruff]`` config
in pyproject.toml); this script covers the highest-signal subset of the
selected families so the gate can run in environments where ruff is not
installable:

  E9    syntax / indentation errors (via ``compile()``)
  F401  unused imports (module scope; ``__all__`` and re-export
        conventions respected)
  F811  redefinition of an imported name by a later import
  E711  comparison to None with ==/!=
  E712  comparison to True/False with ==/!=
  E722  bare ``except:``

(E731/E741 are in the repo's ruff ignore list and are not checked here.)

Usage::

    python tools/lint.py [paths...]     # default: src tests tools benchmarks examples
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

def _names_loaded(tree: ast.AST) -> set:
    loaded = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            loaded.add(node.id)
        elif isinstance(node, ast.Attribute):
            n = node
            while isinstance(n, ast.Attribute):
                n = n.value
            if isinstance(n, ast.Name):
                loaded.add(n.id)
        elif (isinstance(node, (ast.AnnAssign, ast.arg))
              and isinstance(node.annotation, ast.Constant)
              and isinstance(node.annotation.value, str)):
            # quoted annotations count as usage (ruff semantics)
            try:
                loaded |= _names_loaded(
                    ast.parse(node.annotation.value, mode="eval"))
            except SyntaxError:
                pass
    return loaded


def _module_imports(tree: ast.Module):
    """(alias, lineno, public_name) for module-level import bindings."""
    for node in tree.body:
        if isinstance(node, ast.Import):
            for a in node.names:
                name = a.asname or a.name.split(".")[0]
                yield name, node.lineno, a.name
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                name = a.asname or a.name
                yield name, node.lineno, a.name


def _dunder_all(tree: ast.Module) -> set:
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "__all__"
                and isinstance(node.value, (ast.List, ast.Tuple))):
            return {e.value for e in node.value.elts
                    if isinstance(e, ast.Constant)}
    return set()


def check_file(path: Path) -> list:
    problems = []
    text = path.read_text()
    try:
        tree = ast.parse(text, filename=str(path))
        compile(text, str(path), "exec")
    except SyntaxError as e:
        return [(path, e.lineno or 0, "E9", str(e.msg))]

    loaded = _names_loaded(tree)
    exported = _dunder_all(tree)
    seen = {}
    for name, lineno, orig in _module_imports(tree):
        if name in seen and seen[name] != lineno:
            problems.append((path, lineno, "F811",
                             f"redefinition of imported {name!r}"))
        seen[name] = lineno
        if name.startswith("_") or name in exported:
            continue
        # "import x as x" is the explicit re-export idiom
        if orig == name and f"import {name} as {name}" in text:
            continue
        if name not in loaded:
            problems.append((path, lineno, "F401",
                             f"{name!r} imported but unused"))

    for node in ast.walk(tree):
        if isinstance(node, ast.Compare):
            for op, cmp_ in zip(node.ops, node.comparators):
                if not isinstance(op, (ast.Eq, ast.NotEq)):
                    continue
                if isinstance(cmp_, ast.Constant):
                    if cmp_.value is None:
                        problems.append((path, node.lineno, "E711",
                                         "comparison to None with ==/!="))
                    elif cmp_.value is True or cmp_.value is False:
                        problems.append((path, node.lineno, "E712",
                                         "comparison to True/False with "
                                         "==/!="))
        elif isinstance(node, ast.ExceptHandler) and node.type is None:
            problems.append((path, node.lineno, "E722", "bare except"))
    return problems


def main(argv) -> int:
    roots = [Path(p) for p in argv] or [Path("src"), Path("tests"),
                                        Path("tools"), Path("benchmarks"),
                                        Path("examples")]
    files = []
    for r in roots:
        files += sorted(r.rglob("*.py")) if r.is_dir() else [r]
    problems = []
    for f in files:
        problems += check_file(f)
    for path, lineno, code, msg in problems:
        print(f"{path}:{lineno}: {code} {msg}")
    print(f"lint: {len(files)} files, {len(problems)} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
