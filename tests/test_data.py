"""Data pipeline: determinism, host sharding, learnable structure."""
import numpy as np
import pytest

from repro.data import ByteCorpus, SyntheticLM, make_pipeline


def test_deterministic_per_step():
    p = SyntheticLM(256, 64, 8, seed=3)
    a = p.batch(5)
    b = p.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = p.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_targets_are_shifted_tokens():
    p = SyntheticLM(256, 64, 4, seed=0, noise=0.0)
    b = p.batch(0)
    # target[i] == token[i+1] by construction of the window
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_host_sharding_partitions_batch():
    p = SyntheticLM(256, 64, 8, seed=1)
    h0 = p.batch(3, host_id=0, num_hosts=2)
    h1 = p.batch(3, host_id=1, num_hosts=2)
    assert h0["tokens"].shape == (4, 64)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_structure_is_learnable():
    """Pattern periodicity: token[t] == token[t - period] mostly."""
    p = SyntheticLM(256, 128, 16, seed=0, noise=0.0)
    b = p.batch(0)["tokens"]
    hits = 0
    for row in b:
        for per in range(3, 9):
            if np.mean(row[per:] == row[:-per]) > 0.99:
                hits += 1
                break
    assert hits >= 14  # nearly every row has a short period


def test_byte_corpus_bounds():
    p = ByteCorpus(32, 4, seed=0)
    b = p.batch(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 256


def test_make_pipeline_dispatch():
    assert isinstance(make_pipeline("synthetic", 256, 32, 4), SyntheticLM)
    assert isinstance(make_pipeline("bytes", 256, 32, 4), ByteCorpus)
    with pytest.raises(ValueError):
        make_pipeline("nope", 256, 32, 4)
