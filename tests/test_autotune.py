"""Persistent (bm, bn, bk) tuning table: keying, persistence, resolution,
validation, and the proof that a table hit is actually APPLIED by
``fused_qmm`` (and is bit-identical to the heuristic fallback).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune
from repro.kernels.autotune import (COUNTERS, DEFAULT_TABLE_PATH, SCHEMA,
                                    TuningTable, resolve_tiles, set_table,
                                    tuning_key, validate_table)
from repro.kernels.fp4_matmul import fused_qmm


@pytest.fixture(autouse=True)
def _restore_table():
    """Every test swaps the process-wide table; re-arm the lazy JSON load
    afterwards so other test files see the committed table again."""
    yield
    set_table(None)


def _table(key, bm, bn, bk, us=1.0):
    t = TuningTable()
    t.record(key, bm, bn, bk, us)
    return t


def test_tuning_key_format():
    key = tuning_key(256, 512, 384, ("float32", "bfloat16"),
                     ("block", "tile"), (False, True), 128)
    assert key == "m256_n512_k384/float32xbfloat16/block:tile/nt/b128"
    assert autotune._KEY_RE.match(key)


def test_table_save_load_round_trip(tmp_path):
    key = tuning_key(256, 256, 256, ("float32", "float32"),
                     ("block", "tile"), (False, False))
    t = _table(key, 128, 256, 128, us=42.125)
    path = tmp_path / "t.json"
    t.save(path)
    data = json.loads(path.read_text())
    assert data["schema"] == SCHEMA
    t2 = TuningTable.load(path)
    assert t2.lookup(key) == (128, 256, 128)
    assert t2.entries[key]["us"] == 42.12  # rounded on record
    assert t2.lookup("no/such/key") is None


def test_resolve_tiles_hit_miss_and_bad_entry():
    dt, modes, tr = ("float32", "float32"), ("block", "tile"), (False, False)
    key = tuning_key(256, 256, 256, dt, modes, tr)
    set_table(_table(key, 128, 128, 128))
    assert resolve_tiles(256, 256, 256, dtypes=dt, modes=modes,
                         trans=tr) == (128, 128, 128)
    # miss: different shape
    assert resolve_tiles(512, 256, 256, dtypes=dt, modes=modes,
                         trans=tr) is None
    # unusable entry (tiles don't divide the keyed shape) -> ignored, not
    # an error: a stale table can only fail to speed things up
    bad = tuning_key(384, 384, 384, dt, modes, tr)
    set_table(_table(bad, 256, 256, 256))
    assert resolve_tiles(384, 384, 384, dtypes=dt, modes=modes,
                         trans=tr) is None


def test_set_table_clears_resolution_cache():
    dt, modes, tr = ("float32", "float32"), ("block", "tile"), (False, False)
    key = tuning_key(256, 256, 256, dt, modes, tr)
    set_table(_table(key, 128, 128, 128))
    assert resolve_tiles(256, 256, 256, dtypes=dt, modes=modes,
                         trans=tr) == (128, 128, 128)
    set_table(_table(key, 256, 256, 256))  # must not serve the stale 128s
    assert resolve_tiles(256, 256, 256, dtypes=dt, modes=modes,
                         trans=tr) == (256, 256, 256)


def test_validate_table(tmp_path):
    key = tuning_key(256, 256, 256, ("float32", "float32"),
                     ("block", "tile"), (False, False))
    good = tmp_path / "good.json"
    _table(key, 128, 256, 128, us=3.5).save(good)
    assert validate_table(good) == []

    bad = tmp_path / "bad.json"
    t = TuningTable()
    t.record(key, 96, 256, 128, us=3.5)          # 96 not a block multiple
    t.record("not a key", 128, 128, 128, us=1.0)  # malformed key
    t.record(tuning_key(256, 256, 256, ("float32", "float32"),
                        ("block", "block"), (False, False)),
             512, 128, 128, us=1.0)               # 512 does not divide 256
    t.save(bad)
    errors = validate_table(bad)
    assert len(errors) == 3
    assert any("not a positive multiple" in e for e in errors)
    assert any("malformed key" in e for e in errors)
    assert any("does not divide" in e for e in errors)

    assert validate_table(tmp_path / "absent.json")  # unreadable


def test_committed_table_is_valid():
    assert DEFAULT_TABLE_PATH.exists(), DEFAULT_TABLE_PATH
    assert validate_table(DEFAULT_TABLE_PATH) == []


def test_table_tiling_is_applied_and_bit_identical(monkeypatch):
    """A table hit must (a) actually be consulted — the hit counter grows —
    (b) actually be APPLIED — the tiles reaching the jit'd pipeline body
    are the table's, not the heuristic's — and (c) be bit-identical to the
    heuristic fallback (the table entry keeps the heuristic's bk, so even
    the f32 accumulation order matches; bm/bn never touch the math)."""
    import importlib
    fm = importlib.import_module("repro.kernels.fp4_matmul")

    m = n = k = 384  # _pick_tile heuristic gives (384, 384, 384)
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(ka, (m, k), jnp.float32)
    b = jax.random.normal(kb, (k, n), jnp.float32) * 0.05
    kw = dict(a_mode="block", b_mode="tile", interpret=True)

    applied = {}
    orig = fm._fused_qmm

    def spy(a_, b_, **kws):
        applied.update(bm=kws["bm"], bn=kws["bn"], bk=kws["bk"])
        return orig(a_, b_, **kws)

    monkeypatch.setattr(fm, "_fused_qmm", spy)

    set_table(TuningTable())
    y_fallback = fused_qmm(a, b, **kw)  # heuristic tiles
    assert (applied["bm"], applied["bn"], applied["bk"]) == (384, 384, 384)

    key = tuning_key(m, n, k, ("float32", "float32"), ("block", "tile"),
                     (False, False))
    set_table(_table(key, 128, 128, 384))
    hits = COUNTERS["hit"]
    y_table = fused_qmm(a, b, **kw)
    assert COUNTERS["hit"] == hits + 1, "table was not consulted"
    assert (applied["bm"], applied["bn"], applied["bk"]) == (128, 128, 384), \
        "table tiling was not applied"
    np.testing.assert_array_equal(
        np.asarray(y_table).view(np.uint8),
        np.asarray(y_fallback).view(np.uint8),
        err_msg="table hit not bit-identical to heuristic fallback")


def test_partial_explicit_tiles_skip_the_table():
    """Any explicitly-passed tile disables the lookup (explicit wins)."""
    m = n = k = 256
    ka, kb = jax.random.split(jax.random.PRNGKey(1))
    a = jax.random.normal(ka, (m, k), jnp.float32)
    b = jax.random.normal(kb, (k, n), jnp.float32)
    key = tuning_key(m, n, k, ("float32", "float32"), ("block", "tile"),
                     (False, False))
    set_table(_table(key, 128, 128, 128))
    resolve_tiles.cache_clear()
    before = dict(COUNTERS)
    fused_qmm(a, b, a_mode="block", b_mode="tile", bm=256, interpret=True)
    assert dict(COUNTERS) == before, "partial tiles must skip the lookup"
