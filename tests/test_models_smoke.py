"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes + no NaNs (assignment requirement)."""
import importlib

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import TrainConfig
from repro.core.recipe import RECIPES
from repro.models import build_model
from repro.train.train_step import make_optimizer, make_train_step

ARCHS = [
    "nemotron-4-15b", "llama3.2-3b", "h2o-danube-3-4b", "granite-34b",
    "mixtral-8x22b", "olmoe-1b-7b", "llama-3.2-vision-90b", "whisper-base",
    "mamba2-780m", "jamba-1.5-large-398b",
    "gpt2-125m", "gpt2-335m", "gpt2-774m", "llama-125m", "llama-1b",
]

# Full-graph train-step compiles dominate CPU CI time; the fast set keeps
# one arch per family-shaped code path (dense, moe, ssm, enc-dec) and the
# rest run under -m slow.
TRAIN_STEP_FAST = {"llama-125m", "mixtral-8x22b", "mamba2-780m",
                   "whisper-base"}
TRAIN_ARCHS = [a if a in TRAIN_STEP_FAST else
               pytest.param(a, marks=pytest.mark.slow) for a in ARCHS]


def _reduced(arch):
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))
    return mod.REDUCED, mod.CONFIG


def _batch(cfg, b=2, s=32, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            ks[2], (b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (b, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, _ = _reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch, RECIPES["paper_fp4"])
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", TRAIN_ARCHS)
def test_one_train_step(arch):
    cfg, _ = _reduced(arch)
    model = build_model(cfg)
    tcfg = TrainConfig(recipe="paper_fp4", total_steps=10, global_batch=2,
                       seq_len=32, learning_rate=1e-3)
    step = make_train_step(model, tcfg, RECIPES["paper_fp4"], jit=True,
                           donate=False)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer(model, tcfg)
    opt_state = opt.init(params)
    comp = jnp.zeros((), jnp.float32)
    batch = {k: jnp.asarray(v) for k, v in _batch(cfg).items()}
    p2, o2, c2, metrics = step(params, opt_state, comp, batch,
                               jnp.asarray(0))
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert delta > 0
    for leaf in jax.tree.leaves(p2):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS[:10])
def test_full_config_matches_assignment(arch):
    """FULL configs keep the assigned hyperparameters (spot contract)."""
    _, cfg = _reduced(arch)
    expected = {
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expected


def test_moe_archs_declare_experts():
    _, mixtral = _reduced("mixtral-8x22b")
    assert (mixtral.moe.num_experts, mixtral.moe.top_k) == (8, 2)
    _, olmoe = _reduced("olmoe-1b-7b")
    assert (olmoe.moe.num_experts, olmoe.moe.top_k) == (64, 8)
    _, jamba = _reduced("jamba-1.5-large-398b")
    assert (jamba.moe.num_experts, jamba.moe.top_k) == (16, 2)


def test_jamba_layer_pattern():
    _, cfg = _reduced("jamba-1.5-large-398b")
    specs = cfg.layer_specs()
    n_attn = sum(1 for s in specs if s.mixer == "attn")
    assert n_attn == 9  # 72 layers, 1:7 ratio
    n_moe = sum(1 for s in specs if s.ffn == "moe")
    assert n_moe == 36  # every other layer
    assert cfg.scan_period() == 8


def test_vision_cross_layers():
    _, cfg = _reduced("llama-3.2-vision-90b")
    specs = cfg.layer_specs()
    assert sum(1 for s in specs if s.cross) == 20  # every 5th of 100
