"""The paper's theoretical cost model: Fig 1(a) and Tables 2/3 numbers."""
import pytest

from repro.core.cost_model import (BlockDims, compute_share,
                                   schedule_adjusted_cost, theoretical_cost)
from repro.core.recipe import RECIPES

# LLaMA-7B block at 4k ctx (Fig. 1a setting)
LLAMA7B_4K = BlockDims(d_model=4096, d_ff=11008, n_heads=32, n_kv_heads=32,
                       head_dim=128, seq_len=4096, n_ff_matmuls=3)
# LLaMA2-125M (Table 2 ablation model, 2k ctx)
LLAMA125M = BlockDims(d_model=768, d_ff=3072, n_heads=12, n_kv_heads=12,
                      head_dim=64, seq_len=2048, n_ff_matmuls=3)


def test_fig1a_ffn_share():
    """Paper: FFN ~57% of block compute for LLaMA-7B @ 4k."""
    share = compute_share(LLAMA7B_4K)
    assert 0.50 <= share["ffn"] <= 0.62, share
    assert abs(sum(share.values()) - 1.0) < 1e-9


@pytest.mark.parametrize("recipe,expected", [
    ("all_fp4", 0.571),          # Table 2 row 1: 57.1%
    ("t2_fp4_fp8_fp8", 0.696),   # 69.6%
    ("t2_fp8_fp4_fp4", 0.607),   # 60.7%
    ("t2_fp8_fp4_fp8", 0.661),   # 66.1%
    ("bf16", 1.0),               # 100%
])
def test_table2_costs_calibrated(recipe, expected):
    from repro.core.cost_model import paper_calibrated_cost
    cost = paper_calibrated_cost(RECIPES[recipe])
    assert abs(cost - expected) < 0.005, (recipe, cost, expected)


def test_table2_ordering_analytic():
    """Our first-principles model reproduces the paper's cost ORDERING."""
    names = ["all_fp4", "t2_fp8_fp4_fp4", "t2_fp8_fp4_fp8",
             "t2_fp4_fp8_fp8", "bf16"]
    costs = [theoretical_cost(RECIPES[n], LLAMA125M) for n in names]
    assert costs == sorted(costs), dict(zip(names, costs))


def test_table3_schedule_cost_between():
    """With the 2-stage tail, cost sits between pure-low and FP16
    (Table 3: 67.5% -> 69.7% with the schedule)."""
    r_no = RECIPES["paper_fp4_nosched"]
    r_yes = RECIPES["paper_fp4"]
    d = LLAMA125M
    lo = theoretical_cost(r_no, d)
    hi = schedule_adjusted_cost(r_yes, d)
    assert lo < hi < 1.0
    assert 0.01 < hi - lo < 0.05  # 7.5% tail at ~30-60% saving


def test_paper_recipe_cheaper_than_bf16_costlier_than_allfp4():
    from repro.core.cost_model import paper_calibrated_cost
    assert (paper_calibrated_cost(RECIPES["all_fp4"])
            < paper_calibrated_cost(RECIPES["paper_fp4"])
            < paper_calibrated_cost(RECIPES["bf16"]))
