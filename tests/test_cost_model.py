"""The paper's theoretical cost model: Fig 1(a) and Tables 2/3 numbers,
the plan-aware v2 (ModelDims / plan_cost / schedule_cost, exact uniform
parity), and the telemetry-driven plan searcher's frontier contract."""
import json

import pytest

from repro.configs.base import ControllerSettings, get_config
from repro.core.cost_model import (BlockDims, CostCalibration, ModelDims,
                                   _cal_key, calibrate, compute_share,
                                   paper_calibrated_cost, plan_cost,
                                   schedule_adjusted_cost, schedule_cost,
                                   speed_factor, theoretical_cost)
from repro.core.recipe import RECIPES, PrecisionPlan
from repro.telemetry.controller import PlanSearcher

# LLaMA-7B block at 4k ctx (Fig. 1a setting)
LLAMA7B_4K = BlockDims(d_model=4096, d_ff=11008, n_heads=32, n_kv_heads=32,
                       head_dim=128, seq_len=4096, n_ff_matmuls=3)
# LLaMA2-125M (Table 2 ablation model, 2k ctx)
LLAMA125M = BlockDims(d_model=768, d_ff=3072, n_heads=12, n_kv_heads=12,
                      head_dim=64, seq_len=2048, n_ff_matmuls=3)


def test_fig1a_ffn_share():
    """Paper: FFN ~57% of block compute for LLaMA-7B @ 4k."""
    share = compute_share(LLAMA7B_4K)
    assert 0.50 <= share["ffn"] <= 0.62, share
    assert abs(sum(share.values()) - 1.0) < 1e-9


@pytest.mark.parametrize("recipe,expected", [
    ("all_fp4", 0.571),          # Table 2 row 1: 57.1%
    ("t2_fp4_fp8_fp8", 0.696),   # 69.6%
    ("t2_fp8_fp4_fp4", 0.607),   # 60.7%
    ("t2_fp8_fp4_fp8", 0.661),   # 66.1%
    ("bf16", 1.0),               # 100%
])
def test_table2_costs_calibrated(recipe, expected):
    from repro.core.cost_model import paper_calibrated_cost
    cost = paper_calibrated_cost(RECIPES[recipe])
    assert abs(cost - expected) < 0.005, (recipe, cost, expected)


def test_table2_ordering_analytic():
    """Our first-principles model reproduces the paper's cost ORDERING."""
    names = ["all_fp4", "t2_fp8_fp4_fp4", "t2_fp8_fp4_fp8",
             "t2_fp4_fp8_fp8", "bf16"]
    costs = [theoretical_cost(RECIPES[n], LLAMA125M) for n in names]
    assert costs == sorted(costs), dict(zip(names, costs))


def test_table3_schedule_cost_between():
    """With the 2-stage tail, cost sits between pure-low and FP16
    (Table 3: 67.5% -> 69.7% with the schedule)."""
    r_no = RECIPES["paper_fp4_nosched"]
    r_yes = RECIPES["paper_fp4"]
    d = LLAMA125M
    lo = theoretical_cost(r_no, d)
    hi = schedule_adjusted_cost(r_yes, d)
    assert lo < hi < 1.0
    assert 0.01 < hi - lo < 0.05  # 7.5% tail at ~30-60% saving


def test_paper_recipe_cheaper_than_bf16_costlier_than_allfp4():
    from repro.core.cost_model import paper_calibrated_cost
    assert (paper_calibrated_cost(RECIPES["all_fp4"])
            < paper_calibrated_cost(RECIPES["paper_fp4"])
            < paper_calibrated_cost(RECIPES["bf16"]))


# ---------------------------------------------------------------------------
# Plan-aware cost model v2 (tentpole)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(RECIPES))
@pytest.mark.parametrize("n_layers", [1, 2, 5, 12])
def test_plan_cost_uniform_parity_bit_exact(name, n_layers):
    """The exact-parity guarantee: a uniform plan prices bit-identically
    to the old single-block recipe path at ANY depth — `==`, not approx."""
    r = RECIPES[name]
    got = plan_cost(PrecisionPlan.uniform(r, n_layers),
                    ModelDims.from_block(LLAMA125M, n_layers))
    assert got == theoretical_cost(r, LLAMA125M)


@pytest.mark.parametrize("name", sorted(RECIPES))
def test_paper_calibrated_cost_plan_parity(name):
    r = RECIPES[name]
    for n in (1, 4):
        assert (paper_calibrated_cost(PrecisionPlan.uniform(r, n))
                == paper_calibrated_cost(r))


def test_cost_entry_points_reject_non_recipes():
    with pytest.raises(TypeError, match="as_plan"):
        theoretical_cost("paper_fp4", LLAMA125M)
    with pytest.raises(TypeError, match="deprecated"):
        paper_calibrated_cost(RECIPES["paper_fp4"].ffn_linear)


def test_plan_cost_depth_graded_between_uniform_bounds():
    """A first/last-k protected plan costs more than the uniform recipe
    (it runs FP8 rows at the edges) but less than all-FP8."""
    dims = ModelDims.from_block(LLAMA125M, 8)
    lo = plan_cost(PrecisionPlan.uniform(RECIPES["all_fp4"], 8), dims)
    fl = plan_cost(PrecisionPlan.first_last_k(RECIPES["all_fp4"], 8, k=2),
                   dims)
    hi = plan_cost(PrecisionPlan.uniform(RECIPES["fp8"], 8), dims)
    assert lo < fl < hi < 1.0
    # demoting one cell's wgrad strictly cuts cost, promote strictly adds
    plan = PrecisionPlan.uniform(RECIPES["fp8"], 8)
    assert plan_cost(plan.demote("ffn", layer=3), dims) < plan_cost(
        plan, dims)
    p4 = PrecisionPlan.uniform(RECIPES["all_fp4"], 8)
    assert plan_cost(p4.promote("attn", layer=0), dims) > plan_cost(
        p4, dims)


def test_model_dims_from_config_families():
    # dense tiny: per-layer dims uniform, lm-head priced separately
    tiny = get_config("tiny")
    dims = ModelDims.from_config(tiny, seq_len=64)
    assert dims.n_layers == tiny.n_layers
    assert all(ld == dims.layers[0] for ld in dims.layers)
    assert dims.head_flops == 2 * tiny.d_model * tiny.vocab_size
    ld = dims.layers[0]
    assert ld.attn_linear > 0 and ld.attn_sdpa > 0 and ld.ffn > 0
    no_head = ModelDims.from_config(tiny, seq_len=64, include_head=False)
    assert no_head.head_flops == 0.0
    # BF16-head pricing pulls the ratio toward 1 vs the head-free dims
    p = PrecisionPlan.uniform(RECIPES["all_fp4"], tiny.n_layers)
    assert plan_cost(p, dims) > plan_cost(p, no_head)
    # MoE: expert flops scale with router top-k
    moe = get_config("olmoe-1b-7b")
    md = ModelDims.from_config(moe, seq_len=128)
    dense_like = moe.replace(moe=None)
    dd = ModelDims.from_config(dense_like, seq_len=128)
    assert md.layers[-1].ffn == moe.moe.top_k * dd.layers[-1].ffn
    # SSM: mamba projections priced as the FFN class, no attention flops
    ssm = get_config("mamba2-780m")
    sd = ModelDims.from_config(ssm, seq_len=128)
    assert sd.layers[0].attn_linear == 0 and sd.layers[0].attn_sdpa == 0
    assert sd.layers[0].ffn == 3 * 2 * ssm.d_model * (
        ssm.mamba.expand * ssm.d_model)


def test_plan_cost_depth_mismatch_raises():
    dims = ModelDims.from_block(LLAMA125M, 4)
    with pytest.raises(ValueError, match="layers"):
        plan_cost(PrecisionPlan.uniform(RECIPES["all_fp4"], 6), dims)


def test_schedule_cost_integrates_stage2():
    dims = ModelDims.from_block(LLAMA125M, 2)
    plan = PrecisionPlan.uniform(RECIPES["paper_fp4"], 2)
    lo = plan_cost(plan, dims)
    hi = plan_cost(PrecisionPlan.uniform(RECIPES["bf16"], 2), dims)
    cont = schedule_cost(plan, dims)
    assert lo < cont < hi
    frac = plan.target_precision_frac
    assert cont == pytest.approx((1 - frac) * lo + frac * hi)
    # step-budget form quantizes the switch exactly like the schedule
    total = 100
    switch = int(round(total * (1 - frac)))
    stepped = schedule_cost(plan, dims, total_steps=total)
    assert stepped == pytest.approx(
        (switch * lo + (total - switch) * hi) / total)
    # no stage 2 -> plain plan cost
    nosched = PrecisionPlan.uniform(RECIPES["paper_fp4_nosched"], 2)
    assert schedule_cost(nosched, dims) == plan_cost(nosched, dims)


# ---------------------------------------------------------------------------
# Plan searcher: frontier monotonicity + checkpoint-resume bit-exactness
# (pure Python on synthetic telemetry rows; trainer wiring is covered in
# tests/test_telemetry.py)
# ---------------------------------------------------------------------------

def _searcher(every=3, **kw):
    dims = ModelDims.from_config(get_config("tiny"), seq_len=64)
    return PlanSearcher(dims, ControllerSettings(
        plan_search=True, plan_search_every=every, **kw))


def _row(errs):
    """Synthetic telemetry row with one fwd rel_err key per cell."""
    return {f"tel/{c.split('/')[0]}/{c.split('/')[1]}/mm0/fwd_x/rel_err": v
            for c, v in errs.items()}


START_ERRS = {"l00/ffn": 0.20, "l01/ffn": 0.15,
              "l00/attn": 0.10, "l01/attn": 0.05}


def _drive(searcher, base, errs, steps, react=True, start=0):
    """Feed rows; when the searcher promotes a cell, simulate the FP8
    error drop (x1/8) so the measured signal reacts like a real run."""
    events = []
    for step in range(start, start + steps):
        searcher.observe(step, _row(errs))
        for ev in searcher.maybe_move(step, base):
            events.append(ev)
            if react and ev["event"] == "plan_search":
                if ev["op"] == "promote":
                    errs[ev["cell"]] /= 8.0
                else:
                    errs[ev["cell"]] *= 4.0
    return events


def test_searcher_frontier_monotone():
    s = _searcher()
    base = PrecisionPlan.uniform(RECIPES["all_fp4"], 2)
    _drive(s, base, dict(START_ERRS), steps=40)
    assert s.done
    assert len(s.edits) == 4  # every promotable cell visited exactly once
    assert len(s.frontier) == 5
    costs = [p["cost"] for p in s.frontier]
    errors = [p["error"] for p in s.frontier]
    # monotone frontier: strictly increasing cost, strictly decreasing
    # error — no search step added a point at higher cost with
    # equal-or-worse error
    assert costs == sorted(costs) and len(set(costs)) == len(costs)
    assert errors == sorted(errors, reverse=True)
    assert len(set(errors)) == len(errors)
    # greedy order: worst cell first
    assert s.edits[0] == ["promote", "l00/ffn"]


def test_searcher_respects_cost_budget_and_demotes():
    """With a cost budget below the next promotion, the searcher frees
    budget by demoting the healthiest cell's wgrad roles instead."""
    dims = ModelDims.from_config(get_config("tiny"), seq_len=64)
    base = PrecisionPlan.uniform(RECIPES["fp8"], 2)
    budget = plan_cost(base, dims)  # no promotion can fit
    s = PlanSearcher(dims, ControllerSettings(
        plan_search=True, plan_search_every=3,
        plan_search_cost_budget=budget,
        plan_search_demote_threshold=0.5))
    errs = {"l00/ffn": 0.04, "l01/ffn": 0.03,
            "l00/attn": 0.02, "l01/attn": 0.01}
    events = _drive(s, base, errs, steps=30)
    demotes = [e for e in events if e.get("event") == "plan_search"
               and e["op"] == "demote"]
    assert demotes and demotes[0]["cell"] == "l01/attn"  # healthiest first
    edited = s.apply(base)
    mm = edited.layers[1].attn_linear
    assert mm.wgrad_g.fmt == "fp4_e2m1" and mm.wgrad_g.stochastic
    assert mm.wgrad_x.fmt == "fp4_e2m1"
    assert mm.dgrad_g.fmt == "fp8_e5m2"  # dgrad never demoted
    assert plan_cost(edited, dims) < budget


def test_searcher_max_edits_caps_search():
    s = _searcher(plan_search_max_edits=2)
    base = PrecisionPlan.uniform(RECIPES["all_fp4"], 2)
    _drive(s, base, dict(START_ERRS), steps=40)
    assert s.done and len(s.edits) == 2


def test_searcher_resume_bit_exact():
    """Snapshot the searcher state mid-search through a JSON round-trip
    (the checkpoint-extra path); the resumed searcher must replay the
    remainder bit-identically to the uninterrupted one."""
    base = PrecisionPlan.uniform(RECIPES["all_fp4"], 2)
    ref_errs, cut_errs = dict(START_ERRS), dict(START_ERRS)
    ref = _searcher()
    _drive(ref, base, ref_errs, steps=40)

    a = _searcher()
    _drive(a, base, cut_errs, steps=7)  # stop mid-window, 2 edits applied
    assert len(a.edits) == 2 and not a.done
    state = json.loads(json.dumps(a.state_dict()))  # ckpt extra round-trip
    b = _searcher()
    b.load_state(state)
    assert b.state_dict() == a.state_dict()
    _drive(b, base, cut_errs, steps=33, start=7)
    assert b.state_dict() == ref.state_dict()      # bit-exact floats
    assert b.apply(base) is not None
    assert [p["cost"] for p in b.frontier] == [p["cost"]
                                               for p in ref.frontier]
    assert [p["error"] for p in b.frontier] == [p["error"]
                                                for p in ref.frontier]


# ---------------------------------------------------------------------------
# Measured cost calibration (wall-clock-calibrated plan costs)
# ---------------------------------------------------------------------------

# A synthetic "this host" table where FP8 matmuls measured ~3x the plain
# matmul but FP4 QDQ measured *slower* than plain (0.5x) — the opposite
# ranking from the paper's bit-width theory (fp4=4x > fp8=2x).  Format-only
# keys act as granularity wildcards via the lookup fallback.
FP8_FAST = calibrate({
    ("fp4_e2m1", "fp4_e2m1"): 0.5,
    ("fp4_e2m1", "fp8_e4m3"): 0.5,
    ("fp4_e2m1", "fp8_e5m2"): 0.5,
    ("fp8_e4m3", "fp8_e4m3"): 3.0,
    ("fp8_e4m3", "fp8_e5m2"): 3.0,
    ("fp8_e5m2", "fp8_e5m2"): 3.0,
    ("bf16", "bf16"): 1.0,
}, source="test")


def test_speed_factor_lookup_order_and_paper_fallback():
    fp4 = RECIPES["all_fp4"].ffn_linear
    bf = RECIPES["bf16"].ffn_linear
    # paper defaults (no calibration): min of the formats' assumed factors
    assert speed_factor(fp4.fwd_x, fp4.fwd_w) == 4.0
    assert speed_factor(bf.fwd_x, bf.fwd_w) == 1.0
    # exact (key_a, key_b) hit
    cal = calibrate({(_cal_key(fp4.fwd_x), _cal_key(fp4.fwd_w)): 0.25})
    assert speed_factor(fp4.fwd_x, fp4.fwd_w, cal) == 0.25
    # swapped-pair hit
    cal = calibrate({(_cal_key(fp4.fwd_w), _cal_key(fp4.fwd_x)): 0.3})
    assert speed_factor(fp4.fwd_x, fp4.fwd_w, cal) == 0.3
    # format-only wildcard (granularity stripped)
    cal = calibrate({("fp4_e2m1", "fp4_e2m1"): 0.4})
    assert speed_factor(fp4.fwd_x, fp4.fwd_w, cal) == 0.4
    # uncovered pair falls back to the paper factor
    assert speed_factor(bf.fwd_x, bf.fwd_w, cal) == 1.0


def test_calibration_json_roundtrip(tmp_path):
    path = str(tmp_path / "speed_factors.json")
    FP8_FAST.to_json(path)
    with open(path) as f:
        payload = json.load(f)
    assert payload["schema"] == "speed_factors.v1"
    back = CostCalibration.from_json(path)
    assert dict(back.table) == dict(FP8_FAST.table)
    assert back.source == "test"


@pytest.mark.parametrize("name", sorted(RECIPES))
def test_plan_cost_no_calibration_is_bit_exact_paper_path(name):
    """calibration=None must be the PR-5 arithmetic, bitwise: the explicit
    None call equals the legacy two-arg call, which the uniform-parity
    tests above pin to theoretical_cost."""
    dims = ModelDims.from_config(get_config("tiny"), seq_len=64)
    plan = PrecisionPlan.uniform(RECIPES[name], dims.n_layers)
    assert plan_cost(plan, dims) == plan_cost(plan, dims, None)
    assert schedule_cost(plan, dims) == schedule_cost(plan, dims,
                                                      calibration=None)


def test_searcher_reranks_candidates_under_measured_factors():
    """The acceptance contract: the same two candidate plans swap rank when
    pricing switches from paper theory to the measured table, and the
    PlanSearcher's own events price with whichever table it was built with.
    """
    dims = ModelDims.from_config(get_config("tiny"), seq_len=64)
    base = PrecisionPlan.uniform(RECIPES["all_fp4"], dims.n_layers)
    promoted = base.promote("ffn", layer=0)
    # paper theory: promoting a cell to FP8 always costs more
    assert plan_cost(promoted, dims) > plan_cost(base, dims)
    # measured: fp8 is the fast path on this host, so the SAME promotion
    # is a cost *decrease* — the candidates re-rank
    assert plan_cost(promoted, dims, FP8_FAST) < plan_cost(base, dims,
                                                           FP8_FAST)

    # and the searcher prices frontier points / moves with its table
    for cal in (None, FP8_FAST):
        s = PlanSearcher(dims, ControllerSettings(
            plan_search=True, plan_search_every=3), calibration=cal)
        events = _drive(s, base, dict(START_ERRS), steps=4)
        frontier0 = next(e for e in events
                         if e["event"] == "frontier_point")
        move = next(e for e in events if e["event"] == "plan_search")
        assert move["op"] == "promote" and move["cell"] == "l00/ffn"
        assert frontier0["cost"] == plan_cost(base, dims, cal)
        assert move["cost"] == plan_cost(
            base.promote("ffn", layer=0), dims, cal)
        if cal is None:
            assert move["cost"] > frontier0["cost"]
        else:
            assert move["cost"] < frontier0["cost"]
