"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps.

Assignment requirement: "For each Pallas kernel, sweep shapes/dtypes and
assert_allclose against the ref.py pure-jnp oracle."
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, fp4_matmul, quantize_blockwise
from repro.kernels.ref import (flash_attention_ref, fp4_matmul_ref,
                               quantize_blockwise_ref)

MM_SHAPES = [(128, 128, 128), (256, 384, 128), (200, 300, 260),
             (64, 500, 70), (128, 129, 127)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    # bf16 inputs land EXACTLY on RTN tie points (e.g. x/amax == 0.75), and
    # the in-Pallas division can differ by 1 ulp from the oracle's, flipping
    # a tie by one grid step (verified: xq grids agree everywhere except
    # exact ties).  Amax scales are tie-fragile by nature; pow2 scales are
    # exact.  Tolerance = a few flipped E2M1 ties per reduction.
    return dict(rtol=6e-2, atol=6e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fp4_matmul_sweep(m, k, n, dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 7 + n))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) * 0.05
    y = fp4_matmul(x.astype(dtype), w.astype(dtype))
    ref = fp4_matmul_ref(x.astype(dtype), w.astype(dtype))
    scale = max(float(jnp.abs(ref.astype(jnp.float32)).max()), 1.0)
    np.testing.assert_allclose(np.asarray(y, np.float32) / scale,
                               np.asarray(ref, np.float32) / scale,
                               **_tol(dtype))


@pytest.mark.parametrize("fmt", ["fp4_e2m1", "fp8_e4m3", "fp8_e5m2"])
@pytest.mark.parametrize("shape", [(128, 128), (256, 384), (130, 70)])
def test_quantize_blockwise_sweep(fmt, shape):
    x = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    y = quantize_blockwise(x, fmt, 128)
    ref = quantize_blockwise_ref(x, fmt, 128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5,
                               atol=1e-6)


def test_quantize_per_row_matches_block_spec():
    from repro.core.quantize import QuantSpec, qdq
    x = jax.random.normal(jax.random.PRNGKey(2), (256, 256), jnp.float32)
    y = quantize_blockwise(x, "fp4_e2m1", 128, per_row=True)
    ref = qdq(x, QuantSpec("fp4_e2m1", "block", 128), 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("s,kvh", [(128, 4), (256, 2), (128, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(s, kvh, causal):
    b, h, d = 2, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(s + kvh), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)
    o = flash_attention(q, k, v, causal=causal)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)


def test_flash_attention_bf16():
    b, s, h, d = 1, 128, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.bfloat16)
    o = flash_attention(q, k, v)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32), rtol=3e-2,
                               atol=3e-2)


def test_flash_attention_grads_match_ref():
    b, s, h, d = 1, 128, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)

    def f(fn):
        return jax.grad(lambda q, k, v: jnp.sum(fn(q, k, v) ** 2),
                        argnums=(0, 1, 2))(q, k, v)

    g = f(lambda q, k, v: flash_attention(q, k, v))
    gr = f(lambda q, k, v: flash_attention_ref(q, k, v))
    for a, b_ in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("m,k,n", [(130, 129, 127), (1, 1, 1),
                                   (127, 257, 383)])
def test_fp4_matmul_padding_eps_floor_regression(m, k, n):
    """Non-multiple-of-128 shapes in ALL three dims: ops.py zero-pads, and
    the padded K-tail makes the weight's last (128 x 128) tile mostly (or,
    with a zeroed-out input region, entirely) zero — quantize_tile must take
    the _EPS-floor scale path and contribute exactly nothing, matching the
    oracle's identically-padded blocked view."""
    kx, kw = jax.random.split(jax.random.PRNGKey(k))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) * 0.05
    # Zero the real K-tail of w so the padded tile is ALL zero (pure
    # eps-floor path), not just zero-padded.
    if k > 128:
        w = w.at[128:].set(0.0)
        x = x.at[:, 128:].set(jnp.abs(x[:, 128:]) + 1.0)  # nonzero partner
    y = fp4_matmul(x, w)
    ref = fp4_matmul_ref(x, w)
    assert y.shape == (m, n)
    assert bool(jnp.isfinite(y).all())
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


def test_fp4_matmul_mixed_formats():
    """x FP8 + w FP4 (the paper's wgrad setting) also matches ref."""
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(2), (256, 128),
                          jnp.float32) * 0.05
    y = fp4_matmul(x, w, x_fmt="fp8_e4m3", w_fmt="fp4_e2m1")
    ref = fp4_matmul_ref(x, w, x_fmt="fp8_e4m3", w_fmt="fp4_e2m1")
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)
