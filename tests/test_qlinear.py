"""Quantized-matmul custom_vjp: STE semantics, per-role precision."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qlinear import qlinear, qmatmul
from repro.core.quantize import QuantSpec, qdq
from repro.core.recipe import (MM_BF16, MM_FP4_ALL, MM_FFN_PAPER, MM_FP8,
                               MatmulRecipe, RECIPES)

KEY0 = jnp.zeros((2,), jnp.uint32)


def _data(m=64, k=96, n=48, scale=0.1):
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) * scale
    return x, w


def test_passthrough_is_exact():
    x, w = _data()
    np.testing.assert_allclose(np.asarray(qlinear(x, w, MM_BF16)),
                               np.asarray(x @ w), rtol=1e-6)


def test_forward_matches_manual_qdq():
    x, w = _data()
    r = MM_FFN_PAPER
    y = qmatmul(x, w, KEY0, r)
    ref = qdq(x, r.fwd_x, 1) @ qdq(w, r.fwd_w, 0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


def test_ste_backward_matches_manual():
    """dx must be Q(g) @ Q(w)^T and dw must be Q(x)^T @ Q(g) exactly."""
    x, w = _data()
    r = MM_FFN_PAPER
    y, vjp = jax.vjp(lambda a, b: qmatmul(a, b, KEY0, r), x, w)
    g = jax.random.normal(jax.random.PRNGKey(3), y.shape, jnp.float32)
    dx, dw = vjp(g)
    dx_ref = qdq(g, r.dgrad_g, 1) @ qdq(w.T, r.dgrad_w, 0)
    dw_ref = qdq(x.T, r.wgrad_x, 1) @ qdq(g, r.wgrad_g, 0)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_ref),
                               rtol=1e-5, atol=1e-5)


def test_paper_recipe_dgrad_unquantized():
    """§3.2: the activation-gradient path of FFN linears stays BF16."""
    r = MM_FFN_PAPER
    assert r.dgrad_g.is_passthrough and r.dgrad_w.is_passthrough
    assert r.fwd_x.fmt == "fp4_e2m1" and r.fwd_x.granularity == "block"
    assert r.wgrad_g.fmt == "fp8_e5m2"


def test_recipe_grid_distinct_losses():
    """Different recipes must actually change the computation."""
    x, w = _data(scale=1.0)
    outs = {}
    for name in ("bf16", "fp8", "all_fp4", "paper_fp4"):
        r = RECIPES[name].ffn_linear
        outs[name] = np.asarray(qlinear(x, w, r))
    err4 = np.abs(outs["all_fp4"] - outs["bf16"]).max()
    err8 = np.abs(outs["fp8"] - outs["bf16"]).max()
    assert err4 > err8 > 0  # fp4 noisier than fp8, both nonzero


def test_quantization_error_ordering_backward():
    """all-FP4 backward noisier than FP8 backward (Table 2 mechanism)."""
    x, w = _data(scale=1.0)

    def grads(r):
        return jax.grad(lambda a, b: jnp.sum(qmatmul(a, b, KEY0, r) ** 2),
                        argnums=(0, 1))(x, w)

    gx16, gw16 = grads(MM_BF16)
    gx8, gw8 = grads(MM_FP8)
    gx4, gw4 = grads(MM_FP4_ALL)
    e8 = float(jnp.abs(gw8 - gw16).mean())
    e4 = float(jnp.abs(gw4 - gw16).mean())
    assert e4 > e8 > 0


def test_qlinear_leading_dims_and_bias():
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 5, 32))
    w = jax.random.normal(jax.random.PRNGKey(2), (32, 16)) * 0.1
    b = jnp.ones((16,))
    y = qlinear(x, w, MM_FP8, bias=b)
    assert y.shape == (2, 3, 5, 16)
    ref = qlinear(x.reshape(-1, 32), w, MM_FP8) + b
    np.testing.assert_allclose(np.asarray(y).reshape(-1, 16),
                               np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_stochastic_rounding_uses_key():
    spec_sr = QuantSpec("fp4_e2m1", "block", stochastic=True)
    r = MatmulRecipe(fwd_x=spec_sr, fwd_w=QuantSpec("fp4_e2m1", "tile"))
    x, w = _data(scale=1.0)
    k1 = jax.random.key_data(jax.random.PRNGKey(1))
    k2 = jax.random.key_data(jax.random.PRNGKey(2))
    y1 = qmatmul(x, w, k1, r)
    y2 = qmatmul(x, w, k2, r)
    assert float(jnp.abs(y1 - y2).max()) > 0  # different keys, different SR
