"""Mamba2 SSD: chunked form vs sequential recurrence oracle; decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Optional-hypothesis shim lives in conftest: real @given when
# installed, skip-marked no-ops otherwise.
from conftest import given, requires_hypothesis, settings, st

from repro.configs.base import get_config
from repro.core.recipe import RECIPES
from repro.models.ssm import (init_mamba_cache, mamba_mixer, ssd_chunked,
                              ssd_reference)


def _ssd_inputs(b=2, s=64, h=4, p=8, n=16, g=2, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, s, g, n), jnp.float32)
    cm = jax.random.normal(ks[4], (b, s, g, n), jnp.float32)
    return x, dt, a, bm, cm


@pytest.mark.parametrize("chunk", [8, 16, 64])
@pytest.mark.parametrize("unroll", [False, True])
def test_chunked_matches_reference(chunk, unroll):
    x, dt, a, bm, cm = _ssd_inputs()
    y1, s1 = ssd_chunked(x, dt, a, bm, cm, chunk=chunk, unroll=unroll)
    y2, s2 = ssd_reference(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4,
                               atol=1e-4)


def test_initial_state_continuation():
    """SSD over [0:64] == SSD over [0:32] then [32:64] with carried state."""
    x, dt, a, bm, cm = _ssd_inputs(s=64)
    y_full, s_full = ssd_chunked(x, dt, a, bm, cm, chunk=16)
    y1, s1 = ssd_chunked(x[:, :32], dt[:, :32], a, bm[:, :32], cm[:, :32],
                         chunk=16)
    y2, s2 = ssd_chunked(x[:, 32:], dt[:, 32:], a, bm[:, 32:], cm[:, 32:],
                         chunk=16, initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)


@requires_hypothesis
@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_decay_bounded_property(seed):
    """With A<0 and bounded inputs, states stay bounded (stability)."""
    x, dt, a, bm, cm = _ssd_inputs(key=seed)
    _, s1 = ssd_chunked(x, dt, a, bm, cm, chunk=16)
    assert bool(jnp.isfinite(s1).all())
    assert float(jnp.abs(s1).max()) < 1e4


def test_mixer_prefill_then_decode_matches_full():
    cfg = get_config("mamba2-780m")
    import importlib
    cfg = importlib.import_module("repro.configs.mamba2_780m").REDUCED
    cfg = cfg.replace(dtype="float32")
    from repro.models.ssm import mamba_param_specs
    from repro.nn.params import init_params
    params = init_params(jax.random.PRNGKey(0), mamba_param_specs(cfg))
    r = RECIPES["bf16"].ffn_linear
    b, s = 2, 40
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model),
                          jnp.float32) * 0.5
    y_full, _ = mamba_mixer(params, cfg, x, r)
    cache = init_mamba_cache(cfg, b, dtype=jnp.float32)
    y_pre, cache = mamba_mixer(params, cfg, x[:, :32], r, cache=cache)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :32]),
                               rtol=2e-3, atol=2e-3)
    outs = []
    for t in range(32, s):
        y_t, cache = mamba_mixer(params, cfg, x[:, t:t + 1], r, cache=cache,
                                 decode=True)
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, 32:]),
                               rtol=2e-3, atol=2e-3)
