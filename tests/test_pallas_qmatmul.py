"""Fused-kernel training path: ``pallas_qmatmul`` (fwd + dgrad + wgrad)
vs the unfused ``qmatmul`` QDQ reference, across the paper's recipes.

All Pallas calls run in interpret mode on CPU (the ops.py default), so
these are exact-code-path parity tests against ``dot_qdq``: same amax
groups, same RTN grid, only f32 dot accumulation order differs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig, get_config
from repro.core.qlinear import (kernel_quant_mode, matmul_impl,
                                pallas_qmatmul, qmatmul)
from repro.core.quantize import QuantSpec
from repro.core.recipe import (MM_FP4_ALL, MM_FFN_PAPER, MM_FP8,
                               MatmulRecipe, RECIPES)
from repro.kernels.ops import pallas_qmm
from repro.kernels.ref import qmm_ref

KEY0 = jnp.zeros((2,), jnp.uint32)
RECIPE_CASES = [("fp8", MM_FP8), ("fp4_all", MM_FP4_ALL),
                ("ffn_paper", MM_FFN_PAPER)]
SHAPES = [(128, 128, 128), (200, 300, 260), (64, 500, 70)]


def _data(m, k, n, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) * 0.05
    return x, w


def _close(a, b, rtol=1e-5, atol=1e-5):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    scale = max(float(np.abs(b).max()), 1.0)
    np.testing.assert_allclose(a / scale, b / scale, rtol=rtol, atol=atol)


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("rname,recipe", RECIPE_CASES)
def test_forward_parity(rname, recipe, m, k, n):
    x, w = _data(m, k, n)
    _close(pallas_qmatmul(x, w, KEY0, recipe), qmatmul(x, w, KEY0, recipe))


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("rname,recipe", RECIPE_CASES)
def test_gradient_parity(rname, recipe, m, k, n):
    """fwd + dgrad + wgrad parity via jax.grad on a scalar loss.

    The loss is linear in y (sum(y * c)) so both implementations see the
    SAME backward cotangent; a nonlinear loss would feed each its own
    slightly-different y and FP4 rounding-tie flips would dominate.
    """
    x, w = _data(m, k, n, seed=1)
    c = jax.random.normal(jax.random.PRNGKey(2), (m, n), jnp.float32)

    def loss(f):
        return jax.grad(lambda a, b: jnp.sum(f(a, b, KEY0, recipe) * c),
                        argnums=(0, 1))(x, w)

    (dx_p, dw_p), (dx_q, dw_q) = loss(pallas_qmatmul), loss(qmatmul)
    _close(dx_p, dx_q)
    _close(dw_p, dw_q)


@pytest.mark.parametrize("rname,recipe", RECIPE_CASES)
def test_bf16_parity_one_ulp(rname, recipe):
    """In bf16 (the training dtype) the kernel quantizes in the input dtype
    exactly like the qdq path, so fwd/dgrad/wgrad agree to ~1 output ulp
    (dot accumulation order is the only remaining difference)."""
    kx, kw, kc = jax.random.split(jax.random.PRNGKey(8), 3)
    x = jax.random.normal(kx, (200, 260), jnp.float32).astype(jnp.bfloat16)
    w = (jax.random.normal(kw, (260, 140), jnp.float32)
         * 0.05).astype(jnp.bfloat16)
    c = jax.random.normal(kc, (200, 140), jnp.float32).astype(jnp.bfloat16)

    def run(f):
        y, vjp = jax.vjp(lambda a, b: f(a, b, KEY0, recipe), x, w)
        return (y,) + vjp(c)

    for p, q in zip(run(pallas_qmatmul), run(qmatmul)):
        _close(p, q, rtol=2e-2, atol=2e-2)  # 1-2 bf16 ulps, normalized


def test_ffn_paper_dgrad_is_bf16_passthrough():
    """MM_FFN_PAPER: the dgrad role is unquantized — the fused path must
    produce the plain g @ w^T (f32-accumulated), not a quantized one."""
    x, w = _data(128, 256, 128)
    g = jax.random.normal(jax.random.PRNGKey(3), (128, 128), jnp.float32)
    _, vjp = jax.vjp(lambda a, b: pallas_qmatmul(a, b, KEY0, MM_FFN_PAPER),
                     x, w)
    dx, _ = vjp(g)
    _close(dx, g @ w.T)


@pytest.mark.parametrize("trans_a,trans_b", [(False, False), (False, True),
                                             (True, False)])
def test_transposed_operand_variants_match_oracle(trans_a, trans_b):
    """The kernel's in-VMEM transposition quantizes relative to the
    effective (post-transpose) reduction axis — exactly qmm_ref."""
    spec_a = QuantSpec("fp4_e2m1", "block")
    spec_b = QuantSpec("fp8_e5m2", "block")
    ka, kb = jax.random.split(jax.random.PRNGKey(4))
    a = jax.random.normal(ka, (200, 140) if trans_a else (140, 200),
                          jnp.float32)
    b = jax.random.normal(kb, (75, 200) if trans_b else (200, 75),
                          jnp.float32)
    y = pallas_qmm(a, b, spec_a, spec_b,
                   mode_a=kernel_quant_mode(spec_a),
                   mode_b=kernel_quant_mode(spec_b),
                   trans_a=trans_a, trans_b=trans_b)
    _close(y, qmm_ref(a, b, spec_a, spec_b, trans_a=trans_a,
                      trans_b=trans_b))


@pytest.mark.parametrize("gran_a,gran_b", [("token", "token"),
                                           ("tensor", "tile"),
                                           ("block", "token")])
def test_scaled_granularities_match_oracle(gran_a, gran_b):
    """token/tensor amax groups span the whole reduction axis; their scales
    are precomputed and streamed into the kernel."""
    spec_a = QuantSpec("fp8_e4m3", gran_a)
    spec_b = QuantSpec("fp8_e4m3", gran_b)
    a, b = _data(130, 260, 70, seed=5)
    y = pallas_qmm(a, b, spec_a, spec_b,
                   mode_a=kernel_quant_mode(spec_a),
                   mode_b=kernel_quant_mode(spec_b))
    _close(y, qmm_ref(a, b, spec_a, spec_b))


def test_unsupported_spec_falls_back_to_qdq():
    """Stochastic rounding isn't kernel-realizable; that role must fall
    back to dot_qdq (identical results incl. key consumption)."""
    sr = MatmulRecipe(
        fwd_x=QuantSpec("fp4_e2m1", "block", stochastic=True),
        fwd_w=QuantSpec("fp4_e2m1", "tile"))
    assert kernel_quant_mode(sr.fwd_x) is None
    x, w = _data(128, 128, 128, seed=6)
    key = jax.random.key_data(jax.random.PRNGKey(7)).astype(jnp.uint32)
    np.testing.assert_allclose(np.asarray(pallas_qmatmul(x, w, key, sr)),
                               np.asarray(qmatmul(x, w, key, sr)),
                               rtol=1e-6, atol=1e-6)


def test_matmul_impl_registry():
    assert matmul_impl("qdq") is qmatmul
    assert matmul_impl("pallas") is pallas_qmatmul
    with pytest.raises(ValueError):
        matmul_impl("nope")


def test_trainer_one_step_linear_impl_pallas():
    """One optimizer step on the tiny config with every model linear routed
    through the fused kernel (fwd+dgrad+wgrad in interpret mode)."""
    from repro.data import SyntheticLM
    from repro.models import build_model
    from repro.train.trainer import Trainer

    cfg = get_config("tiny").replace(linear_impl="pallas")
    model = build_model(cfg)
    pipe = SyntheticLM(cfg.vocab_size, 32, 2, seed=0)
    tcfg = TrainConfig(recipe="paper_fp4", total_steps=1, global_batch=2,
                       seq_len=32, learning_rate=1e-3, log_every=0)
    tr = Trainer(model, tcfg, pipe)
    st = tr.train()
    assert st.step == 1
    assert np.isfinite(tr.history[-1]["loss"])
    for leaf in jax.tree.leaves(st.params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())
