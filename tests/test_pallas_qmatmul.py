"""Fused-kernel training path: ``pallas_qmatmul`` (fwd + dgrad + wgrad)
vs the unfused ``qmatmul`` QDQ reference, across the paper's recipes.

All Pallas calls run in interpret mode on CPU (the ops.py default), so
these are exact-code-path parity tests against ``dot_qdq``: same amax
groups, same RTN grid, only f32 dot accumulation order differs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig, get_config
from repro.core.qlinear import (kernel_quant_mode, matmul_impl,
                                pallas_qmatmul, qmatmul)
from repro.core.quantize import QuantSpec
from repro.core.recipe import (MM_FP4_ALL, MM_FFN_PAPER, MM_FP8,
                               MatmulRecipe, RECIPES)
from repro.kernels.ops import pallas_qmm
from repro.kernels.ref import qmm_ref

KEY0 = jnp.zeros((2,), jnp.uint32)
RECIPE_CASES = [("fp8", MM_FP8), ("fp4_all", MM_FP4_ALL),
                ("ffn_paper", MM_FFN_PAPER)]
SHAPES = [(128, 128, 128), (200, 300, 260), (64, 500, 70)]


def _data(m, k, n, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) * 0.05
    return x, w


def _close(a, b, rtol=1e-5, atol=1e-5):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    scale = max(float(np.abs(b).max()), 1.0)
    np.testing.assert_allclose(a / scale, b / scale, rtol=rtol, atol=atol)


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("rname,recipe", RECIPE_CASES)
def test_forward_parity(rname, recipe, m, k, n):
    x, w = _data(m, k, n)
    _close(pallas_qmatmul(x, w, KEY0, recipe), qmatmul(x, w, KEY0, recipe))


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("rname,recipe", RECIPE_CASES)
def test_gradient_parity(rname, recipe, m, k, n):
    """fwd + dgrad + wgrad parity via jax.grad on a scalar loss.

    The loss is linear in y (sum(y * c)) so both implementations see the
    SAME backward cotangent; a nonlinear loss would feed each its own
    slightly-different y and FP4 rounding-tie flips would dominate.
    """
    x, w = _data(m, k, n, seed=1)
    c = jax.random.normal(jax.random.PRNGKey(2), (m, n), jnp.float32)

    def loss(f):
        return jax.grad(lambda a, b: jnp.sum(f(a, b, KEY0, recipe) * c),
                        argnums=(0, 1))(x, w)

    (dx_p, dw_p), (dx_q, dw_q) = loss(pallas_qmatmul), loss(qmatmul)
    _close(dx_p, dx_q)
    _close(dw_p, dw_q)


@pytest.mark.parametrize("rname,recipe", RECIPE_CASES)
def test_bf16_parity_one_ulp(rname, recipe):
    """In bf16 (the training dtype) the kernel quantizes in the input dtype
    exactly like the qdq path, so fwd/dgrad/wgrad agree to ~1 output ulp
    (dot accumulation order is the only remaining difference)."""
    kx, kw, kc = jax.random.split(jax.random.PRNGKey(8), 3)
    x = jax.random.normal(kx, (200, 260), jnp.float32).astype(jnp.bfloat16)
    w = (jax.random.normal(kw, (260, 140), jnp.float32)
         * 0.05).astype(jnp.bfloat16)
    c = jax.random.normal(kc, (200, 140), jnp.float32).astype(jnp.bfloat16)

    def run(f):
        y, vjp = jax.vjp(lambda a, b: f(a, b, KEY0, recipe), x, w)
        return (y,) + vjp(c)

    for p, q in zip(run(pallas_qmatmul), run(qmatmul)):
        _close(p, q, rtol=2e-2, atol=2e-2)  # 1-2 bf16 ulps, normalized


def test_ffn_paper_dgrad_is_bf16_passthrough():
    """MM_FFN_PAPER: the dgrad role is unquantized — the fused path must
    produce the plain g @ w^T (f32-accumulated), not a quantized one."""
    x, w = _data(128, 256, 128)
    g = jax.random.normal(jax.random.PRNGKey(3), (128, 128), jnp.float32)
    _, vjp = jax.vjp(lambda a, b: pallas_qmatmul(a, b, KEY0, MM_FFN_PAPER),
                     x, w)
    dx, _ = vjp(g)
    _close(dx, g @ w.T)


@pytest.mark.parametrize("trans_a,trans_b", [(False, False), (False, True),
                                             (True, False)])
def test_transposed_operand_variants_match_oracle(trans_a, trans_b):
    """The kernel's in-VMEM transposition quantizes relative to the
    effective (post-transpose) reduction axis — exactly qmm_ref."""
    spec_a = QuantSpec("fp4_e2m1", "block")
    spec_b = QuantSpec("fp8_e5m2", "block")
    ka, kb = jax.random.split(jax.random.PRNGKey(4))
    a = jax.random.normal(ka, (200, 140) if trans_a else (140, 200),
                          jnp.float32)
    b = jax.random.normal(kb, (75, 200) if trans_b else (200, 75),
                          jnp.float32)
    y = pallas_qmm(a, b, spec_a, spec_b,
                   mode_a=kernel_quant_mode(spec_a),
                   mode_b=kernel_quant_mode(spec_b),
                   trans_a=trans_a, trans_b=trans_b)
    _close(y, qmm_ref(a, b, spec_a, spec_b, trans_a=trans_a,
                      trans_b=trans_b))


@pytest.mark.parametrize("gran_a,gran_b", [("token", "token"),
                                           ("tensor", "tile"),
                                           ("block", "token")])
def test_scaled_granularities_match_oracle(gran_a, gran_b):
    """token/tensor amax groups span the whole reduction axis; the quantize
    pass computes them in-kernel with a two-sweep grid (sweep 0 accumulates
    amax in scratch, sweep 1 quantizes)."""
    spec_a = QuantSpec("fp8_e4m3", gran_a)
    spec_b = QuantSpec("fp8_e4m3", gran_b)
    a, b = _data(130, 260, 70, seed=5)
    y = pallas_qmm(a, b, spec_a, spec_b,
                   mode_a=kernel_quant_mode(spec_a),
                   mode_b=kernel_quant_mode(spec_b))
    _close(y, qmm_ref(a, b, spec_a, spec_b))


def test_unsupported_spec_falls_back_to_qdq():
    """fp16 (clip-only codec) and non-128 blocks aren't kernel-realizable;
    those roles must fall back to dot_qdq (identical results)."""
    assert kernel_quant_mode(QuantSpec("fp16")) is None
    assert kernel_quant_mode(QuantSpec("fp4_e2m1", "block", block=64)) is None
    fb = MatmulRecipe(fwd_x=QuantSpec("fp16"),
                      fwd_w=QuantSpec("fp4_e2m1", "tile"))
    x, w = _data(128, 128, 128, seed=6)
    key = jax.random.key_data(jax.random.PRNGKey(7)).astype(jnp.uint32)
    np.testing.assert_allclose(np.asarray(pallas_qmatmul(x, w, key, fb)),
                               np.asarray(qmatmul(x, w, key, fb)),
                               rtol=1e-6, atol=1e-6)


def test_stochastic_specs_are_kernel_realizable():
    """Since the quantize-once rework, stochastic rounding runs in-kernel:
    kernel_quant_mode no longer disqualifies SR specs."""
    assert kernel_quant_mode(
        QuantSpec("fp4_e2m1", "block", stochastic=True)) == "block"
    assert kernel_quant_mode(
        QuantSpec("fp8_e5m2", "token", stochastic=True)) == "token"


def test_full_fp4_recipe_zero_qdq_fallbacks():
    """The full-FP4 recipe (stochastic wgrad_g) must run ALL THREE roles
    through the Pallas path: every operand spec is kernel-realizable."""
    recipe = RECIPES["fine_grained_fp4"].ffn_linear
    for slot in ("fwd_x", "fwd_w", "dgrad_g", "dgrad_w",
                 "wgrad_x", "wgrad_g"):
        assert kernel_quant_mode(getattr(recipe, slot)) is not None, slot
    assert recipe.wgrad_g.stochastic  # the role that used to fall back
    # And the whole fwd+bwd actually executes through the kernel pipeline.
    x, w = _data(128, 256, 128, seed=9)
    key = jax.random.key_data(jax.random.PRNGKey(11)).astype(jnp.uint32)
    y, vjp = jax.vjp(lambda a, b: pallas_qmatmul(a, b, key, recipe), x, w)
    dx, dw = vjp(jnp.ones_like(y))
    for t in (y, dx, dw):
        assert bool(jnp.isfinite(t).all())


def test_in_kernel_sr_mean_unbiased_vs_qdq_reference():
    """In-kernel stochastic rounding (counter-hash noise) must be mean-
    unbiased like the QDQ SR reference: averaging Q_sr(x) over seeds
    converges to x, and the two means agree within sampling error."""
    spec = QuantSpec("fp4_e2m1", "block", stochastic=True)
    recipe = MatmulRecipe(fwd_x=spec, fwd_w=QuantSpec("bf16"))
    x = jax.random.uniform(jax.random.PRNGKey(3), (128, 128), jnp.float32,
                           0.05, 4.0)
    w = jnp.eye(128, dtype=jnp.float32)  # y = Q_sr(x) @ I isolates Q_sr(x)
    n = 48
    acc_k = jnp.zeros_like(x)
    acc_q = jnp.zeros_like(x)
    for s in range(n):
        key = jax.random.key_data(
            jax.random.PRNGKey(1000 + s)).astype(jnp.uint32)
        acc_k = acc_k + pallas_qmatmul(x, w, key, recipe)
        acc_q = acc_q + qmatmul(x, w, key, recipe)
    mean_k, mean_q = np.asarray(acc_k) / n, np.asarray(acc_q) / n
    xs = np.asarray(x)
    # Per-element grid step bound: scale * 2 (top-binade step of E2M1 on a
    # per-row amax scale <= 4/6); CLT tolerance ~ step * 4 / sqrt(12 n).
    step = np.abs(xs).max(1, keepdims=True) / 6.0 * 2.0
    tol = step * 4.0 / np.sqrt(12.0 * n) + 1e-3
    assert np.abs(mean_k - xs).mean() < np.abs(step).mean() * 0.2
    assert (np.abs(mean_k - mean_q) < 2 * tol).mean() > 0.99
    # global bias averages out across 16k elements
    assert abs((mean_k - xs).mean()) < 5e-3


@pytest.mark.parametrize("trans_a,trans_b", [(False, True), (True, False)])
def test_bf16_transposed_fused_roles_parity(trans_a, trans_b):
    """bf16-dtype parity for the trans_a/trans_b fused roles (the dgrad /
    wgrad read patterns) vs qmm_ref — the quantize pass is bit-exact in
    bf16 too (ties included), so only dot accumulation order differs."""
    spec_a = QuantSpec("fp4_e2m1", "block")
    spec_b = QuantSpec("fp8_e5m2", "block")
    ka, kb = jax.random.split(jax.random.PRNGKey(13))
    a = jax.random.normal(ka, (200, 140) if trans_a else (140, 200),
                          jnp.float32).astype(jnp.bfloat16)
    b = jax.random.normal(kb, (75, 200) if trans_b else (200, 75),
                          jnp.float32).astype(jnp.bfloat16)
    y = pallas_qmm(a, b, spec_a, spec_b,
                   mode_a=kernel_quant_mode(spec_a),
                   mode_b=kernel_quant_mode(spec_b),
                   trans_a=trans_a, trans_b=trans_b)
    ref = qmm_ref(a, b, spec_a, spec_b, trans_a=trans_a, trans_b=trans_b)
    _close(y, ref, rtol=1e-2, atol=1e-2)  # ~1 bf16 output ulp


def test_quantize_pass_bit_exact_vs_oracle():
    """Phase 1 standalone (quantize_panels) is BIT-exact vs the shared-codec
    oracle in f32 and bf16 — RTN, and SR with the kernel's coordinate-keyed
    noise reconstructed outside (tiling-invariant, so the oracle needs no
    knowledge of panel sizes)."""
    from repro.kernels.fp4_matmul import quantize_panels
    from repro.kernels.ref import quantize_panels_ref
    from repro.kernels.rounding import hash_uniform
    x = jax.random.normal(jax.random.PRNGKey(21), (256, 384), jnp.float32)
    for dtype in (jnp.float32, jnp.bfloat16):
        xd = x.astype(dtype)
        for mode in ("block", "tile", "token", "tensor"):
            got = np.asarray(quantize_panels(
                xd, mode=mode, fmt_name="fp4_e2m1").astype(jnp.float32))
            ref = np.asarray(quantize_panels_ref(
                xd, QuantSpec("fp4_e2m1", mode)).astype(jnp.float32))
            np.testing.assert_array_equal(got, ref, err_msg=f"{dtype}/{mode}")
    # SR: same seed -> kernel noise is hash(seed, global coord), so the
    # oracle reproduces it bit-exactly with one full-array hash call.
    seed = jnp.asarray([1234], jnp.int32)
    got = np.asarray(quantize_panels(x, mode="block", fmt_name="fp4_e2m1",
                                     sr=True, seed=seed))
    noise = hash_uniform(x.shape, seed[0], 0, 0)
    ref = np.asarray(quantize_panels_ref(x, QuantSpec("fp4_e2m1", "block"),
                                         noise=noise))
    np.testing.assert_array_equal(got, ref)
    # transposed read (the wgrad x^T pattern): noise keys on the EFFECTIVE
    # orientation, so the oracle still reconstructs it exactly.
    gotT = np.asarray(quantize_panels(x.T, mode="block", sr=True, seed=seed,
                                      fmt_name="fp4_e2m1", trans=True))
    refT = np.asarray(quantize_panels_ref(x.T, QuantSpec("fp4_e2m1", "block"),
                                          trans=True, noise=noise))
    np.testing.assert_array_equal(gotT, refT)


def test_decoupled_mxu_tiling_matches_quant_grid():
    """The matmul pass tiling (bm, bn, bk) is independent of the 128-wide
    quant group: different tilings give the same result (quantization
    happened once, before tiling)."""
    from repro.kernels.fp4_matmul import fused_qmm
    x, w = _data(256, 512, 256, seed=14)
    outs = []
    for bm, bn, bk in [(128, 128, 128), (256, 256, 512), (256, 128, 256)]:
        outs.append(np.asarray(fused_qmm(
            x, w, a_mode="block", b_mode="tile", bm=bm, bn=bn, bk=bk,
            interpret=True)))
    spec_a, spec_b = QuantSpec("fp4_e2m1", "block"), QuantSpec("fp4_e2m1",
                                                               "tile")
    for o in outs:
        _close(o, qmm_ref(x, w, spec_a, spec_b))
    # same quantized operands -> only f32 dot order differs between tilings
    _close(outs[0], outs[1], rtol=1e-6, atol=1e-6)


def test_pallas_qmatmul_stats_bit_identical_y():
    """The telemetry-epilogue variant returns the same y and sensible
    finalized stats (matching a full-population operand_stats run)."""
    from repro.core.qlinear import pallas_qmatmul_stats
    from repro.kernels.fp4_matmul import finalize_quant_stats
    from repro.telemetry.collect import operand_stats
    x, w = _data(128, 256, 128, seed=15)
    recipe = MM_FFN_PAPER
    y0 = pallas_qmatmul(x, w, KEY0, recipe)
    y1, (sx, sw) = pallas_qmatmul_stats(x, w, KEY0, recipe)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    fx = {k: float(v) for k, v in finalize_quant_stats(sx).items()}
    # 128 rows -> no subsampling in operand_stats: exact same population
    ref = {k: float(v)
           for k, v in operand_stats(x, recipe.fwd_x, 1).items()}
    for k in ("clip", "underflow", "rel_err", "scale_spread"):
        np.testing.assert_allclose(fx[k], ref[k], rtol=1e-5, atol=1e-6)
    assert sw is not None
    # gradient flows exactly like the stats-free variant
    g = jax.grad(lambda a, b: jnp.sum(
        pallas_qmatmul_stats(a, b, KEY0, recipe)[0]), argnums=(0, 1))(x, w)
    g0 = jax.grad(lambda a, b: jnp.sum(
        pallas_qmatmul(a, b, KEY0, recipe)), argnums=(0, 1))(x, w)
    for a, b in zip(g, g0):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_matmul_impl_registry():
    assert matmul_impl("qdq") is qmatmul
    assert matmul_impl("pallas") is pallas_qmatmul
    with pytest.raises(ValueError):
        matmul_impl("nope")


def test_trainer_one_step_linear_impl_pallas():
    """One optimizer step on the tiny config with every model linear routed
    through the fused kernel (fwd+dgrad+wgrad in interpret mode)."""
    from repro.data import SyntheticLM
    from repro.models import build_model
    from repro.train.trainer import Trainer

    cfg = get_config("tiny").replace(linear_impl="pallas")
    model = build_model(cfg)
    pipe = SyntheticLM(cfg.vocab_size, 32, 2, seed=0)
    tcfg = TrainConfig(recipe="paper_fp4", total_steps=1, global_batch=2,
                       seq_len=32, learning_rate=1e-3, log_every=0)
    tr = Trainer(model, tcfg, pipe)
    st = tr.train()
    assert st.step == 1
    assert np.isfinite(tr.history[-1]["loss"])
    for leaf in jax.tree.leaves(st.params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


def test_trainer_pallas_with_telemetry_epilogue():
    """telemetry=True + linear_impl=pallas: the fwd_x/fwd_w stats come from
    the quantize pass's in-kernel epilogue (pallas_qmatmul_stats) inside
    the scanned, jitted train step — metrics present and finite."""
    from repro.data import SyntheticLM
    from repro.models import build_model
    from repro.train.trainer import Trainer

    cfg = get_config("tiny").replace(linear_impl="pallas")
    model = build_model(cfg)
    pipe = SyntheticLM(cfg.vocab_size, 32, 2, seed=0)
    tcfg = TrainConfig(recipe="paper_fp4", total_steps=1, global_batch=2,
                       seq_len=32, learning_rate=1e-3, log_every=0,
                       telemetry=True)
    tr = Trainer(model, tcfg, pipe)
    tr.train()
    row = tr.history[-1]
    keys = [k for k in row if "/fwd_x/" in k or "/fwd_w/" in k]
    assert keys, sorted(row)
    for k in keys:
        assert np.isfinite(row[k]), (k, row[k])
    assert any(row[k] > 0 for k in keys if k.endswith("rel_err"))
