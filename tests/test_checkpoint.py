"""Checkpointing: atomicity, retention, resume exactness, corruption."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.configs.base import TrainConfig, get_config
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train.trainer import Trainer


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (16, 8)),
            "b": {"c": jnp.arange(5), "d": jnp.float32(3.5)},
            "e": [jnp.ones((2, 2)), jnp.zeros((3,))]}


def test_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"), extra={"step": 7})
    out = load_pytree(str(tmp_path / "ck"), t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shape_mismatch_rejected(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    bad = dict(t, a=jnp.zeros((4, 4)))
    with pytest.raises(ValueError):
        load_pytree(str(tmp_path / "ck"), bad)


def test_retention_keeps_newest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_corrupt_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _tree(1))
    # simulate a crash mid-save: directory without manifest
    os.makedirs(tmp_path / "step_00000002")
    assert mgr.latest_step() == 1
    restored, extra = mgr.restore(_tree(0))
    assert extra["step"] == 1


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    mgr.save(1, _tree(1))
    mgr.wait()
    assert mgr.latest_step() == 1


def test_resume_is_bit_exact(tmp_path):
    cfg = get_config("tiny")
    model = build_model(cfg)
    pipe = SyntheticLM(cfg.vocab_size, 32, 4, seed=0)
    tcfg = TrainConfig(recipe="bf16", total_steps=12, global_batch=4,
                       seq_len=32, learning_rate=1e-3, checkpoint_every=4,
                       checkpoint_dir=str(tmp_path / "A"), log_every=0)
    # interrupted at step 8, resumed
    Trainer(model, tcfg, pipe).train(num_steps=8)
    stB = Trainer(model, tcfg, pipe).train()
    # uninterrupted control
    tcfgC = dataclasses.replace(tcfg, checkpoint_dir=str(tmp_path / "C"))
    stC = Trainer(model, tcfgC, pipe).train()
    for a, b in zip(jax.tree.leaves(stB.params), jax.tree.leaves(stC.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert stB.step == stC.step == 12


def test_elastic_reshard_roundtrip():
    """reshard() re-places arrays; values unchanged (1-device mesh)."""
    from repro.distributed.elastic import choose_mesh_shape, reshard
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    t = _tree()
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), t)
    out = reshard(t, sh)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert choose_mesh_shape(512) == (32, 16)
    assert choose_mesh_shape(384) == (24, 16)
    assert choose_mesh_shape(100) == (25, 4)
    assert choose_mesh_shape(7) == (7, 1)
