"""Layer-resolved precision plans: resolution goldens + end-to-end.

Covers the PR-4 acceptance criteria:
  * a uniform plan reproduces the recipe-threaded graph bit-identically
    (jaxpr AND lowered StableHLO, scan and unroll modes), with a single
    stack scan;
  * scan-run partitioning groups correctly for first/last-K presets
    (period 1 and period > 1);
  * a depth-graded plan trains end-to-end under scan_layers=True with
    per-layer controller demotion of a single layer, and checkpoint resume
    across the demotion boundary is bit-exact;
  * string/dict serialization round-trips.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ControllerSettings, TrainConfig, get_config
from repro.core.quantize import QuantSpec
from repro.core.recipe import (MM_BF16, MM_FP8, RECIPES, LayerRecipe,
                               PrecisionPlan, as_plan)
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train.train_step import make_optimizer, make_train_step
from repro.train.trainer import Trainer


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_config("tiny")


def _batch(cfg, seq=64, batch=4, seed=0):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (batch, seq), 0,
                              cfg.vocab_size)
    return {"tokens": toks, "targets": toks}


# ---------------------------------------------------------------------------
# Serialization
# ---------------------------------------------------------------------------

def test_quantspec_str_roundtrip_all_registry_specs():
    """Every spec reachable from the recipe registry survives the compact
    string syntax."""
    seen = set()
    for r in RECIPES.values():
        for mm in (r.attn_linear, r.ffn_linear, r.head_linear):
            for role in ("fwd_x", "fwd_w", "dgrad_g", "dgrad_w",
                         "wgrad_x", "wgrad_g"):
                seen.add(getattr(mm, role))
    assert len(seen) > 5
    for spec in seen:
        s = spec.to_str()
        back = QuantSpec.from_str(s)
        assert back == spec or (back.is_passthrough and spec.is_passthrough
                                and back.fmt == spec.fmt), (spec, s, back)


def test_quantspec_str_examples():
    assert QuantSpec.from_str("fp4_e2m1@block128") == QuantSpec(
        "fp4_e2m1", "block", 128)
    assert QuantSpec.from_str("fp8_e5m2@token") == QuantSpec(
        "fp8_e5m2", "token")
    assert QuantSpec.from_str("fp4_e2m1@block128:sr") == QuantSpec(
        "fp4_e2m1", "block", 128, stochastic=True)
    assert QuantSpec.from_str("bf16").is_passthrough
    with pytest.raises(ValueError):
        QuantSpec.from_str("fp3_x@block128")
    with pytest.raises(ValueError):
        QuantSpec.from_str("fp4_e2m1@widget")
    with pytest.raises(ValueError):
        QuantSpec.from_str("bf16:maybe")


def test_plan_dict_roundtrip_json():
    plan = PrecisionPlan.first_last_k(RECIPES["paper_fp4"], 6, k=2)
    plan = plan.promote("ffn", layer=3)
    d = json.loads(json.dumps(plan.to_dict()))
    back = PrecisionPlan.from_dict(d)
    assert back == plan
    # row table is deduplicated: the promoted l03 row coincides with the
    # FP8-protected boundary row, so only 2 distinct rows for 6 layers
    assert len(d["rows"]) == 2 and len(d["layers"]) == 6


# ---------------------------------------------------------------------------
# Resolution / partitioning
# ---------------------------------------------------------------------------

def test_plan_demote_wgrad_role_subset():
    """The asymmetric role-subset demotion (ISSUE 5): only the named role
    subsets move, only already-quantized operands are lowered, FP4
    gradient operands gain SR, and dgrad is untouchable by default."""
    base = PrecisionPlan.uniform(RECIPES["fp8"], 4)
    p = base.demote("ffn", layer=1)
    mm = p.layers[1].ffn_linear
    assert mm.wgrad_x.fmt == "fp4_e2m1" and not mm.wgrad_x.stochastic
    assert mm.wgrad_g.fmt == "fp4_e2m1" and mm.wgrad_g.stochastic
    assert mm.wgrad_g.granularity == MM_FP8.wgrad_g.granularity
    assert mm.fwd_x == MM_FP8.fwd_x and mm.dgrad_g == MM_FP8.dgrad_g
    assert p.layers[0] == base.layers[0]
    assert p.name.endswith("l01.ffn.wgrad=fp4")
    # no-ops: an all-FP4 cell has nothing lower; a passthrough (BF16)
    # dgrad subset never becomes quantized; explicit head demote works
    all4 = PrecisionPlan.uniform(RECIPES["all_fp4"], 4)
    assert all4.demote("ffn", layer=0) is all4
    paper = PrecisionPlan.uniform(RECIPES["paper_fp4"], 4)
    assert paper.demote("ffn", layer=0, roles=("dgrad",)) is paper
    assert base.demote("head") is base  # BF16 head: nothing quantized
    with pytest.raises(ValueError, match="role subsets"):
        base.demote("ffn", roles=("bogus",))
    # serialization of the demoted specs round-trips (checkpoint form)
    assert PrecisionPlan.from_dict(json.loads(json.dumps(p.to_dict()))) == p
    # whole-class demotion edits every row
    allp = base.demote("ffn")
    assert all(r.ffn_linear.wgrad_g.fmt == "fp4_e2m1" for r in allp.layers)


def test_scan_runs_uniform_single_run():
    plan = PrecisionPlan.uniform(RECIPES["paper_fp4"], 12)
    assert plan.scan_runs(1) == [(0, 12)]
    assert plan.scan_runs(3) == [(0, 4)]
    assert plan.is_uniform


def test_scan_runs_first_last_k():
    plan = PrecisionPlan.first_last_k(RECIPES["paper_fp4"], 12, k=2)
    assert plan.scan_runs(1) == [(0, 2), (2, 10), (10, 12)]
    # period 2: groups of 2 layers; boundary groups differ from the middle
    assert plan.scan_runs(2) == [(0, 1), (1, 5), (5, 6)]
    # period 3: k=2 splits the first/last group off (mixed signature)
    assert plan.scan_runs(3) == [(0, 1), (1, 3), (3, 4)]
    # protected rows: quantized roles raised to FP8, but the paper's BF16
    # dgrad path stays UNquantized (protection must never lower precision)
    prot = plan.layers[0].ffn_linear
    assert prot.fwd_x == MM_FP8.fwd_x and prot.wgrad_g == MM_FP8.wgrad_g
    assert prot.dgrad_g.is_passthrough and prot.dgrad_w.is_passthrough
    assert plan.layers[5].ffn_linear == RECIPES["paper_fp4"].ffn_linear
    assert plan.layers[11].attn_linear == RECIPES["paper_fp4"].attn_linear


def test_first_last_k_never_demotes_bf16():
    plan = PrecisionPlan.first_last_k(RECIPES["bf16"], 4, k=1)
    assert all(r.ffn_linear == MM_BF16 for r in plan.layers)


def test_ramp_preset():
    plan = PrecisionPlan.ramp(RECIPES["paper_fp4"], 8, frac=0.5)
    base = RECIPES["paper_fp4"]
    # rung 0: protected FP8 (quantized roles only; BF16 dgrad stays)
    assert plan.layers[0].ffn_linear.fwd_x == MM_FP8.fwd_x
    assert plan.layers[0].ffn_linear.dgrad_g.is_passthrough
    # last rung: the recipe itself; tail beyond the ramp too
    assert plan.layers[3] == LayerRecipe(base.attn_linear, base.ffn_linear)
    assert plan.layers[7] == plan.layers[3]
    # middle rung: FP4 forward, FP8 backward
    mid = plan.layers[2].ffn_linear
    assert mid.fwd_x == base.ffn_linear.fwd_x
    assert mid.wgrad_x == MM_FP8.wgrad_x
    # monotone: runs are contiguous
    assert plan.scan_runs(1) == [(0, 2), (2, 3), (3, 8)]


def test_plan_resize():
    plan = PrecisionPlan.first_last_k(RECIPES["paper_fp4"], 8, k=2)
    small = plan.resize(4)
    assert small.n_layers == 4
    assert small.layers[0] == plan.layers[0]       # protected ends survive
    assert small.layers[3] == plan.layers[7]
    assert plan.resize(8) is plan
    uni = PrecisionPlan.uniform(RECIPES["fp8"], 6).resize(3)
    assert uni.is_uniform and uni.n_layers == 3


def test_as_plan_coercion_and_depth_check():
    p = as_plan(RECIPES["paper_fp4"], 5)
    assert isinstance(p, PrecisionPlan) and p.n_layers == 5
    assert as_plan(p, 5) is p
    with pytest.raises(ValueError):
        as_plan(p, 6)


# ---------------------------------------------------------------------------
# Golden: uniform plan == recipe graph, bit-identically
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scan_layers", [True, False],
                         ids=["scan", "unroll"])
def test_uniform_plan_graph_bit_identical(tiny_cfg, scan_layers):
    """The recipe-threaded entry (pre-plan API) and an explicit uniform
    plan must trace to the identical jaxpr AND lower to identical
    StableHLO — the plan refactor cannot perturb the uniform graph."""
    cfg = tiny_cfg.replace(scan_layers=scan_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    recipe = RECIPES["paper_fp4"]
    plan = PrecisionPlan.uniform(recipe, cfg.n_layers)

    def mk_loss(spec):
        def loss(p, b):
            return model.loss(p, b, spec)[0]
        return loss

    loss_recipe, loss_plan = mk_loss(recipe), mk_loss(plan)

    import re

    def jaxpr_str(fn):
        # strip memory addresses from embedded function reprs (trace-run
        # artifacts, not graph structure)
        return re.sub(r"0x[0-9a-f]+", "0x", str(jax.make_jaxpr(fn)(
            params, batch)))

    assert jaxpr_str(loss_recipe) == jaxpr_str(loss_plan)
    hlo_r = jax.jit(loss_recipe).lower(params, batch).as_text()
    hlo_p = jax.jit(loss_plan).lower(params, batch).as_text()
    assert hlo_r == hlo_p


def test_graded_plan_splits_scan_uniform_does_not(tiny_cfg):
    """Under scan mode a uniform plan keeps the single stack scan; a
    first/last-K plan adds exactly the partition's extra scans."""
    cfg = tiny_cfg.replace(n_layers=4, scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def n_scans(plan):
        jx = jax.make_jaxpr(
            lambda p, b: model.loss(p, b, plan)[0])(params, batch)
        return str(jx).count("scan[")

    uni = PrecisionPlan.uniform(RECIPES["paper_fp4"], 4)
    graded = PrecisionPlan.first_last_k(RECIPES["paper_fp4"], 4, k=1)
    assert graded.scan_runs(1) == [(0, 1), (1, 3), (3, 4)]
    assert n_scans(graded) > n_scans(uni)  # partition adds stack scans


def test_uniform_plan_train_step_bit_identical(tiny_cfg):
    """make_train_step(recipe) and make_train_step(uniform plan) evolve
    params bit-identically."""
    cfg = tiny_cfg
    model = build_model(cfg)
    pipe = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    params = model.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(recipe="paper_fp4", total_steps=10, global_batch=8,
                       seq_len=64)
    outs = {}
    for tag, spec in (("recipe", RECIPES["paper_fp4"]),
                      ("plan", PrecisionPlan.uniform(RECIPES["paper_fp4"],
                                                     cfg.n_layers))):
        step = make_train_step(model, tcfg, spec, jit=True, donate=False)
        opt_state = make_optimizer(model, tcfg).init(params)
        p, o, c, m = step(params, opt_state, jnp.zeros((), jnp.float32),
                          batch, jnp.asarray(0, jnp.int32))
        p, o, c, m = step(p, o, c, batch, jnp.asarray(1, jnp.int32))
        outs[tag] = p
    for a, b in zip(jax.tree.leaves(outs["recipe"]),
                    jax.tree.leaves(outs["plan"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_graded_plan_scan_matches_unroll(tiny_cfg):
    """Per-layer plan resolution agrees between the two stacking modes:
    the scan-run partition must place exactly the same row on exactly the
    same layer as the direct unroll indexing."""
    cfg_s = tiny_cfg.replace(n_layers=4, scan_layers=True, dtype="float32")
    cfg_u = cfg_s.replace(scan_layers=False)
    model_s, model_u = build_model(cfg_s), build_model(cfg_u)
    plan = PrecisionPlan.first_last_k(RECIPES["paper_fp4"], 4, k=1)
    params_s = model_s.init(jax.random.PRNGKey(0))
    # re-index scan params (groups stacked on a leading dim) as unroll
    # params (list of per-layer trees); period is 1 for the dense config
    group = params_s["stack"]["groups"]["l00"]
    layers = [jax.tree.map(lambda a, i=i: a[i], group) for i in range(4)]
    params_u = dict(params_s, stack={"layers": layers})
    batch = _batch(cfg_s)
    # rtol 1e-4: scan and unroll lower to differently-fused XLA graphs, and
    # FP4 QDQ amplifies the resulting f32 reassociation noise (~2e-5 rel
    # observed); plan-row misalignment would show up ~100x larger (below)
    loss_s, _ = model_s.loss(params_s, batch, plan)
    loss_u, _ = model_u.loss(params_u, batch, plan)
    np.testing.assert_allclose(np.asarray(loss_s), np.asarray(loss_u),
                               rtol=1e-4)
    # swapping the plan row of one middle layer changes the loss by the
    # SAME amount in both stacking modes — the row lands on that specific
    # layer (an off-by-one between modes would give disagreeing deltas)
    plan2 = plan.promote("ffn", layer=2)
    loss_s2, _ = model_s.loss(params_s, batch, plan2)
    loss_u2, _ = model_u.loss(params_u, batch, plan2)
    np.testing.assert_allclose(np.asarray(loss_s2), np.asarray(loss_u2),
                               rtol=1e-4)
    d_s = float(loss_s2) - float(loss_s)
    d_u = float(loss_u2) - float(loss_u)
    assert abs(d_s) > 1e-3                     # the cell edit is visible
    np.testing.assert_allclose(d_s, d_u, rtol=0.1)
    # a different layer's cell produces a distinguishably different delta
    plan3 = plan.promote("ffn", layer=1)
    d_s3 = float(model_s.loss(params_s, batch, plan3)[0]) - float(loss_s)
    d_u3 = float(model_u.loss(params_u, batch, plan3)[0]) - float(loss_u)
    np.testing.assert_allclose(d_s3, d_u3, rtol=0.1)
    assert abs(d_s3 - d_s) > 1e-3


# ---------------------------------------------------------------------------
# End-to-end: depth-graded training + per-layer demotion + bit-exact resume
# ---------------------------------------------------------------------------

def _mk_trainer(cfg, ckdir, total=30):
    tcfg = TrainConfig(recipe="paper_fp4", plan_preset="first_last_k",
                       plan_k=1, total_steps=total, global_batch=8,
                       seq_len=64, learning_rate=3e-3, log_every=0,
                       checkpoint_every=5, checkpoint_dir=str(ckdir),
                       telemetry=True,
                       controller=ControllerSettings(
                           demote_overflow_threshold=0.2,
                           demote_patience=2))
    model = build_model(cfg)
    return Trainer(model, tcfg, SyntheticLM(cfg.vocab_size, 64, 8, seed=0))


def _force_demotion(tr, step):
    """Drive the controller's per-layer rule with a synthetic overflow
    storm on layer 1's ffn (patience 2 -> latches on the second row)."""
    storm = {"loss": 1.0, "tel/l01/ffn/mm0/wgrad_x/clip": 0.9,
             "tel/bwd/l01/ffn/wgrad_g/clip": 0.9}
    events = tr.controller.observe(step, storm)
    events += tr.controller.observe(step, storm)
    assert [e["event"] for e in events] == ["demote"]
    assert events[0]["cell"] == "l01/ffn"


def test_depth_graded_demotion_resume_bit_exact(tiny_cfg, tmp_path):
    """Acceptance: first/last-1 FP8 plan on a 4-layer scan-mode model,
    controller demotes one middle layer's ffn cell mid-run, a checkpoint
    straddles the demotion boundary, and a fresh-process resume continues
    bit-exactly vs. the uninterrupted run."""
    cfg = tiny_cfg.replace(n_layers=4, scan_layers=True)

    # uninterrupted reference: 30 steps, demotion latched after step 9
    ref = _mk_trainer(cfg, tmp_path / "ref")
    state = ref.train(num_steps=10)
    _force_demotion(ref, 9)
    ref_final = ref.train(state)
    assert ref.history[9]["recipe"] == "paper_fp4+fl1"
    assert ref.history[10]["recipe"] == "paper_fp4+fl1+l01.ffn=fp8"
    demoted_plan = ref._active_plan(10)
    dem = demoted_plan.layers[1].ffn_linear
    assert dem.fwd_x == MM_FP8.fwd_x             # quantized roles -> FP8
    assert dem.dgrad_g.is_passthrough            # BF16 dgrad stays BF16
    assert demoted_plan.layers[2].ffn_linear == \
        RECIPES["paper_fp4"].ffn_linear          # only l01 demoted
    # the demoted row equals the FP8-protected boundary row (paper_fp4's
    # attn cell is already FP8), so it merges into the leading run
    assert demoted_plan.scan_runs(1) == [(0, 2), (2, 3), (3, 4)]

    # interrupted run: same prefix, stop at 20 (checkpoints at 15, 20
    # carry the demoted controller state), resume in a fresh Trainer
    trb = _mk_trainer(cfg, tmp_path / "b")
    state = trb.train(num_steps=10)
    _force_demotion(trb, 9)
    trb.train(state, num_steps=10)               # stops at step 20

    trc = _mk_trainer(cfg, tmp_path / "b")       # fresh process stand-in
    resumed = trc.resume()
    assert resumed is not None and resumed.step == 20
    assert trc.controller.demoted == ["l01/ffn"]
    assert trc._active_plan(20).name == "paper_fp4+fl1+l01.ffn=fp8"
    final = trc.train(resumed)

    for a, b in zip(jax.tree.leaves(ref_final.params),
                    jax.tree.leaves(final.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # the checkpoint extra records the active plan table: the step-20
    # checkpoint (stage 1) carries the demoted plan, while the final one
    # (past the §3.3 switch at step 28) records the bf16 stage-2 plan
    from repro.checkpoint.manager import load_manifest
    import os
    steps = sorted(os.listdir(tmp_path / "b"))
    stage1 = PrecisionPlan.from_dict(
        load_manifest(str(tmp_path / "b" / steps[0]))["extra"]["plan"])
    assert stage1.layers[1].ffn_linear.fwd_x == MM_FP8.fwd_x
    assert stage1.name == "paper_fp4+fl1+l01.ffn=fp8"
    stage2 = PrecisionPlan.from_dict(
        load_manifest(str(tmp_path / "b" / steps[-1]))["extra"]["plan"])
    assert stage2.name == "bf16" and stage2.is_passthrough


def test_trainer_builds_depth_graded_plan_from_config(tiny_cfg):
    cfg = tiny_cfg.replace(n_layers=4)
    model = build_model(cfg)
    tcfg = TrainConfig(recipe="paper_fp4", plan_preset="first_last_k",
                       plan_k=1, total_steps=10)
    tr = Trainer(model, tcfg, SyntheticLM(cfg.vocab_size, 64, 8, seed=0))
    assert tr.plan.name == "paper_fp4+fl1"
    assert tr.plan.scan_runs(1) == [(0, 1), (1, 3), (3, 4)]
    tcfg2 = TrainConfig(recipe="paper_fp4", plan_preset="ramp",
                        plan_frac=0.5, total_steps=10)
    tr2 = Trainer(model, tcfg2, SyntheticLM(cfg.vocab_size, 64, 8, seed=0))
    assert tr2.plan.name == "paper_fp4+ramp0.5"
    with pytest.raises(ValueError):
        Trainer(model, TrainConfig(plan_preset="nope"), None)
