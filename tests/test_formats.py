"""Format-grid and rounding tests (+ hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Optional-hypothesis shim lives in conftest: real @given when
# installed, skip-marked no-ops otherwise.
from conftest import given, requires_hypothesis, settings, st

from repro.core.formats import (FORMATS, FP4_E2M1, FP8_E4M3,
                                format_values, round_to_format)

LOWBIT = ["fp4_e2m1", "fp4_e1m2", "fp6_e2m3", "fp6_e3m2", "fp8_e4m3",
          "fp8_e5m2"]


def test_e2m1_grid():
    vals = np.asarray(format_values(FP4_E2M1))
    np.testing.assert_array_equal(vals, [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0,
                                         6.0])


def test_e4m3_max_and_grid_size():
    vals = np.asarray(format_values(FP8_E4M3))
    assert vals.max() == 448.0
    # 2^7 non-negative codes minus reserved NaN pattern (we model max=448
    # by construction); grid must be strictly increasing
    assert np.all(np.diff(vals) > 0)


@pytest.mark.parametrize("name", LOWBIT)
def test_representables_are_fixed_points(name):
    fmt = FORMATS[name]
    vals = format_values(fmt)
    both = jnp.concatenate([vals, -vals])
    out = round_to_format(both, fmt)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(both))


@pytest.mark.parametrize("name", LOWBIT)
def test_rounding_lands_on_grid(name):
    fmt = FORMATS[name]
    vals = np.asarray(format_values(fmt))
    x = jnp.asarray(np.random.default_rng(0).uniform(
        -2 * fmt.max_value, 2 * fmt.max_value, size=4096), jnp.float32)
    y = np.asarray(round_to_format(x, fmt))
    grid = np.concatenate([vals, -vals])
    dist = np.min(np.abs(y[:, None] - grid[None, :]), axis=1)
    assert dist.max() == 0.0


@pytest.mark.parametrize("name", LOWBIT)
def test_round_to_nearest(name):
    """|x - rtn(x)| must be <= distance to every grid point."""
    fmt = FORMATS[name]
    vals = np.asarray(format_values(fmt))
    grid = np.sort(np.concatenate([vals, -vals]))
    x = np.random.default_rng(1).uniform(-fmt.max_value, fmt.max_value,
                                         size=2048).astype(np.float32)
    y = np.asarray(round_to_format(jnp.asarray(x), fmt))
    best = np.min(np.abs(x[:, None] - grid[None, :]), axis=1)
    np.testing.assert_allclose(np.abs(x - y), best, rtol=0, atol=1e-6)


def test_clipping_saturates():
    x = jnp.asarray([1e9, -1e9, 7.0, -6.1])
    y = np.asarray(round_to_format(x, FP4_E2M1))
    np.testing.assert_array_equal(y, [6.0, -6.0, 6.0, -6.0])


@requires_hypothesis
@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
                min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_monotonicity_property(xs):
    """RTN is monotone non-decreasing."""
    x = jnp.asarray(sorted(xs), jnp.float32)
    y = np.asarray(round_to_format(x, FP8_E4M3))
    assert np.all(np.diff(y) >= 0)


@requires_hypothesis
@given(st.floats(0.01, 5.9, allow_nan=False))
@settings(max_examples=30, deadline=None)
def test_sign_symmetry_property(v):
    fmt = FP4_E2M1
    a = float(round_to_format(jnp.float32(v), fmt))
    b = float(round_to_format(jnp.float32(-v), fmt))
    assert a == -b


def test_stochastic_rounding_unbiased():
    fmt = FP4_E2M1
    x = jnp.full((20000,), 1.25, jnp.float32)  # midpoint of [1.0, 1.5]
    key = jax.random.PRNGKey(0)
    y = np.asarray(round_to_format(x, fmt, stochastic_key=key))
    assert set(np.unique(y)) <= {1.0, 1.5}
    np.testing.assert_allclose(y.mean(), 1.25, atol=0.01)


def test_bf16_roundtrip_dtype():
    x = jax.random.normal(jax.random.PRNGKey(0), (128,), jnp.bfloat16)
    y = round_to_format(x, FP8_E4M3)
    assert y.dtype == jnp.bfloat16
