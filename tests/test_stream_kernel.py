"""Single-pass streaming pipeline vs the two-pass reference.

The contract (see ``fused_qmm``): for the SAME ``(bm, bn, bk)`` the stream
and two_pass pipelines are **bit-identical** — same y, same telemetry
stats — for every supported granularity pair, dtype, rounding mode and
trans layout.  (Across *different* tilings only y's f32 accumulation order
changes, which is true of the two-pass path too and deliberately not part
of the contract.)

Everything runs in interpret mode on CPU (the fused_qmm default resolves
interpret from the backend inside ops.py; here we pass interpret=True
explicitly since we call the kernel module directly).
"""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qlinear import pallas_qmatmul, pallas_qmatmul_two_pass
from repro.core.recipe import MM_FFN_PAPER, MM_FP8
from repro.kernels.fp4_matmul import (default_pipeline, fused_qmm,
                                      stream_supported, use_pipeline)

# The module object (``repro.kernels.fp4_matmul`` the *package attribute*
# resolves to the re-exported function, not the module).
FM = importlib.import_module("repro.kernels.fp4_matmul")

M, N, K = 256, 256, 384
TILINGS = [(128, 128, 128), (256, 256, 384), (128, 256, 128)]
SEED_A = jnp.asarray(7, jnp.int32)
SEED_B = jnp.asarray(11, jnp.int32)


def _data(shape_a, shape_b, dtype=jnp.float32, seed=0):
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(ka, shape_a, jnp.float32).astype(dtype)
    b = (jax.random.normal(kb, shape_b, jnp.float32) * 0.05).astype(dtype)
    return a, b


def _run_both(a, b, tiles=None, **kw):
    bm, bn, bk = tiles if tiles else (None, None, None)
    outs = {}
    for pipe in ("stream", "two_pass"):
        outs[pipe] = fused_qmm(a, b, bm=bm, bn=bn, bk=bk, pipeline=pipe,
                               interpret=True, **kw)
    return outs["stream"], outs["two_pass"]


def _assert_bits_equal(x, y, what=""):
    assert x.dtype == y.dtype, (x.dtype, y.dtype, what)
    np.testing.assert_array_equal(
        np.asarray(x).view(np.uint8), np.asarray(y).view(np.uint8),
        err_msg=f"bitwise mismatch: {what}")


@pytest.mark.parametrize("tiles", TILINGS)
@pytest.mark.parametrize("dtype,sr", [
    (jnp.float32, False), (jnp.float32, True), (jnp.bfloat16, False),
], ids=["f32_rtn", "f32_sr", "bf16_rtn"])
def test_same_tiling_bit_exact(tiles, dtype, sr):
    """Stream == two_pass bitwise at the same tiling: y AND the full
    telemetry stats vectors, with the stats epilogue not perturbing y."""
    a, b = _data((M, K), (K, N), dtype)
    kw = dict(a_mode="block", b_mode="tile", a_sr=sr, b_sr=sr,
              seed_a=SEED_A if sr else None, seed_b=SEED_B if sr else None)
    (ys, (sa_s, sb_s)), (yt, (sa_t, sb_t)) = _run_both(
        a, b, tiles, collect_stats=True, **kw)
    _assert_bits_equal(ys, yt, "y (stats on)")
    _assert_bits_equal(sa_s, sa_t, "stats_a")
    _assert_bits_equal(sb_s, sb_t, "stats_b")
    ys_off, yt_off = _run_both(a, b, tiles, **kw)
    _assert_bits_equal(ys_off, yt_off, "y (stats off)")
    _assert_bits_equal(ys_off, ys, "y stats-on vs stats-off")


def test_bf16_sr_bit_exact():
    a, b = _data((M, K), (K, N), jnp.bfloat16, seed=3)
    ys, yt = _run_both(a, b, (128, 128, 128), a_mode="block", b_mode="tile",
                       a_sr=True, b_sr=True, seed_a=SEED_A, seed_b=SEED_B)
    _assert_bits_equal(ys, yt, "bf16 SR")


def test_pass_mode_dgrad_layout():
    """The dgrad role: both operands passthrough, RHS stored transposed."""
    g, w = _data((M, N), (K, N), jnp.bfloat16, seed=4)  # g @ w^T -> (M, K)
    ys, yt = _run_both(g, w, (128, 128, 128), a_mode="pass", b_mode="pass",
                       trans_b=True)
    _assert_bits_equal(ys, yt, "pass/pass trans_b")


def test_wgrad_layout_fp8():
    """The wgrad role: LHS stored transposed, fp8 block pair."""
    x, g = _data((K, M), (K, N), seed=5)  # x^T @ g with trans_a
    ys, yt = _run_both(x, g, (128, 128, 128), a_mode="block", b_mode="block",
                       a_fmt="fp8_e4m3", b_fmt="fp8_e5m2", trans_a=True)
    _assert_bits_equal(ys, yt, "block/block fp8 trans_a")


def test_token_granularity_falls_back_to_two_pass():
    """token/tensor need the whole reduction axis before scaling — stream
    auto-routes to two_pass, so pipeline="stream" must equal "two_pass"
    trivially (bitwise)."""
    assert not stream_supported("token", "tile")
    a, b = _data((256, 256), (256, 256), seed=6)
    ys, yt = _run_both(a, b, (128, 128, 128), a_mode="token",
                       b_mode="tensor", a_fmt="fp8_e4m3", b_fmt="fp8_e5m2")
    _assert_bits_equal(ys, yt, "token/tensor fallback")


def test_operand_cache_bit_exact(monkeypatch):
    """The VMEM operand caches (LHS row panel, full quantized RHS) are pure
    reuse optimizations: forcing either or both off (budget 0) must not
    change a single bit."""
    a, b = _data((M, K), (K, N), seed=7)
    kw = dict(a_mode="block", b_mode="tile", pipeline="stream",
              bm=128, bn=128, bk=128, interpret=True, collect_stats=True)
    y_ref, (sa_ref, sb_ref) = fused_qmm(a, b, **kw)
    for attrs in (("_AQ_CACHE_BYTES",), ("_BQ_CACHE_BYTES",),
                  ("_AQ_CACHE_BYTES", "_BQ_CACHE_BYTES")):
        with monkeypatch.context() as mp:
            for attr in attrs:
                mp.setattr(FM, attr, 0)
            # _fused_qmm's jit cache captured the cached kernel
            jax.clear_caches()
            y, (sa, sb) = fused_qmm(a, b, **kw)
            _assert_bits_equal(y_ref, y, f"y, cache off: {attrs}")
            _assert_bits_equal(sa_ref, sa, f"stats_a, cache off: {attrs}")
            _assert_bits_equal(sb_ref, sb, f"stats_b, cache off: {attrs}")
    jax.clear_caches()


@pytest.mark.parametrize("recipe,name", [(MM_FFN_PAPER, "ffn_paper"),
                                         (MM_FP8, "fp8")])
def test_qlinear_stream_vs_two_pass(recipe, name):
    """Through the training entry points: ``pallas_qmatmul`` (stream) and
    ``pallas_qmatmul_two_pass`` agree bitwise on fwd AND the vjp
    (dgrad + wgrad).  MM_FP8 exercises the token-granularity fallback."""
    key = jnp.zeros((2,), jnp.uint32)
    x, w = _data((128, 128), (128, 128), seed=8)
    c = jax.random.normal(jax.random.PRNGKey(9), (128, 128), jnp.float32)

    def run(f):
        y, vjp = jax.vjp(lambda p, q: f(p, q, key, recipe), x, w)
        dx, dw = vjp(c)
        return y, dx, dw

    ys, dxs, dws = run(pallas_qmatmul)
    yt, dxt, dwt = run(pallas_qmatmul_two_pass)
    _assert_bits_equal(ys, yt, f"{name} fwd")
    _assert_bits_equal(dxs, dxt, f"{name} dgrad")
    _assert_bits_equal(dws, dwt, f"{name} wgrad")


def test_use_pipeline_nesting():
    assert default_pipeline() == "stream"
    with use_pipeline("two_pass"):
        assert default_pipeline() == "two_pass"
        with use_pipeline("stream"):
            assert default_pipeline() == "stream"
        assert default_pipeline() == "two_pass"
    assert default_pipeline() == "stream"
    with pytest.raises(AssertionError):
        with use_pipeline("bogus"):
            pass


def test_stream_supported_matrix():
    for mode in ("pass", "block", "tile"):
        assert stream_supported(mode, "tile")
        assert stream_supported("block", mode)
    for mode in ("token", "tensor"):
        assert not stream_supported(mode, "tile")
        assert not stream_supported("block", mode)
