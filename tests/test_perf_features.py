"""Hillclimb-born distribution features (EXPERIMENTS.md §Perf)."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.distributed.sharding import default_rules


class FakeMesh:
    def __init__(self, shape, axes):
        self.shape = dict(zip(axes, shape))
        self.axis_names = axes
        self.size = int(np.prod(shape))


MESH = FakeMesh((16, 16), ("data", "model"))


def test_free_head_shard_unlocks_weight_sharding():
    cfg = get_config("llama3.2-3b")  # 24 heads % 16 != 0
    base = default_rules(MESH, cfg)
    free = default_rules(MESH, cfg, free_head_shard=True)
    shape = (3072, 3072)
    assert base._spec(base.param_rules, ("embed", "heads"), shape) == \
        P("data", None)
    assert free._spec(free.param_rules, ("embed", "heads"), shape) == \
        P("data", "model")
    # activation head dims (count=24) still replicate under free sharding
    assert free._spec(free.act_rules, ("batch", "seq", "heads", None),
                      (256, 4096, 24, 128)) == P("data", None, None, None)


def test_context_parallel_act_rule():
    cfg = get_config("llama3.2-3b")
    rules = default_rules(MESH, cfg, act_overrides={"seq_q": ("model",)})
    spec = rules._spec(rules.act_rules, ("batch", "heads", "seq_q", None),
                       (16, 24, 4096, 128))
    # heads (24) can't take model; seq_q (4096) does
    assert spec == P("data", None, "model", None)


def test_split_mamba_projection_shardings():
    cfg = get_config("jamba-1.5-large-398b")
    from repro.models.ssm import mamba_param_specs
    rules = default_rules(MESH, cfg)
    specs = mamba_param_specs(cfg)
    def spec_of(k):
        sp = specs[k]
        return rules._spec(rules.param_rules, sp.axes, sp.shape)
    assert spec_of("in_x") == P("data", "model")   # 256 heads % 16 == 0
    # jamba has n_groups=8 < 16 -> B/C replicate on the groups dim
    assert spec_of("in_b") == P("data", None)
    # dt projection shards on head count (256 % 16 == 0)
    assert spec_of("in_dt") == P("data", "model")


def test_mamba_groups_granule_blocks_nondivisible():
    cfg = get_config("mamba2-780m")  # n_groups=1 -> B/C replicated
    from repro.models.ssm import mamba_param_specs
    rules = default_rules(MESH, cfg)
    specs = mamba_param_specs(cfg)
    def spec_of(k):
        sp = specs[k]
        return rules._spec(rules.param_rules, sp.axes, sp.shape)
    assert spec_of("in_b") == P("data", None)
    # but x/z projections shard on heads (48 % 16 == 0)
    assert spec_of("in_x") == P("data", "model")


def test_bf16eq_collective_metric():
    from repro.analysis.hlo import collective_bytes
    hlo = """
  %a = f32[1024]{0} all-reduce(%p), to_apply=%add
  %b = bf16[1024]{0} all-gather(%q)
"""
    out = collective_bytes(hlo)
    assert out["effective_total"] == pytest.approx(2 * 4096 + 2048)
    assert out["effective_total_bf16eq"] == pytest.approx(4096 + 2048)


def test_all_fp4_sched_recipe_registered():
    from repro.core.recipe import RECIPES, PrecisionPlan
    r = RECIPES["all_fp4_sched"]
    assert r.target_precision_frac == 0.1
    from repro.core.schedule import TargetPrecisionSchedule
    s = TargetPrecisionSchedule(PrecisionPlan.uniform(r, 4), 100)
    assert s.switch_step == 90


def test_pallas_attention_impl_matches_chunked():
    """attention_impl='pallas' routes SDPA through the Pallas flash kernel
    (interpret mode on CPU) and must match the chunked path."""
    import importlib
    import jax
    import jax.numpy as jnp
    from repro.core.recipe import RECIPES
    from repro.models import build_model
    cfg = importlib.import_module("repro.configs.tiny").CONFIG.replace(
        dtype="float32")
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 128), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    outs = {}
    for impl in ("chunked", "pallas"):
        model = build_model(cfg.replace(attention_impl=impl))
        params = model.init(jax.random.PRNGKey(1))
        logits, _ = model.forward(params, batch, RECIPES["bf16"])
        outs[impl] = logits
    np.testing.assert_allclose(np.asarray(outs["pallas"]),
                               np.asarray(outs["chunked"]),
                               rtol=2e-4, atol=2e-4)
