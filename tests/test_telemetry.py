"""Telemetry subsystem: in-graph stats (incl. layer-indexed backward
probes), controller decision rules (per-(layer, class) demotion, LR
backoff), plan-based schedule, resume across the switch boundary, JSONL."""
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ControllerSettings, TrainConfig, get_config
from repro.core.recipe import MM_FP8, RECIPES, PrecisionPlan
from repro.core.schedule import TargetPrecisionSchedule
from repro.data import SyntheticLM
from repro.models import build_model
from repro.telemetry import collect as tel_collect
from repro.telemetry.controller import PrecisionController
from repro.telemetry.writer import (AsyncJsonlWriter, JsonlWriter,
                                    read_jsonl)
from repro.train.train_step import make_optimizer, make_train_step
from repro.train.trainer import StepTimeMonitor, Trainer


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("tiny")
    model = build_model(cfg)
    pipe = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
    return cfg, model, pipe


N_LAYERS = 2  # tiny config depth; controller tests use matching plans


def _plan(recipe="paper_fp4", n=N_LAYERS):
    return PrecisionPlan.uniform(RECIPES[recipe], n)


def _schedule(total=100, recipe="paper_fp4", target=None):
    return TargetPrecisionSchedule(
        _plan(recipe), total,
        target=_plan(target) if target else None)


# ---------------------------------------------------------------------------
# In-graph collection
# ---------------------------------------------------------------------------

def test_telemetry_metrics_present(tiny_setup, tmp_path):
    cfg, model, pipe = tiny_setup
    jsonl = str(tmp_path / "tel.jsonl")
    tcfg = TrainConfig(recipe="paper_fp4", total_steps=3, global_batch=8,
                       seq_len=64, learning_rate=3e-3, log_every=0,
                       telemetry=True, telemetry_jsonl=jsonl)
    tr = Trainer(model, tcfg, pipe)
    tr.train()
    row = tr.history[-1]
    # per-layer x per-role forward stats for both layers
    for layer in ("l00", "l01"):
        for slot in ("fwd_x", "fwd_w", "wgrad_x"):
            key = f"tel/{layer}/ffn/mm0/{slot}/underflow"
            assert key in row, sorted(k for k in row if "ffn/mm0" in k)
            assert 0.0 <= row[key] <= 1.0
        assert row[f"tel/{layer}/ffn/mm0/fwd_x/rel_err"] > 0  # FP4 is noisy
        assert f"tel/gnorm/{layer}" in row and row[f"tel/gnorm/{layer}"] > 0
    # backward-side (probe-transported) stats: per-class aggregates plus
    # layer-resolved rows from the indexed probes
    assert row["tel/bwd/attn/taps"] > 0
    assert row["tel/bwd/ffn/wgrad_g/rel_err"] > 0        # FP8 wgrad
    assert row["tel/bwd/ffn/dgrad_g/rel_err"] == 0.0      # BF16 dgrad
    assert 0.0 <= row["tel/bwd/attn/dgrad_g/underflow"] <= 1.0
    for layer in ("l00", "l01"):
        assert row[f"tel/bwd/{layer}/ffn/taps"] > 0
        assert row[f"tel/bwd/{layer}/ffn/wgrad_g/rel_err"] > 0
        assert row[f"tel/bwd/{layer}/attn/taps"] > 0
    # head taps only land in the class aggregate (no layer index)
    assert row["tel/bwd/attn/taps"] == (row["tel/bwd/l00/attn/taps"]
                                        + row["tel/bwd/l01/attn/taps"])
    # JSONL log mirrors history
    logged = read_jsonl(jsonl)
    assert len(logged) == 3
    assert logged[-1]["step"] == 2
    assert any(k.startswith("tel/") for k in logged[-1])
    assert "straggler" in logged[-1]  # StepTimeMonitor folded into rows


def test_telemetry_disabled_is_aux_free_and_bit_identical(tiny_setup):
    """Off => no tel aux in the graph outputs AND the training math with
    telemetry on is untouched (params evolve bit-identically)."""
    cfg, model, pipe = tiny_setup
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    params = model.init(jax.random.PRNGKey(0))
    results = {}
    for tel in (False, True):
        tcfg = TrainConfig(recipe="paper_fp4", total_steps=10,
                           global_batch=8, seq_len=64, telemetry=tel)
        step = make_train_step(model, tcfg, RECIPES["paper_fp4"],
                               jit=True, donate=False)
        opt_state = make_optimizer(model, tcfg).init(params)
        p, o, c, metrics = step(params, opt_state,
                                jnp.zeros((), jnp.float32), batch,
                                jnp.asarray(0, jnp.int32))
        p, o, c, metrics2 = step(p, o, c, batch, jnp.asarray(1, jnp.int32))
        results[tel] = (p, metrics, metrics2)
    p_off, m_off, _ = results[False]
    p_on, m_on, _ = results[True]
    assert not any(k.startswith("tel/") for k in m_off)
    assert any(k.startswith("tel/") for k in m_on)
    # identical non-telemetry metric set (aux-free graph apart from tel/)
    assert set(m_off) == {k for k in m_on if not k.startswith("tel/")}
    for a, b in zip(jax.tree.leaves(p_off), jax.tree.leaves(p_on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_telemetry_every_samples_alternate_steps(tiny_setup):
    cfg, model, pipe = tiny_setup
    tcfg = TrainConfig(recipe="paper_fp4", total_steps=4, global_batch=8,
                       seq_len=64, learning_rate=3e-3, log_every=0,
                       telemetry=True, telemetry_every=2)
    tr = Trainer(model, tcfg, pipe)
    tr.train()
    has_tel = [any(k.startswith("tel/") for k in r) for r in tr.history]
    assert has_tel == [True, False, True, False]


def test_grad_tap_identity_gradients():
    """grad_tap must not perturb cotangents; probe grads carry the stats,
    routed into the current layer's probe row."""
    recipe = RECIPES["paper_fp4"].ffn_linear
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 64), jnp.float32)
    probes = tel_collect.make_probes(3)
    col = tel_collect.TelemetryCollector()

    def f(x, probes):
        with tel_collect.collecting(col, probes):
            with tel_collect.layer_frame(1):
                with tel_collect.module_scope("ffn"):
                    y = tel_collect.grad_tap(x * 2.0, recipe)
            with tel_collect.module_scope("head"):
                y = y + 0.0 * tel_collect.grad_tap(x * 1.0, recipe)
        return jnp.sum(y ** 2)

    g, pg = jax.grad(f, argnums=(0, 1))(x, probes)
    np.testing.assert_allclose(np.asarray(g), np.asarray(8.0 * x), rtol=1e-6)
    assert float(pg["ffn"][1, -1]) == 1.0        # tap in layer 1's row
    assert float(pg["ffn"][0, -1]) == 0.0        # not in layer 0's
    assert float(pg["head"][-1, -1]) == 1.0      # root tap -> trailing row
    assert float(pg["attn"].sum()) == 0.0
    m = tel_collect.probe_metrics(pg)
    assert m["tel/bwd/ffn/wgrad_g/rel_err"] > 0  # FP8 wgrad_g quant error
    assert m["tel/bwd/l01/ffn/wgrad_g/rel_err"] > 0   # layer-resolved row
    assert float(m["tel/bwd/l00/ffn/taps"]) == 0.0


def test_grad_tap_traced_layer_index():
    """A traced layer index (the scan-body case) scatter-adds each tap
    into its own probe row."""
    recipe = RECIPES["paper_fp4"].ffn_linear
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 32), jnp.float32)
    col = tel_collect.TelemetryCollector()

    def f(x, probes):
        with tel_collect.collecting(col, probes):
            def body(h, idx):
                with tel_collect.layer_frame(idx):
                    with tel_collect.module_scope("ffn"):
                        h = tel_collect.grad_tap(h * 2.0, recipe)
                return h, ()
            y, _ = jax.lax.scan(body, x, jnp.arange(2))
        return jnp.sum(y ** 2)

    pg = jax.grad(f, argnums=1)(x, tel_collect.make_probes(2))
    assert float(pg["ffn"][0, -1]) == 1.0
    assert float(pg["ffn"][1, -1]) == 1.0
    assert float(pg["ffn"][2, -1]) == 0.0


# ---------------------------------------------------------------------------
# Schedule target recipe (satellite)
# ---------------------------------------------------------------------------

def test_schedule_target_recipe_configurable(tiny_setup):
    cfg, model, pipe = tiny_setup
    sched = _schedule(total=100, target="fp8")
    assert sched.target_plan.name == "fp8"
    assert sched.plan_at(99).name == "fp8"
    assert sched.plan_at(0).name == "paper_fp4"
    # default stays the BF16 baseline
    assert _schedule(total=100).target_plan.name == "bf16"
    # threaded from TrainConfig
    tcfg = TrainConfig(recipe="paper_fp4", total_steps=10,
                       target_recipe="fp8")
    tr = Trainer(model, tcfg, pipe)
    assert tr.schedule.target_plan.name == "fp8"


def test_schedule_stage2_is_plan_transform():
    """A depth-graded stage-1 plan collapses to the uniform target at the
    §3.3 boundary — the switch edits every row, not just a name."""
    plan = PrecisionPlan.first_last_k(RECIPES["paper_fp4"], 8, k=2)
    sched = TargetPrecisionSchedule(plan, 100)
    assert sched.plan_at(0) is plan
    tgt = sched.plan_at(99)
    assert tgt.name == "bf16" and tgt.is_uniform and tgt.is_passthrough


def test_plan_promote_cell():
    base = PrecisionPlan.uniform(RECIPES["paper_fp4"], 4)
    p = base.promote("ffn", layer=2)
    # role-wise protection: quantized roles -> FP8, the paper's BF16 dgrad
    # path stays unquantized (promotion never lowers a role's precision)
    assert p.layers[2].ffn_linear.fwd_x == MM_FP8.fwd_x
    assert p.layers[2].ffn_linear.wgrad_g == MM_FP8.wgrad_g
    assert p.layers[2].ffn_linear.dgrad_g.is_passthrough
    assert p.layers[1].ffn_linear == base.layers[1].ffn_linear
    assert p.layers[2].attn_linear == base.layers[2].attn_linear
    assert p.name != base.name
    # no-op when the cell is already protected
    assert p.promote("ffn", layer=2) is p
    # whole-class promotion still expressible as a plan transform
    allp = base.promote("ffn")
    assert all(r.ffn_linear.fwd_x == MM_FP8.fwd_x for r in allp.layers)
    # the (unquantized) head cell cannot be "protected" any further...
    assert base.promote("head") is base
    # ...but an explicit target still applies
    h = base.promote("head", to=MM_FP8)
    assert h.head_linear == MM_FP8 and h.promote("head", to=MM_FP8) is h


# ---------------------------------------------------------------------------
# Controller decision rules (deterministic, synthetic rows)
# ---------------------------------------------------------------------------

def test_controller_dynamic_switch_on_error_ema():
    ctrl = PrecisionController(
        _schedule(total=100),  # fixed switch at 92
        ControllerSettings(switch_error_threshold=0.1, error_ema_decay=0.5))
    row = {"loss": 1.0, "tel/l00/ffn/mm0/fwd_x/rel_err": 0.3}
    events = []
    for step in range(10):
        events += ctrl.observe(step, row)
    assert [e["event"] for e in events] == ["switch"]
    s = events[0]["step"]
    assert s < 92
    assert ctrl.active_plan(s + 1).name == "bf16"
    assert ctrl.active_plan(s).name == "paper_fp4"  # switch is next-step


def test_controller_fixed_fraction_still_applies():
    ctrl = PrecisionController(
        _schedule(total=100),
        ControllerSettings(switch_error_threshold=0.0))  # rule disabled
    for step in range(5):
        ctrl.observe(step, {"loss": 1.0,
                            "tel/l00/ffn/mm0/fwd_x/rel_err": 0.9})
    assert ctrl.switched_at is None
    assert ctrl.active_plan(91).name == "paper_fp4"
    assert ctrl.active_plan(92).name == "bf16"       # fraction boundary


def test_controller_demotes_single_layer_cell():
    """One noisy layer demotes ONLY its own (layer, class) cell — the
    other layers keep running FP4 (the per-layer upgrade of the old
    class-global rule)."""
    ctrl = PrecisionController(
        _schedule(total=100),
        ControllerSettings(demote_overflow_threshold=0.2,
                           demote_patience=3))
    storm = {"loss": 1.0, "tel/l00/ffn/mm0/wgrad_x/clip": 0.5,
             "tel/bwd/l00/ffn/wgrad_g/clip": 0.6,
             "tel/l01/ffn/mm0/wgrad_x/clip": 0.0,
             "tel/l00/attn/mm0/wgrad_x/clip": 0.0}
    events = []
    for step in range(5):
        events += ctrl.observe(step, storm)
    demotes = [e for e in events if e["event"] == "demote"]
    assert len(demotes) == 1 and demotes[0]["cell"] == "l00/ffn"
    assert demotes[0]["layer"] == 0
    assert demotes[0]["module_class"] == "ffn"
    base = RECIPES["paper_fp4"]
    active = ctrl.active_plan(10)
    dem = active.layers[0].ffn_linear                      # demoted cell
    assert dem.fwd_x == MM_FP8.fwd_x and dem.wgrad_g == MM_FP8.wgrad_g
    assert dem.dgrad_g.is_passthrough                      # BF16 dgrad kept
    assert active.layers[1].ffn_linear == base.ffn_linear  # untouched
    assert active.layers[0].attn_linear == base.attn_linear
    # calm cells never demote
    assert ctrl.demoted == ["l00/ffn"]
    # the scan partition now isolates the demoted layer
    assert active.scan_runs(1) == [(0, 1), (1, 2)]


def test_controller_classifies_rootframe_head_keys():
    """Root-frame (lm-head) keys have no lNN segment; they must still feed
    the demotion signal for the head class."""
    ctrl = PrecisionController(
        _schedule(total=100),
        ControllerSettings(demote_overflow_threshold=0.2,
                           demote_patience=2))
    storm = {"loss": 1.0, "tel/head/mm0/wgrad_x/clip": 0.9}
    events = []
    for step in range(3):
        events += ctrl.observe(step, storm)
    assert [e["cell"] for e in events if e["event"] == "demote"] == ["head"]
    # paper_fp4's head is already unquantized BF16 — the demotion latches
    # in controller state but the plan transform is a no-op (there is no
    # higher precision to protect it at)
    assert ctrl.active_plan(10).head_linear.is_passthrough


def test_controller_demotion_needs_sustained_signal():
    ctrl = PrecisionController(
        _schedule(total=100),
        ControllerSettings(demote_overflow_threshold=0.2,
                           demote_patience=3))
    hot = {"loss": 1.0, "tel/l00/ffn/mm0/wgrad_x/clip": 0.5}
    cold = {"loss": 1.0, "tel/l00/ffn/mm0/wgrad_x/clip": 0.0}
    for step, row in enumerate([hot, hot, cold, hot, hot]):
        assert ctrl.observe(step, row) == []               # streak broken
    assert ctrl.demoted == []


def test_demotions_survive_stage2_switch():
    """Bugfix regression (ISSUE 5): a demoted cell must stay promoted
    across the §3.3 switch whenever the stage-2 plan still quantizes it.
    Pre-fix, ``active_plan`` dropped every demotion as soon as the target
    plan was active."""
    sched = TargetPrecisionSchedule(_plan("paper_fp4"), 100,
                                    target=_plan("fine_grained_fp4"))
    ctrl = PrecisionController(sched, ControllerSettings(
        demote_overflow_threshold=0.2, demote_patience=2))
    storm = {"loss": 1.0, "tel/l00/ffn/mm0/wgrad_x/clip": 0.9}
    for step in range(3):
        ctrl.observe(step, storm)
    assert ctrl.demoted == ["l00/ffn"]
    assert ctrl.active_plan(50).layers[0].ffn_linear.fwd_x == MM_FP8.fwd_x
    # cross the fixed-fraction boundary (switch step 92): the stage-2 plan
    # quantizes ffn at FP4, so the demoted cell must stay at FP8
    tgt = sched.target_plan
    p2 = ctrl.active_plan(95)
    assert p2 != tgt
    assert p2.layers[0].ffn_linear.fwd_x == MM_FP8.fwd_x
    assert p2.layers[1] == tgt.layers[1]          # only the cell is edited
    # a stage-2 plan that does NOT quantize the cell is untouched (the
    # demotion has nothing to protect at BF16)
    sched_bf16 = _schedule(total=100)
    ctrl2 = PrecisionController(sched_bf16, ControllerSettings(
        demote_overflow_threshold=0.2, demote_patience=2))
    for step in range(3):
        ctrl2.observe(step, storm)
    assert ctrl2.active_plan(95) == sched_bf16.target_plan


def test_demoted_plan_cache_keyed_by_base():
    """Bugfix regression (ISSUE 5): the demoted-plan cache must key on
    the base plan too — keyed by the cell set alone, a plan derived from
    one base was served for another once ``plan_at(step)`` varied."""
    sched = TargetPrecisionSchedule(_plan("paper_fp4"), 100,
                                    target=_plan("fine_grained_fp4"))
    ctrl = PrecisionController(sched, ControllerSettings())
    ctrl.demoted = ["l00/ffn"]
    a = ctrl._demoted_plan(_plan("paper_fp4"))
    b = ctrl._demoted_plan(_plan("fine_grained_fp4"))
    assert a != b
    assert a.layers[1].ffn_linear == RECIPES["paper_fp4"].ffn_linear
    assert b.layers[1].ffn_linear == RECIPES["fine_grained_fp4"].ffn_linear
    # both demote the addressed cell
    for p in (a, b):
        assert p.layers[0].ffn_linear.fwd_x == MM_FP8.fwd_x


def test_controller_spike_triggers_rollback_and_replay():
    ctrl = PrecisionController(
        _schedule(total=100),
        ControllerSettings(spike_factor=2.0, spike_warmup=3,
                           replay_steps=4, max_rollbacks=1))
    events = []
    for step in range(6):
        events += ctrl.observe(step, {"loss": 1.0})
    assert events == []
    events = ctrl.observe(6, {"loss": 5.0})                # spike
    assert [e["event"] for e in events] == ["rollback"]
    ctrl.begin_replay(4)                                   # trainer restored
    assert ctrl.active_plan(5).name == "bf16"              # replay window
    assert ctrl.active_plan(8).name == "paper_fp4"         # window over
    # replay steps don't re-trigger; max_rollbacks caps further ones
    assert ctrl.observe(5, {"loss": 5.0}) == []
    assert ctrl.observe(9, {"loss": 50.0}) == []           # capped
    # state round-trips through checkpoint extra (JSON)
    state = json.loads(json.dumps(ctrl.state_dict()))
    ctrl2 = PrecisionController(_schedule(total=100), ControllerSettings())
    ctrl2.load_state(state)
    assert ctrl2.replay_until == ctrl.replay_until
    assert ctrl2.rollbacks == 1


def test_controller_lr_backoff_and_recovery():
    """Satellite: each rollback shrinks the LR scale multiplicatively;
    clean steps recover it geometrically back to 1.0; the scale persists
    through controller checkpoint state."""
    ctrl = PrecisionController(
        _schedule(total=1000),
        ControllerSettings(spike_factor=2.0, spike_warmup=3,
                           replay_steps=0, max_rollbacks=4,
                           lr_backoff=0.5, lr_recovery_steps=10))
    for step in range(6):
        ctrl.observe(step, {"loss": 1.0})
    assert ctrl.lr_scale == 1.0                  # no rollback yet
    events = ctrl.observe(6, {"loss": 5.0})      # spike -> rollback
    assert [e["event"] for e in events] == ["rollback"]
    assert events[0]["lr_scale"] == pytest.approx(0.5)
    assert ctrl.lr_scale == pytest.approx(0.5)
    # geometric recovery: back to 1.0 after ~lr_recovery_steps clean steps
    for step in range(7, 17):
        ctrl.observe(step, {"loss": 1.0})
    assert ctrl.lr_scale == pytest.approx(1.0)
    for step in range(17, 20):
        ctrl.observe(step, {"loss": 1.0})
    assert ctrl.lr_scale == 1.0                  # capped at 1.0
    # a second rollback compounds on whatever scale is current
    ctrl.observe(20, {"loss": 50.0})
    assert ctrl.lr_scale == pytest.approx(0.5)
    # round-trips through checkpoint state
    state = json.loads(json.dumps(ctrl.state_dict()))
    ctrl2 = PrecisionController(_schedule(total=1000), ControllerSettings())
    ctrl2.load_state(state)
    assert ctrl2.lr_scale == pytest.approx(0.5)


def test_trainer_lr_backoff_scales_step_lr(tiny_setup, tmp_path):
    """Trainer-level: after a rollback the executed step's lr metric is
    scaled down, and it recovers over subsequent steps."""
    cfg, model, pipe = tiny_setup
    tcfg = TrainConfig(recipe="paper_fp4", total_steps=100, global_batch=8,
                       seq_len=64, learning_rate=3e-3, log_every=0,
                       checkpoint_every=2, checkpoint_dir=str(tmp_path),
                       controller=ControllerSettings(
                           spike_factor=2.0, replay_steps=1,
                           lr_backoff=0.5, lr_recovery_steps=4))
    tr = Trainer(model, tcfg, pipe)
    state = tr.train(num_steps=4)
    lr_before = tr.history[-1]["lr"]
    ev = {"event": "rollback", "step": 3, "loss": 9.0, "loss_ema": 1.0}
    tr.controller.rollbacks = 1
    tr.controller._observe_lr([ev])              # as if observe() fired it
    state = tr._apply_controller_events(state, [ev], lambda s: None)
    assert tr.controller.lr_scale == pytest.approx(0.5)
    tr.train(state, num_steps=1)
    # the very next executed step ran at half the scheduled LR
    assert tr.history[-1]["lr"] == pytest.approx(
        0.5 * lr_before, rel=0.15)  # rel slack: cosine schedule drift


def test_trainer_rollback_restores_checkpoint(tiny_setup, tmp_path):
    """Trainer-level rollback: a rollback event restores the latest
    checkpoint and arms the high-precision replay window."""
    cfg, model, pipe = tiny_setup
    tcfg = TrainConfig(recipe="paper_fp4", total_steps=100, global_batch=8,
                       seq_len=64, learning_rate=3e-3, log_every=0,
                       checkpoint_every=4, checkpoint_dir=str(tmp_path),
                       controller=ControllerSettings(spike_factor=2.0,
                                                     replay_steps=3))
    tr = Trainer(model, tcfg, pipe)
    state = tr.train(num_steps=8)          # checkpoints at steps 4 and 8
    assert state.step == 8
    ev = {"event": "rollback", "step": 7, "loss": 9.0, "loss_ema": 1.0}
    tr.controller.rollbacks = 1            # as if observe() emitted it
    state2 = tr._apply_controller_events(state, [ev], lambda s: None)
    assert state2.step == 8                # latest intact checkpoint
    assert tr.controller.replay_until == 8 + 3
    assert tr._active_plan(9).name == "bf16"    # replaying at target
    assert tr._active_plan(11).name == "paper_fp4"


def test_plan_search_composes_with_demotions():
    """Search edits compose with safety demotions: frontier points price
    the plan the steps actually ran, and a cell the controller already
    protected is never re-proposed by the searcher."""
    from repro.core.cost_model import ModelDims, plan_cost
    dims = ModelDims.from_config(get_config("tiny"), seq_len=64)
    ctrl = PrecisionController(
        _schedule(total=1000, recipe="all_fp4"),
        ControllerSettings(plan_search=True, plan_search_every=3,
                           demote_overflow_threshold=0.2,
                           demote_patience=2),
        dims=dims)
    row = {"loss": 1.0,
           "tel/l00/ffn/mm0/fwd_x/rel_err": 0.3,   # worst cell ...
           "tel/l01/ffn/mm0/fwd_x/rel_err": 0.1,
           "tel/l00/ffn/mm0/wgrad_x/clip": 0.9}    # ... but overflowing
    events = []
    for step in range(12):
        events += ctrl.observe(step, row)
    demotes = [e for e in events if e["event"] == "demote"]
    assert [e["cell"] for e in demotes] == ["l00/ffn"]
    moves = [e for e in events if e["event"] == "plan_search"]
    assert moves and all(m["cell"] != "l00/ffn" for m in moves)
    assert moves[0]["cell"] == "l01/ffn"  # next-worst promotable cell
    # the frontier prices the effective (demotion-composed) plan
    points = [e for e in events if e["event"] == "frontier_point"]
    assert points[0]["cost"] == plan_cost(
        ctrl._demoted_plan(ctrl.schedule.plan), dims)
    assert "l00.ffn=fp8" in points[0]["plan"]


def test_searcher_window_reset_on_replay_demotion():
    """A demotion that latches during rollback replay (when the search
    itself is gated off) must still discard the searcher's partial
    measurement window — its samples belong to the pre-demotion plan."""
    from repro.core.cost_model import ModelDims
    dims = ModelDims.from_config(get_config("tiny"), seq_len=64)
    ctrl = PrecisionController(
        _schedule(total=1000, recipe="all_fp4"),
        ControllerSettings(plan_search=True, plan_search_every=5,
                           demote_overflow_threshold=0.2,
                           demote_patience=2),
        dims=dims)
    row = {"loss": 1.0, "tel/l00/ffn/mm0/fwd_x/rel_err": 0.3}
    ctrl.observe(0, row)
    ctrl.observe(1, row)
    assert ctrl.searcher._err_n == 2        # partial window accumulated
    ctrl.begin_replay(2)                    # replay window: steps 2..6
    storm = dict(row, **{"tel/l00/ffn/mm0/wgrad_x/clip": 0.9})
    ctrl.observe(2, storm)
    ctrl.observe(3, storm)                  # demotion latches mid-replay
    assert ctrl.demoted == ["l00/ffn"]
    assert ctrl.searcher._err_n == 0        # stale window discarded


def test_trainer_plan_search_wiring(tiny_setup, tmp_path):
    """Tentpole wiring: with ``plan_search`` the searcher edits the live
    plan (history shows the edited plan's name), measures a real frontier
    from the in-graph telemetry, and its state persists in the checkpoint
    extra so a fresh trainer resumes it."""
    cfg, model, pipe = tiny_setup
    tcfg = TrainConfig(recipe="all_fp4", total_steps=100, global_batch=8,
                       seq_len=64, learning_rate=3e-3, log_every=0,
                       telemetry=True,
                       checkpoint_every=4, checkpoint_dir=str(tmp_path),
                       controller=ControllerSettings(
                           plan_search=True, plan_search_every=3,
                           plan_search_max_edits=1))
    tr = Trainer(model, tcfg, pipe)
    tr.train(num_steps=8)
    s = tr.controller.searcher
    assert len(s.edits) == 1 and s.edits[0][0] == "promote"
    moves = [e for e in tr.controller.events
             if e["event"] == "plan_search"]
    assert len(moves) == 1 and moves[0]["cell"] == s.edits[0][1]
    names = [r["recipe"] for r in tr.history]
    assert names[0] == "all_fp4" and "=fp8" in names[-1]
    # frontier measured from live telemetry: uniform FP4 first, the
    # promoted plan cheaper-error at higher cost (monotone)
    assert s.done and len(s.frontier) == 2
    assert s.frontier[0]["plan"] == "all_fp4"
    assert s.frontier[1]["cost"] > s.frontier[0]["cost"]
    assert s.frontier[1]["error"] < s.frontier[0]["error"]
    # searcher state rides the controller checkpoint extra
    tr2 = Trainer(model, tcfg, pipe)
    assert tr2.resume() is not None
    assert tr2.controller.searcher.state_dict() == s.state_dict()
    assert tr2._active_plan(8).name == tr._active_plan(8).name


# ---------------------------------------------------------------------------
# Resume across the precision-switch boundary (satellite)
# ---------------------------------------------------------------------------

def test_resume_across_switch_boundary(tiny_setup, tmp_path):
    """Checkpoint in stage 1, resume in a fresh Trainer, cross the §3.3
    switch: the active recipe is re-derived and training is bit-exact
    vs. an uninterrupted run."""
    cfg, model, pipe = tiny_setup

    def mk(ckdir):
        tcfg = TrainConfig(recipe="paper_fp4", total_steps=40,
                           global_batch=8, seq_len=64, learning_rate=3e-3,
                           log_every=0, checkpoint_every=10,
                           checkpoint_dir=str(ckdir))
        return Trainer(model, tcfg, SyntheticLM(cfg.vocab_size, 64, 8,
                                                seed=0))

    ref = mk(tmp_path / "a").train()               # uninterrupted
    trb = mk(tmp_path / "b")
    trb.train(num_steps=30)                        # stop in stage 1
    trc = mk(tmp_path / "b")                       # fresh process stand-in
    resumed = trc.resume()
    assert resumed is not None and resumed.step == 30
    assert trc._active_plan(resumed.step).name == "paper_fp4"
    final = trc.train(resumed)
    recipes = [r["recipe"] for r in trc.history]
    assert recipes[0] == "paper_fp4" and recipes[-1] == "bf16"
    switch = trc.schedule.switch_step
    assert trc.history[switch - 30]["recipe"] == "bf16"
    assert trc.history[switch - 31]["recipe"] == "paper_fp4"
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(final.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Writers / bench JSON (satellite)
# ---------------------------------------------------------------------------

def test_jsonl_writer_roundtrip(tmp_path):
    path = str(tmp_path / "x.jsonl")
    with JsonlWriter(path) as w:
        w.write({"step": 0, "loss": 1.5, "recipe": "paper_fp4"})
        w.write({"event": "demote", "module_class": "ffn",
                 "overflow": np.float32(0.5)})
    rows = read_jsonl(path)
    assert rows[0]["loss"] == 1.5
    assert rows[1]["event"] == "demote"
    assert isinstance(rows[1]["overflow"], float)  # numpy scalars coerced


def test_bench_write_json(tmp_path):
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import common
    common.emit("kernel/test_row", 12.34, "impl=test")
    out = str(tmp_path / "BENCH_test.json")
    common.write_json(out)
    with open(out) as f:
        payload = json.load(f)
    assert payload["schema"] == "bench.v1"
    names = [r["name"] for r in payload["benchmarks"]]
    assert "kernel/test_row" in names


def test_jsonl_writer_strict_json_nonfinite_and_arrays(tmp_path):
    """NaN/Inf become null (strict JSON has no non-finite literals) and
    numpy/jax arrays become nested lists — verified through the full
    write -> parse round trip, and the raw file never contains the bare
    ``NaN``/``Infinity`` tokens json.dumps would otherwise emit."""
    path = str(tmp_path / "strict.jsonl")
    with JsonlWriter(path) as w:
        w.write({"loss": float("nan"), "scale": float("inf"),
                 "neg": float("-inf"), "ok": 1.25,
                 "hist": np.arange(4, dtype=np.float32),
                 "jarr": jnp.ones((2, 2)),
                 "nested": {"v": np.float64("nan"), "xs": [np.inf, 2.0]}})
    raw = open(path).read()
    assert "NaN" not in raw and "Infinity" not in raw
    row = read_jsonl(path)[0]
    assert row["loss"] is None and row["scale"] is None
    assert row["neg"] is None and row["ok"] == 1.25
    assert row["hist"] == [0.0, 1.0, 2.0, 3.0]
    assert row["jarr"] == [[1.0, 1.0], [1.0, 1.0]]
    assert row["nested"]["v"] is None
    assert row["nested"]["xs"] == [None, 2.0]


def test_async_writer_slow_sink_does_not_block_and_loses_nothing(tmp_path):
    """The host-offload acceptance: with a sink 1000x slower than a step,
    ``write`` latency stays microseconds (bounded enqueue, not I/O) and a
    clean ``close()`` still lands every accepted row on disk."""
    import time as _time
    path = str(tmp_path / "slow.jsonl")
    w = AsyncJsonlWriter(path, queue_size=256)
    real_sink = w._write_row

    def slow_sink(row):
        _time.sleep(0.01)
        real_sink(row)

    w._write_row = slow_sink
    n = 20
    t0 = _time.perf_counter()
    for i in range(n):
        w.write({"step": i, "loss": 1.0 / (i + 1)})
    enqueue_s = _time.perf_counter() - t0
    # 20 writes through the sync path would take >= 0.2s; the async path
    # must not even be in the same decade
    assert enqueue_s < 0.05, f"write blocked on slow sink: {enqueue_s:.3f}s"
    w.close()
    rows = read_jsonl(path)
    assert [r["step"] for r in rows] == list(range(n))
    assert w.dropped == 0


def test_async_writer_counts_drops_and_logs_event(tmp_path):
    """When the bounded queue backs up, rows are dropped (never blocking
    the step), the drop counter says how many, and close() appends a
    self-describing ``telemetry_writer_drops`` event."""
    import threading as _threading
    path = str(tmp_path / "drops.jsonl")
    w = AsyncJsonlWriter(path, queue_size=2)
    gate = _threading.Event()
    real_sink = w._write_row

    def gated_sink(row):
        gate.wait(timeout=10)
        real_sink(row)

    w._write_row = gated_sink
    for i in range(10):   # 1 in-flight + 2 queued; the rest must drop
        w.write({"step": i})
    assert w.dropped > 0
    dropped = w.dropped
    gate.set()
    w.close()
    assert w.dropped == dropped   # close drains, never drops more
    rows = read_jsonl(path)
    assert rows[-1] == {"event": "telemetry_writer_drops",
                        "dropped": dropped}
    assert len(rows) == 10 - dropped + 1
    # writes after close are counted as dropped, not silently eaten
    w.write({"step": 99})
    assert w.dropped == dropped + 1


def test_trainer_straggler_jsonl_events_and_report(tiny_setup, tmp_path):
    """A flagged straggler step lands in the JSONL log as a structured
    ``{"event": "straggler"}`` row (dt + EMA + factor) and the report
    renders a Stragglers section from it."""
    cfg, model, pipe = tiny_setup
    jsonl = str(tmp_path / "straggler.jsonl")
    tcfg = TrainConfig(recipe="paper_fp4", total_steps=6, global_batch=8,
                       seq_len=64, log_every=0, telemetry_jsonl=jsonl)
    tr = Trainer(model, tcfg, pipe)
    # factor=0 flags every post-first step regardless of host speed —
    # deterministic straggler signal without sleeping in the test
    tr.monitor = StepTimeMonitor(factor=0.0, warmup=0)
    tr.train()
    tr.writer.close()
    rows = read_jsonl(jsonl)
    evs = [r for r in rows if r.get("event") == "straggler"]
    assert evs, "no straggler events written"
    for ev in evs:
        assert ev["step"] in tr.monitor.flagged
        assert ev["dt"] > 0 and ev["ema"] > 0
        assert ev["factor"] == 0.0
    flagged = [r for r in rows if "event" not in r and r.get("straggler")]
    assert {r["step"] for r in flagged} == {e["step"] for e in evs}
    from benchmarks.telemetry_report import build_report
    report = build_report(rows)
    assert "## Stragglers" in report
    assert f"step {evs[0]['step']}:" in report
