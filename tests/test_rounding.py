"""The shared bit-exact integer RTN/SR codec (``kernels.rounding``) vs the
transcendental reference ``formats.round_to_format``.

Acceptance: the integer RTN must match ``round_to_format`` EXACTLY on a
dense grid of exponent-boundary values (where a floor(log2)-based
implementation is most fragile), for every low-bit format, in f32 and bf16.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.formats import FORMATS, format_values, round_to_format
from repro.core.quantize import QuantSpec, pow2_floor, qdq
from repro.kernels.rounding import (hash_uniform, quantize_tile,
                                    round_to_grid, uniform_from_bits)

LOWBIT = [n for n, f in FORMATS.items() if not f.passthrough]


def _boundary_grid(fmt):
    """Dense sweep concentrated at binade edges: 2^e * (1 +- k ulps) for
    every exponent the format's grid spans, plus linspace fill, specials,
    and random covers of the whole clip range."""
    rng = np.random.default_rng(0)
    es = np.arange(fmt.emin - fmt.mbits - 4,
                   int(np.log2(fmt.max_value)) + 3)
    vals = []
    for e in es:
        b = np.float32(2.0 ** e)
        for k in range(-8, 9):
            vals.append(b * (np.float32(1.0) + np.float32(k) *
                             np.float32(2.0 ** -23)))
        vals.extend(np.linspace(b, 2 * b, 53, dtype=np.float32))
    vals = np.asarray(vals, np.float32)
    vals = np.concatenate([
        vals, -vals,
        np.asarray([0.0, fmt.max_value, -fmt.max_value,
                    fmt.max_value * 1.5, fmt.min_subnormal,
                    fmt.min_subnormal * 0.49], np.float32),
        rng.uniform(-2 * fmt.max_value, 2 * fmt.max_value,
                    20000).astype(np.float32),
    ])
    return vals


@pytest.mark.parametrize("name", LOWBIT)
def test_integer_rtn_bit_exact_f32(name):
    fmt = FORMATS[name]
    vals = jnp.asarray(_boundary_grid(fmt))
    a = np.asarray(round_to_grid(vals, fmt))
    b = np.asarray(round_to_format(vals, fmt))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("name", LOWBIT)
def test_integer_rtn_bit_exact_bf16(name):
    fmt = FORMATS[name]
    vals = jnp.asarray(_boundary_grid(fmt)).astype(jnp.bfloat16)
    a = np.asarray(round_to_grid(vals, fmt).astype(jnp.float32))
    b = np.asarray(round_to_format(vals, fmt).astype(jnp.float32))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("name", ["fp4_e2m1", "fp8_e4m3"])
def test_integer_rtn_lands_on_grid(name):
    fmt = FORMATS[name]
    grid = set(np.asarray(format_values(fmt)).tolist())
    vals = jnp.asarray(_boundary_grid(fmt))
    out = np.abs(np.asarray(round_to_grid(vals, fmt)))
    assert set(out.tolist()) <= grid


def test_pow2_floor_exact():
    rng = np.random.default_rng(1)
    s = jnp.asarray(np.exp(rng.uniform(-40, 10, 20000)).astype(np.float32))
    got = np.asarray(pow2_floor(s))
    ref = np.exp2(np.floor(np.log2(np.asarray(s, np.float64)))
                  ).astype(np.float32)
    np.testing.assert_array_equal(got, ref)


def test_sr_mean_unbiased_and_matches_qdq_reference():
    """floor(t + u) SR through the shared codec: (a) the seed-averaged mean
    converges to the input; (b) it agrees with round_to_format's
    jax.random-based SR mean within sampling error."""
    fmt = FORMATS["fp4_e2m1"]
    x = np.linspace(0.01, 5.9, 97, dtype=np.float32)
    n = 4000
    xt = jnp.broadcast_to(jnp.asarray(x), (n, 97))
    noise = hash_uniform((n, 97), jnp.int32(123), 0, 0)
    mean_hash = np.asarray(round_to_grid(xt, fmt, noise)).mean(0)
    keys = jax.random.split(jax.random.PRNGKey(7), 8)
    mean_ref = np.mean([np.asarray(round_to_format(
        xt[:500], fmt, stochastic_key=k)).mean(0) for k in keys], axis=0)
    # top-binade step is 2 -> se ~ 2 * sqrt(p(1-p)/n) <= 0.016; 5 sigma
    assert np.abs(mean_hash - x).max() < 0.08
    assert np.abs(mean_hash - mean_ref).max() < 0.12
    assert abs((mean_hash - x).mean()) < 0.01  # global bias ~ se/sqrt(97)


def test_hash_noise_is_coordinate_keyed():
    """Noise depends only on (seed, global coordinate): offset slicing of a
    larger field reproduces the tile's noise (tiling invariance), and
    different seeds decorrelate."""
    full = np.asarray(hash_uniform((256, 256), jnp.int32(5), 0, 0))
    tile = np.asarray(hash_uniform((128, 128), jnp.int32(5), 128, 64))
    np.testing.assert_array_equal(tile, full[128:256, 64:192])
    other = np.asarray(hash_uniform((256, 256), jnp.int32(6), 0, 0))
    assert np.abs(np.corrcoef(full.ravel(), other.ravel())[0, 1]) < 0.02
    assert 0.45 < full.mean() < 0.55 and full.min() >= 0 and full.max() < 1


def test_uniform_from_bits_range():
    bits = jnp.asarray(np.random.default_rng(2).integers(
        0, 2 ** 32, 10000, dtype=np.uint32))
    u = np.asarray(uniform_from_bits(bits))
    assert u.min() >= 0.0 and u.max() < 1.0


def test_quantize_tile_matches_qdq():
    """The shared tile QDQ helper (used by kernels.quantize) matches the
    core QDQ reference for both granularities it implements."""
    x = jax.random.normal(jax.random.PRNGKey(3), (128, 128), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(quantize_tile(x, FORMATS["fp4_e2m1"], per_row=True)),
        np.asarray(qdq(x, QuantSpec("fp4_e2m1", "block"), 1)))
    np.testing.assert_array_equal(
        np.asarray(quantize_tile(x, FORMATS["fp8_e4m3"], per_row=False)),
        np.asarray(qdq(x, QuantSpec("fp8_e4m3", "tile"), 1)))
