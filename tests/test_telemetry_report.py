"""telemetry_report robustness: degenerate logs (empty, events-only,
rows missing ``tel/`` keys) must render, never raise, and the straggler
section must reflect the JSONL events."""
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.telemetry_report import build_report, sparkline, split_rows
from repro.telemetry.writer import JsonlWriter, read_jsonl


def test_report_empty_log():
    report = build_report([])
    assert "(empty log)" in report
    assert report.startswith("# Quantization telemetry report")


def test_report_empty_file_roundtrip(tmp_path):
    path = str(tmp_path / "empty.jsonl")
    open(path, "w").close()
    assert read_jsonl(path) == []
    assert "(empty log)" in build_report(read_jsonl(path))


def test_report_events_only_log():
    """A crashed run's tail can be all controller events and no step rows;
    the header and decision log must still render."""
    rows = [{"event": "switch", "step": 50, "to": "bf16"},
            {"event": "demote", "step": 60, "cell": "l01/ffn"},
            {"event": "telemetry_writer_drops", "dropped": 3}]
    report = build_report(rows)
    assert "- steps logged: 0" in report
    assert "- controller events: 3" in report
    assert "## Controller decisions" in report
    assert "**switch**" in report and "**demote**" in report
    # no step sections on an events-only log
    assert "## Loss" not in report
    assert "Layer x role" not in report


def test_report_rows_without_tel_keys():
    """log_every-style rows with loss but no telemetry metrics: loss
    sparkline renders, quant sections degrade to their placeholders."""
    rows = [{"step": i, "recipe": "paper_fp4", "loss": 2.0 - 0.1 * i}
            for i in range(5)]
    report = build_report(rows)
    assert "- steps logged: 5" in report
    assert "## Loss" in report
    assert "(no per-layer telemetry in log)" in report
    assert "(no backward-side telemetry in log)" in report
    assert "## Forward quant relative error" not in report
    assert "## Stragglers" not in report


def test_report_null_metrics_from_strict_writer(tmp_path):
    """NaN metrics arrive as null after the writer's strict-JSON pass;
    series() must skip-or-cope, not crash the report."""
    path = str(tmp_path / "nulls.jsonl")
    with JsonlWriter(path) as w:
        w.write({"step": 0, "recipe": "paper_fp4", "loss": 1.5})
        w.write({"step": 1, "recipe": "paper_fp4", "loss": float("nan"),
                 "grad_norm": float("inf")})
    rows = read_jsonl(path)
    assert rows[1]["loss"] is None
    with pytest.raises(TypeError):
        build_report(rows)  # nulls in a numeric series are a loud error...
    # ...so report-level consumers drop null metrics first:
    cleaned = [{k: v for k, v in r.items() if v is not None} for r in rows]
    report = build_report(cleaned)
    assert "## Loss" in report and "first=1.5" in report


def test_report_straggler_events_rendered():
    rows = [{"step": 0, "recipe": "paper_fp4", "loss": 2.0},
            {"step": 1, "recipe": "paper_fp4", "loss": 1.9,
             "straggler": True},
            {"event": "straggler", "step": 1, "dt": 0.5, "ema": 0.1,
             "factor": 2.5}]
    report = build_report(rows)
    assert "## Stragglers" in report
    assert "steps flagged by StepTimeMonitor: [1]" in report
    assert "- step 1: 500ms vs EMA 100ms (x5.0)" in report
    # straggler events are evidence, not controller decisions
    assert "**straggler**" not in report


def test_split_rows_and_sparkline_degenerate():
    steps, events = split_rows([{"step": 0}, {"event": "x"}])
    assert len(steps) == 1 and len(events) == 1
    assert sparkline([]) == ""
    assert len(sparkline([1.0] * 500, width=40)) == 40
    assert sparkline([5.0]) in "▁▂▃▄▅▆▇█"
