"""Serving-path contracts: prefill+decode == full forward, per family."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.recipe import RECIPES
from repro.models import build_model
from repro.train.serve import generate


def _model(arch, **over):
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))
    cfg = mod.REDUCED.replace(dtype="float32", **over)
    if cfg.moe is not None:
        # GShard capacity drops depend on batch composition, so prefill-vs-
        # full consistency only holds in the DROPLESS regime (a documented
        # property of capacity-based routing, not a bug).
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    return build_model(cfg), cfg


def _consistency(arch, s=24, n_dec=6, tol=1e-4, extras_fn=None, **over):
    model, cfg = _model(arch, **over)
    params = model.init(jax.random.PRNGKey(0))
    r = RECIPES["bf16"]
    b = 2
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    extras = extras_fn(cfg, b) if extras_fn else {}
    full, _ = model.forward(params, dict(extras, tokens=toks, targets=toks),
                            r)
    cache = model.init_cache(b, s + 4, dtype=jnp.float32)
    lg, cache = model.prefill(params, dict(extras, tokens=toks[:, :s - n_dec]),
                              cache, r)
    errs = [float(jnp.max(jnp.abs(lg[:, 0] - full[:, s - n_dec - 1])))]
    for t in range(s - n_dec, s):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache, r)
        if t < s - 1:
            errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert max(errs) < tol, errs


def test_dense_consistency():
    _consistency("tiny")


def test_gqa_dense_consistency():
    _consistency("llama3.2-3b")


def test_mqa_consistency():
    _consistency("granite-34b")


def test_swa_ring_buffer_consistency():
    # window smaller than sequence: ring wraps during decode
    _consistency("h2o-danube-3-4b", s=24, tol=2e-4)


def test_moe_consistency():
    _consistency("mixtral-8x22b", tol=5e-4)


def test_mamba_consistency():
    _consistency("mamba2-780m", tol=5e-4)


@pytest.mark.slow
def test_hybrid_consistency():
    _consistency("jamba-1.5-large-398b", tol=1e-3)


@pytest.mark.slow
def test_vlm_consistency():
    def vis(cfg, b):
        return {"vision": jax.random.normal(
            jax.random.PRNGKey(9), (b, cfg.n_patches, cfg.d_model),
            jnp.float32)}
    _consistency("llama-3.2-vision-90b", extras_fn=vis, tol=5e-4)


@pytest.mark.slow
def test_whisper_consistency():
    def frames(cfg, b):
        return {"frames": jax.random.normal(
            jax.random.PRNGKey(9), (b, cfg.n_frames, cfg.d_model),
            jnp.float32)}
    _consistency("whisper-base", extras_fn=frames, tol=5e-4)


def test_generate_greedy_deterministic():
    model, cfg = _model("tiny")
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    out1 = generate(model, params, prompts, max_new_tokens=8, jit=False)
    out2 = generate(model, params, prompts, max_new_tokens=8, jit=False)
    assert out1.shape == (2, 16)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(out1[:, :8]),
                                  np.asarray(prompts))
