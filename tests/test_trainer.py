"""Trainer: convergence, schedule switch, grad-accum equivalence,
compression, straggler monitor."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig, get_config
from repro.core.recipe import RECIPES
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train.train_step import make_optimizer, make_train_step
from repro.train.trainer import StepTimeMonitor, Trainer


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("tiny")
    model = build_model(cfg)
    pipe = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
    return cfg, model, pipe


def test_loss_decreases_and_schedule_switches(tiny_setup):
    cfg, model, pipe = tiny_setup
    tcfg = TrainConfig(recipe="paper_fp4", total_steps=40, global_batch=8,
                       seq_len=64, learning_rate=3e-3, log_every=0)
    tr = Trainer(model, tcfg, pipe)
    st = tr.train()
    assert tr.history[-1]["loss"] < tr.history[0]["loss"] - 0.3
    recipes = [r["recipe"] for r in tr.history]
    assert recipes[0] == "paper_fp4" and recipes[-1] == "bf16"
    # switch at 1 - 0.075 of 40 = step 37
    assert recipes[36] == "paper_fp4" and recipes[37] == "bf16"


def test_grad_accumulation_equivalence(tiny_setup):
    """mean-of-microbatch-grads == full-batch grads (equal token counts).

    Compared at the GRADIENT level: post-Adam params are ill-conditioned to
    bf16 forward noise (g/sqrt(v) at step 1 amplifies any reordering)."""
    cfg, model, pipe = tiny_setup
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    params = model.init(jax.random.PRNGKey(0))

    def loss_fn(p, b):
        return model.loss(p, b, RECIPES["bf16"])[0]

    g_full = jax.grad(loss_fn)(params, batch)
    k = 4
    mbs = jax.tree.map(lambda x: x.reshape(k, -1, *x.shape[1:]), batch)
    g_acc = None
    for i in range(k):
        g_i = jax.grad(loss_fn)(params, jax.tree.map(lambda x: x[i], mbs))
        g_acc = g_i if g_acc is None else jax.tree.map(jnp.add, g_acc, g_i)
    g_acc = jax.tree.map(lambda x: x / k, g_acc)
    # bf16 forward noise reorders reductions between the two slicings; the
    # embedding grads (long scatter-add chains) see the largest wobble
    # (~7e-4 absolute).  Agreement is to bf16 noise, not bit-exact.
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=1e-3)
    # and the trainer's scan-based accumulation path produces the same loss
    tcfg = TrainConfig(recipe="bf16", total_steps=10, global_batch=8,
                       seq_len=64, learning_rate=1e-3, microbatch=4)
    step = make_train_step(model, tcfg, RECIPES["bf16"], jit=True,
                           donate=False)
    opt_state = make_optimizer(model, tcfg).init(params)
    _, _, _, m = step(params, opt_state, jnp.zeros(()), batch,
                      jnp.asarray(0))
    assert abs(float(m["loss"]) - float(loss_fn(params, batch))) < 5e-3


def test_fp8_grad_compression_trains(tiny_setup):
    cfg, model, pipe = tiny_setup
    tcfg = TrainConfig(recipe="bf16", total_steps=30, global_batch=8,
                       seq_len=64, learning_rate=3e-3,
                       grad_compression="fp8", log_every=0)
    tr = Trainer(model, tcfg, pipe)
    st = tr.train()
    assert tr.history[-1]["loss"] < tr.history[0]["loss"] - 0.3


def test_eval_returns_ppl(tiny_setup):
    cfg, model, pipe = tiny_setup
    tcfg = TrainConfig(recipe="bf16", total_steps=5, global_batch=8,
                       seq_len=64, log_every=0)
    tr = Trainer(model, tcfg, pipe)
    st = tr.train()
    ev = tr.evaluate(st, n_batches=2)
    assert ev["val_ppl"] == pytest.approx(np.exp(ev["val_loss"]), rel=1e-6)


def test_straggler_monitor_flags_outliers():
    mon = StepTimeMonitor(factor=2.0, warmup=3)
    flagged = []
    for i, dt in enumerate([1.0] * 10 + [5.0] + [1.0] * 3):
        if mon.record(i, dt):
            flagged.append(i)
    assert flagged == [10]


def test_lr_schedule_shape():
    from repro.optim.schedule import warmup_cosine
    lr = warmup_cosine(1e-3, 1000, warmup_frac=0.1, min_frac=0.1)
    assert float(lr(0)) < float(lr(99))           # warming up
    assert float(lr(100)) == pytest.approx(1e-3, rel=1e-2)  # peak
    assert float(lr(999)) == pytest.approx(1e-4, rel=5e-2)  # decayed to 10%
