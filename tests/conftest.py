import os

# Tests run single-device (the dry-run alone uses 512 host devices, in its
# own process).  Keep compilation deterministic and quiet.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest

jax.config.update("jax_default_matmul_precision", "highest")

# hypothesis is optional (pip install -e '.[test]'): without it the
# @given property tests skip (via requires_hypothesis) and everything else
# still runs.  Test modules import the shim: from conftest import given, ...
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*a, **k):
        return lambda f: f

    settings = given

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

requires_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def assert_trees_close(a, b, rtol=1e-5, atol=1e-5):
    import jax.numpy as jnp
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32),
                                   rtol=rtol, atol=atol)
