import os

# Tests run single-device (the dry-run alone uses 512 host devices, in its
# own process).  Keep compilation deterministic and quiet.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest

jax.config.update("jax_default_matmul_precision", "highest")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def assert_trees_close(a, b, rtol=1e-5, atol=1e-5):
    import jax.numpy as jnp
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32),
                                   rtol=rtol, atol=atol)
