"""Chunked flash attention vs naive oracle; caches; SWA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import flash_attention_ref
from repro.models.attention import (chunked_attention, init_attn_cache,
                                    _update_cache)


def _qkv(b=2, s=128, h=4, kvh=2, d=32, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("chunk", [16, 37, 128, 200])
@pytest.mark.parametrize("unroll", [False, True])
def test_chunked_matches_naive_causal(chunk, unroll):
    q, k, v = _qkv()
    pos = jnp.arange(128, dtype=jnp.int32)
    out = chunked_attention(q, k, v, pos, pos, causal=True, chunk=chunk,
                            unroll=unroll)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_noncausal_matches_naive():
    q, k, v = _qkv()
    pos = jnp.arange(128, dtype=jnp.int32)
    out = chunked_attention(q, k, v, pos, pos, causal=False, chunk=32)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_sliding_window_masks_far_tokens():
    """Output at position p must not depend on keys older than the window."""
    q, k, v = _qkv(s=64)
    pos = jnp.arange(64, dtype=jnp.int32)
    w = 16
    out = chunked_attention(q, k, v, pos, pos, causal=True, window=w,
                            chunk=16)
    # perturb keys/values at positions < 32; outputs at p >= 48 (p - kpos
    # >= w for all perturbed kpos) must be identical
    k2 = k.at[:, :32].add(100.0)
    v2 = v.at[:, :32].add(-50.0)
    out2 = chunked_attention(q, k2, v2, pos, pos, causal=True, window=w,
                             chunk=16)
    np.testing.assert_allclose(np.asarray(out[:, 48:]),
                               np.asarray(out2[:, 48:]), rtol=1e-5,
                               atol=1e-5)
    assert float(jnp.abs(out[:, :30] - out2[:, :30]).max()) > 0


def test_invalid_cache_slots_are_masked():
    """k_pos == -1 (unwritten ring slots) must contribute nothing."""
    q, k, v = _qkv(s=32)
    pos = jnp.arange(32, dtype=jnp.int32)
    kpos = pos.at[20:].set(-1)
    out = chunked_attention(q, k, v, pos, kpos, causal=True, chunk=8)
    ref = flash_attention_ref(q[:, :], k[:, :20], v[:, :20], causal=False)
    # compare only queries >= 19 which see all 20 valid keys causally
    np.testing.assert_allclose(np.asarray(out[:, 19]),
                               np.asarray(ref[:, 19]), rtol=1e-4, atol=1e-4)


def test_fully_masked_chunk_guard():
    """A chunk where every key is masked must not produce NaNs."""
    q, k, v = _qkv(s=16)
    pos = jnp.arange(16, dtype=jnp.int32)
    kpos = jnp.full((16,), -1, jnp.int32)  # everything invalid
    out = chunked_attention(q, k, v, pos, kpos, causal=True, chunk=4)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_ring_buffer_update_wraps():
    from repro.configs.base import get_config
    cfg = get_config("tiny").replace(sliding_window=8)
    cache = init_attn_cache(cfg, batch=1, max_len=8)
    hd = cfg.resolved_head_dim
    for t in range(12):
        kt = jnp.full((1, 1, cfg.n_kv_heads, hd), float(t))
        new, k_all, v_all, kpos = _update_cache(
            cache, kt, kt, jnp.asarray(t), cfg.sliding_window)
        cache = new
    # slots hold positions 4..11 (last 8), wrapped
    assert sorted(np.asarray(cache["pos"]).tolist()) == list(range(4, 12))
    slot_of_11 = int(np.where(np.asarray(cache["pos"]) == 11)[0][0])
    assert slot_of_11 == 11 % 8
    np.testing.assert_array_equal(np.asarray(cache["k"][0, slot_of_11, 0]),
                                  11.0)
