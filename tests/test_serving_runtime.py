"""Serving runtime: weight-only quantization, streaming prefill, batching."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.recipe import RECIPES
from repro.models import build_model
from repro.train.serving_runtime import (ContinuousBatcher,
                                         quantize_weights_for_serving,
                                         streaming_prefill)


@pytest.fixture(scope="module")
def tiny():
    cfg = importlib.import_module("repro.configs.tiny").CONFIG.replace(
        dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_weight_only_quant_keeps_logits_close(tiny):
    cfg, model, params = tiny
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    base, _ = model.forward(params, batch, RECIPES["bf16"])
    # random-init weights are the worst case for weight-only quant (~2^-m
    # relative noise per layer compounds); contracts here are boundedness,
    # fp8-closer-than-fp4 ordering, and protected-param identity.
    for fmt, tol in (("fp8_e4m3", 1.0), ("fp4_e2m1", 3.0)):
        qp = quantize_weights_for_serving(model, params, fmt)
        out, _ = model.forward(qp, batch, RECIPES["bf16"])
        err = float(jnp.abs(out - base).max())
        assert err < tol, (fmt, err)
        # protected params untouched
        np.testing.assert_array_equal(
            np.asarray(qp["final_norm"]["scale"]),
            np.asarray(params["final_norm"]["scale"]))
    # fp8 weight-only is strictly closer than fp4 (sanity ordering)
    e8 = float(jnp.abs(model.forward(quantize_weights_for_serving(
        model, params, "fp8_e4m3"), batch, RECIPES["bf16"])[0] - base).max())
    e4 = float(jnp.abs(model.forward(quantize_weights_for_serving(
        model, params, "fp4_e2m1"), batch, RECIPES["bf16"])[0] - base).max())
    assert e8 < e4


def test_streaming_prefill_matches_one_shot(tiny):
    cfg, model, params = tiny
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 48), 0,
                              cfg.vocab_size)
    r = RECIPES["bf16"]
    c1 = model.init_cache(2, 64, dtype=jnp.float32)
    lg1, c1 = model.prefill(params, {"tokens": toks}, c1, r)
    c2 = model.init_cache(2, 64, dtype=jnp.float32)
    lg2, c2 = streaming_prefill(model, params, toks, c2, r, segment=16)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), rtol=1e-4,
                               atol=1e-4)
    assert int(c1["length"]) == int(c2["length"]) == 48
    # decoding from both caches agrees
    t = toks[:, -1:]
    d1, _ = model.decode_step(params, t, c1, r)
    d2, _ = model.decode_step(params, t, c2, r)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-4,
                               atol=1e-4)


def test_streaming_prefill_mamba():
    cfg = importlib.import_module("repro.configs.mamba2_780m").REDUCED
    cfg = cfg.replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 64), 0,
                              cfg.vocab_size)
    r = RECIPES["bf16"]
    c1 = model.init_cache(1, 80, dtype=jnp.float32)
    lg1, _ = model.prefill(params, {"tokens": toks}, c1, r)
    c2 = model.init_cache(1, 80, dtype=jnp.float32)
    lg2, _ = streaming_prefill(model, params, toks, c2, r, segment=16)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), rtol=2e-3,
                               atol=2e-3)


def test_continuous_batcher_matches_sequential(tiny):
    cfg, model, params = tiny
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (8, 12, 5, 9, 7)]
    new = [4, 3, 5, 2, 4]
    # reference: sequential generation per request
    from repro.train.serve import generate
    ref = {}
    for i, (p, n) in enumerate(zip(prompts, new)):
        out = generate(model, params, jnp.asarray(p[None]),
                       max_new_tokens=n, recipe=RECIPES["bf16"], jit=False)
        ref[i] = np.asarray(out[0, len(p):]).tolist()
    # continuous batching with 2 slots over 5 requests
    b = ContinuousBatcher(model, params, n_slots=2, max_len=64)
    ids = [b.submit(p, n) for p, n in zip(prompts, new)]
    got = b.run()
    assert sorted(got) == sorted(ids)
    for i in ids:
        assert got[i] == ref[i], (i, got[i], ref[i])
