"""Step/phase profiler: percentiles, StepTimer warmup/window/MFU,
train-step flop accounting, span no-op safety."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.core.cost_model import ModelDims
from repro.telemetry.profiler import (PHASES, StepTimer, device_peak_flops,
                                      graph_span, percentiles, phase_span,
                                      train_step_flops)


# ---------------------------------------------------------------------------
# percentiles (nearest-rank)
# ---------------------------------------------------------------------------

def test_percentiles_nearest_rank_exact():
    xs = list(range(1, 101))  # 1..100: pN is exactly N (nearest rank)
    p = percentiles(xs)
    assert p == {"p50": 50.0, "p95": 95.0, "p99": 99.0}
    # order-independent, no interpolation ever (values come FROM the data)
    p = percentiles([3.0, 1.0, 2.0])
    assert p["p50"] == 2.0 and p["p95"] == 3.0 and p["p99"] == 3.0
    assert percentiles([7.0])["p50"] == 7.0


def test_percentiles_empty_is_nan():
    p = percentiles([])
    assert all(v != v for v in p.values())  # NaN
    assert set(p) == {"p50", "p95", "p99"}


def test_percentiles_custom_qs():
    p = percentiles(list(range(1, 11)), qs=(10.0, 90.0))
    assert p == {"p10": 1.0, "p90": 9.0}


# ---------------------------------------------------------------------------
# StepTimer
# ---------------------------------------------------------------------------

def test_step_timer_warmup_excluded_and_window_bounded():
    t = StepTimer(warmup=2, window=4)
    for dt in (99.0, 88.0):      # compile-time outliers: counted, excluded
        t.record(dt)
    assert t.n_total == 2 and t.times == []
    assert t.summary() == {"steps": 0, "warmup": 2, "spikes": 0}
    for dt in (1.0, 2.0, 3.0, 4.0, 5.0):   # 5 post-warmup, window keeps 4
        t.record(dt)
    assert t.times == [2.0, 3.0, 4.0, 5.0]
    s = t.summary()
    assert s["steps"] == 4
    assert s["p50_ms"] == 3.0e3 and s["p99_ms"] == 5.0e3
    assert s["mean_ms"] == pytest.approx(3.5e3)


def test_step_timer_recompile_spike_excluded():
    """A post-warmup recompilation (e.g. a controller plan edit) must not
    drag the percentiles: records > spike_factor x window median are
    counted/reported separately, not kept."""
    t = StepTimer(warmup=0, spike_factor=20.0)
    for dt in (0.10, 0.11, 0.09, 0.10):
        t.record(dt)
    t.record(3.27)               # the old baseline's p95=3.27s pathology
    assert t.n_spikes == 1
    assert 3.27 not in t.times and len(t.times) == 4
    s = t.summary()
    assert s["spikes"] == 1
    assert s["spike_max_ms"] == pytest.approx(3270.0)
    assert s["p95_ms"] == pytest.approx(110.0)  # spike-free percentiles
    t.record(0.10)               # normal steps keep flowing afterwards
    assert len(t.times) == 5 and t.n_spikes == 1


def test_step_timer_spike_filter_needs_a_median():
    """The first 3 post-warmup records are always kept — there is no
    median to judge against yet (a slow-but-real first step must not be
    silently dropped)."""
    t = StepTimer(warmup=0, spike_factor=20.0)
    for dt in (5.0, 0.1, 0.1):
        t.record(dt)
    assert t.times == [5.0, 0.1, 0.1] and t.n_spikes == 0
    t.record(5.0)                # now 5.0 > 20 x median(=0.1): spike
    assert t.n_spikes == 1 and len(t.times) == 3


def test_step_timer_spike_filter_disabled():
    t = StepTimer(warmup=0, spike_factor=None)
    for dt in (0.1, 0.1, 0.1, 99.0):
        t.record(dt)
    assert t.n_spikes == 0 and 99.0 in t.times
    assert "spike_max_ms" not in t.summary()


def test_step_timer_summary_throughput_and_mfu():
    t = StepTimer(warmup=0)
    for _ in range(5):
        t.record(0.5)   # p50 = 0.5s
    s = t.summary(tokens_per_step=1024, flops_per_step=2e9, peak_flops=1e10)
    assert s["tokens_per_sec"] == pytest.approx(2048.0)
    assert s["flops_per_sec"] == pytest.approx(4e9)
    assert s["mfu"] == pytest.approx(0.4)


def test_step_timer_time_call_blocks_and_returns():
    t = StepTimer(warmup=0)
    out = t.time_call(lambda x: x * 2, jnp.ones((4,)))
    assert out.tolist() == [2.0] * 4
    assert len(t.times) == 1 and t.times[0] > 0


# ---------------------------------------------------------------------------
# flops / MFU helpers
# ---------------------------------------------------------------------------

def test_train_step_flops_is_3x_forward():
    dims = ModelDims.from_config(get_config("tiny"), seq_len=64)
    tokens = 8 * 64
    assert train_step_flops(dims, tokens) == 3.0 * dims.total_fwd_flops * tokens


def test_device_peak_flops_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_PEAK_FLOPS", "1.23e14")
    assert device_peak_flops() == 1.23e14
    monkeypatch.delenv("REPRO_PEAK_FLOPS")
    assert device_peak_flops() > 0  # table/CPU fallback, never raises


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_phase_span_is_safe_noop_wrapper():
    for name in PHASES:
        with phase_span(name):
            pass  # always-on: must never raise outside a capture


def test_graph_span_pure_metadata_bit_identical():
    """named_scope must not change the compiled computation."""
    x = jnp.arange(8, dtype=jnp.float32)

    def plain(x):
        return jnp.sum(x * x)

    def spanned(x):
        with graph_span("fwd"):
            y = x * x
        with graph_span("collective"):
            return jnp.sum(y)

    a = jax.jit(plain)(x)
    b = jax.jit(spanned)(x)
    assert float(a) == float(b)
    # identical lowered program shape (metadata-only difference; the name
    # itself only survives into debug/xprof metadata, not the default text)
    assert jax.jit(spanned).lower(x).as_text() is not None


def test_graph_span_differentiable():
    def f(x):
        with graph_span("quantize"):
            return jnp.sum(x ** 3)
    g = jax.grad(f)(jnp.full((3,), 2.0))
    assert g.tolist() == [12.0] * 3
