"""Sharding rules (divisibility/granules/conflicts) + subprocess SPMD test."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import get_config
from repro.distributed.sharding import default_rules, opt_state_shardings


@pytest.fixture(scope="module")
def mesh1():
    # 1-device (1,1) mesh: rule logic is device-count independent
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def _fake_mesh(shape, axes):
    """Rule-evaluation-only mesh (never used to place data)."""
    class FakeMesh:
        def __init__(self):
            self.shape = dict(zip(axes, shape))
            self.axis_names = axes
            self.size = int(np.prod(shape))
    return FakeMesh()


def test_divisible_dims_get_sharded():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    cfg = get_config("nemotron-4-15b")
    rules = default_rules(mesh, cfg)
    spec = rules._spec(rules.param_rules, ("embed", "heads"), (6144, 6144))
    assert spec == P("data", "model")


def test_nondivisible_granule_replicates():
    """llama3.2-3b: 24 heads % 16 -> heads replicated (baseline finding)."""
    mesh = _fake_mesh((16, 16), ("data", "model"))
    cfg = get_config("llama3.2-3b")
    rules = default_rules(mesh, cfg)
    spec = rules._spec(rules.param_rules, ("embed", "heads"), (3072, 3072))
    assert spec == P("data", None)


def test_kv_heads_replicated_when_fewer_than_tp():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    cfg = get_config("nemotron-4-15b")  # kv=8 < tp=16
    rules = default_rules(mesh, cfg)
    spec = rules._spec(rules.param_rules, ("embed", "kv_heads"),
                       (6144, 1024))
    assert spec == P("data", None)


def test_odd_vocab_replicates():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    cfg = get_config("whisper-base")  # vocab 51865 % 16 != 0
    rules = default_rules(mesh, cfg)
    spec = rules._spec(rules.param_rules, ("vocab", "embed"), (51865, 512))
    assert spec == P(None, "data")


def test_multi_pod_prefix_fallback():
    """batch=32 over ('pod','data')=32 shards fully; batch=1 replicates."""
    mesh = _fake_mesh((2, 16, 16), ("pod", "data", "model"))
    rules = default_rules(mesh, get_config("tiny"))
    s32 = rules._spec(rules.act_rules, ("batch", None), (32, 7))
    assert s32 == P(("pod", "data"), None)
    s1 = rules._spec(rules.act_rules, ("batch", None), (1, 7))
    assert s1 == P(None, None)


def test_mesh_axis_used_once():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    cfg = get_config("olmoe-1b-7b")
    rules = default_rules(mesh, cfg)
    # experts and mlp both want 'model'; only the first gets it
    spec = rules._spec(rules.param_rules, ("experts", "embed", "mlp"),
                       (64, 2048, 1024))
    assert spec == P("model", "data", None)


def test_ep_vs_tp_in_expert():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    olmoe = default_rules(mesh, get_config("olmoe-1b-7b"))
    mixtral = default_rules(mesh, get_config("mixtral-8x22b"))
    # olmoe: 64 experts % 16 == 0 -> EP on the expert dim
    assert olmoe._spec(olmoe.param_rules, ("experts", "embed", "mlp"),
                       (64, 2048, 1024))[0] == "model"
    # mixtral: 8 experts % 16 != 0 -> expert dim replicated, d_ff TP
    s = mixtral._spec(mixtral.param_rules, ("experts", "embed", "mlp"),
                      (8, 6144, 16384))
    assert s == P(None, "data", "model")


def test_opt_state_shardings_adamw(mesh1):
    from repro.optim import adamw
    import jax.numpy as jnp
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    opt = adamw()
    state = opt.init(params)
    from jax.sharding import NamedSharding
    psh = {"w": NamedSharding(mesh1, P("data", "model")),
           "b": NamedSharding(mesh1, P(None))}
    osh = opt_state_shardings(state, params, psh, mesh1)
    assert osh.mu["w"].spec == P("data", "model")
    assert osh.count.spec == P()


def test_opt_state_shardings_adafactor(mesh1):
    from repro.optim import adafactor
    import jax.numpy as jnp
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    state = adafactor().init(params)
    from jax.sharding import NamedSharding
    psh = {"w": NamedSharding(mesh1, P("data", "model")),
           "b": NamedSharding(mesh1, P(None))}
    osh = opt_state_shardings(state, params, psh, mesh1)
    assert osh.vr["w"].spec == P("data")     # rows keep row sharding
    assert osh.vc["w"].spec == P("model")    # cols keep col sharding


DRYRUN_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, json
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.configs.base import get_config, TrainConfig, ShapeCell
    from repro.distributed.mesh import make_mesh
    from repro.distributed.sharding import default_rules
    from repro.launch import specs as specs_lib
    from repro.models import build_model
    from repro.core.recipe import RECIPES
    from repro.train.train_step import make_train_step
    from repro.nn.layers import set_sharding_context

    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = get_config("tiny").replace(scan_layers=True)
    model = build_model(cfg)
    rules = default_rules(mesh, cfg)
    cell = ShapeCell("t", 64, 4, "train")
    tcfg = TrainConfig(recipe="paper_fp4", total_steps=10,
                       global_batch=4, seq_len=64)
    fn = make_train_step(model, tcfg, RECIPES["paper_fp4"], jit=False)
    args, shardings = specs_lib.train_inputs(model, tcfg, cell, rules)
    set_sharding_context(rules)
    with mesh:
        compiled = jax.jit(fn, in_shardings=shardings).lower(*args).compile()
    txt = compiled.as_text()
    n_coll = sum(txt.count(k) for k in
                 ("all-reduce", "all-gather", "reduce-scatter"))
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # one entry per partition on some jax
        ca = ca[0] if ca else {}
    print(json.dumps({"ok": True, "collectives": n_coll,
                      "flops": ca.get("flops", 0)}))
""")


@pytest.mark.slow
def test_spmd_train_step_compiles_on_8_fake_devices():
    """End-to-end SPMD lower+compile in a subprocess (needs its own
    XLA_FLAGS before jax import)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(
                   os.path.join(os.path.dirname(__file__), "..", "src")))
    # force CPU: --xla_force_host_platform_device_count only applies there,
    # and auto-detecting backends can stall for minutes probing TPU metadata
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", DRYRUN_SNIPPET], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["collectives"] > 0


ELASTIC_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import get_config, TrainConfig
    from repro.data import SyntheticLM
    from repro.distributed.mesh import make_mesh
    from repro.distributed.sharding import default_rules
    from repro.distributed.elastic import choose_mesh_shape, reshard
    from repro.models import build_model
    from repro.core.recipe import RECIPES
    from repro.train.train_step import make_optimizer, make_train_step

    cfg = get_config("tiny")
    model = build_model(cfg)
    tcfg = TrainConfig(recipe="bf16", total_steps=10, global_batch=8,
                       seq_len=32, learning_rate=1e-3)
    pipe = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
    step_fn = make_train_step(model, tcfg, RECIPES["bf16"], jit=True,
                              donate=False)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer(model, tcfg)
    opt_state = opt.init(params)

    def run_step(params, opt_state, mesh, i):
        rules = default_rules(mesh, cfg)
        shard = rules.param_shardings(model.param_specs())
        params = reshard(params, shard)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        with mesh:
            p, o, _, m = step_fn(params, opt_state, jnp.zeros(()), batch,
                                 jnp.asarray(i))
        return p, o, float(m["loss"])

    # steps 0-1 on an 8-device (2,4) mesh
    mesh8 = make_mesh((2, 4), ("data", "model"))
    params, opt_state, l0 = run_step(params, opt_state, mesh8, 0)
    # "lose" 4 devices -> rescale to (1,4) over the survivors and continue
    shape = choose_mesh_shape(4, prefer_model=4)
    mesh4 = make_mesh(shape, ("data", "model"), devices=jax.devices()[:4])
    params = jax.tree.map(lambda x: np.asarray(x), params)   # host round-trip
    opt_state = jax.tree.map(lambda x: np.asarray(x), opt_state)
    params, opt_state, l1 = run_step(params, opt_state, mesh4, 1)
    print(json.dumps({"ok": True, "l0": l0, "l1": l1,
                      "shape": list(shape)}))
""")


@pytest.mark.slow
def test_elastic_rescale_across_device_counts():
    """Train a step on 8 devices, lose half, reshard, keep training."""
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(
                   os.path.join(os.path.dirname(__file__), "..", "src")))
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", ELASTIC_SNIPPET], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["shape"] == [1, 4]
    assert np.isfinite(res["l0"]) and np.isfinite(res["l1"])


# ---------------------------------------------------------------------------
# data-parallel structure on the rules (mesh-native train path)
# ---------------------------------------------------------------------------

def test_dp_axes_and_size():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    cfg = get_config("llama-1b")
    rules = default_rules(mesh, cfg)
    assert rules.dp_axes == ("data",)
    assert rules.dp_size == 16


def test_manual_over_strips_data_axes_only():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    cfg = get_config("llama-1b")
    rules = default_rules(mesh, cfg)
    inner = rules.manual_over(("data",))
    assert inner.dp_axes == ()
    assert inner.act_rules["batch"] is None
    # model-axis assignments survive
    assert inner.param_rules["mlp"] == rules.param_rules["mlp"]
    assert "model" in (inner.param_rules["mlp"] or ())


def test_scale_logical_axes_policy():
    from repro.core.quantize import scale_logical_axes
    axes = ("tokens", "embed")
    assert scale_logical_axes("tensor", 1, axes) == ()
    # token scales collapse the reduction dim, replicate along it
    assert scale_logical_axes("token", 1, axes) == ("tokens", None)
    assert scale_logical_axes("token", 0, axes) == (None, "embed")
    # block/tile scale grids ride their operand's reduction axis
    assert scale_logical_axes("block", 1, axes) == ("tokens", "embed", None)
    assert scale_logical_axes("block", 0, axes) == ("tokens", None, "embed")
    assert scale_logical_axes("tile", 1, axes) == ("tokens", None,
                                                   "embed", None)
    with pytest.raises(ValueError):
        scale_logical_axes("bogus", 1, axes)


def test_production_mesh_routes_through_make_mesh(monkeypatch):
    from repro.distributed import mesh as mesh_mod
    from repro.launch.mesh import make_production_mesh
    calls = {}

    def fake_make_mesh(shape, axes, devices=None, axis_types=None):
        calls["shape"], calls["axes"] = shape, axes
        calls["axis_types"] = axis_types
        return "mesh"

    monkeypatch.setattr("repro.launch.mesh.make_mesh", fake_make_mesh)
    assert make_production_mesh() == "mesh"
    assert calls["shape"] == (16, 16)
    assert calls["axes"] == ("data", "model")
    assert calls["axis_types"] == ("auto", "auto")
    assert make_production_mesh(multi_pod=True) == "mesh"
    assert calls["shape"] == (2, 16, 16)
    assert calls["axes"] == ("pod", "data", "model")


def test_make_mesh_axis_types_validation():
    from repro.distributed.mesh import make_mesh
    with pytest.raises(ValueError):
        make_mesh((1,), ("data",), axis_types=("auto", "auto"))
    with pytest.raises(ValueError):
        make_mesh((1,), ("data",), axis_types=("bogus",))
    m = make_mesh((1,), ("data",), axis_types=("auto",))
    assert m.axis_names == ("data",)
