"""qlint static analyzer: HLO walker parsing, comms payload audit,
fallback-reason vocabulary, seeded role-safety violations, recompile
census, and the expectations gate."""
import dataclasses
import json

from repro.analysis import qlint
from repro.analysis.hlo import (HloOp, collective_bytes, parse_collectives,
                                walk_hlo)
from repro.analysis.qlint import (Finding, QlintReport, audit_hlo_comms,
                                  audit_scale_placement,
                                  compare_expectations,
                                  expectations_payload)
from repro.configs.base import TrainConfig, get_config
from repro.core.qlinear import kernel_quant_mode, kernel_unsupported_reason
from repro.core.quantize import QuantSpec
from repro.core.recipe import RECIPES, PrecisionPlan


# ---------------------------------------------------------------------------
# shared HLO walker
# ---------------------------------------------------------------------------

_HLO = """\
HloModule jit_step, entry_computation_layout={()->f32[]}

ENTRY %main {
  %p0 = f32[8,16]{1,0} parameter(0)
  %ar = f16[1024,512]{1,0} all-reduce(%x), replica_groups={{0,1}}, \
metadata={op_name="jit(train_step)/jit(main)/collective/add"}
  %ags = (bf16[8]{0}, bf16[16]{0}) all-gather-start(%p1), dimensions={0}
  %agd = bf16[16]{0} all-gather-done(%ags)
  %amax = f32[] all-reduce(%m), to_apply=%max_f32, \
metadata={op_name="jit(train_step)/jit(main)/collective/reduce_max"}
  %add.7 = f32[8,16]{1,0} add(%p0, %p0)
}
"""


def test_walk_hlo_parses_ops_shapes_and_metadata():
    ops = {op.mnemonic: op for op in walk_hlo(_HLO)}
    assert "parameter" in ops and "add" in ops
    ar = ops["all-reduce"]
    assert isinstance(ar, HloOp)
    assert ar.base == "all-reduce" and ar.variant == ""
    ag = ops["all-gather-start"]
    assert ag.base == "all-gather" and ag.variant == "-start"
    # async -start tuples keep every buffer; payload is the largest
    assert ag.payload_shape() == ("bf16", "16")
    assert ops["all-gather-done"].variant == "-done"


def test_walk_hlo_op_name_extraction():
    ops = [op for op in walk_hlo(_HLO) if op.op_name]
    paths = {op.op_name for op in ops}
    assert "jit(train_step)/jit(main)/collective/add" in paths
    assert "jit(train_step)/jit(main)/collective/reduce_max" in paths


def test_parse_collectives_counts_start_once():
    ops = parse_collectives(_HLO)
    kinds = sorted(k for k, _, _ in ops)
    # -done skipped, -start counted once; the two genuine all-reduces
    assert kinds == ["all-gather", "all-reduce", "all-reduce"]
    cb = collective_bytes(_HLO)
    assert cb["n_ops"] == 3
    # one f16[1024,512] payload at factor 2 dominates
    assert cb["raw_all-reduce_f16"] == 1024 * 512 * 2


# ---------------------------------------------------------------------------
# comms audit: fp8 wire payloads vs the scalar amax scale reductions
# ---------------------------------------------------------------------------

def test_audit_hlo_comms_clean_fp8_with_scale_reductions():
    census, findings = audit_hlo_comms(_HLO, expect_fp8=True)
    # the f16 payload is the legalized fp8 gradient; the scalar f32
    # reduce_max is the shared-scale amax reduction, censused not flagged
    assert findings == []
    assert census["grad_allreduce_dtypes"] == {"f16": 1}
    assert census["scale_allreduce_dtypes"] == {"f32": 1}


def test_audit_hlo_comms_flags_uncompressed_payload():
    bad = _HLO.replace("f16[1024,512]", "f32[1024,512]")
    _, findings = audit_hlo_comms(bad, expect_fp8=True)
    assert any(f.check == "comms" and f.severity == "violation"
               and "f32" in f.message for f in findings)


def test_audit_hlo_comms_requires_a_payload_allreduce():
    no_payload = "\n".join(l for l in _HLO.splitlines() if "%ar " not in l)
    _, findings = audit_hlo_comms(no_payload, expect_fp8=True)
    assert any("no payload all-reduce" in f.message for f in findings)
    # without fp8 expectation the same text is fine
    _, findings = audit_hlo_comms(no_payload, expect_fp8=False)
    assert findings == []


# ---------------------------------------------------------------------------
# structured fallback reasons (kernel support vocabulary)
# ---------------------------------------------------------------------------

def test_fallback_reason_vocabulary():
    ok = QuantSpec("fp4_e2m1", "block", block=128)
    assert kernel_unsupported_reason(ok) is None
    assert kernel_quant_mode(ok) is not None
    odd_block = QuantSpec("fp4_e2m1", "block", block=64)
    reason = kernel_unsupported_reason(odd_block)
    assert reason is not None and reason.startswith("unsupported_block")
    assert kernel_quant_mode(odd_block) is None
    clip_only = QuantSpec("fp16", "tensor")
    reason = kernel_unsupported_reason(clip_only)
    assert reason is not None and reason.startswith("unsupported_dtype")


# ---------------------------------------------------------------------------
# label parsing + scale placement policy
# ---------------------------------------------------------------------------

def test_label_layers_unroll_and_scan_forms():
    assert qlint._label_layers("L3", 8) == [3]
    assert qlint._label_layers("L0:2:1", 8) == [0, 1]
    assert qlint._label_layers(None, 8) == []


def test_scale_placement_policy_clean_on_paper_plan():
    plan = PrecisionPlan.uniform(RECIPES["fine_grained_fp4"], 2)
    assert audit_scale_placement(plan) == []


# ---------------------------------------------------------------------------
# expectations gate
# ---------------------------------------------------------------------------

def _report(label, route="pallas"):
    r = QlintReport(label)
    r.cells = [{"layer": "L0", "cls": "ffn", "role": "fwd", "route": route,
                "spec_a": "fp4_e2m1@block128", "spec_b": "fp4_e2m1@tile128",
                "sr_a": False, "sr_b": False, "mode_a": "block",
                "mode_b": "tile", "pipeline": "stream", "reasons": []}]
    r.summary = {"pallas_calls": {"fwd": 2}, "qdq_markers": {}}
    return r


def test_expectations_roundtrip_and_drift():
    payload = expectations_payload([_report("g")])
    assert compare_expectations(payload, json.loads(json.dumps(payload))) \
        == []
    drifted = expectations_payload([_report("g", route="qdq_fallback")])
    diffs = compare_expectations(drifted, payload)
    assert diffs and any("cells" in d for d in diffs)
    missing = compare_expectations({"graphs": {}, "n_violations": 0,
                                    "n_fallbacks": 0}, payload)
    assert any("missing" in d for d in missing)


def test_expectations_count_violations():
    r = _report("g")
    r.add(Finding("role_safety", "violation", "L0/ffn/fwd:lhs", "seeded"))
    payload = expectations_payload([r])
    assert payload["n_violations"] == 1
    assert payload["graphs"]["g"]["n_violations"] == 1


# ---------------------------------------------------------------------------
# traced-graph audits (jaxpr only — no XLA compile, keep these fast)
# ---------------------------------------------------------------------------

def _tcfg(**kw):
    kw.setdefault("recipe", "fine_grained_fp4")
    kw.setdefault("total_steps", 4)
    # 4 x 32 = 128 tokens: the block128 wgrad kernels need a full group
    # along the token-reduction dim or they'd legitimately fall back
    kw.setdefault("global_batch", 4)
    kw.setdefault("seq_len", 32)
    kw.setdefault("log_every", 0)
    return TrainConfig(**kw)


def test_train_graph_audit_clean_and_covers_all_cells():
    cfg = get_config("tiny").replace(scan_layers=False,
                                    linear_impl="pallas")
    report = qlint.audit_train_graph(cfg, _tcfg(), label="t",
                                     compile_hlo=False)
    assert report.violations() == []
    assert report.fallbacks() == []
    assert report.ok
    # every (layer, class, role) quantized cell + the protected head
    routes = {(c["layer"], c["cls"], c["role"]): c["route"]
              for c in report.cells}
    assert routes[(None, "head", "fwd")] == "dot"
    for i in range(cfg.n_layers):
        for cls in ("attn", "ffn"):
            for role in ("fwd", "dgrad", "wgrad"):
                assert routes[(f"L{i}", cls, role)] == "pallas"
    assert report.summary["recompile"]["n_compiled"] \
        <= report.summary["recompile"]["budget"]


def test_seeded_violation_fails_the_gate():
    """Trace a quantized-dgrad plan but audit against the paper's
    protected plan: the role-safety check must catch the quantize on the
    BF16-protected dgrad path and fail the gate."""
    cfg = get_config("tiny").replace(scan_layers=False)
    protected = PrecisionPlan.uniform(RECIPES["paper_fp4"], cfg.n_layers)
    # sanity: the reference really protects the ffn dgrad path
    assert protected.layer(0).for_class("ffn").dgrad_g.is_passthrough
    report = qlint.audit_train_graph(cfg, _tcfg(), label="seeded",
                                     compile_hlo=False, plan=protected)
    viols = report.violations()
    assert viols, "seeded violation was not detected"
    assert any(f.check == "role_safety" and "protected" in f.message
               and "dgrad" in f.where for f in viols)
    assert not report.ok
    payload = expectations_payload([report])
    assert payload["n_violations"] > 0


def test_qdq_impl_routes_and_markers():
    cfg = get_config("tiny").replace(scan_layers=False, linear_impl="qdq")
    report = qlint.audit_train_graph(cfg, _tcfg(), label="qdq",
                                     compile_hlo=False)
    assert report.violations() == []
    quantized = [c for c in report.cells if c["cls"] in ("attn", "ffn")]
    assert quantized and all(c["route"] == "qdq" for c in quantized)
    # QDQ path stages qdq_* markers under the qrole scopes
    assert report.summary["qdq_markers"]


def test_fallback_cell_is_enumerated_with_reason(monkeypatch):
    """A block size the kernel grid cannot tile falls back to QDQ and the
    audit reports it as a fallback finding carrying the structured
    reason — not as a violation."""
    cfg = get_config("tiny").replace(scan_layers=False,
                                    linear_impl="pallas")
    base = RECIPES["fine_grained_fp4"]
    odd = dataclasses.replace(
        base, name="odd_block_test",
        ffn_linear=dataclasses.replace(
            base.ffn_linear,
            fwd_x=QuantSpec("fp4_e2m1", "block", block=64),
            fwd_w=QuantSpec("fp4_e2m1", "block", block=64)))
    monkeypatch.setitem(RECIPES, "odd_block_test", odd)
    report = qlint.audit_train_graph(cfg, _tcfg(recipe="odd_block_test"),
                                     label="odd", compile_hlo=False)
    falls = report.fallbacks()
    assert falls, "block64 spec should fall back to QDQ"
    assert any("unsupported_block" in f.message for f in falls)
    assert report.violations() == []


def test_decode_engine_audit_clean_packed():
    cfg = get_config("tiny").replace(linear_impl="pallas")
    report = qlint.audit_decode_graph(cfg, RECIPES["fine_grained_fp4"],
                                      label="dec", n_slots=2, max_len=32,
                                      compile_hlo=False)
    assert report.violations() == []
    routes = {(c["cls"], c["role"]): c["route"] for c in report.cells}
    # the protected (unpacked) lm head is a plain dot even when packed
    assert routes[("head", "fwd")] == "dot"
    assert routes[("ffn", "fwd")] == "pallas"


def test_trainer_qlint_report_hook():
    from repro.models import build_model
    from repro.train.trainer import Trainer

    cfg = get_config("tiny").replace(scan_layers=True,
                                    linear_impl="pallas")
    trainer = Trainer(build_model(cfg), _tcfg(), pipeline=None, jit=True)
    report = trainer.qlint_report()
    assert report.violations() == []
    census = report.summary["recompile"]
    assert census["n_compiled"] <= census["budget"]


def test_recompile_census_flags_foreign_plan():
    from repro.models import build_model
    from repro.train.trainer import Trainer

    cfg = get_config("tiny").replace(scan_layers=True)
    trainer = Trainer(build_model(cfg), _tcfg(), pipeline=None, jit=True)
    trainer._step_fn(trainer.plan)
    # a compiled step for a plan outside the schedule/controller set
    foreign = PrecisionPlan.uniform(RECIPES["bf16"], cfg.n_layers)
    trainer._step_fn(foreign)
    census, findings = qlint.recompile_census(trainer)
    assert any(f.check == "recompile" for f in findings)
    assert census["n_compiled"] == len(census["keys"])
