"""Mesh-native train step: fp8 quantize-before-communicate reduction,
1x1-mesh bit-exactness, and the subprocess 8-device end-to-end test with
collective-bytes accounting against real sharded-step HLO."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig, get_config
from repro.models import build_model
from repro.optim import (compressed_psum, compressed_reduce_dp,
                         init_compression_state)
from repro.train.trainer import Trainer


class _Pipe:
    def __init__(self, vocab, batch, seq):
        self.v, self.b, self.s = vocab, batch, seq

    def batch(self, step):
        rng = np.random.RandomState(step % 100)
        tok = rng.randint(0, self.v, size=(self.b, self.s))
        return {"tokens": tok, "targets": tok}


# ---------------------------------------------------------------------------
# compressed_psum: error feedback over steps (vmap lanes model the replica
# group, so this runs on one real device)
# ---------------------------------------------------------------------------

def test_compressed_psum_error_feedback_unbiased_over_steps():
    f = jax.jit(jax.vmap(
        lambda g, r: compressed_psum(g, r, "dp"), axis_name="dp"))
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(4, 64).astype(np.float32))
    r = jnp.zeros((4, 64), jnp.float32)
    true_mean = np.asarray(g).mean(0)
    steps = 40
    acc = np.zeros(64, np.float64)
    for _ in range(steps):
        out, r = f(g, r)
        # every lane sees the same reduced value
        np.testing.assert_array_equal(np.asarray(out[0]),
                                      np.asarray(out[1]))
        acc += np.asarray(out[0], np.float64)
    one_step_err = float(np.abs(np.asarray(out[0]) - true_mean).max())
    time_avg_err = float(np.abs(acc / steps - true_mean).max())
    # error feedback: the time-average converges well below the one-shot
    # fp8 quantization error, and residuals stay bounded (local error only)
    assert time_avg_err < one_step_err / 3
    amax = float(np.abs(np.asarray(g)).max())
    assert float(jnp.abs(r).max()) < amax  # no residual blow-up


def test_compressed_psum_sum_semantics():
    f = jax.vmap(lambda g, r: compressed_psum(g, r, "dp", mean=False),
                 axis_name="dp")
    g = jnp.asarray([[1.0, 2.0], [3.0, 4.0]], jnp.float32)
    out, _ = f(g, jnp.zeros_like(g))
    np.testing.assert_allclose(np.asarray(out[0]), [4.0, 6.0], rtol=0.1)


# ---------------------------------------------------------------------------
# compressed_reduce_dp (GSPMD form): same contract, leading replica axis
# ---------------------------------------------------------------------------

def test_compressed_reduce_dp_mean_and_residual():
    rng = np.random.RandomState(1)
    tree = {"w": jnp.asarray(rng.randn(4, 8, 16).astype(np.float32)),
            "b": jnp.asarray(rng.randn(4, 16).astype(np.float32))}
    res = init_compression_state(
        {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}, dp_size=4)
    out, new_res = compressed_reduce_dp(tree, res)
    for k in tree:
        assert out[k].shape == tree[k].shape[1:]
        assert new_res[k].shape == tree[k].shape  # per-slice residual
        true = np.asarray(tree[k], np.float64).mean(0)
        scale = np.abs(np.asarray(tree[k])).max()
        # one fp8 shot with shared scale: coarse but in the ballpark
        np.testing.assert_allclose(np.asarray(out[k]), true,
                                   atol=0.15 * scale)


def test_compressed_reduce_dp_error_feedback_converges():
    rng = np.random.RandomState(2)
    g = jnp.asarray(rng.randn(4, 64).astype(np.float32))
    r = jnp.zeros((4, 64), jnp.float32)
    true_mean = np.asarray(g, np.float64).mean(0)
    f = jax.jit(lambda g, r: compressed_reduce_dp(g, r))
    steps = 40
    acc = np.zeros(64, np.float64)
    errs = []
    for _ in range(steps):
        out, r = f(g, r)
        acc += np.asarray(out, np.float64)
        errs.append(float(np.abs(np.asarray(out) - true_mean).max()))
    # The local quantization error is fed back, so the time-average beats
    # the worst single step by a wide margin.  (Unlike compressed_psum's
    # f32-accumulating vmap stand-in, the real fp8 summation also rounds
    # at each accumulation — an error no shard observes locally — so a
    # small bias floor remains; that matches fp8-ring-all-reduce hardware.)
    time_avg_err = float(np.abs(acc / steps - true_mean).max())
    assert time_avg_err < max(errs) / 2
    # residuals capture one step's local quant error and stay bounded
    assert float(jnp.abs(r).max()) < float(jnp.abs(g).max())


# ---------------------------------------------------------------------------
# 1x1 mesh: the mesh-native step must reproduce the unsharded graph
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("compression", ["none", "fp8"])
def test_mesh_1x1_bit_exact(compression):
    cfg = get_config("tiny")
    model = build_model(cfg)
    pipe = _Pipe(cfg.vocab_size, 2, 32)
    kw = dict(total_steps=3, global_batch=2, seq_len=32, log_every=0,
              grad_compression=compression)
    t0 = Trainer(model, TrainConfig(**kw), pipe)
    s0 = t0.train(t0.init_state(), num_steps=2)
    t1 = Trainer(model, TrainConfig(**kw, mesh_shape=(1,),
                                    mesh_axes=("data",)), pipe)
    assert t1.rules is not None and t1.rules.dp_size == 1
    s1 = t1.train(t1.init_state(), num_steps=2)
    for a, b in zip(jax.tree.leaves(s0.params), jax.tree.leaves(s1.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert t0.history[-1]["loss"] == t1.history[-1]["loss"]


# ---------------------------------------------------------------------------
# 8 forced CPU devices: data+model-sharded fp8 step end-to-end, with the
# compressed gradient reduction measured from real HLO (subprocess: the
# device-count flag must be set before jax initializes)
# ---------------------------------------------------------------------------

SPMD_FP8_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.analysis.hlo import collective_bytes, parse_collectives
    from repro.configs.base import TrainConfig, get_config
    from repro.models import build_model
    from repro.train.trainer import Trainer
    from repro.train.train_step import compression_state_sharding

    cfg = get_config("tiny")
    model = build_model(cfg)

    class Pipe:
        def __init__(self, v, b, s): self.v, self.b, self.s = v, b, s
        def batch(self, step):
            rng = np.random.RandomState(step % 100)
            tok = rng.randint(0, self.v, size=(self.b, self.s))
            return {"tokens": tok, "targets": tok}

    B, S = 8, 32
    pipe = Pipe(cfg.vocab_size, B, S)
    tc = TrainConfig(total_steps=3, global_batch=B, seq_len=S, log_every=0,
                     grad_compression="fp8", mesh_shape=(4, 2),
                     mesh_axes=("data", "model"), fsdp=False)
    tr = Trainer(model, tc, pipe)
    assert tr.rules.dp_size == 4

    # end-to-end: two optimizer steps on the 4x2 data+model mesh
    st = tr.train(tr.init_state(), num_steps=2)
    loss = float(tr.history[-1]["loss"])

    # real HLO of the compiled sharded step
    fn = tr._step_fn(tr._active_plan(0), telemetry=False)
    s0 = tr.init_state()
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    hlo = fn.lower(s0.params, s0.opt_state, s0.comp_state, batch,
                   jnp.asarray(0, jnp.int32), jnp.asarray(1.0, jnp.float32)
                   ).compile().as_text()
    ops = parse_collectives(hlo)
    cb = collective_bytes(hlo)
    # XLA:CPU legalizes the fp8 payload to f16: wire bytes are half
    fp8_wire = cb.get("raw_all-reduce_f16", 0.0) * 0.5

    # bf16-gradient baseline for the SAME reduction: sum over the replica
    # axis in bf16 with identical shardings (f32 in HLO = legalized bf16)
    c_sh = compression_state_sharding(
        tr.rules, tr.rules.param_shardings(model.param_specs()))
    base = jax.jit(lambda g: jax.tree.map(
        lambda x: jnp.sum(x.astype(jnp.bfloat16), axis=0), g),
        in_shardings=(c_sh,)).lower(s0.comp_state).compile()
    cb_base = collective_bytes(base.as_text())
    base_wire = cb_base.get("raw_all-reduce_f32", 0.0) * 0.5

    # fsdp params + fp8 compression must be rejected up front
    bad = TrainConfig(total_steps=3, global_batch=B, seq_len=S,
                      grad_compression="fp8", mesh_shape=(4, 2),
                      mesh_axes=("data", "model"), fsdp=True)
    try:
        Trainer(model, bad, pipe)._step_fn(tr._active_plan(0),
                                           telemetry=False)
        fsdp_raises = False
    except ValueError:
        fsdp_raises = True

    print(json.dumps({
        "loss": loss,
        "n_ops": len(ops),
        "kinds_ok": all(k in ("all-reduce", "all-gather", "reduce-scatter",
                              "all-to-all", "collective-permute")
                        and isinstance(b, int) and b >= 0
                        for k, _, b in ops),
        "fp8_wire": fp8_wire,
        "base_wire": base_wire,
        "fsdp_raises": fsdp_raises,
    }))
""")


# ---------------------------------------------------------------------------
# qlint over the sharded step: the fused-kernel scope markers must survive
# into the per-device HLO, the fp8 gradient payload must be on the wire,
# and the audit must come back clean (0 violations / 0 fallbacks)
# ---------------------------------------------------------------------------

SPMD_QLINT_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import json
    from repro.analysis import qlint
    from repro.configs.base import TrainConfig, get_config

    cfg = get_config("tiny").replace(scan_layers=True, linear_impl="pallas")
    tcfg = TrainConfig(recipe="fine_grained_fp4", total_steps=4,
                       global_batch=8, seq_len=32, log_every=0,
                       mesh_shape=(4, 2), mesh_axes=("data", "model"),
                       fsdp=False, grad_compression="fp8")
    report = qlint.audit_train_graph(cfg, tcfg, label="spmd4x2",
                                     compile_hlo=True)
    print(json.dumps({
        "n_violations": len(report.violations()),
        "n_fallbacks": len(report.fallbacks()),
        "fallback_reasons": sorted({r for c in report.cells
                                    for r in c["reasons"]}),
        "hlo_role_ops": report.summary.get("hlo_role_ops", {}),
        "grad_ar_dtypes": report.summary.get("comms", {}).get(
            "grad_allreduce_dtypes", {}),
        "violations": [f.to_dict() for f in report.violations()][:8],
    }))
""")


@pytest.mark.slow
def test_spmd_qlint_fused_kernels_in_per_device_hlo():
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(
                   os.path.join(os.path.dirname(__file__), "..", "src")))
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", SPMD_QLINT_SNIPPET],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    if res["n_fallbacks"] and any("shape" in r or "block" in r
                                  for r in res["fallback_reasons"]):
        # >1-way model sharding can shrink a K panel below the kernel's
        # tile; that is a routing decision, not an analyzer bug
        pytest.skip("K-panel kernel fell back under model-axis sharding: "
                    f"{res['fallback_reasons']}")
    assert res["n_violations"] == 0, res["violations"]
    assert res["n_fallbacks"] == 0
    # fused-kernel role scopes survive into the per-device HLO
    role_ops = res["hlo_role_ops"]
    for role in ("fwd", "dgrad", "wgrad"):
        assert role_ops.get(role, 0) > 0, role_ops
    # and the gradient bytes crossed the wire as (legalized) fp8
    assert res["grad_ar_dtypes"], "no gradient all-reduce payload found"
    assert all(d in ("f8e4m3fn", "f8e5m2", "f16")
               for d in res["grad_ar_dtypes"])


@pytest.mark.slow
def test_spmd_fp8_train_end_to_end_8_devices():
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(
                   os.path.join(os.path.dirname(__file__), "..", "src")))
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", SPMD_FP8_SNIPPET], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert np.isfinite(res["loss"])
    # real collectives parsed from the sharded-step HLO
    assert res["n_ops"] > 0 and res["kinds_ok"]
    # the compressed gradient reduction exists and costs at most half the
    # bf16-gradient baseline on the wire
    assert res["fp8_wire"] > 0
    assert res["fp8_wire"] <= 0.5 * res["base_wire"]
    assert res["fsdp_raises"]
