"""HLO collective parser + roofline term math + compression numerics."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo import collective_bytes, parse_collectives
from repro.analysis.roofline import (model_flops, roofline_terms,
                                     scan_flop_corrections)
from repro.configs.base import SHAPE_CELLS, get_config

HLO_SAMPLE = """
HloModule test
%body {
  %ag = bf16[4,1024,512]{2,1,0} all-gather(%p0), replica_groups={}
  %ar = f32[128,256]{1,0} all-reduce(%p1), to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%p2), to_apply=%add
  %cp = bf16[2,2]{1,0} collective-permute(%p3)
  %agd = bf16[4,4]{1,0} all-gather-done(%ags)
  %tup = (bf16[8,8]{1,0}, u32[]) all-gather-start(%p4)
}
"""


def test_parse_collectives_kinds_and_bytes():
    ops = parse_collectives(HLO_SAMPLE)
    kinds = sorted(o[0] for o in ops)
    assert kinds == ["all-gather", "all-gather", "all-reduce",
                     "collective-permute", "reduce-scatter"]
    d = {(o[0], o[1]): o[2] for o in ops}
    assert d[("all-gather", "bf16[4,1024,512]")] == 4 * 1024 * 512 * 2
    assert d[("all-reduce", "f32[128,256]")] == 128 * 256 * 4
    # -done skipped; -start counted via its tuple first element
    assert ("all-gather", "bf16[8,8]") in d


def test_collective_bytes_ring_factors():
    out = collective_bytes(HLO_SAMPLE)
    ar = 128 * 256 * 4
    expected_eff = (out["raw_all-gather"] + 2.0 * ar
                    + out["raw_reduce-scatter"]
                    + out["raw_collective-permute"])
    assert out["effective_total"] == pytest.approx(expected_eff)


def test_collective_bytes_per_dtype_and_wire():
    hlo = """
    %ar1 = f16[1000]{0} all-reduce(%p0), to_apply=%add
    %ar2 = f32[500]{0} all-reduce(%p1), to_apply=%add
    %ag = bf16[100]{0} all-gather(%p2)
    """
    out = collective_bytes(hlo)
    assert out["raw_all-reduce_f16"] == 1000 * 2
    assert out["raw_all-reduce_f32"] == 500 * 4
    assert out["raw_all-gather_bf16"] == 100 * 2
    # wire accounting undoes XLA:CPU legalization: f16 (fp8 payload) and
    # f32 (bf16 payload) both halve; genuine bf16 stays as-is
    expected_wire = (2.0 * 1000 * 2 * 0.5 + 2.0 * 500 * 4 * 0.5
                     + 1.0 * 100 * 2)
    assert out["effective_total_wire"] == pytest.approx(expected_wire)
    # the historic bf16eq metric halves f32 only
    expected_bf16eq = 2.0 * 1000 * 2 + 2.0 * 500 * 4 * 0.5 + 1.0 * 100 * 2
    assert out["effective_total_bf16eq"] == pytest.approx(expected_bf16eq)


def test_roofline_terms_bottleneck_selection():
    t = roofline_terms(hlo_flops=197e12, hlo_bytes=0.1, collective_bytes_eff=0.1,
                       chips=256)
    assert t["bottleneck"] == "compute"
    assert t["compute_s"] == pytest.approx(1.0)
    t = roofline_terms(hlo_flops=1.0, hlo_bytes=819e9 * 2,
                       collective_bytes_eff=0.1, chips=256)
    assert t["bottleneck"] == "memory"
    assert t["step_time_lower_bound_s"] == pytest.approx(2.0)


def test_model_flops_conventions():
    cfg = get_config("llama3.2-3b")
    cells = {c.name: c for c in SHAPE_CELLS}
    n = 3_212_749_824
    assert model_flops(cfg, cells["train_4k"], n) == pytest.approx(
        6 * n * 256 * 4096)
    assert model_flops(cfg, cells["decode_32k"], n) == pytest.approx(
        2 * n * 128)


def test_scan_corrections_zero_when_single_chunk():
    cfg = get_config("tiny").replace(attention_chunk=4096)
    cell = [c for c in SHAPE_CELLS if c.name == "train_4k"][0]
    corr = scan_flop_corrections(cfg, cell, 256)
    assert corr["attn"] == 0.0


def test_scan_corrections_positive_for_long_ctx():
    cfg = get_config("nemotron-4-15b").replace(attention_chunk=2048)
    cell = [c for c in SHAPE_CELLS if c.name == "prefill_32k"][0]
    corr = scan_flop_corrections(cfg, cell, 256)
    assert corr["attn"] > 0
    # missing fraction = (n_chunks-1)/n_chunks = 15/16 of SDPA flops
    from repro.analysis.roofline import _attention_flops
    per_layer = _attention_flops(cfg, 32, 32768, 32768)
    expect = 32 * per_layer * (15 / 16) / 256
    assert corr["attn"] == pytest.approx(expect)


def test_fp8_compression_error_feedback():
    """Error feedback: averaged compressed grads converge to the truth."""
    from repro.optim import fp8_compress_grads, init_compression_state
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 1e-3}
    res = init_compression_state(g)
    acc = jnp.zeros_like(g["w"])
    n = 50
    for _ in range(n):
        comp, res = fp8_compress_grads(g, res)
        acc = acc + comp["w"]
    mean_err = float(jnp.abs(acc / n - g["w"]).mean())
    one_shot = float(jnp.abs(fp8_compress_grads(g, init_compression_state(g)
                                                )[0]["w"] - g["w"]).mean())
    assert mean_err < one_shot / 5  # feedback beats one-shot quantization
