"""MoE routing: capacity semantics, aux losses, gradient flow."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoESettings
from repro.core.recipe import RECIPES
from repro.models.moe import moe, moe_param_specs
from repro.nn.params import init_params


def _cfg(e=4, k=2, cf=1.25, gsz=32):
    return ModelConfig(
        name="t", family="moe", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab_size=128,
        moe=MoESettings(num_experts=e, top_k=k, capacity_factor=cf,
                        group_size=gsz))


def _run(cfg, x=None, recipe="bf16", key=0):
    params = init_params(jax.random.PRNGKey(key), moe_param_specs(cfg))
    if x is None:
        x = jax.random.normal(jax.random.PRNGKey(key + 1), (2, 64,
                                                            cfg.d_model))
    return moe(params, cfg, x, RECIPES[recipe].ffn_linear), params, x


def test_output_shape_and_finite():
    (out, aux), _, x = _run(_cfg())
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux["moe_frac_dropped"]) < 0.5


def test_high_capacity_drops_nothing():
    (out, aux), _, _ = _run(_cfg(cf=4.0))
    assert float(aux["moe_frac_dropped"]) == 0.0


def test_tiny_capacity_drops_tokens():
    (out, aux), _, _ = _run(_cfg(cf=0.1))
    assert float(aux["moe_frac_dropped"]) > 0.3


def test_nondivisible_group_padding():
    cfg = _cfg(gsz=48)  # 128 tokens -> 3 groups of 48 (padded)
    (out, aux), _, x = _run(cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())


def test_gradients_reach_router_and_experts():
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), moe_param_specs(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))

    def loss(p):
        out, aux = moe(p, cfg, x, RECIPES["bf16"].ffn_linear)
        return jnp.sum(out ** 2) + aux["moe_load_balance"] \
            + aux["moe_router_z"]

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["w_up"]).max()) > 0
    assert float(jnp.abs(g["w_down"]).max()) > 0


def test_expert_permutation_consistency():
    """Permuting expert weights (and router columns) permutes nothing
    observable: output must be identical."""
    cfg = _cfg()
    (out1, _), params, x = _run(cfg)
    perm = jnp.asarray([2, 0, 3, 1])
    p2 = dict(params)
    p2["router"] = params["router"][:, perm]
    for k in ("w_up", "w_down", "w_gate"):
        if k in params:
            p2[k] = params[k][perm]
    out2, _ = moe(p2, cfg, x, RECIPES["bf16"].ffn_linear)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=2e-4, atol=2e-4)


def test_load_balance_loss_prefers_uniform():
    """A router collapsed onto one expert must have higher LB loss than a
    roughly-uniform router.  (Positive inputs so a +bias-like weight shift
    collapses routing for every token.)"""
    cfg = _cfg(k=1)  # top-1 makes the collapse fully visible
    params = init_params(jax.random.PRNGKey(0), moe_param_specs(cfg))
    # Shrink the router logits so the baseline is actually near-uniform
    # (at init scale the softmax skew already costs ~3x the LB floor,
    # which made the 2x collapsed-vs-uniform margin seed-dependent).
    params = dict(params, router=params["router"] * 0.1)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(1),
                                  (2, 64, cfg.d_model))) + 0.5
    _, aux_uniform = moe(params, cfg, x, RECIPES["bf16"].ffn_linear)
    p2 = dict(params)
    p2["router"] = params["router"].at[:, 0].add(10.0)  # collapse
    _, aux_collapsed = moe(p2, cfg, x, RECIPES["bf16"].ffn_linear)
    assert (float(aux_collapsed["moe_load_balance"])
            > 2.0 * float(aux_uniform["moe_load_balance"]))
