"""Batched decode engine + quantize-once packed serving panels."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packed import PackedTensor, pack_tensor
from repro.core.quantize import QuantSpec, qdq
from repro.core.recipe import RECIPES
from repro.models import build_model
from repro.train.serve import generate, make_decode_fn, make_prefill_fn
from repro.train.serving_runtime import (ContinuousBatcher, DecodeEngine,
                                         quantize_weights_for_serving,
                                         serving_memory_report)


def _cfg(arch, **over):
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))
    return mod.REDUCED.replace(dtype="float32", **over)


# ---------------------------------------------------------------------------
# Packed codec: bitwise parity with the training QDQ reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["fp4_e2m1", "fp8_e4m3"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pack_dequant_bitwise_matches_qdq(fmt, dtype):
    spec = QuantSpec(fmt, "tile", 32)
    # odd (non-multiple-of-block, odd column count) shape on purpose
    w = (jax.random.normal(jax.random.PRNGKey(0), (70, 53)) * 3).astype(dtype)
    ref = qdq(w, spec, 1)
    pk = pack_tensor(w, spec)
    got = pk.dequantize()
    assert got.dtype == ref.dtype
    assert np.asarray(got).tobytes() == np.asarray(ref).tobytes()


def test_stacked_pack_is_per_layer():
    """Tile blocks must never span scan-stacked layers / MoE experts."""
    spec = QuantSpec("fp4_e2m1", "tile", 16)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 24, 18)) * 2
    pk = pack_tensor(w, spec)
    ref = jax.vmap(lambda m: qdq(m, spec, 1))(w)
    assert np.asarray(pk.dequantize()).tobytes() == np.asarray(ref).tobytes()


def test_packed_forward_bitwise_matches_qdq_forward():
    """The packed serving path must reproduce the legacy QDQ forward
    bit-for-bit (unroll mode: the scan-stack path additionally QDQs the
    stacked norm scales, a legacy quirk packed leaves alone)."""
    cfg = _cfg("tiny", scan_layers=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    r = RECIPES["bf16"]
    for fmt in ("fp4_e2m1", "fp8_e4m3"):
        ref, _ = model.forward(
            quantize_weights_for_serving(model, params, fmt, packed=False),
            batch, r)
        out, _ = model.forward(
            quantize_weights_for_serving(model, params, fmt, packed=True),
            batch, r)
        assert np.asarray(out).tobytes() == np.asarray(ref).tobytes(), fmt


def test_protected_classes_stay_dense():
    """Norms, embeddings, routers-by-dtype, mamba conv/dt/A must not pack;
    the mamba in-projections and out_proj must."""
    cfg = _cfg("mamba2-780m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qp = quantize_weights_for_serving(model, params, "fp4_e2m1")

    def flat(tree):
        return {jax.tree_util.keystr(p): leaf
                for p, leaf in jax.tree_util.tree_flatten_with_path(
                    tree, is_leaf=lambda x: isinstance(x, PackedTensor))[0]}

    orig, quant = flat(params), flat(qp)
    protected = ("conv_wx", "conv_wb", "conv_wc", "dt_bias", "a_log",
                 "d_skip", "embed", "scale")
    packed_names = ("in_x", "in_z", "out_proj")
    seen_packed = 0
    for key, leaf in quant.items():
        name = key.rsplit("'", 2)[-2] if "'" in key else key
        if any(name == p for p in protected):
            assert not isinstance(leaf, PackedTensor), key
            np.testing.assert_array_equal(np.asarray(leaf),
                                          np.asarray(orig[key]))
        if any(name == p for p in packed_names):
            assert isinstance(leaf, PackedTensor), key
            seen_packed += 1
    assert seen_packed  # the eligible class actually packed


def test_memory_report_measures_compression():
    cfg = _cfg("tiny")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    r4 = serving_memory_report(
        quantize_weights_for_serving(model, params, "fp4_e2m1"))
    r8 = serving_memory_report(
        quantize_weights_for_serving(model, params, "fp8_e4m3"))
    assert 0.20 < r4["vs_bf16"] < 0.30, r4
    assert 0.45 < r8["vs_bf16"] < 0.55, r8
    assert r4["packed_params"] == r8["packed_params"] > 0
    assert r4["packed_bytes"] < r8["packed_bytes"]


# ---------------------------------------------------------------------------
# Batched decode engine
# ---------------------------------------------------------------------------

def test_engine_matches_sequential_generate():
    """Bucket-padded prefill + batched per-slot decode == one-at-a-time
    greedy generation, token-exact, across mixed prompt lengths."""
    cfg = _cfg("tiny")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 12, 9)]
    n_new = 6

    engine = DecodeEngine(model, params, n_slots=len(prompts), max_len=64,
                          min_bucket=8)
    assert engine._can_bucket
    for s, p in enumerate(prompts):
        tok, c1 = engine.prefill(p)
        engine.insert(c1, tok, s)
    got = [[engine.last_tok[s]] for s in range(len(prompts))]
    for _ in range(n_new - 1):
        nxt = engine.generate_step()
        for s in range(len(prompts)):
            got[s].append(int(nxt[s]))

    for s, p in enumerate(prompts):
        ref = generate(model, params, jnp.asarray(p)[None],
                       max_new_tokens=n_new, jit=False)[0, len(p):]
        assert got[s] == [int(t) for t in ref], (s, got[s], ref)


def test_engine_fp8_kv_logits_close():
    """FP8 KV cache decode stays within tolerance of the exact-cache
    logits (per-(token, head) scales over head_dim)."""
    cfg = _cfg("tiny")
    model = build_model(cfg)
    mq = build_model(cfg.replace(kv_cache_format="fp8_e4m3"))
    params = model.init(jax.random.PRNGKey(0))
    r = RECIPES["bf16"]
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0,
                              cfg.vocab_size)

    c_ref = model.init_cache(2, 32, jnp.float32, per_slot=True)
    c_q = mq.init_cache(2, 32, jnp.float32, per_slot=True)
    lg_ref, c_ref = model.prefill(params, {"tokens": toks}, c_ref, r)
    lg_q, c_q = mq.prefill(params, {"tokens": toks}, c_q, r)
    errs = [float(jnp.max(jnp.abs(lg_q - lg_ref)))]
    for _ in range(4):
        nxt = jnp.argmax(lg_ref[:, -1], axis=-1)[:, None].astype(jnp.int32)
        lg_ref, c_ref = model.decode_step(params, nxt, c_ref, r)
        lg_q, c_q = mq.decode_step(params, nxt, c_q, r)
        errs.append(float(jnp.max(jnp.abs(lg_q - lg_ref)))
                    )
    assert max(errs) < 0.5, errs
    assert max(errs) > 0.0  # quantization actually happened


def test_engine_rejects_non_8bit_kv_format():
    cfg = _cfg("tiny")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        DecodeEngine(model, params, kv_format="fp4_e2m1")


def test_mamba_engine_exact_length_fallback():
    """SSM recurrences can't take bucket padding; the engine must fall
    back to exact-length prefill and still match sequential decode."""
    cfg = _cfg("mamba2-780m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (4, 6)]
    bat = ContinuousBatcher(model, params, n_slots=2, max_len=32)
    assert not bat.engine._can_bucket
    rids = [bat.submit(p, 3) for p in prompts]
    out = bat.run()
    for rid, p in zip(rids, prompts):
        ref = generate(model, params, jnp.asarray(p)[None],
                       max_new_tokens=3, jit=False)[0, len(p):]
        assert out[rid] == [int(t) for t in ref], (rid, out[rid], ref)


def test_serve_fn_cache_reuses_compiled_fns():
    cfg = _cfg("tiny")
    model = build_model(cfg)
    model2 = build_model(cfg)
    r = RECIPES["bf16"]
    assert make_decode_fn(model, r) is make_decode_fn(model, r)
    assert make_prefill_fn(model, r) is make_prefill_fn(model, r)
    # distinct key dimensions get distinct fns
    assert make_decode_fn(model, r) is not make_decode_fn(model, r,
                                                          jit=False)
    assert make_decode_fn(model, r) is not make_prefill_fn(model, r)
    assert make_decode_fn(model, r) is not make_decode_fn(model2, r)
