"""Scaled QDQ: granularity semantics, idempotence, underflow diagnostics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Optional-hypothesis shim lives in conftest: real @given when
# installed, skip-marked no-ops otherwise.
from conftest import given, requires_hypothesis, settings, st

from repro.core.quantize import QuantSpec, compute_scale, qdq, underflow_rate

GRANS = ["tensor", "token", "block", "tile"]


@pytest.mark.parametrize("gran", GRANS)
@pytest.mark.parametrize("axis", [0, 1])
def test_shape_preserved_and_idempotent(gran, axis):
    x = jax.random.normal(jax.random.PRNGKey(0), (100, 200), jnp.float32)
    spec = QuantSpec("fp4_e2m1", gran, 64)
    y = qdq(x, spec, axis)
    assert y.shape == x.shape
    np.testing.assert_array_equal(np.asarray(qdq(y, spec, axis)),
                                  np.asarray(y))


def test_token_granularity_is_per_row():
    """Scaling one row must not affect another row's quantization."""
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64), jnp.float32)
    spec = QuantSpec("fp4_e2m1", "token")
    y1 = np.asarray(qdq(x, spec, 1))
    x2 = x.at[0].mul(1000.0)
    y2 = np.asarray(qdq(x2, spec, 1))
    np.testing.assert_array_equal(y1[1:], y2[1:])


def test_block_granularity_isolation():
    """Per-(1x64) blocks: an outlier only degrades its own block."""
    x = jnp.ones((1, 128), jnp.float32) * 0.01
    x = x.at[0, 0].set(100.0)
    tensor = np.asarray(qdq(x, QuantSpec("fp4_e2m1", "tensor"), 1))
    block = np.asarray(qdq(x, QuantSpec("fp4_e2m1", "block", 64), 1))
    # whole-tensor scaling: the small values underflow to 0
    assert np.all(tensor[0, 1:] == 0)
    # block scaling: the second block (no outlier) survives
    assert np.all(block[0, 64:] != 0)


def test_tile_granularity_matches_manual():
    x = jax.random.normal(jax.random.PRNGKey(2), (256, 256), jnp.float32)
    spec = QuantSpec("fp8_e4m3", "tile", 128)
    y = np.asarray(qdq(x, spec, 1))
    # manual: quantize each 128x128 tile independently
    from repro.core.formats import FP8_E4M3, round_to_format
    for i in range(2):
        for j in range(2):
            t = np.asarray(x)[i*128:(i+1)*128, j*128:(j+1)*128]
            s = np.abs(t).max() / FP8_E4M3.max_value
            ref = np.asarray(round_to_format(jnp.asarray(t / s),
                                             FP8_E4M3)) * s
            np.testing.assert_allclose(y[i*128:(i+1)*128, j*128:(j+1)*128],
                                       ref, rtol=1e-6, atol=1e-6)


def test_nondivisible_padding():
    x = jax.random.normal(jax.random.PRNGKey(3), (130, 70), jnp.float32)
    for gran in ("block", "tile"):
        y = qdq(x, QuantSpec("fp4_e2m1", gran, 64), 1)
        assert y.shape == x.shape
        assert bool(jnp.isfinite(y).all())


def test_amax_preserved_per_group():
    x = jax.random.normal(jax.random.PRNGKey(4), (64, 256), jnp.float32)
    spec = QuantSpec("fp4_e2m1", "token")
    y = qdq(x, spec, 1)
    np.testing.assert_allclose(np.abs(np.asarray(y)).max(1),
                               np.abs(np.asarray(x)).max(1), rtol=1e-5)


def test_pow2_scale():
    x = jax.random.normal(jax.random.PRNGKey(5), (32, 32), jnp.float32)
    spec = QuantSpec("fp4_e2m1", "tensor", pow2_scale=True)
    s = float(compute_scale(x, spec, 1))
    assert abs(np.log2(s) - round(np.log2(s))) < 1e-6


@requires_hypothesis
@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_error_bound_property(seed):
    """QDQ error per element is bounded by half the local grid step:
    |x - qdq(x)| <= amax_group / 2^m (coarse bound for E2M1: step <= amax/2
    in the top binade)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, 64), jnp.float32)
    spec = QuantSpec("fp4_e2m1", "token")
    y = qdq(x, spec, 1)
    err = np.abs(np.asarray(x - y))
    amax = np.abs(np.asarray(x)).max(1, keepdims=True)
    assert np.all(err <= amax / 4 + 1e-7)  # E2M1 max rel step = 1/4 amax/2


def test_underflow_rate_matches_paper_ballpark():
    """Fig 1(b): small-magnitude gradients underflow FP4 but not FP8."""
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (4096, 128)) * 0.02  # the paper's ~0.02 grads
    # inject heavy tail so amax >> typical value (outlier-driven underflow)
    g = g.at[0, 0].set(30.0)
    r4 = float(underflow_rate(g, QuantSpec("fp4_e2m1", "tensor")))
    r8 = float(underflow_rate(g, QuantSpec("fp8_e4m3", "tensor")))
    assert r4 > 0.5 and r8 < 0.01
    # fine-grained blocks rescue most of it (the paper's remedy)
    r4b = float(underflow_rate(g, QuantSpec("fp4_e2m1", "block", 128)))
    assert r4b < r4 / 2
