"""Table 3: the 2-stage target-precision schedule closes the FP4 gap.

Paper (Llama-125M): no-schedule 1.6851 -> schedule 1.6622 vs FP16 1.6567.
Contract reproduced: val_loss(sched) strictly between no-sched and bf16,
recovering >= ~40% of the gap.
"""
from __future__ import annotations

from benchmarks.common import BENCH_LLAMA, emit, train_once
from repro.core.cost_model import paper_calibrated_cost
from repro.core.recipe import RECIPES


def run(steps: int = 400) -> dict:
    rows = {
        "paper_fp4_nosched": "no",
        "paper_fp4": "yes",
        # secondary pair: the schedule's effect is clearest on the WORST
        # recipe (all-FP4), whose quantization-noise gap is large at this
        # scale (the paper recipe barely degrades the tiny bench model).
        "all_fp4": "no",
        "all_fp4_sched": "yes",
        "bf16": "-",
    }
    out = {}
    for name, sched in rows.items():
        r = train_once(BENCH_LLAMA, name, steps=steps)
        frac = RECIPES[name].target_precision_frac
        cost = paper_calibrated_cost(RECIPES[name])
        cost = (1 - frac) * cost + frac * 1.0
        out[name] = r
        emit(f"table3/{name}", r["us_per_step"],
             f"target_precision={sched};val_loss={r['val_loss']:.4f};"
             f"val_ppl={r['val_ppl']:.3f};cost={cost:.3f}")
    for pre, (a, b) in {"paper": ("paper_fp4_nosched", "paper_fp4"),
                        "allfp4": ("all_fp4", "all_fp4_sched")}.items():
        gap_no = out[a]["val_loss"] - out["bf16"]["val_loss"]
        gap_yes = out[b]["val_loss"] - out["bf16"]["val_loss"]
        rec = 1.0 - gap_yes / gap_no if gap_no > 0 else float("nan")
        emit(f"table3/gap_recovered_{pre}", 0.0, f"recovered={rec:.3f};"
             f"gap_nosched={gap_no:.4f};gap_sched={gap_yes:.4f}")
    return out


if __name__ == "__main__":
    run()
