"""Cost-vs-quant-error frontier from the telemetry-driven plan searcher.

Trains the tiny config starting from the uniform FP4 plan (``all_fp4``,
the Table-2 failure mode) with in-graph telemetry and the controller's
``PlanSearcher`` enabled.  Every ``--every`` steps the searcher finalizes
a measured frontier point for the running plan — theoretical cost from
``core.cost_model.plan_cost`` x the window's mean forward quant rel-err —
and greedily promotes the worst-error (layer, class) cell to FP8.  The
resulting Pareto frontier is emitted as BENCH rows and (with ``--json``)
a machine-readable BENCH JSON that ``benchmarks/check_bench.py
--frontier`` guards in CI.

The acceptance contract of the searcher is checked here too: the frontier
must be monotone (cost up, error down) and contain at least one plan
strictly cheaper than ``fine_grained_fp4``'s stage-1 cost with lower
measured quant error than uniform FP4.

Usage:
    python -m benchmarks.plan_frontier [--steps 96] [--every 8]
        [--smoke] [--json artifacts/BENCH_plan_frontier.json]
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import emit, write_json
from repro.configs.base import ControllerSettings, TrainConfig, get_config
from repro.core.cost_model import plan_cost
from repro.core.recipe import RECIPES, PrecisionPlan
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train.trainer import Trainer

SEQ, BATCH = 64, 8


def run(steps: int = 96, every: int = 8, start: str = "all_fp4",
        json_out: str = "") -> dict:
    cfg = get_config("tiny")
    model = build_model(cfg)
    pipe = SyntheticLM(cfg.vocab_size, SEQ, BATCH, seed=0)
    tcfg = TrainConfig(
        recipe=start, total_steps=steps, global_batch=BATCH, seq_len=SEQ,
        learning_rate=3e-3, log_every=0, telemetry=True,
        controller=ControllerSettings(plan_search=True,
                                      plan_search_every=every))
    tr = Trainer(model, tcfg, pipe)
    tr.train(log=print)

    searcher = tr.controller.searcher
    frontier = searcher.frontier
    for i, p in enumerate(frontier):
        # cost in basis points of the FP16 baseline (the JSON value field
        # is rounded to 0.1, too coarse for cost ratios); cost/error ride
        # in `derived` at full float precision (repr round-trips exactly —
        # the check_bench monotonicity guard compares the same strict
        # ordering the searcher's Pareto pruning enforced)
        emit(f"plan_frontier/point{i:02d}", p["cost"] * 1e4,
             f"cost={p['cost']!r};error={p['error']!r};"
             f"step={p['step']};plan={p['plan']}", unit="cost_bp")
    emit("plan_frontier/points", float(len(frontier)),
         f"edits={len(searcher.edits)};done={searcher.done}", unit="count")

    # Acceptance: a plan strictly cheaper than fine_grained_fp4's stage-1
    # cost with lower measured quant error than the uniform-FP4 start
    # (frontier[0] — the cheapest point — IS the start plan).
    fg_cost = plan_cost(
        PrecisionPlan.uniform(RECIPES["fine_grained_fp4"], cfg.n_layers),
        searcher.dims)
    uniform_err = frontier[0]["error"] if frontier else float("nan")
    hit = [p for p in frontier[1:]
           if p["cost"] < fg_cost and p["error"] < uniform_err]
    monotone = all(frontier[i]["cost"] > frontier[i - 1]["cost"]
                   and frontier[i]["error"] < frontier[i - 1]["error"]
                   for i in range(1, len(frontier)))
    ok = bool(hit) and monotone and len(frontier) >= 2
    emit("plan_frontier/acceptance", 1.0 if ok else 0.0,
         f"monotone={monotone};beats_fine_grained={len(hit)};"
         f"fine_grained_cost={fg_cost:.6f};uniform_fp4_error="
         f"{uniform_err:.6f}", unit="bool")
    if json_out:
        write_json(json_out)
    return {"frontier": frontier, "ok": ok}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=96)
    ap.add_argument("--every", type=int, default=8)
    ap.add_argument("--start", default="all_fp4")
    ap.add_argument("--smoke", action="store_true",
                    help="short CI run (fewer steps, tighter windows)")
    ap.add_argument("--json", default="", help="write BENCH JSON here")
    args = ap.parse_args()
    steps, every = (42, 6) if args.smoke else (args.steps, args.every)
    out = run(steps=steps, every=every, start=args.start,
              json_out=args.json)
    if not out["ok"]:
        print("[plan_frontier] FAIL: frontier acceptance not met",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
