import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimb driver: run one dry-run cell with overrides and report the
roofline deltas vs the recorded baseline.

    PYTHONPATH=src python -m benchmarks.hillclimb \
        --arch llama3.2-3b --shape train_4k --tag seqpar --seq-parallel

Results accumulate under artifacts/hillclimb/<arch>__<cell>__<tag>.json.
"""
import argparse
import json

from repro.configs.base import SHAPE_CELLS
from repro.launch import dryrun


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--recipe", default="paper_fp4")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--attn-seq-shard", action="store_true",
                    help="context-parallel attention: q-seq over 'model'")
    ap.add_argument("--free-head-shard", action="store_true",
                    help="shard QKV/O weight dims ignoring head granules")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--remat-policy", default=None)
    ap.add_argument("--attention-chunk", type=int, default=None)
    ap.add_argument("--loss-chunk", type=int, default=None)
    ap.add_argument("--moe-group", type=int, default=None)
    ap.add_argument("--experts-axis", default=None,
                    help="mesh axis for the experts dim, e.g. data")
    ap.add_argument("--mamba-chunk", type=int, default=None)
    ap.add_argument("--out", default="artifacts/hillclimb")
    args = ap.parse_args()

    cell = {c.name: c for c in SHAPE_CELLS}[args.shape]

    def patch(cfg):
        kw = {}
        if args.remat_policy:
            kw["remat_policy"] = args.remat_policy
        if args.attention_chunk:
            kw["attention_chunk"] = args.attention_chunk
        if args.loss_chunk is not None:
            kw["loss_chunk"] = args.loss_chunk
        if args.moe_group and cfg.moe is not None:
            import dataclasses
            kw["moe"] = dataclasses.replace(cfg.moe,
                                            group_size=args.moe_group)
        if args.mamba_chunk and cfg.mamba is not None:
            import dataclasses
            kw["mamba"] = dataclasses.replace(cfg.mamba,
                                              chunk=args.mamba_chunk)
        return cfg.replace(**kw) if kw else cfg

    overrides = {}
    if args.experts_axis:
        overrides["experts"] = (args.experts_axis,)
    act_overrides = {}
    if args.attn_seq_shard:
        act_overrides["seq_q"] = ("model",)

    res = dryrun.run_cell(
        args.arch, cell, "single", recipe=args.recipe,
        fsdp=not args.no_fsdp, seq_parallel=args.seq_parallel,
        free_head_shard=args.free_head_shard,
        cfg_patch=patch, rules_overrides=overrides or None,
        act_overrides=act_overrides or None)

    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out,
                        f"{args.arch}__{cell.name}__{args.tag}.json")
    res["tag"] = args.tag
    res["overrides"] = {k: v for k, v in vars(args).items()
                        if v not in (None, False) and k not in
                        ("arch", "shape", "out")}
    with open(path, "w") as f:
        json.dump(res, f, indent=2)

    # delta vs baseline artifact
    base_path = os.path.join(
        "artifacts/dryrun",
        f"{args.arch}__{cell.name}__single__{args.recipe}.json")
    if os.path.exists(base_path):
        with open(base_path) as f:
            base = json.load(f)
        bt, nt = base["roofline"], res["roofline"]
        print("\n=== delta vs baseline ===")
        for k in ("compute_s", "memory_s", "collective_s",
                  "step_time_lower_bound_s"):
            b, n = bt[k], nt[k]
            print(f"  {k:26s} {b:10.3f} -> {n:10.3f}   "
                  f"({(n - b) / max(b, 1e-12) * 100:+.1f}%)")
        print(f"  bottleneck {bt['bottleneck']} -> {nt['bottleneck']};  "
              f"MFU@bound {bt.get('mfu_at_bound', 0):.3f} -> "
              f"{nt.get('mfu_at_bound', 0):.3f}")


if __name__ == "__main__":
    main()
