"""Post-hoc report from a telemetry JSONL log (``TrainConfig.telemetry_jsonl``).

Reads the per-step rows + controller events the trainer appended and emits a
markdown report: loss/quant-error trajectories (ASCII sparklines), a
layer x role quant-health heatmap (forward-side slots from the per-layer
scan-output stats AND backward-side dgrad_g/wgrad_g from the layer-indexed
probes — full per-layer resolution on both sides since the indexed-probe
transport), backward-side per-class aggregates, the plan searcher's
cost-vs-quant-error frontier (``frontier_point`` events), and the
controller's decision log.  With matplotlib available (optional — not a dependency),
``--plots DIR`` also writes PNG curves and a layer x role heatmap image.

Usage:
    python -m benchmarks.telemetry_report runs/telemetry.jsonl
    python -m benchmarks.telemetry_report runs/telemetry.jsonl \
        --out report.md --plots plots/
"""
from __future__ import annotations

import argparse
import collections
import os
import re
from typing import Dict, List

from repro.telemetry.writer import read_jsonl

_SPARK = "▁▂▃▄▅▆▇█"

_LAYER_RE = re.compile(r"^tel/l(\d+)/([^/]+)/mm(\d+)/([^/]+)/([^/]+)$")
_BWD_LAYER_RE = re.compile(
    r"^tel/bwd/l(\d+)/([^/]+)/(dgrad_g|wgrad_g)/([^/]+)$")


def sparkline(xs: List[float], width: int = 40) -> str:
    if not xs:
        return ""
    if len(xs) > width:  # downsample to width buckets (bucket means)
        k = len(xs) / width
        xs = [sum(xs[int(i * k):max(int(i * k) + 1, int((i + 1) * k))])
              / max(1, len(xs[int(i * k):max(int(i * k) + 1,
                                             int((i + 1) * k))]))
              for i in range(width)]
    lo, hi = min(xs), max(xs)
    span = (hi - lo) or 1.0
    return "".join(_SPARK[int((x - lo) / span * (len(_SPARK) - 1))]
                   for x in xs)


def _mean(vals: List[float]) -> float:
    return sum(vals) / len(vals) if vals else float("nan")


def split_rows(rows: List[Dict]):
    steps = [r for r in rows if "event" not in r]
    events = [r for r in rows if "event" in r]
    return steps, events


def series(steps: List[Dict], key: str) -> List[float]:
    return [float(r[key]) for r in steps if key in r]


def fwd_error_series(steps: List[Dict]) -> List[float]:
    out = []
    for r in steps:
        vals = [v for k, v in r.items()
                if k.startswith("tel/") and "/fwd_" in k
                and k.endswith("/rel_err")]
        if vals:
            out.append(_mean(vals))
    return out


def heatmap_cells(last: Dict, stats=("underflow", "rel_err")):
    """(layer, role-column, stat) -> values from one row, combining the
    forward-side per-layer taps (fwd_x/fwd_w/wgrad_x/dgrad_w, mean over mm
    call sites) with the backward-side layer-indexed probe rows
    (dgrad_g/wgrad_g) — the full layer x role resolution."""
    cells: Dict[tuple, List[float]] = collections.defaultdict(list)
    cols, layers = set(), set()
    for k, v in last.items():
        m = _LAYER_RE.match(k)
        if m:
            layer, scope, _mm, slot, stat = m.groups()
        else:
            mb = _BWD_LAYER_RE.match(k)
            if not mb:
                continue
            layer, _cls, slot, stat = mb.groups()
            if float(last.get(f"tel/bwd/l{int(layer):02d}/{_cls}/taps",
                              0.0)) <= 0:
                continue  # untapped probe row (all-zero, not a signal)
        if stat not in stats:
            continue
        layers.add(int(layer))
        cols.add((slot, stat))
        cells[(int(layer), slot, stat)].append(float(v))
    return cells, sorted(cols), sorted(layers)


def per_layer_table(last: Dict) -> List[str]:
    """Final-step layer x role heatmap table (fwd taps + bwd probes)."""
    cells, cols, layers = heatmap_cells(last)
    if not cells:
        return ["(no per-layer telemetry in log)"]
    lines = ["| layer | " + " | ".join(f"{s}/{t}" for s, t in cols) + " |",
             "|---" * (len(cols) + 1) + "|"]
    for layer in layers:
        vals = [cells.get((layer, s, t)) for s, t in cols]
        lines.append(f"| l{layer:02d} | " + " | ".join(
            f"{_mean(v):.4f}" if v else "-" for v in vals) + " |")
    return lines


def bwd_table(last: Dict) -> List[str]:
    rows = [(k, v) for k, v in sorted(last.items())
            if k.startswith("tel/bwd/")]
    if not rows:
        return ["(no backward-side telemetry in log)"]
    return ["| metric | value |", "|---|---|"] + [
        f"| {k} | {float(v):.5f} |" for k, v in rows]


def build_report(rows: List[Dict]) -> str:
    steps, events = split_rows(rows)
    out = ["# Quantization telemetry report", ""]
    if not rows:
        return "\n".join(out + ["(empty log)"])
    if steps:
        out += [f"- steps logged: {len(steps)} "
                f"(step {steps[0]['step']} .. {steps[-1]['step']})",
                f"- recipes seen: "
                f"{sorted({r.get('recipe', '?') for r in steps})}",
                f"- controller events: {len(events)}", ""]
    else:
        # events-only log (e.g. a crashed run's tail): the step sections
        # have nothing to say, but the decision log below still renders
        out += ["- steps logged: 0",
                f"- controller events: {len(events)}", ""]
    loss = series(steps, "loss")
    if loss:
        out += ["## Loss", "```",
                f"{sparkline(loss)}  first={loss[0]:.4f} "
                f"last={loss[-1]:.4f} min={min(loss):.4f}", "```", ""]
    err = fwd_error_series(steps)
    if err:
        out += ["## Forward quant relative error (mean over layers/slots)",
                "```",
                f"{sparkline(err)}  first={err[0]:.4f} last={err[-1]:.4f} "
                f"max={max(err):.4f}", "```", ""]
    g = series(steps, "grad_norm")
    if g:
        out += ["## Grad norm", "```",
                f"{sparkline(g)}  last={g[-1]:.4f} max={max(g):.4f}",
                "```", ""]
    # Stage-2 (target-precision) steps carry no quant stats — report the
    # last step that does.
    if steps:
        layer_row = next((r for r in reversed(steps)
                          if any(_LAYER_RE.match(k) for k in r)), steps[-1])
        bwd_row = next(
            (r for r in reversed(steps)
             if any(k.startswith("tel/bwd/") and k.endswith("/taps")
                    and float(v) > 0 for k, v in r.items())),
            steps[-1])
        out += [f"## Layer x role quant health (step {layer_row['step']}; "
                "fwd slots mean over call sites, dgrad_g/wgrad_g from the "
                "layer-indexed probes)", ""] \
            + per_layer_table(layer_row) + [""]
        out += [f"## Backward-side stats (step {bwd_row['step']}, per "
                "module class)", ""] + bwd_table(bwd_row) + [""]
    points = [e for e in events if e.get("event") == "frontier_point"]
    if points:
        # every measured point, in search order; dominated points (the
        # searcher prunes these from its Pareto frontier) are marked so
        # the table never contradicts the check_bench --frontier guard
        def dominated(p):
            return any(q is not p and float(q["cost"]) <= float(p["cost"])
                       and float(q["error"]) <= float(p["error"])
                       and (float(q["cost"]) < float(p["cost"])
                            or float(q["error"]) < float(p["error"]))
                       for q in points)
        out += ["## Plan search (theoretical cost vs measured fwd quant "
                "rel_err; ✓ = on the Pareto frontier)", "",
                "| step | cost | quant rel_err | frontier | plan |",
                "|---|---|---|---|---|"]
        for p in sorted(points, key=lambda e: e["step"]):
            mark = "" if dominated(p) else "✓"
            out.append(f"| {p['step']} | {float(p['cost']):.4f} | "
                       f"{float(p['error']):.5f} | {mark} | "
                       f"{p.get('plan', '?')} |")
        out.append("")
    decisions = [e for e in events
                 if e.get("event") not in ("frontier_point", "straggler")]
    if decisions:
        out += ["## Controller decisions", ""]
        for ev in decisions:
            kv = ", ".join(f"{k}={v}" for k, v in ev.items()
                           if k != "event")
            out.append(f"- **{ev['event']}** ({kv})")
        out.append("")
    # Straggler evidence from both channels: the per-step flag folded into
    # history rows, and the trainer's {"event": "straggler"} JSONL events
    # (which carry the measured dt vs the detector's EMA).
    straggler_events = [e for e in events if e.get("event") == "straggler"]
    stragglers = [r["step"] for r in steps if r.get("straggler")]
    if stragglers or straggler_events:
        out += ["## Stragglers", ""]
        if stragglers:
            out.append(f"steps flagged by StepTimeMonitor: {stragglers}")
        for ev in sorted(straggler_events, key=lambda e: e.get("step", 0)):
            dt, ema = float(ev.get("dt", 0)), float(ev.get("ema", 0))
            out.append(f"- step {ev.get('step', '?')}: {dt * 1e3:.0f}ms vs "
                       f"EMA {ema * 1e3:.0f}ms"
                       + (f" (x{dt / ema:.1f})" if ema > 0 else ""))
        out.append("")
    return "\n".join(out)


def write_plots(rows: List[Dict], directory: str) -> bool:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        return False
    steps, _ = split_rows(rows)
    os.makedirs(directory, exist_ok=True)
    for name, ys in (("loss", series(steps, "loss")),
                     ("fwd_rel_err", fwd_error_series(steps)),
                     ("grad_norm", series(steps, "grad_norm"))):
        if not ys:
            continue
        fig, ax = plt.subplots(figsize=(6, 3))
        ax.plot(ys)
        ax.set_title(name)
        ax.set_xlabel("logged step")
        fig.tight_layout()
        fig.savefig(os.path.join(directory, f"{name}.png"), dpi=120)
        plt.close(fig)
    # layer x role heatmap (rel_err) from the last instrumented step
    layer_row = next((r for r in reversed(steps)
                      if any(_LAYER_RE.match(k) for k in r)), None)
    if layer_row is not None:
        cells, cols, layers = heatmap_cells(layer_row, stats=("rel_err",))
        if cells:
            import numpy as _np
            grid = _np.full((len(layers), len(cols)), _np.nan)
            for i, layer in enumerate(layers):
                for j, (slot, stat) in enumerate(cols):
                    vs = cells.get((layer, slot, stat))
                    if vs:
                        grid[i, j] = _mean(vs)
            fig, ax = plt.subplots(
                figsize=(1.2 + 0.9 * len(cols), 1.0 + 0.35 * len(layers)))
            im = ax.imshow(grid, aspect="auto", cmap="viridis")
            ax.set_xticks(range(len(cols)),
                          [sl for sl, _ in cols], rotation=45, ha="right")
            ax.set_yticks(range(len(layers)),
                          [f"l{l:02d}" for l in layers])
            ax.set_title("quant rel_err by layer x role")
            fig.colorbar(im, ax=ax, shrink=0.8)
            fig.tight_layout()
            fig.savefig(os.path.join(directory, "layer_role_heatmap.png"),
                        dpi=120)
            plt.close(fig)
    return True


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl", help="telemetry JSONL written by the trainer")
    ap.add_argument("--out", default=None, help="write markdown here "
                    "(default: stdout)")
    ap.add_argument("--plots", default=None,
                    help="directory for PNG plots (needs matplotlib)")
    args = ap.parse_args()
    rows = read_jsonl(args.jsonl)
    report = build_report(rows)
    if args.out:
        with open(args.out, "w") as f:
            f.write(report + "\n")
        print(f"wrote {args.out}")
    else:
        print(report)
    if args.plots:
        ok = write_plots(rows, args.plots)
        print(f"plots: {'written to ' + args.plots if ok else 'skipped (no matplotlib)'}")


if __name__ == "__main__":
    main()
