"""Shared benchmark scaffolding: tiny-but-meaningful training runs + CSV
rows, optionally mirrored to a machine-readable BENCH JSON file."""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train.trainer import Trainer

# The benchmark model: a GPT2-small-shaped micro config.  Big enough that
# FP4 noise is visible, small enough for CPU.
BENCH_GPT = ModelConfig(
    name="bench-gpt", family="dense", n_layers=4, d_model=128, n_heads=4,
    n_kv_heads=4, head_dim=32, d_ff=512, vocab_size=512,
    activation="gelu", norm="layernorm", pos_emb="learned", max_seq_len=128,
    tie_embeddings=True, attention_chunk=128)
BENCH_LLAMA = ModelConfig(
    name="bench-llama", family="dense", n_layers=4, d_model=128, n_heads=4,
    n_kv_heads=4, head_dim=32, d_ff=352, vocab_size=512,
    activation="swiglu", norm="rmsnorm", pos_emb="rope",
    rope_theta=10000.0, max_seq_len=128, attention_chunk=128)

ROWS: List[str] = []
RECORDS: List[Dict[str, Any]] = []


def emit(name: str, us_per_call: float, derived: str,
         unit: str = "us") -> None:
    """Record one benchmark row.  ``unit`` defaults to microseconds;
    analytic counters (e.g. tile-QDQ counts) pass their own unit so JSON
    consumers can separate counts from timings without string-sniffing."""
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                    "unit": unit, "derived": derived})
    print(row, flush=True)


def write_json(path: str) -> None:
    """Dump everything emitted so far as a machine-readable BENCH_*.json
    (perf-trajectory artifact; `--json` on run.py / kernel_bench.py)."""
    payload = {
        "schema": "bench.v1",
        "created_unix": time.time(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "benchmarks": RECORDS,
    }
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[bench] wrote {len(RECORDS)} records -> {path}", flush=True)


def train_once(cfg: ModelConfig, recipe: str, steps: int = 300,
               seed: int = 0, lr: float = 3e-3,
               seq: int = 64, batch: int = 16) -> Dict[str, float]:
    """Train the bench model; returns losses + wall-time per step."""
    model = build_model(cfg)
    tcfg = TrainConfig(recipe=recipe, total_steps=steps, global_batch=batch,
                       seq_len=seq, learning_rate=lr, log_every=0, seed=seed)
    pipe = SyntheticLM(cfg.vocab_size, seq, batch, seed=seed)
    tr = Trainer(model, tcfg, pipe)
    t0 = time.time()
    st = tr.train()
    wall = time.time() - t0
    ev = tr.evaluate(st, n_batches=4)
    train_tail = float(np.mean([r["loss"] for r in tr.history[-20:]]))
    return {"train_loss": train_tail, "val_loss": ev["val_loss"],
            "val_ppl": ev["val_ppl"],
            "us_per_step": wall / steps * 1e6,
            "state": st, "trainer": tr}


def timeit(fn, *args, n: int = 20, warmup: int = 3) -> float:
    """Median wall-time per call in microseconds (blocking on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)
