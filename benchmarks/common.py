"""Shared benchmark scaffolding: tiny-but-meaningful training runs + CSV
rows, optionally mirrored to a machine-readable BENCH JSON file."""
from __future__ import annotations

import json
import os
import platform
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train.trainer import Trainer

# The benchmark model: a GPT2-small-shaped micro config.  Big enough that
# FP4 noise is visible, small enough for CPU.
BENCH_GPT = ModelConfig(
    name="bench-gpt", family="dense", n_layers=4, d_model=128, n_heads=4,
    n_kv_heads=4, head_dim=32, d_ff=512, vocab_size=512,
    activation="gelu", norm="layernorm", pos_emb="learned", max_seq_len=128,
    tie_embeddings=True, attention_chunk=128)
BENCH_LLAMA = ModelConfig(
    name="bench-llama", family="dense", n_layers=4, d_model=128, n_heads=4,
    n_kv_heads=4, head_dim=32, d_ff=352, vocab_size=512,
    activation="swiglu", norm="rmsnorm", pos_emb="rope",
    rope_theta=10000.0, max_seq_len=128, attention_chunk=128)

ROWS: List[str] = []
RECORDS: List[Dict[str, Any]] = []


def emit(name: str, us_per_call: float, derived: str,
         unit: str = "us", extra: Optional[Dict[str, Any]] = None) -> None:
    """Record one benchmark row.  ``unit`` defaults to microseconds;
    analytic counters (e.g. tile-QDQ counts) pass their own unit so JSON
    consumers can separate counts from timings without string-sniffing.
    ``extra`` keys (e.g. step-time percentiles ``p50_us``/``p95_us``/
    ``p99_us``) merge into the JSON record — same bench.v1 schema, richer
    entries."""
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    rec = {"name": name, "us_per_call": round(us_per_call, 1),
           "unit": unit, "derived": derived}
    if extra:
        rec.update({k: (round(v, 3) if isinstance(v, float) else v)
                    for k, v in extra.items()})
    RECORDS.append(rec)
    print(row, flush=True)


def write_json(path: str) -> None:
    """Dump everything emitted so far as a machine-readable BENCH_*.json
    (perf-trajectory artifact; `--json` on run.py / kernel_bench.py)."""
    payload = {
        "schema": "bench.v1",
        "created_unix": time.time(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "benchmarks": RECORDS,
    }
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[bench] wrote {len(RECORDS)} records -> {path}", flush=True)


def train_once(cfg: ModelConfig, recipe: str, steps: int = 300,
               seed: int = 0, lr: float = 3e-3,
               seq: int = 64, batch: int = 16) -> Dict[str, float]:
    """Train the bench model; returns losses + wall-time per step."""
    model = build_model(cfg)
    tcfg = TrainConfig(recipe=recipe, total_steps=steps, global_batch=batch,
                       seq_len=seq, learning_rate=lr, log_every=0, seed=seed)
    pipe = SyntheticLM(cfg.vocab_size, seq, batch, seed=seed)
    tr = Trainer(model, tcfg, pipe)
    t0 = time.time()
    st = tr.train()
    wall = time.time() - t0
    ev = tr.evaluate(st, n_batches=4)
    train_tail = float(np.mean([r["loss"] for r in tr.history[-20:]]))
    out = {"train_loss": train_tail, "val_loss": ev["val_loss"],
           "val_ppl": ev["val_ppl"],
           "us_per_step": wall / steps * 1e6,
           "state": st, "trainer": tr}
    # measured per-step percentiles from the trainer's StepTimer (warmup/
    # compile steps excluded, unlike the crude wall/steps figure above)
    summ = tr.step_time_summary()
    for k in ("p50_ms", "p95_ms", "p99_ms"):
        if k in summ:
            out[k.replace("_ms", "_us")] = summ[k] * 1e3
    return out


def timeit_stats(fn, *args, n: int = 20,
                 warmup: int = 3) -> Dict[str, float]:
    """Wall-time stats per call in microseconds (blocking on outputs):
    ``{"median_us", "p50_us", "p95_us", "p99_us"}`` — median is numpy's
    interpolated median (the historical ``timeit`` value), p* are the
    profiler's nearest-rank percentiles."""
    from repro.telemetry.profiler import percentiles
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    pct = percentiles(ts)
    return {"median_us": float(np.median(ts) * 1e6),
            **{f"{k}_us": v * 1e6 for k, v in pct.items()}}


def timeit(fn, *args, n: int = 20, warmup: int = 3) -> float:
    """Median wall-time per call in microseconds (blocking on outputs)."""
    return timeit_stats(fn, *args, n=n, warmup=warmup)["median_us"]
