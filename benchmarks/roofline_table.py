"""Render the §Roofline table from the dry-run artifacts (artifacts/dryrun).

Also usable as a module: ``rows()`` returns the parsed records for
EXPERIMENTS.md generation.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import emit

ART = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def rows(mesh: str = "single") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ART, f"*__{mesh}__*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def run() -> None:
    recs = rows("single")
    if not recs:
        emit("roofline/missing", 0.0, f"no artifacts under {ART}")
        return
    for r in recs:
        tag = f"roofline/{r['arch']}/{r['cell']}"
        if r["status"] == "skipped":
            emit(tag, 0.0, "status=skipped;" + r["reason"][:60])
            continue
        if r["status"] != "ok":
            emit(tag, 0.0, f"status={r['status']}")
            continue
        t = r["roofline"]
        emit(tag, t["step_time_lower_bound_s"] * 1e6,
             f"compute_s={t['compute_s']:.4f};memory_s={t['memory_s']:.4f};"
             f"collective_s={t['collective_s']:.4f};"
             f"bottleneck={t['bottleneck']};"
             f"useful_ratio={t.get('useful_flops_ratio', 0):.3f};"
             f"mfu_bound={t.get('mfu_at_bound', 0):.3f}")


if __name__ == "__main__":
    run()
