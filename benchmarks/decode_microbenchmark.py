"""Decode-engine microbenchmark: prefill / insert / per-token generate.

Times the three ``DecodeEngine`` stages on the bench llama across the
serving precision matrix — weights {bf16, fp8 packed, fp4 packed} x KV
cache {bf16, fp8} — plus the seed-style per-slot Python decode loop as the
batched-generate baseline, and the measured packed-weight bytes/param.

Generate rows carry straggler-free percentiles (``p50_us``/``p95_us``/
``p99_us``; warmup excludes compile) so tail jitter is visible separately
from the median.  ``decode/batched_speedup`` is the acceptance headline:
batched generate must beat the per-slot loop at the same occupancy
(ratio < 1.0).  Gated in CI by ``check_bench --decode``.

Usage:
    python -m benchmarks.decode_microbenchmark [--smoke] [--json OUT.json]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_LLAMA, emit, timeit_stats, write_json
from repro.core.recipe import RECIPES
from repro.models import build_model
from repro.train.serve import make_decode_fn
from repro.train.serving_runtime import (DecodeEngine,
                                         quantize_weights_for_serving,
                                         serving_memory_report)

MAX_LEN = 128
N_SLOTS = 4
PROMPT_LENS = (16, 24, 32, 48)   # mixed lengths: slots sit at different
#                                  offsets, the realistic engine state


def _prompts(vocab: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, (n,)).astype(np.int32)
            for n in PROMPT_LENS]


def _fill(engine: DecodeEngine, prompts) -> None:
    for s, p in enumerate(prompts):
        tok, c1 = engine.prefill(p)
        engine.release(s)
        engine.insert(c1, int(tok), s)


def _per_slot_loop_step(model, params, prompts, recipe):
    """Seed-style baseline: one b=1 jitted decode per live slot per step."""
    decode = make_decode_fn(model, recipe)
    caches, last = [], []
    prefill = jax.jit(
        lambda pr, t, c: model.prefill(pr, {"tokens": t}, c, recipe))
    for p in prompts:
        cache = model.init_cache(1, MAX_LEN)
        # pad to the engine's bucket sizes so prefill cost is comparable;
        # the loop baseline differs only in its decode structure
        logits, cache = prefill(params, jnp.asarray(p)[None], cache)
        caches.append(cache)
        last.append(int(jnp.argmax(logits[0, -1].astype(jnp.float32))))

    def step():
        outs = []
        for i in range(len(caches)):
            tok = jnp.asarray([[last[i]]], jnp.int32)
            lg, caches[i] = decode(params, tok, caches[i])
            last[i] = int(jnp.argmax(lg[0, -1].astype(jnp.float32)))
            outs.append(last[i])
        return np.asarray(outs)

    return step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer timed iterations (CI wall-clock budget)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the bench.v1 JSON artifact")
    args = ap.parse_args(argv)
    n, warmup = (6, 2) if args.smoke else (20, 3)

    cfg = BENCH_LLAMA
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    recipe = RECIPES["bf16"]
    prompts = _prompts(cfg.vocab_size)
    weights = {
        "bf16": params,
        "fp8": quantize_weights_for_serving(model, params, "fp8_e4m3"),
        "fp4": quantize_weights_for_serving(model, params, "fp4_e2m1"),
    }

    base_generate = None
    for wname, wp in weights.items():
        for kvname, kvfmt in (("bf16", None), ("fp8", "fp8_e4m3")):
            tag = f"w{wname}_kv{kvname}"
            engine = DecodeEngine(model, wp, n_slots=N_SLOTS,
                                  max_len=MAX_LEN, recipe=recipe,
                                  kv_format=kvfmt)
            st = timeit_stats(lambda: engine.prefill(prompts[2]),
                              n=n, warmup=warmup)
            emit(f"decode/prefill_{tag}", st["median_us"],
                 f"len={PROMPT_LENS[2]} bucket-padded {tag}")

            tok, c1 = engine.prefill(prompts[0])

            def reinsert():
                engine.release(0)
                engine.insert(c1, tok, 0)

            st = timeit_stats(reinsert, n=n, warmup=warmup)
            emit(f"decode/insert_{tag}", st["median_us"],
                 f"slot splice {tag}")

            _fill(engine, prompts)
            st = timeit_stats(engine.generate_step, n=n, warmup=warmup)
            emit(f"decode/generate_{tag}", st["median_us"],
                 f"batched step n_slots={N_SLOTS} {tag}",
                 extra={k: st[k] for k in ("p50_us", "p95_us", "p99_us")})
            if tag == "wbf16_kvbf16":
                base_generate = st["median_us"]

    loop = _per_slot_loop_step(model, params, prompts, recipe)
    st = timeit_stats(loop, n=n, warmup=warmup)
    emit("decode/generate_per_slot_loop", st["median_us"],
         f"seed-style loop n_slots={N_SLOTS} wbf16 kvbf16",
         extra={k: st[k] for k in ("p50_us", "p95_us", "p99_us")})

    speedup = base_generate / st["median_us"]
    emit("decode/batched_speedup", speedup,
         f"batched/loop per-step ratio={speedup:.3f} (must be < 1.0)",
         unit="ratio")

    for fmt in ("fp4", "fp8"):
        rep = serving_memory_report(weights[fmt])
        emit(f"decode/bytes_per_param_{fmt}", rep["bytes_per_packed_param"],
             f"packed payload+scales vs_bf16={rep['vs_bf16']:.4f}",
             unit="bytes")

    if args.json:
        write_json(args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
