"""App. B: quantization granularity must tighten as models grow.

The paper reports: GPT-125M trains with per-token/channel FP4 everywhere;
GPT-335M needs per-block wgrad; GPT-774M+ needs per-block forward AND FP8
wgrad (= the final recipe).  At CPU scale we reproduce the *mechanism*:
on a fixed model, coarser-granularity FP4 recipes lose more loss, and the
ordering  per-token < per-block < paper(fp8-wgrad)  holds for stability.
"""
from __future__ import annotations

from benchmarks.common import BENCH_LLAMA, emit, train_once

ROWS = ["gpt125m_fp4", "gpt335m_fp4", "paper_fp4", "bf16"]


def run(steps: int = 300) -> dict:
    out = {}
    for name in ROWS:
        r = train_once(BENCH_LLAMA, name, steps=steps)
        out[name] = r
        emit(f"appb/{name}", r["us_per_step"],
             f"val_loss={r['val_loss']:.4f};val_ppl={r['val_ppl']:.3f}")
    ordered = sorted(ROWS[:3], key=lambda n: out[n]["val_loss"])
    emit("appb/granularity_ranking", 0.0,
         "best_to_worst=" + ">".join(ordered))
    return out


if __name__ == "__main__":
    run()
