"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (correctness
path), so wall-times here measure (a) the pure-jnp QDQ+matmul simulation
(what training actually pays on CPU) and (b) the chunked-flash vs naive
attention — both meaningful CPU comparisons.  TPU wall-times come from the
roofline analysis instead.
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit, timeit_stats, write_json
from repro.core.qlinear import (pallas_qmatmul, pallas_qmatmul_two_pass,
                                qlinear, qmatmul)
from repro.core.recipe import RECIPES, MatmulRecipe
from repro.kernels.fp4_matmul import fused_qmm, use_pipeline
from repro.kernels.ref import fp4_matmul_ref
from repro.models.attention import chunked_attention
from repro.kernels.ref import flash_attention_ref


def _quant_work_counters(m, k, n, tag: str) -> None:
    """Analytic per-role quantize-work counters for the two-phase pipeline.

    The pre-rework fused kernel re-QDQ'd every LHS (128 x 128) K-tile once
    per output-column visit and every RHS K-tile once per output-row visit
    — O(M/bm * N/bn) tile-QDQs per operand element-touch.  The quantize
    pass does each K-panel ONCE.  Counts are exact tile-QDQ totals for one
    matmul of the given shape (128-padded), emitted so the redundancy win
    is visible in the BENCH JSON.
    """
    t = 128
    mt, kt, nt = -(-m // t), -(-k // t), -(-n // t)
    for role, (op_tiles, revisit) in {
        "fwd": ((mt * kt, nt), (kt * nt, mt)),
        "dgrad": ((mt * nt, kt), (nt * kt, mt)),
        "wgrad": ((kt * mt, nt), (mt * nt, kt)),
    }.items():
        (lhs_tiles, lhs_rev), (rhs_tiles, rhs_rev) = op_tiles, revisit
        old = lhs_tiles * lhs_rev + rhs_tiles * rhs_rev
        new = lhs_tiles + rhs_tiles
        emit(f"kernel/{tag}_quant_tile_qdqs_{role}", float(new),
             f"old_fused={old};new_pipeline={new};"
             f"redundancy_x={old / new:.1f};one_qdq_per_kpanel=true",
             unit="tile_qdqs")


def _bench_fused_roles(x, w, recipe, tag: str) -> None:
    """Time the Pallas pipelines vs unfused qmatmul for all three training
    matmuls: fwd via the primal, dgrad+wgrad via the VJP.

    ``pallas_fused`` is the two-pass reference pipeline (quantize to HBM,
    then the tiled matmul — the historical meaning of the entry, kept so
    the committed baseline stays comparable); ``pallas_stream`` is the
    single-pass streaming pipeline (quantized K-panels live in VMEM and are
    consumed directly by the MXU loop).  The stream rows carry a
    ``speedup_vs_two_pass`` derived field so the overlap win is measured,
    and ``check_bench`` REQUIREs both entry families.
    """
    key = jnp.zeros((2,), jnp.uint32)
    c = jnp.ones((x.shape[0], w.shape[1]), x.dtype)

    times = {}
    for impl_name, mm, pipe in (
            ("qdq", qmatmul, None),
            ("pallas_fused", pallas_qmatmul_two_pass, None),
            ("pallas_stream", pallas_qmatmul, "stream")):
        # use_pipeline must cover tracing, which happens at the first timed
        # call; pallas_stream pins the pipeline explicitly so the row stays
        # a stream measurement even if the session default changes.
        ctx = use_pipeline(pipe) if pipe else contextlib.nullcontext()
        with ctx:
            f_fwd = jax.jit(lambda a, b, mm=mm: mm(a, b, key, recipe))
            # vjp once OUTSIDE the timed region (it runs the primal); time
            # only the jitted pullback so the row really is dgrad+wgrad.
            _, pullback = jax.vjp(lambda p, q: mm(p, q, key, recipe), x, w)
            f_bwd = jax.jit(pullback)
            times[impl_name] = (timeit(f_fwd, x, w, n=15),
                                timeit(f_bwd, c, n=15))
    for impl_name, (t_fwd, t_bwd) in times.items():
        extra_f = extra_b = ""
        if impl_name == "pallas_stream":
            tp_f, tp_b = times["pallas_fused"]
            extra_f = f";speedup_vs_two_pass={tp_f / t_fwd:.3f}"
            extra_b = f";speedup_vs_two_pass={tp_b / t_bwd:.3f}"
        emit(f"kernel/{tag}_fwd_{impl_name}", t_fwd,
             f"impl={impl_name};role=fwd{extra_f}")
        emit(f"kernel/{tag}_dgrad_wgrad_{impl_name}", t_bwd,
             f"impl={impl_name};role=dgrad+wgrad{extra_b}")
    _quant_work_counters(x.shape[0], x.shape[1], w.shape[1], tag)


def _bench_stream_overlap(x, w, tag: str) -> None:
    """Both pipelines pinned to the same fixed (128, 128, 128) tiling —
    the constrained multi-tile regime real VMEM budgets force on TPU (the
    autotuned whole-dim tiles reduce both pipelines to one grid step each,
    where the comparison degenerates).  two_pass walks a quantize grid AND
    a matmul grid with an HBM round-trip between them; stream walks one
    fused grid with both operand caches live.  NOTE: interpret mode prices
    emulated op count, not launches or HBM traffic, so the CPU ratio here
    is a trend anchor for the TPU re-measurement (ROADMAP item 3), not a
    speedup claim."""
    times = {}
    for pipe in ("two_pass", "stream"):
        f = jax.jit(lambda a, b, p=pipe: fused_qmm(
            a, b, a_mode="block", b_mode="tile", bm=128, bn=128, bk=128,
            pipeline=p, interpret=True))
        times[pipe] = timeit(f, x, w, n=15)
    emit(f"kernel/{tag}_fwd_two_pass_t128", times["two_pass"],
         "impl=two_pass;tiles=128x128x128")
    emit(f"kernel/{tag}_fwd_stream_t128", times["stream"],
         f"impl=stream;tiles=128x128x128;"
         f"speedup_vs_two_pass={times['two_pass'] / times['stream']:.3f}")


def _bench_telemetry_epilogue(x, w, recipe, tag: str) -> None:
    """Quantize-pass telemetry epilogue on vs off (same kernel, stats
    accumulators + (1, 8) stats output added) for the fwd role."""
    from repro.core.qlinear import kernel_quant_mode
    from repro.kernels.ops import pallas_qmm
    sa, sb = recipe.fwd_x, recipe.fwd_w
    ma, mb = kernel_quant_mode(sa), kernel_quant_mode(sb)
    f_off = jax.jit(lambda a, b: pallas_qmm(
        a, b, sa, sb, mode_a=ma, mode_b=mb))
    f_on = jax.jit(lambda a, b: pallas_qmm(
        a, b, sa, sb, mode_a=ma, mode_b=mb, collect_stats=True)[0])
    t_off = timeit(f_off, x, w, n=15)
    t_on = timeit(f_on, x, w, n=15)
    emit(f"kernel/{tag}_quant_epilogue_off", t_off, "telemetry_epilogue=off")
    emit(f"kernel/{tag}_quant_epilogue_on", t_on,
         f"telemetry_epilogue=on;overhead_x={t_on / t_off:.3f}")


def _bench_flash_attention() -> None:
    """Pallas flash-attention forward kernel (interpret mode on CPU) vs the
    chunked-jnp path at the same shape — closes the benchmark coverage gap:
    the matmul kernels were regression-guarded, the attention kernel was
    not.  256-seq keeps interpret-mode runtime sane (grid 8 * 2 * 2)."""
    from repro.kernels import flash_attention
    b, s, h, d = 2, 256, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    f_flash = jax.jit(lambda q, k, v: flash_attention(
        q, k, v, causal=True, chunk=128))
    f_chunk = jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, pos, pos, causal=True, chunk=128))
    t_f = timeit(f_flash, q, k, v, n=10)
    t_c = timeit(f_chunk, q, k, v, n=10)
    emit("kernel/flash_attention_fwd_256", t_f,
         f"impl=pallas_interpret;bq=128;bk=128;rel_chunked={t_f / t_c:.2f}")
    emit("kernel/attention_chunked_256", t_c, "impl=chunked_jnp;chunk=128")


def _bench_telemetry_step() -> None:
    """Full train-step wall time, telemetry off vs on (tiny config).

    The in-graph taps add O(elements) stat reductions next to O(M*K*N)
    matmuls; the emitted overhead ratio is the acceptance number for the
    telemetry subsystem (<10% at real model sizes — the tiny-config CPU
    ratio here is the pessimistic bound since its matmuls are small).
    """
    from repro.configs.base import TrainConfig, get_config
    from repro.data import SyntheticLM
    from repro.models import build_model
    from repro.train.train_step import make_optimizer, make_train_step

    cfg = get_config("tiny")
    model = build_model(cfg)
    pipe = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    params = model.init(jax.random.PRNGKey(0))
    step0 = jnp.asarray(0, jnp.int32)
    times = {}
    for tel in (False, True):
        tcfg = TrainConfig(recipe="paper_fp4", total_steps=20,
                           global_batch=8, seq_len=64, telemetry=tel)
        step = make_train_step(model, tcfg, RECIPES["paper_fp4"],
                               jit=True, donate=False)
        opt_state = make_optimizer(model, tcfg).init(params)
        comp = jnp.zeros((), jnp.float32)
        times[tel] = timeit_stats(step, params, opt_state, comp, batch,
                                  step0, n=10)
    emit("kernel/train_step_tiny_telemetry_off", times[False]["median_us"],
         "recipe=paper_fp4;telemetry=off",
         extra={k: times[False][k] for k in ("p50_us", "p95_us", "p99_us")})
    emit("kernel/train_step_tiny_telemetry_on", times[True]["median_us"],
         f"recipe=paper_fp4;telemetry=on;overhead_x="
         f"{times[True]['median_us'] / times[False]['median_us']:.3f}",
         extra={k: times[True][k] for k in ("p50_us", "p95_us", "p99_us")})
    # production setting: sample stats every N steps (telemetry_every)
    t_on, t_off = times[True]["median_us"], times[False]["median_us"]
    for every in (5, 10):
        amortized = (t_on + (every - 1) * t_off) / every
        emit(f"kernel/train_step_tiny_telemetry_every{every}", amortized,
             f"recipe=paper_fp4;telemetry_every={every};"
             f"overhead_x={amortized / t_off:.3f}")


def measure_speed_factors(size: int = 256, n: int = 10,
                          recipes=("bf16", "fp8", "paper_fp4",
                                   "fine_grained_fp4")):
    """Measure wall-clock matmul speed factors for the cost model.

    For every distinct operand-spec pair appearing in the given recipes'
    matmul roles (fwd: (fwd_x, fwd_w), dgrad: (dgrad_g, dgrad_w), wgrad:
    (wgrad_x, wgrad_g) — exactly the pairings ``cost_model._linear_time``
    prices), time the jitted QDQ matmul at ``size^3`` and express its
    throughput relative to the plain matmul at the same shape — the same
    normalization as the paper's ``_SPEED`` theory, so the table drops
    straight into ``cost_model.calibrate``.  Keys follow
    ``cost_model._cal_key``: ``fmt`` for passthrough, ``fmt@granularity``
    otherwise.

    Returns a ``CostCalibration``.  On this CPU container the QDQ
    simulation is *slower* than the plain matmul (factors < 1 where theory
    says 4x) — which is the point: the searcher should rank plans by what
    this host actually pays, and on FP4 tensor-core hardware the same
    harness measures the real speedup.
    """
    from repro.core.cost_model import _cal_key, calibrate
    from repro.core.qlinear import dot_qdq

    x = jax.random.normal(jax.random.PRNGKey(0), (size, size), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1),
                          (size, size), jnp.float32) * 0.05
    t_ref = timeit(jax.jit(lambda a, b: a @ b), x, w, n=n)
    pairs = {}
    for rname in recipes:
        recipe = RECIPES[rname]
        for f in dataclasses.fields(recipe):
            mm = getattr(recipe, f.name)
            if not isinstance(mm, MatmulRecipe):
                continue
            for sa, sb in ((mm.fwd_x, mm.fwd_w), (mm.dgrad_g, mm.dgrad_w),
                           (mm.wgrad_x, mm.wgrad_g)):
                pairs.setdefault((_cal_key(sa), _cal_key(sb)), (sa, sb))
    table = {}
    for (ka, kb), (sa, sb) in sorted(pairs.items()):
        f_mm = jax.jit(lambda a, b, sa=sa, sb=sb: dot_qdq(a, b, sa, sb))
        t = timeit(f_mm, x, w, n=n)
        factor = t_ref / t
        table[(ka, kb)] = factor
        emit(f"kernel/speed_factor_{ka}*{kb}", t,
             f"measured_factor={factor:.4f};ref_plain_us={t_ref:.1f};"
             f"shape={size}x{size}x{size}", unit="us")
    return calibrate(table, source=f"kernel_bench:{size}^3")


def run_autotune(path: str) -> None:
    """Populate and save the persistent ``(bm, bn, bk)`` tuning table.

    Sweeps the tile candidates for the paper-recipe FFN matmul roles (the
    shapes/granularities the fused-role benches and the qlinear training
    path actually issue) and writes a ``qmm_tuning_table.v1`` JSON that
    ``fused_qmm`` consults on every call without explicit tiles.  The
    committed copy lives at ``src/repro/kernels/tuning_table.json`` and is
    validated in CI (``python -m repro.kernels.autotune --validate``).
    """
    from repro.kernels.autotune import TuningTable, autotune_qmm

    table = TuningTable(meta={
        "source": "kernel_bench --autotune",
        "backend": jax.default_backend(),
        "note": "interpret-mode timings on CPU; regenerate on TPU for "
                "hardware-true tiles",
    })
    jobs = (
        # paper FFN fwd: fp4 block x fp4 tile, nn
        dict(m=256, n=256, k=256, a_mode="block", b_mode="tile"),
        dict(m=512, n=512, k=512, a_mode="block", b_mode="tile"),
        # paper FFN dgrad: bf16 passthrough pair, g @ w^T
        dict(m=256, n=256, k=256, a_mode="pass", b_mode="pass",
             trans_b=True),
        # paper FFN wgrad: fp8 block pair, x^T @ g
        dict(m=256, n=256, k=256, a_mode="block", b_mode="block",
             a_fmt="fp8_e4m3", b_fmt="fp8_e5m2", trans_a=True),
    )
    for job in jobs:
        tiles, us = autotune_qmm(table=table, **job)
        print(f"[autotune] m{job['m']}_n{job['n']}_k{job['k']} "
              f"{job['a_mode']}:{job['b_mode']} -> bm={tiles[0]} "
              f"bn={tiles[1]} bk={tiles[2]} ({us:.0f}us)", flush=True)
    table.save(path)
    print(f"[autotune] wrote {len(table.entries)} entries -> {path}",
          flush=True)


def run() -> None:
    m, k, n = 512, 512, 512
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32) * 0.05

    f_bf = jax.jit(lambda a, b: a @ b)
    f_q = jax.jit(lambda a, b: fp4_matmul_ref(a, b))
    t_bf = timeit(f_bf, x, w)
    t_q = timeit(f_q, x, w)
    emit("kernel/matmul_plain_512", t_bf, "impl=xla_dot")
    emit("kernel/matmul_fp4qdq_512", t_q,
         f"impl=simulated_qdq;overhead_x={t_q / t_bf:.2f}")

    rcp = RECIPES["paper_fp4"].ffn_linear
    f_lin = jax.jit(lambda a, b: qlinear(a, b, rcp))
    emit("kernel/qlinear_paper_fp4_512", timeit(f_lin, x, w),
         "fwd=fp4_block")

    # Fused Pallas path, all three roles (interpret mode on CPU: this
    # validates the code path and counts; TPU wall-times come from the
    # roofline analysis).  256^3 keeps interpret-mode runtime sane.
    xs, ws = x[:256, :256], w[:256, :256]
    _bench_fused_roles(xs, ws, RECIPES["paper_fp4"].ffn_linear,
                       "qmm256_ffn_paper")
    _bench_stream_overlap(xs, ws, "qmm256_ffn_paper")
    _bench_telemetry_epilogue(xs, ws, RECIPES["paper_fp4"].ffn_linear,
                              "qmm256_ffn_paper")

    b, s, h, d = 2, 512, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    kk = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    f_naive = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v))
    f_chunk = jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, pos, pos, causal=True, chunk=128))
    t_n = timeit(f_naive, q, kk, v, n=10)
    t_c = timeit(f_chunk, q, kk, v, n=10)
    emit("kernel/attention_naive_512", t_n, "memory=O(S^2)")
    emit("kernel/attention_chunked_512", t_c,
         f"memory=O(S*chunk);rel={t_c / t_n:.2f}")

    _bench_flash_attention()
    _bench_telemetry_step()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as machine-readable JSON")
    ap.add_argument("--measure-speed", default=None, metavar="PATH",
                    help="measure wall-clock speed factors and write a "
                         "speed_factors.v1 JSON (feeds TrainConfig."
                         "cost_calibration / cost_model.calibrate); skips "
                         "the full kernel sweep")
    ap.add_argument("--autotune", default=None, metavar="PATH",
                    help="sweep (bm, bn, bk) candidates for the paper-"
                         "recipe matmul roles and write the tuning table "
                         "JSON here (commit to src/repro/kernels/"
                         "tuning_table.json); skips the full kernel sweep")
    args = ap.parse_args()
    if args.measure_speed:
        cal = measure_speed_factors()
        cal.to_json(args.measure_speed)
        print(f"[bench] wrote {len(cal.table)} measured speed factors -> "
              f"{args.measure_speed}", flush=True)
    elif args.autotune:
        run_autotune(args.autotune)
    else:
        run()
    if args.json:
        write_json(args.json)
