"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (correctness
path), so wall-times here measure (a) the pure-jnp QDQ+matmul simulation
(what training actually pays on CPU) and (b) the chunked-flash vs naive
attention — both meaningful CPU comparisons.  TPU wall-times come from the
roofline analysis instead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.qlinear import qlinear
from repro.core.recipe import RECIPES
from repro.kernels.ref import fp4_matmul_ref
from repro.models.attention import chunked_attention
from repro.kernels.ref import flash_attention_ref


def run() -> None:
    m, k, n = 512, 512, 512
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32) * 0.05

    f_bf = jax.jit(lambda a, b: a @ b)
    f_q = jax.jit(lambda a, b: fp4_matmul_ref(a, b))
    t_bf = timeit(f_bf, x, w)
    t_q = timeit(f_q, x, w)
    emit("kernel/matmul_plain_512", t_bf, "impl=xla_dot")
    emit("kernel/matmul_fp4qdq_512", t_q,
         f"impl=simulated_qdq;overhead_x={t_q / t_bf:.2f}")

    rcp = RECIPES["paper_fp4"].ffn_linear
    f_lin = jax.jit(lambda a, b: qlinear(a, b, rcp))
    emit("kernel/qlinear_paper_fp4_512", timeit(f_lin, x, w),
         "fwd=fp4_block")

    b, s, h, d = 2, 512, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    kk = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    f_naive = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v))
    f_chunk = jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, pos, pos, causal=True, chunk=128))
    t_n = timeit(f_naive, q, kk, v, n=10)
    t_c = timeit(f_chunk, q, kk, v, n=10)
    emit("kernel/attention_naive_512", t_n, "memory=O(S^2)")
    emit("kernel/attention_chunked_512", t_c,
         f"memory=O(S*chunk);rel={t_c / t_n:.2f}")


if __name__ == "__main__":
    run()
