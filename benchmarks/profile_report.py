"""Measured step/phase profile -> ``BENCH_step.json`` (``check_bench --step``).

Produces the wall-clock evidence the paper's theoretical cost tables lack:

  * a short profiled Trainer smoke run per recipe (paper_fp4 vs bf16) —
    the trainer's ``StepTimer`` supplies warmup-excluded p50/p95/p99 step
    times, tokens/sec and MFU (``step/train_step_*`` entries, percentile
    fields in the record);
  * a per-phase breakdown (``step/phase_*``).  Phases inside ONE jitted
    step cannot be separately host-timed, so the breakdown uses jitted-
    callable deltas at the same shape: fwd = t(loss); bwd = t(grad) - fwd;
    optim = t(step) - t(grad); quantize = t(fwd_fp4) - t(fwd_bf16) (the
    QDQ work the FP4 forward adds over the plain one).  For intra-step
    attribution beyond this, capture a real trace — the train loop and
    step graph carry ``phase_span``/``graph_span`` annotations (see the
    README's observability section);
  * the telemetry tap overhead (instrumented vs plain step graph) and the
    async JSONL writer's drop counter from the smoke run.

All timings are CPU/interpret-mode and trend-only; ``check_bench --step``
therefore gates on the fp4/bf16 *ratio* (host speed cancels), mirroring
the kernel gate's normalize-then-compare discipline.

Usage:
    python -m benchmarks.profile_report --json BENCH_step.json
"""
from __future__ import annotations

import argparse
import os
import tempfile

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit, write_json
from repro.configs.base import TrainConfig, get_config
from repro.core.recipe import RECIPES, as_plan
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train.train_step import make_optimizer, make_train_step
from repro.train.trainer import Trainer

SEQ, BATCH = 64, 8


def _smoke_run(model, recipe: str, steps: int) -> dict:
    """Profiled Trainer run: StepTimer percentiles + MFU + writer drops."""
    with tempfile.TemporaryDirectory() as td:
        tcfg = TrainConfig(recipe=recipe, total_steps=steps,
                           global_batch=BATCH, seq_len=SEQ, log_every=0,
                           telemetry_jsonl=os.path.join(td, "tel.jsonl"))
        pipe = SyntheticLM(model.cfg.vocab_size, SEQ, BATCH, seed=0)
        tr = Trainer(model, tcfg, pipe)
        tr.train()
        summ = tr.step_time_summary()
        summ["writer_dropped"] = tr.writer.dropped
        tr.writer.close()
    return summ


def _phase_breakdown(model, steps_hint: int = 10) -> None:
    """Jitted-callable phase deltas at the smoke shape (fp4 recipe)."""
    plan_fp4 = as_plan(RECIPES["paper_fp4"], model.cfg.n_layers)
    plan_bf16 = as_plan(RECIPES["bf16"], model.cfg.n_layers)
    pipe = SyntheticLM(model.cfg.vocab_size, SEQ, BATCH, seed=0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    params = model.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(recipe="paper_fp4", total_steps=100,
                       global_batch=BATCH, seq_len=SEQ)
    opt_state = make_optimizer(model, tcfg).init(params)
    comp = jnp.zeros((), jnp.float32)
    step0 = jnp.asarray(0, jnp.int32)

    f_fwd = jax.jit(lambda p, b: model.loss(p, b, plan_fp4)[0])
    f_fwd_bf16 = jax.jit(lambda p, b: model.loss(p, b, plan_bf16)[0])
    f_grad = jax.jit(jax.grad(lambda p, b: model.loss(p, b, plan_fp4)[0]))
    f_step = make_train_step(model, tcfg, plan_fp4, jit=True, donate=False)
    f_step_tel = make_train_step(
        model, TrainConfig(recipe="paper_fp4", total_steps=100,
                           global_batch=BATCH, seq_len=SEQ, telemetry=True),
        plan_fp4, jit=True, donate=False)

    n = steps_hint
    t_fwd = timeit(f_fwd, params, batch, n=n)
    t_fwd_bf16 = timeit(f_fwd_bf16, params, batch, n=n)
    t_grad = timeit(f_grad, params, batch, n=n)
    t_step = timeit(f_step, params, opt_state, comp, batch, step0, n=n)
    t_tel = timeit(f_step_tel, params, opt_state, comp, batch, step0, n=n)

    def _emit_phase(name: str, raw_delta: float, method: str) -> None:
        """One phase row from a jitted-callable delta.

        The deltas are differences of noisy measurements, so a phase whose
        true cost is below the timing noise can come out negative.  A
        negative share is impossible by construction — emit the clamped
        value with a ``noise=true`` marker instead of a bogus negative
        share (``check_bench --step`` rejects negative shares outright).
        """
        t = max(0.0, raw_delta)
        share = t / t_step if t_step > 0 else float("nan")
        noisy = ";noise=true" if raw_delta < 0 else ""
        emit(name, t,
             f"recipe=paper_fp4;share={share:.3f};method={method}{noisy}")

    _emit_phase("step/phase_fwd", t_fwd, "jit_delta")
    _emit_phase("step/phase_bwd", t_grad - t_fwd, "jit_delta(grad-fwd)")
    _emit_phase("step/phase_optim", t_step - t_grad,
                "jit_delta(step-grad)")
    _emit_phase("step/phase_quantize", t_fwd - t_fwd_bf16,
                "jit_delta(fwd_fp4-fwd_bf16)")
    emit("step/telemetry_overhead", t_tel,
         f"recipe=paper_fp4;overhead_x={t_tel / t_step:.3f};"
         "taps=in_graph")


def run(steps: int = 12) -> None:
    cfg = get_config("tiny")
    model = build_model(cfg)
    for recipe in ("paper_fp4", "bf16"):
        summ = _smoke_run(model, recipe, steps)
        p50_us = summ.get("p50_ms", float("nan")) * 1e3
        emit(f"step/train_step_{'fp4' if recipe != 'bf16' else 'bf16'}",
             p50_us,
             f"recipe={recipe};steps={int(summ['steps'])};"
             f"warmup={int(summ['warmup'])};"
             f"spikes={int(summ.get('spikes', 0))};"
             f"mfu={summ.get('mfu', float('nan')):.5f};"
             f"writer_dropped={int(summ['writer_dropped'])}",
             extra={"p50_us": summ.get("p50_ms", float("nan")) * 1e3,
                    "p95_us": summ.get("p95_ms", float("nan")) * 1e3,
                    "p99_us": summ.get("p99_ms", float("nan")) * 1e3,
                    "tokens_per_sec": summ.get("tokens_per_sec",
                                               float("nan")),
                    "mfu": summ.get("mfu", float("nan"))})
    _phase_breakdown(model)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12,
                    help="smoke-run steps per recipe")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the BENCH_step.json artifact here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(steps=args.steps)
    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
