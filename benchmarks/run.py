"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
Sections:
  table1   FP4 vs BF16 pretraining (paper Table 1 contract)
  table2   module-precision ablation + theoretical cost (Table 2)
  table3   target-precision schedule (Table 3)
  fig1     compute share / underflow rates / attention entropy (Fig. 1)
  kernel   micro-benchmarks
  step     measured step/phase profile (StepTimer percentiles + MFU)
  roofline dry-run roofline table (reads artifacts/dryrun)

Timing rows carry step-time percentile fields (``p50_us``/``p95_us``/
``p99_us``) in the record where measured — one schema across table1,
kernel, and step sections (``bench.v1``).
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table2,table3,fig1,appb,kernel,"
                         "step,roofline")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all emitted rows as BENCH JSON")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    def go(name):
        return want is None or name in want

    print("name,us_per_call,derived")
    if go("cost"):
        from repro.core.cost_model import paper_calibrated_cost
        from repro.core.recipe import RECIPES
        from benchmarks.common import emit
        for r in ("all_fp4", "t2_fp8_fp4_fp4", "t2_fp8_fp4_fp8",
                  "t2_fp4_fp8_fp8", "paper_fp4", "fp8", "bf16"):
            emit(f"cost_model/{r}", 0.0,
                 f"paper_calibrated={paper_calibrated_cost(RECIPES[r]):.3f}")
    if go("table1"):
        from benchmarks import table1_fp4_vs_bf16
        table1_fp4_vs_bf16.run(steps=args.steps)
    if go("table2"):
        from benchmarks import table2_module_ablation
        table2_module_ablation.run(steps=args.steps)
    if go("table3"):
        from benchmarks import table3_schedule
        table3_schedule.run(steps=max(args.steps, 400))
    if go("fig1"):
        from benchmarks import fig1_diagnostics
        fig1_diagnostics.run()
    if go("appb"):
        from benchmarks import appb_scaling
        appb_scaling.run(steps=args.steps)
    if go("kernel"):
        from benchmarks import kernel_bench
        kernel_bench.run()
    if go("step"):
        from benchmarks import profile_report
        profile_report.run(steps=min(args.steps, 12))
    if go("roofline"):
        from benchmarks import roofline_table
        roofline_table.run()
    if args.json:
        from benchmarks.common import write_json
        write_json(args.json)


if __name__ == "__main__":
    main()
